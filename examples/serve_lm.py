"""Batched serving example: greedy decode with a continuous-batching server.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2_780m]

Runs the reduced config of any assigned architecture through the serving
stack (slot-based batcher, KV/state caches, fixed-shape decode step) and
reports tokens/s.  Works for every family: dense/MoE KV caches, MLA latent
cache, SSM constant state, hybrid ring buffers, VLM/enc-dec cross caches.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
