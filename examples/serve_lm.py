"""Batched serving example on the continuous-batching runtime.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2_780m]
    PYTHONPATH=src python examples/serve_lm.py --trace \
        --engine ozimmu_h-8:df32 --page-block 16

Runs the reduced config of any assigned architecture through the serving
runtime (repro/serving: slot-based continuous batcher, bucketed batched
prefill, optional paged KV pool, persistent weight split-cache for
emulated GEMMs) and reports tokens/s + TTFT.  Works for every family:
dense/MoE KV caches, MLA latent cache, SSM constant state, hybrid ring
buffers, VLM/enc-dec cross caches.

``--trace`` replays the benchmark request trace (Poisson arrivals, mixed
prompt/generation lengths — the same generator ``benchmarks/
bench_serving.py`` measures) instead of a fixed uniform wave, exercising
admission, queueing and continuous slot refill.
"""
import argparse
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # benchmarks.bench_serving (the --trace source)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", action="store_true",
                    help="replay the bench request trace (Poisson "
                         "arrivals, mixed lengths) through the runtime")
    ap.add_argument("--trace-requests", type=int, default=8)
    args, rest = ap.parse_known_args(argv)

    if not args.trace:
        from repro.launch.serve import main as serve_main
        serve_main(rest)
        return

    import jax
    import numpy as np

    from benchmarks.bench_serving import make_trace, replay
    from repro import configs
    from repro.launch.serve import make_runtime, slot_context
    from repro.models import api

    sp = argparse.ArgumentParser()
    sp.add_argument("--arch", default="internlm2_1_8b")
    sp.add_argument("--engine", default="bf16")
    sp.add_argument("--slots", type=int, default=4)
    sp.add_argument("--max-len", type=int, default=128)
    sp.add_argument("--page-block", type=int, default=None)
    opts = sp.parse_args(rest)

    cfg = configs.get_config(opts.arch, smoke=True, engine_spec=opts.engine)
    model = api.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    ctx = slot_context(cfg, params, 32)
    runtime = make_runtime(cfg, params, slots=opts.slots,
                           max_len=opts.max_len,
                           page_block=opts.page_block, ctx=ctx)
    trace = make_trace(np.random.default_rng(0), n_requests=args.trace_requests,
                       vocab=cfg.vocab, max_len=opts.max_len)
    t0 = time.time()
    # the bench's replay loop: each request is submitted at its Poisson
    # arrival round, exercising admission/queueing/continuous refill
    summary = replay(runtime, trace)
    print(f"[trace] {summary['tokens_generated']} tokens / "
          f"{summary['requests']['finished']} requests in "
          f"{time.time() - t0:.2f}s ({summary['tokens_per_s']:.1f} tok/s); "
          f"TTFT p95 {summary['ttft_s']['p95']}")
    if summary["split_cache"]:
        print(f"[trace] split-cache: "
              f"{summary['split_cache']['avoided_split_bytes'] / 1e6:.2f} MB "
              f"of decode-time weight splitting avoided")


if __name__ == "__main__":
    main()
