"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpoint/restart, using the full framework stack (data pipeline, AdamW,
checkpointing, the train-step factory).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--ozimmu]

`--ozimmu` routes the LM-head GEMM through the paper's INT8 emulation
(ozimmu_h-8:df32) — the numerically hard layer gets high-precision GEMMs
from integer hardware while the rest stays bf16.

The run deliberately kills and resumes itself halfway (checkpoint/restart
demonstration): step counts and loss curves line up across the restart.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch.train import train


def build_cfg_overrides():
    # ~100M params: 12 layers x d=768 x ff=3072, vocab 32k
    return dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                d_ff=3072, vocab=32000, remat_block=2,
                q_chunk=256, kv_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--ozimmu", action="store_true")
    ap.add_argument("--restart-demo", action="store_true", default=True)
    ap.add_argument("--no-restart-demo", dest="restart_demo",
                    action="store_false")
    args = ap.parse_args()

    from repro import configs
    from repro.models.common import ModelConfig

    # register as a custom config through the dense family
    engine = "bf16"  # backbone engine; LM-head override below when --ozimmu
    ckpt_dir = tempfile.mkdtemp(prefix="ozimmu_train_")
    print(f"[example] checkpoints -> {ckpt_dir}")

    import repro.configs.internlm2_1_8b as base_mod
    orig_smoke = base_mod.smoke

    def smoke_100m():
        return orig_smoke().with_(**build_cfg_overrides())

    base_mod.smoke = smoke_100m
    try:
        half = args.steps // 2
        if args.restart_demo:
            print(f"[example] phase 1: steps 0..{half} (then 'crash')")
            _, losses1 = train("internlm2_1_8b", smoke=True, n_steps=half,
                               global_batch=args.batch, seq_len=args.seq,
                               ckpt_dir=ckpt_dir, ckpt_every=half // 2 or 1,
                               engine=engine, log_every=25)
            print("[example] simulated preemption; restarting from latest "
                  "checkpoint")
        _, losses2 = train("internlm2_1_8b", smoke=True, n_steps=args.steps,
                           global_batch=args.batch, seq_len=args.seq,
                           ckpt_dir=ckpt_dir, ckpt_every=100,
                           engine=engine, log_every=25)
    finally:
        base_mod.smoke = orig_smoke

    k = max(1, len(losses2) // 5)
    first, last = np.mean(losses2[:k]), np.mean(losses2[-k:])
    print(f"[example] resumed-run loss: first-{k} {first:.3f} -> "
          f"last-{k} {last:.3f} ({'LEARNING' if last < first else 'FLAT'})")


if __name__ == "__main__":
    main()
