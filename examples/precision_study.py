"""Example: per-layer precision study — where does the Ozaki engine matter?

    PYTHONPATH=src python examples/precision_study.py

Trains the same tiny LM three ways and compares logits fidelity against an
f64 oracle forward:
    bf16 everywhere | f32 everywhere | ozimmu_h-8 (INT8-emulated f64)
demonstrating the paper's technique as a *framework feature* (engine spec
per run) rather than a standalone GEMM demo.
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "true")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api


def main():
    cfg64 = configs.get_config("internlm2_1_8b", smoke=True,
                               engine_spec="f64", dtype="float64")
    model = api.get_model(cfg64)
    params, _ = model.init(jax.random.PRNGKey(0), cfg64)
    params64 = jax.tree.map(lambda p: p.astype(jnp.float64), params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg64.vocab, dtype=jnp.int32)
    batch = {"tokens": tokens}
    ref = model.forward(params64, cfg64, batch)  # f64 oracle

    print(f"{'engine':14s} {'dtype':8s} {'max |dlogits|':>14s} "
          f"{'rel err':>10s}")
    for spec, dtype in (("bf16", "bfloat16"), ("f32", "float32"),
                        ("ozimmu_h-8", "float32")):
        cfg = cfg64.with_(engine_spec=spec, dtype=dtype)
        p = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        out = api.get_model(cfg).forward(p, cfg, batch)
        d = np.max(np.abs(np.asarray(out, np.float64) - np.asarray(ref)))
        rel = d / float(np.max(np.abs(np.asarray(ref))))
        print(f"{spec:14s} {dtype:8s} {d:14.3e} {rel:10.2e}")
    print("\nozimmu_h-8 recovers ~f64-grade logits from INT8 matmuls —")
    print("the paper's scheme as a per-layer precision knob.")


if __name__ == "__main__":
    main()
