"""Quickstart: emulate a high-precision GEMM with INT8 slice products.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API at the three levels you would actually use it:
  1. `ozimmu_matmul`   — drop-in accurate GEMM (the paper's contribution)
  2. `MatmulEngine`    — the pluggable backend every model layer uses
  3. variant comparison — the paper's four configurations on one matrix
"""
import os
import sys

os.environ.setdefault("JAX_ENABLE_X64", "true")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ozimmu
from repro.core.engine import make_engine


def main():
    rng = np.random.default_rng(0)
    n = 256
    # difficult matrices (phi=1): wide exponent range
    a = (rng.uniform(size=(n, n)) - 0.5) * np.exp(rng.standard_normal((n, n)))
    b = (rng.uniform(size=(n, n)) - 0.5) * np.exp(rng.standard_normal((n, n)))
    aj, bj = jnp.asarray(a), jnp.asarray(b)

    exact = np.asarray(aj @ bj)  # fp64 reference

    # 1. drop-in accurate GEMM (paper variant ozIMMU_H, k=8)
    cfg = ozimmu.parse_spec("ozimmu_h-8")
    c = ozimmu.ozimmu_matmul(aj, bj, cfg)
    err = np.max(np.abs(np.asarray(c) - exact) / np.maximum(np.abs(exact),
                                                            1e-300))
    print(f"ozimmu_h-8 vs fp64:  max rel err = {err:.2e}")

    # 2. the engine abstraction used by every model layer
    eng = make_engine("ozimmu_h-8")
    x = jnp.asarray(rng.standard_normal((4, 64, n)))
    w = jnp.asarray(rng.standard_normal((n, 128)))
    y = eng(x, w)
    print(f"engine contraction:  {x.shape} @ {w.shape} -> {y.shape}")

    # 3. the paper's four variants at k=8
    print(f"\n{'variant':12s} {'max rel err':>12s}  (k=8, n={n}, phi=1)")
    for name in ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h"):
        c = ozimmu.ozimmu_matmul(aj, bj, ozimmu.VARIANTS[name].with_(k=8))
        err = np.max(np.abs(np.asarray(c) - exact) /
                     np.maximum(np.abs(exact), 1e-300))
        print(f"{name:12s} {err:12.2e}")
    print("\nRN/H (round-to-nearest splitting) are ~1 slice more accurate;")
    print("EF/H (group-wise error-free accumulation) are 1.2-1.7x faster.")


if __name__ == "__main__":
    main()
