from repro.checkpoint.store import Checkpointer
