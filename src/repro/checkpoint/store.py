"""Checkpointing: step-addressed, async, reshard-on-restore (elastic).

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step metadata
        arrays/<idx>.npy     # one file per leaf (host-local full array)

Design points for 1000+ nodes:

* **Async save** — arrays are snapshotted to host memory synchronously
  (cheap) and written by a background thread; training continues.  ``wait()``
  joins before the next save or exit.
* **Elastic restore** — the manifest stores *global* shapes; restore reads
  each leaf and (re)shards it onto whatever mesh the restoring job uses, so
  a checkpoint from a 512-chip run restores onto 256 chips or vice versa.
* **Atomicity** — writes go to ``<step>.tmp`` and are renamed after fsync;
  a crash mid-save never corrupts the latest complete checkpoint.
* **Retention** — ``keep`` most recent checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = False):
        self.wait()
        leaves, treedef = _leaf_paths(tree)
        # snapshot to host memory now; write in background
        host = [np.asarray(x) for x in leaves]
        manifest = {
            "step": step,
            "treedef": jax.tree.unflatten(
                treedef, list(range(len(leaves)))).__repr__(),
            "n_leaves": len(leaves),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
        }

        def write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(os.path.join(tmp, "arrays"))
            for i, a in enumerate(host):
                np.save(os.path.join(tmp, "arrays", f"{i}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final) if not os.path.exists(final) else None
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def list_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None):
        """Restore into the structure of ``tree_like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        Shardings — leaves are device_put with them (elastic reshard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        base = os.path.join(self.directory, f"step_{step:08d}")
        leaves, treedef = _leaf_paths(tree_like)
        n = len(leaves)
        arrays = [np.load(os.path.join(base, "arrays", f"{i}.npy"))
                  for i in range(n)]
        for a, ref in zip(arrays, leaves):
            assert tuple(a.shape) == tuple(ref.shape), (a.shape, ref.shape)
        if shardings is not None:
            shard_leaves = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, shard_leaves)]
        else:
            arrays = [jax.numpy.asarray(a) for a in arrays]
        return jax.tree.unflatten(treedef, arrays), step
