"""Deterministic, resumable, host-sharded data pipeline.

Design for 1000+-node training:

* **Stateless indexing** — batch ``i`` is a pure function of ``(seed, i)``;
  there is no iterator state to checkpoint.  Restart/elastic-reshard resume
  is "set step counter, continue" — the pipeline itself needs nothing saved.
* **Host sharding** — each host materializes only its slice of the global
  batch (``host_id / num_hosts``); `global_batch` stays the logical unit so
  the same config runs on any number of hosts.
* **Synthetic + file-backed sources** — the synthetic source generates a
  deterministic "language-like" token stream (Zipfian unigram + a repeated
  n-gram process so the loss actually decreases); the file source
  memory-maps a flat uint16/uint32 token file and windows into it.  Both
  share the stateless index contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab: int = 1024
    seed: int = 0
    source: str = "synthetic"       # synthetic | file:<path>
    # modality stubs (assignment: frontends provide precomputed embeddings)
    vision_seq: int = 0
    frames: int = 0
    d_model: int = 0


def _host_slice(cfg: DataConfig, host_id: int, num_hosts: int):
    assert cfg.global_batch % num_hosts == 0, (cfg.global_batch, num_hosts)
    per = cfg.global_batch // num_hosts
    return host_id * per, per


class SyntheticSource:
    """Deterministic language-like stream: Zipf unigrams + copied spans.

    Each (step, row) seeds an independent Philox stream -> reproducible
    regardless of host layout, restart point, or batch parallelism.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._probs = p / p.sum()

    def row(self, step: int, row_idx: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row_idx]))
        toks = rng.choice(cfg.vocab, size=cfg.seq_len, p=self._probs)
        # plant copied spans -> learnable induction structure
        n_spans = max(1, cfg.seq_len // 256)
        for _ in range(n_spans):
            ln = int(rng.integers(8, 32))
            if 2 * ln + 2 >= cfg.seq_len:
                continue
            src = int(rng.integers(0, cfg.seq_len - 2 * ln - 1))
            dst = int(rng.integers(src + ln, cfg.seq_len - ln))
            toks[dst:dst + ln] = toks[src:src + ln]
        return toks.astype(np.int32)


class FileSource:
    """Flat binary token file; batch rows are strided windows."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self._data = np.memmap(path, dtype=np.uint16, mode="r")
        self._n_windows = (len(self._data) - 1) // cfg.seq_len

    def row(self, step: int, row_idx: int) -> np.ndarray:
        cfg = self.cfg
        # deterministic shuffle via multiplicative hashing over windows
        i = (step * cfg.global_batch + row_idx)
        w = (i * 2654435761) % self._n_windows
        start = w * cfg.seq_len
        return np.asarray(self._data[start:start + cfg.seq_len],
                          dtype=np.int32) % cfg.vocab


def make_source(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticSource(cfg)
    if cfg.source.startswith("file:"):
        return FileSource(cfg, cfg.source[5:])
    raise ValueError(f"unknown data source {cfg.source!r}")


class Pipeline:
    """``batch_at(step)`` -> host-local batch dict of numpy arrays."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        self.cfg = cfg
        self.host_id, self.num_hosts = host_id, num_hosts
        self.source = make_source(cfg)
        self._start, self._per_host = _host_slice(cfg, host_id, num_hosts)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = [self.source.row(step, self._start + r)
                for r in range(self._per_host)]
        batch = {"tokens": np.stack(rows)}
        if cfg.vision_seq:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 1 << 20]))
            batch["image_embeds"] = rng.standard_normal(
                (self._per_host, cfg.vision_seq, cfg.d_model)).astype(
                    np.float32)
        if cfg.frames:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 1 << 21]))
            batch["frames"] = rng.standard_normal(
                (self._per_host, cfg.frames, cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
