"""Pallas TPU kernel: fused flash-attention forward.

The Cell-A roofline iteration (EXPERIMENTS §Perf) shows ~75 % of the
train-step HBM traffic is f32 score/probability blocks streamed between
XLA ops.  This kernel keeps the online-softmax state — the (qc, kc) score
block, running max/sum and the output accumulator — in VMEM-resident
tiles; HBM sees only q, k, v and out, removing the O(L^2) traffic term.

Layout: q (BH, Lq, D); k, v (BKV, Lk, D/Dv) with BH = B*H, BKV = B*KV —
the GQA mapping happens in the k/v BlockSpec index_map (query-head block
``bh`` reads kv block ``bh // group``), so K/V are NOT expanded in memory.

Grid: (BH, nq, nk) — nk is the innermost (sequential) reduction axis.  The
running stats (m, l) and accumulator follow the established accumulator
pattern of ``group_gemm``: extra outputs whose index_map ignores nk, so
Pallas keeps their tiles resident in VMEM across the kv sweep; the
normalized output is written on the last nk step.

MXU alignment: qc/kc multiples of 128 recommended on hardware (the ops.py
wrapper pads); interpret=True validates on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_QC = 256
DEFAULT_KC = 512
NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                      acc_ref, *, causal: bool, window, qc: int, kc: int,
                      lk: int, n_k: int, q_offset: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale     # (qc, D)
    k = k_ref[0].astype(jnp.float32)             # (kc, D)
    v = v_ref[0]                                 # (kc, Dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (qc, kc)

    q_pos = (qi * qc + q_offset +
             jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0))
    k_pos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = k_pos < lk                            # input padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (qc, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                       # (qc, kc)
    corr = jnp.exp(m_prev - m_new)               # (qc, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        # per-row logsumexp, saved for the recompute-p backward; +inf on
        # fully-masked (padding) rows so exp(s - lse) == 0 there
        lse_ref[0] = jnp.where(l_ref[...] > 0, m_ref[...] + jnp.log(l),
                               jnp.inf)


@functools.partial(jax.jit, static_argnames=("group", "causal", "window",
                                             "qc", "kc", "q_offset",
                                             "lk", "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        group: int = 1, causal: bool = True, window=None,
                        qc: int = DEFAULT_QC, kc: int = DEFAULT_KC,
                        q_offset: int = 0, lk=None,
                        interpret: bool = True) -> jax.Array:
    """q (BH, Lq, D); k (BKV, Lk, D); v (BKV, Lk, Dv); BH == BKV * group.

    Lq/Lk must be qc/kc multiples (ops.py pads; ``lk`` is the pre-padding
    valid key count).  Returns (BH, Lq, Dv) in q.dtype.
    """
    BH, Lq, D = q.shape
    BKV, Lk = k.shape[0], k.shape[1]
    Dv = v.shape[2]
    assert BH == BKV * group, (BH, BKV, group)
    assert Lq % qc == 0 and Lk % kc == 0, (Lq, qc, Lk, kc)
    n_q, n_k = Lq // qc, Lk // kc
    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, window=window, qc=qc, kc=kc,
        lk=int(lk if lk is not None else Lk), n_k=n_k,
        q_offset=int(q_offset), scale=float(D) ** -0.5)
    o, lse, _, _, _ = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, qc, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kc, D),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, kc, Dv),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qc, Dv), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, qc, 1), lambda bh, qi, ki: (bh, qi, 0)),
            # VMEM-resident running stats / accumulator (index ignores
            # bh/ki: scratch-like tiles reset at ki == 0 on every sweep)
            pl.BlockSpec((qc, 1), lambda bh, qi, ki: (qi, 0)),
            pl.BlockSpec((qc, 1), lambda bh, qi, ki: (qi, 0)),
            pl.BlockSpec((qc, Dv), lambda bh, qi, ki: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Lq, Dv), q.dtype),
            jax.ShapeDtypeStruct((BH, Lq, 1), jnp.float32),
            jax.ShapeDtypeStruct((Lq, 1), jnp.float32),
            jax.ShapeDtypeStruct((Lq, 1), jnp.float32),
            jax.ShapeDtypeStruct((Lq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward kernels: recompute-p flash backward (dq) and (dk, dv)
# ---------------------------------------------------------------------------

def _recompute_p(q_ref, k_ref, lse_ref, qi, ki, *, qc, kc, lk, causal,
                 window, q_offset, scale):
    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    q_pos = (qi * qc + q_offset +
             jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0))
    k_pos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = k_pos < lk
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    return jnp.exp(s - lse_ref[0])              # (qc, kc); lse (1, qc, 1)


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, *, causal, window, qc, kc, lk, n_k,
                         q_offset, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    p = _recompute_p(q_ref, k_ref, lse_ref, qi, ki, qc=qc, kc=kc, lk=lk,
                     causal=causal, window=window, q_offset=q_offset,
                     scale=scale)
    do = do_ref[0].astype(jnp.float32)          # (qc, Dv)
    v = v_ref[0].astype(jnp.float32)            # (kc, Dv)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0])                # (qc, kc)
    k = k_ref[0].astype(jnp.float32)
    dq_ref[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, *, causal, window, qc, kc, lk,
                          n_t, group, q_offset, scale):
    ki = pl.program_id(1)
    t = pl.program_id(2)        # flattened (q-block, group) reduction axis
    qi = t // group

    @pl.when(t == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    p = _recompute_p(q_ref, k_ref, lse_ref, qi, ki, qc=qc, kc=kc, lk=lk,
                     causal=causal, window=window, q_offset=q_offset,
                     scale=scale)
    do = do_ref[0].astype(jnp.float32)
    dv_ref[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (kc, Dv)
    v = v_ref[0].astype(jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0])
    q = q_ref[0].astype(jnp.float32)
    dk_ref[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (kc, D)


@functools.partial(jax.jit, static_argnames=("group", "causal", "window",
                                             "qc", "kc", "q_offset", "lk",
                                             "interpret"))
def flash_attention_bwd(q, k, v, out, lse, dout, *, group: int = 1,
                        causal: bool = True, window=None,
                        qc: int = DEFAULT_QC, kc: int = DEFAULT_KC,
                        q_offset: int = 0, lk=None, interpret: bool = True):
    """Recompute-p flash backward.  Inputs as in the forward plus the saved
    ``out`` and row ``lse`` (BH, Lq, 1); returns (dq, dk, dv) with dk/dv in
    the UNEXPANDED (BKV, ...) layout (the G q-head contributions are summed
    inside the dkv kernel's resident accumulator)."""
    BH, Lq, D = q.shape
    BKV, Lk = k.shape[0], k.shape[1]
    Dv = v.shape[2]
    n_q, n_k = Lq // qc, Lk // kc
    lk_i = int(lk if lk is not None else Lk)
    scale = float(D) ** -0.5
    # delta = rowsum(dout * out): tiny; computed in XLA
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1, keepdims=True)     # (BH, Lq, 1)

    dq, = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, causal=causal, window=window,
                          qc=qc, kc=kc, lk=lk_i, n_k=n_k,
                          q_offset=int(q_offset), scale=scale),
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, qc, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kc, D), lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, kc, Dv), lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, qc, Dv), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, qc, 1), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, qc, 1), lambda bh, qi, ki: (bh, qi, 0)),
        ],
        out_specs=[pl.BlockSpec((qc, D), lambda bh, qi, ki: (qi, 0))]
        if False else [pl.BlockSpec((1, qc, D),
                                    lambda bh, qi, ki: (bh, qi, 0))],
        out_shape=[jax.ShapeDtypeStruct((BH, Lq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    n_t = n_q * group
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, causal=causal,
                          window=window, qc=qc, kc=kc, lk=lk_i, n_t=n_t,
                          group=group, q_offset=int(q_offset), scale=scale),
        grid=(BKV, n_k, n_t),
        in_specs=[
            pl.BlockSpec((1, qc, D),
                         lambda bkv, ki, t: (bkv * group + t % group,
                                             t // group, 0)),
            pl.BlockSpec((1, kc, D), lambda bkv, ki, t: (bkv, ki, 0)),
            pl.BlockSpec((1, kc, Dv), lambda bkv, ki, t: (bkv, ki, 0)),
            pl.BlockSpec((1, qc, Dv),
                         lambda bkv, ki, t: (bkv * group + t % group,
                                             t // group, 0)),
            pl.BlockSpec((1, qc, 1),
                         lambda bkv, ki, t: (bkv * group + t % group,
                                             t // group, 0)),
            pl.BlockSpec((1, qc, 1),
                         lambda bkv, ki, t: (bkv * group + t % group,
                                             t // group, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kc, D), lambda bkv, ki, t: (bkv, ki, 0)),
            pl.BlockSpec((1, kc, Dv), lambda bkv, ki, t: (bkv, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKV, Lk, D), jnp.float32),
            jax.ShapeDtypeStruct((BKV, Lk, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))
