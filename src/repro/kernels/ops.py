"""jit'd public wrappers around the Pallas kernels.

Handles: padding to tile multiples, row-scale preparation, slice-pair
stacking for group GEMMs, and the interpret-mode switch.

The ``INTERPRET`` module switch
-------------------------------
``INTERPRET = True`` runs every Pallas kernel body through the interpreter:
the grid is executed sequentially in Python and the body lowers to plain
XLA ops on the host backend.  This is the *correctness reference path* —
it is what the test suite exercises (this container has no TPU) and it is
bit-identical to the compiled Mosaic kernel for the integer/exact-float
arithmetic used here.  Flip to ``False`` on real TPUs to compile the
kernels; nothing else in the call sites changes.  The switch is a module
global (not a per-call flag) so that benchmarks, tests, and the engine all
agree on one execution mode; override it *before* the first traced call —
the wrappers are ``jit``'d with ``interpret`` as a static argument, so
earlier traces are cached per mode.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.splitting import Split, _pow2_ceil, _pow2_floor, _rowmax
from repro.kernels import group_gemm as _gg
from repro.kernels import scale_accum as _sa
from repro.kernels import split_fused as _sf

# Flip to False when running on real TPUs.
INTERPRET = True


def _pad_to(x: jax.Array, mults: Sequence[int]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _tile_for(dim: int, pref: int, mult: int) -> int:
    """Largest tile <= pref that is a multiple of ``mult`` covering dim."""
    if dim <= mult:
        return mult
    return min(pref, (dim + mult - 1) // mult * mult if dim < pref else pref)


def split_fused(a: jax.Array, k: int, beta: int, *, mode: str = "rn_const",
                axis: int = 0) -> Split:
    """Pallas-accelerated splitting (Alg. 3 'bitmask' / Alg. 8 'rn_const').

    Returns the same :class:`Split` contract as the pure-jnp splitters.
    axis=1 (column scales, for B) is handled by transposing the *scale*
    handling only — digits stay in the original orientation via a transposed
    kernel launch.
    """
    a32 = a.astype(jnp.float32)
    if axis == 1:
        sp = split_fused(a32.T, k, beta, mode=mode, axis=0)
        return Split(jnp.swapaxes(sp.digits, 1, 2), sp.scale, sp.base,
                     beta, 1)
    rowmax = _rowmax(a32, 0)
    if mode == "bitmask":
        base = 2.0 * _pow2_floor(rowmax)
        invgrid = (2.0 ** beta) / base  # 1/grid_1, grid_1 = base*2^-beta
    else:
        mu = _pow2_ceil(rowmax) * (2.0 ** (1 - beta))
        base = mu * (2.0 ** beta)
        invgrid = 1.0 / mu
    m, n = a32.shape
    bm = _tile_for(m, _sf.DEFAULT_BM, 8)
    bn = _tile_for(n, _sf.DEFAULT_BN, 128)
    a_p = _pad_to(a32, (bm, bn))
    inv_p = _pad_to(invgrid[:, None], (bm, 1))
    digits = _sf.split_fused(a_p, inv_p, k=k, beta=beta, mode=mode, bm=bm,
                             bn=bn, interpret=INTERPRET)[:, :m, :n]
    exps = jnp.asarray([2.0 ** (-beta * s) for s in range(1, k + 1)],
                       jnp.float32)
    scale = base[None, :] * exps[:, None]
    return Split(digits, scale, base, beta, 0)


def group_gemm(sa: Split, sb: Split, pairs: Sequence[Tuple[int, int]]
               ) -> jax.Array:
    """sum over slice pairs of A_s @ B_t in int32 via the Pallas kernel.

    Signature matches the ``group_gemm_fn`` hook in
    :func:`repro.core.accumulate.matmul_group_ef` (after partial application
    of sa, sb).  Batched splits — digits ``(k, *batch, m, n)`` — map onto
    the kernel's leading batch grid axis (flattened to one axis, restored
    on exit); output is ``(*batch, m, p)``.
    """
    idx_a = [s - 1 for s, _ in pairs]
    idx_b = [t - 1 for _, t in pairs]
    a8 = sa.digits[jnp.asarray(idx_a)]      # (G, *batch, m, n)
    b8 = sb.digits[jnp.asarray(idx_b)]
    G = a8.shape[0]
    batch = a8.shape[1:-2]
    m, n = a8.shape[-2], a8.shape[-1]
    p = b8.shape[-1]
    a8 = jnp.moveaxis(a8, 0, -3).reshape((-1, G, m, n))
    b8 = jnp.moveaxis(b8, 0, -3).reshape((-1, G, n, p))
    bm = _tile_for(m, _gg.DEFAULT_BM, 128)
    bp = _tile_for(p, _gg.DEFAULT_BP, 128)
    bn = _tile_for(n, _gg.DEFAULT_BN, 128)
    a8 = _pad_to(a8, (1, 1, bm, bn))
    b8 = _pad_to(b8, (1, 1, bn, bp))
    out = _gg.group_gemm(a8, b8, bm=bm, bp=bp, bn=bn, interpret=INTERPRET)
    return out[:, :m, :p].reshape(batch + (m, p))


def scale_accum(p32: jax.Array, srow: jax.Array, scol: jax.Array,
                c_hi: jax.Array, c_lo: jax.Array):
    """Fused df32 epilogue; shapes (m,p), (m,), (p,), (m,p), (m,p)."""
    m, p = p32.shape
    bm = _tile_for(m, _sa.DEFAULT_BM, 8)
    bp = _tile_for(p, _sa.DEFAULT_BP, 128)
    pads = ((-m) % bm, (-p) % bp)
    p32_p = _pad_to(p32, (bm, bp))
    hi_p = _pad_to(c_hi, (bm, bp))
    lo_p = _pad_to(c_lo, (bm, bp))
    srow_p = _pad_to(srow[:, None], (bm, 1))
    scol_p = _pad_to(scol[None, :], (1, bp))
    hi, lo = _sa.scale_accum(p32_p, srow_p, scol_p, hi_p, lo_p, bm=bm, bp=bp,
                             interpret=INTERPRET)
    if pads == (0, 0):
        return hi, lo
    return hi[:m, :p], lo[:m, :p]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None,
                    qc: int = 256, kc: int = 512, q_offset: int = 0):
    """jit'd wrapper for the fused flash-attention forward kernel.

    q (B, Lq, H, D); k, v (B, Lk, KV, D/Dv).  Pads L to tile multiples,
    flattens (B, H) into the kernel's grid-major axis, maps GQA groups in
    the BlockSpec (no K/V expansion), and slices the padding back off.
    """
    from repro.kernels import flash_attention as _fa
    B, Lq, H, D = q.shape
    _, Lk, KV, Dv = v.shape
    group = H // KV
    qc = min(qc, max(8, Lq))
    kc = min(kc, max(8, Lk))
    Lq_p = -(-Lq // qc) * qc
    Lk_p = -(-Lk // kc) * kc
    qt = jnp.pad(q, ((0, 0), (0, Lq_p - Lq), (0, 0), (0, 0)))
    kt = jnp.pad(k, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    vt = jnp.pad(v, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    qt = qt.transpose(0, 2, 1, 3).reshape(B * H, Lq_p, D)
    kt = kt.transpose(0, 2, 1, 3).reshape(B * KV, Lk_p, D)
    vt = vt.transpose(0, 2, 1, 3).reshape(B * KV, Lk_p, Dv)
    o, _ = _fa.flash_attention_fwd(qt, kt, vt, group=group, causal=causal,
                                   window=window, qc=qc, kc=kc,
                                   q_offset=q_offset, lk=Lk,
                                   interpret=INTERPRET)
    o = o.reshape(B, H, Lq_p, Dv).transpose(0, 2, 1, 3)
    return o[:, :Lq]
