"""jit'd public wrappers around the Pallas kernels.

Handles: padding to tile multiples, row-scale preparation, slice-pair
stacking for group GEMMs, batch flattening onto the kernels' leading grid
axis, and the interpret-mode switch.  Block sizes come from the planner's
static-shape autotune table (``repro.core.plan.kernel_blocks``), aligned
per kernel with ``plan.tile``.

The ``INTERPRET`` module switch
-------------------------------
``INTERPRET = True`` runs every Pallas kernel body through the interpreter:
the grid is executed sequentially in Python and the body lowers to plain
XLA ops on the host backend.  This is the *correctness reference path* —
it is what the test suite exercises (this container has no TPU) and it is
bit-identical to the compiled Mosaic kernel for the integer/exact-float
arithmetic used here.  Flip to ``False`` on real TPUs to compile the
kernels; nothing else in the call sites changes.  The switch is a module
global (not a per-call flag) so that benchmarks, tests, and the engine all
agree on one execution mode; override it *before* the first traced call —
the wrappers are ``jit``'d with ``interpret`` as a static argument, so
earlier traces are cached per mode.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import plan
from repro.core.splitting import (Split, _geo_scales, _pow2_ceil,
                                  _pow2_floor, _rowmax, sm_decode)
from repro.kernels import group_gemm as _gg
from repro.kernels import scale_accum as _sa
from repro.kernels import split_fused as _sf
from repro.obs import tracing as _tracing

# Flip to False when running on real TPUs.
INTERPRET = True


def _pad_to(x: jax.Array, mults: Sequence[int]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def split_fused(a: jax.Array, k: int, beta: int, *, mode: str = "rn_const",
                axis: int = 0,
                rowmax_reduce: Optional[Callable] = None) -> Split:
    """Pallas-accelerated splitting (Alg. 3 'bitmask' / Alg. 8 'rn_const' /
    the oz2 constant-grid modes 'oz2_bitmask' / 'oz2_rn' / their
    improved-scaling fast2 twins 'oz2_bitmask_fast2' / 'oz2_rn_fast2').

    Returns the same :class:`Split` contract as the pure-jnp splitters —
    bit-identical digits and scales, in ``a``'s own dtype (f64 inputs stay
    f64 through the interpret path; on TPU use f32).  ``a`` is
    ``(*batch, m, n)``: splitting is row/column-local, so batch and row
    dims flatten together onto the kernel grid.  axis=1 (column scales,
    for B) transposes the trailing two axes in and out of the row kernel.
    ``rowmax_reduce`` widens the row maxima before grids are derived
    (the mesh-axis pmax hook) exactly as in the library splitters.

    The oz2 modes derive ONE grid per batch element from the global |a|
    maximum; without batch dims the kernel runs in its const-grid mode
    (a (1, 1) scalar operand instead of an (m, 1) streamed vector), with
    batch dims the scalar broadcasts onto the flattened row grid —
    bit-identical either way.  The fast2 modes keep the PER-ROW grids of
    their per-row twins (the equilibrated digits are bitwise the per-row
    splitter's — no global broadcast, no extra pass) and attach the
    constant equilibrated-grid base ``gbase = 2`` exactly as
    ``splitting.split_oz2_fast2`` / ``split_oz2_bitmask_fast2`` do.
    """
    if axis == 1:
        sp = split_fused(jnp.swapaxes(a, -1, -2), k, beta, mode=mode,
                         axis=0, rowmax_reduce=rowmax_reduce)
        return Split(jnp.swapaxes(sp.digits, -1, -2), sp.scale, sp.base,
                     beta, 1, gbase=sp.gbase, signmag=sp.signmag)
    rowmax = _rowmax(a, 0)                              # (*batch, m)
    if rowmax_reduce is not None:
        rowmax = rowmax_reduce(rowmax)
    gbase = None
    if mode in ("oz2_rn", "oz2_bitmask"):
        rowmax = jnp.broadcast_to(
            jnp.max(rowmax, axis=-1, keepdims=True), rowmax.shape)
    if mode in ("bitmask", "oz2_bitmask", "oz2_bitmask_fast2"):
        base = 2.0 * _pow2_floor(rowmax)
        invgrid = (2.0 ** beta) / base  # 1/grid_1, grid_1 = base*2^-beta
        kmode = "bitmask"
    elif mode in ("rn_const", "oz2_rn", "oz2_rn_fast2"):
        mu = _pow2_ceil(rowmax) * (2.0 ** (1 - beta))
        base = mu * (2.0 ** beta)
        invgrid = 1.0 / mu
        kmode = "rn_const"
    elif mode == "sm":
        # sign-magnitude: leading grid = anchor * 2^(1-beta) with the
        # strict anchor 2*2^floor(log2 rowmax) > rowmax; the stored base
        # is 2*anchor so scale[s] = base * 2^(-beta*s) (splitting.split_sm)
        anchor = 2.0 * _pow2_floor(rowmax)
        base = 2.0 * anchor
        invgrid = (2.0 ** (beta - 1)) / anchor
        kmode = "sm"
    else:
        raise ValueError(f"fused splitting supports bitmask/rn_const/sm/"
                         f"oz2_bitmask/oz2_rn/oz2_bitmask_fast2/"
                         f"oz2_rn_fast2, got {mode!r}")
    if mode in ("oz2_rn", "oz2_bitmask"):
        gbase = base[..., 0]
    elif mode in ("oz2_rn_fast2", "oz2_bitmask_fast2"):
        # the equilibrated constant grid: per-row digits, scalar base 2
        # (splitting._with_fast2_gbase's contract)
        gbase = jnp.full(base.shape[:-1], 2.0, base.dtype)
    batch = a.shape[:-2]
    m, n = a.shape[-2:]
    rows = math.prod(batch, start=m)
    a2 = a.reshape((rows, n))
    # fast2 keeps per-row grids (streamed), so only the plain oz2 modes
    # qualify for the kernel's const-grid scalar operand
    const_grid = mode in ("oz2_rn", "oz2_bitmask") and not batch
    inv2 = (invgrid[:1, None] if const_grid
            else invgrid.reshape((rows, 1)))
    bm_pref, bn_pref, _ = plan.kernel_blocks(rows, n)
    bm = plan.tile(rows, bm_pref, 8)
    bn = plan.tile(n, bn_pref, 128)
    a_p = _pad_to(a2, (bm, bn))
    inv_p = inv2 if const_grid else _pad_to(inv2, (bm, 1))
    with _tracing.phase_scope("kernel/split_fused"):
        digits = _sf.split_fused(a_p, inv_p, k=k, beta=beta, mode=kmode,
                                 bm=bm, bn=bn, const_grid=const_grid,
                                 interpret=INTERPRET)[:, :rows, :n]
    digits = digits.reshape((k,) + batch + (m, n))
    return Split(digits, _geo_scales(base, beta, k), base, beta, 0,
                 gbase=gbase, signmag=(mode == "sm"))


def group_gemm(sa: Split, sb: Split, pairs: Sequence[Tuple[int, int]]
               ) -> jax.Array:
    """sum over slice pairs of A_s @ B_t in int32 via the Pallas kernel.

    Signature matches the ``group_gemm_fn`` hook in
    :func:`repro.core.accumulate.matmul_group_ef` (after partial application
    of sa, sb).  Batched splits — digits ``(k, *batch, m, n)`` — map onto
    the kernel's leading batch grid axis (flattened to one axis, restored
    on exit); output is ``(*batch, m, p)``.
    """
    idx_a = [s - 1 for s, _ in pairs]
    idx_b = [t - 1 for _, t in pairs]
    # sign-magnitude splits widen to int16 values before the gather (the
    # Pallas MAC body is dtype-generic; int32 accumulation is unchanged)
    da = sm_decode(sa.digits) if sa.signmag else sa.digits
    db = sm_decode(sb.digits) if sb.signmag else sb.digits
    a8 = da[jnp.asarray(idx_a)]             # (G, *batch, m, n)
    b8 = db[jnp.asarray(idx_b)]
    G = a8.shape[0]
    batch = a8.shape[1:-2]
    m, n = a8.shape[-2], a8.shape[-1]
    p = b8.shape[-1]
    a8 = jnp.moveaxis(a8, 0, -3).reshape((-1, G, m, n))
    b8 = jnp.moveaxis(b8, 0, -3).reshape((-1, G, n, p))
    bm_pref, bn_pref, bp_pref = plan.kernel_blocks(m, n, p)
    bm = plan.tile(m, bm_pref, 128)
    bn = plan.tile(n, bn_pref, 128)
    bp = plan.tile(p, bp_pref, 128)
    a8 = _pad_to(a8, (1, 1, bm, bn))
    b8 = _pad_to(b8, (1, 1, bn, bp))
    with _tracing.phase_scope("kernel/group_gemm"):
        out = _gg.group_gemm(a8, b8, bm=bm, bp=bp, bn=bn,
                             interpret=INTERPRET)
    return out[:, :m, :p].reshape(batch + (m, p))


def _epilogue_operands(p32: jax.Array, srow: jax.Array, scol: jax.Array,
                       *accs: jax.Array):
    """Flatten batch, pad to the planned tiles; returns padded operands,
    the (bm, bp) tiles, and an unpad closure."""
    batch = p32.shape[:-2]
    m, p = p32.shape[-2:]
    B = math.prod(batch, start=1)
    bm_pref, bp_pref, _ = plan.kernel_blocks(m, p)
    bm = plan.tile(m, bm_pref, 8)
    bp = plan.tile(p, bp_pref, 128)
    p32_p = _pad_to(p32.reshape((B, m, p)), (1, bm, bp))
    srow_p = _pad_to(srow.reshape((B, m, 1)), (1, bm, 1))
    scol_p = _pad_to(scol.reshape((B, 1, p)), (1, 1, bp))
    accs_p = [_pad_to(c.reshape((B, m, p)), (1, bm, bp)) for c in accs]

    def unpad(x):
        return x[:, :m, :p].reshape(batch + (m, p))

    return p32_p, srow_p, scol_p, accs_p, bm, bp, unpad


def scale_accum(p32: jax.Array, srow: jax.Array, scol: jax.Array,
                c_hi: jax.Array, c_lo: jax.Array):
    """Fused df32 epilogue; p32/c_hi/c_lo ``(*batch, m, p)``,
    srow ``(*batch, m)``, scol ``(*batch, p)``."""
    p32_p, srow_p, scol_p, (hi_p, lo_p), bm, bp, unpad = \
        _epilogue_operands(p32, srow, scol, c_hi, c_lo)
    with _tracing.phase_scope("kernel/scale_accum"):
        hi, lo = _sa.scale_accum(p32_p, srow_p, scol_p, hi_p, lo_p, bm=bm,
                                 bp=bp, interpret=INTERPRET)
    return unpad(hi), unpad(lo)


def scale_accum_plain(p32: jax.Array, srow: jax.Array, scol: jax.Array,
                      c: jax.Array):
    """Fused plain-accumulator epilogue (f64/f32), batched like
    :func:`scale_accum`."""
    p32_p, srow_p, scol_p, (c_p,), bm, bp, unpad = \
        _epilogue_operands(p32, srow, scol, c)
    with _tracing.phase_scope("kernel/scale_accum"):
        out = _sa.scale_accum_plain(p32_p, srow_p, scol_p, c_p, bm=bm,
                                    bp=bp, interpret=INTERPRET)
    return unpad(out)


def scale_accum_update(prod: jax.Array, srow: jax.Array, scol: jax.Array,
                       acc):
    """``scale_accum_fn`` hook for ``accumulate.matmul_naive`` /
    ``matmul_group_ef``: one fused convert+scale+add epilogue step through
    the Pallas kernel (df32 pair or plain accumulator, by ``acc``'s type).
    Bit-identical to the inline jnp epilogue — see kernels/scale_accum.py.
    """
    from repro.core.accumulate import DF32  # local: avoid import cycle
    if isinstance(acc, DF32):
        hi, lo = scale_accum(prod, srow, scol, acc.hi, acc.lo)
        return DF32(hi, lo)
    return scale_accum_plain(prod, srow, scol, acc)


def _oz2_epilogue_operands(word: jax.Array, s: jax.Array, *accs: jax.Array):
    """Const-scale analogue of :func:`_epilogue_operands`: flatten batch,
    pad to tiles, reshape the per-batch scalar to (B, 1, 1)."""
    batch = word.shape[:-2]
    m, p = word.shape[-2:]
    B = math.prod(batch, start=1)
    bm_pref, bp_pref, _ = plan.kernel_blocks(m, p)
    bm = plan.tile(m, bm_pref, 8)
    bp = plan.tile(p, bp_pref, 128)
    word_p = _pad_to(word.reshape((B, m, p)), (1, bm, bp))
    s_p = s.reshape((B, 1, 1))
    accs_p = [_pad_to(c.reshape((B, m, p)), (1, bm, bp)) for c in accs]

    def unpad(x):
        return x[:, :m, :p].reshape(batch + (m, p))

    return word_p, s_p, accs_p, bm, bp, unpad


def oz2_scale_accum(word: jax.Array, s: jax.Array, c_hi: jax.Array,
                    c_lo: jax.Array):
    """Fused oz2 df32 epilogue: ``(c_hi, c_lo) += s * float(word)``,
    compensated; word ``(*batch, m, p)`` int32, s ``(*batch,)`` f32."""
    word_p, s_p, (hi_p, lo_p), bm, bp, unpad = \
        _oz2_epilogue_operands(word, s, c_hi, c_lo)
    with _tracing.phase_scope("kernel/scale_accum"):
        hi, lo = _sa.scale_accum_const(word_p, s_p, hi_p, lo_p, bm=bm,
                                       bp=bp, interpret=INTERPRET)
    return unpad(hi), unpad(lo)


def oz2_scale_accum_plain(word: jax.Array, s: jax.Array, c: jax.Array):
    """Fused oz2 plain epilogue (f64/f32 accumulator; word may be the
    int64 ladder word in f64/x64 mode)."""
    word_p, s_p, (c_p,), bm, bp, unpad = _oz2_epilogue_operands(word, s, c)
    with _tracing.phase_scope("kernel/scale_accum"):
        out = _sa.scale_accum_const_plain(word_p, s_p, c_p, bm=bm, bp=bp,
                                          interpret=INTERPRET)
    return unpad(out)


def oz2_scale_accum_update(word: jax.Array, s: jax.Array, acc):
    """``scale_accum_fn`` hook for ``accumulate.matmul_oz2``: one fused
    ladder-window convert+scale+add through the const-grid Pallas kernels
    (bit-identical to the inline jnp epilogue)."""
    from repro.core.accumulate import DF32  # local: avoid import cycle
    if isinstance(acc, DF32):
        hi, lo = oz2_scale_accum(word, s, acc.hi, acc.lo)
        return DF32(hi, lo)
    return oz2_scale_accum_plain(word, s, acc)


def oz2_unscale(x: jax.Array, ra: jax.Array, rb: jax.Array) -> jax.Array:
    """Fused fast2 post-ladder unscale: ``diag(ra) @ x @ diag(rb)`` per
    batch element in one Pallas pass.  x ``(*batch, m, p)`` float;
    ra ``(*batch, m)`` / rb ``(*batch, p)`` power-of-two equilibration
    factors — exact, bit-identical to ``accumulate._oz2_unscale``."""
    batch = x.shape[:-2]
    m, p = x.shape[-2:]
    B = math.prod(batch, start=1)
    bm_pref, bp_pref, _ = plan.kernel_blocks(m, p)
    bm = plan.tile(m, bm_pref, 8)
    bp = plan.tile(p, bp_pref, 128)
    x_p = _pad_to(x.reshape((B, m, p)), (1, bm, bp))
    ra_p = _pad_to(ra.reshape((B, m, 1)).astype(x.dtype), (1, bm, 1))
    rb_p = _pad_to(rb.reshape((B, 1, p)).astype(x.dtype), (1, 1, bp))
    with _tracing.phase_scope("kernel/unscale"):
        out = _sa.unscale(x_p, ra_p, rb_p, bm=bm, bp=bp,
                          interpret=INTERPRET)
    return out[:, :m, :p].reshape(batch + (m, p))


def oz2_unscale_update(acc, ra: jax.Array, rb: jax.Array):
    """``unscale_fn`` hook for ``accumulate.matmul_oz2`` (fast2): the
    two-sided power-of-two unscale through the Pallas kernel — hi and lo
    limbs separately for a df32 accumulator (a common exact scale
    preserves the pair invariant)."""
    from repro.core.accumulate import DF32  # local: avoid import cycle
    if isinstance(acc, DF32):
        return DF32(oz2_unscale(acc.hi, ra, rb),
                    oz2_unscale(acc.lo, ra, rb))
    return oz2_unscale(acc, ra, rb)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None,
                    qc: int = 256, kc: int = 512, q_offset: int = 0):
    """jit'd wrapper for the fused flash-attention forward kernel.

    q (B, Lq, H, D); k, v (B, Lk, KV, D/Dv).  Pads L to tile multiples,
    flattens (B, H) into the kernel's grid-major axis, maps GQA groups in
    the BlockSpec (no K/V expansion), and slices the padding back off.
    """
    from repro.kernels import flash_attention as _fa
    B, Lq, H, D = q.shape
    _, Lk, KV, Dv = v.shape
    group = H // KV
    qc = min(qc, max(8, Lq))
    kc = min(kc, max(8, Lk))
    Lq_p = -(-Lq // qc) * qc
    Lk_p = -(-Lk // kc) * kc
    qt = jnp.pad(q, ((0, 0), (0, Lq_p - Lq), (0, 0), (0, 0)))
    kt = jnp.pad(k, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    vt = jnp.pad(v, ((0, 0), (0, Lk_p - Lk), (0, 0), (0, 0)))
    qt = qt.transpose(0, 2, 1, 3).reshape(B * H, Lq_p, D)
    kt = kt.transpose(0, 2, 1, 3).reshape(B * KV, Lk_p, D)
    vt = vt.transpose(0, 2, 1, 3).reshape(B * KV, Lk_p, Dv)
    o, _ = _fa.flash_attention_fwd(qt, kt, vt, group=group, causal=causal,
                                   window=window, qc=qc, kc=kc,
                                   q_offset=q_offset, lk=Lk,
                                   interpret=INTERPRET)
    o = o.reshape(B, H, Lq_p, Dv).transpose(0, 2, 1, 3)
    return o[:, :Lq]
