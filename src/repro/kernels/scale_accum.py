"""Pallas TPU kernel: fused epilogue for step (iv) — convert + scale + add.

Naive accumulation materializes, per term: an INT32->float convert, two
diagonal scalings, and an add — four HBM-bound element passes (this is the
"accumulation in FP64" bar that costs 40-50 % of ozIMMU's runtime, Figs 2-3).
This kernel fuses all of them into ONE pass:

    C_hi, C_lo += two_sum(scale_row * float(P32) * scale_col)

with a double-float (hi, lo) accumulator carried in HBM and updated in VMEM
(input_output_aliasing -> in-place).  One read of P32 + read/write of C per
term instead of four.  Any per-term group exponent 2^e is folded into the
row scale by the caller (powers of two — exact).

Two accumulator modes, selected by which entry point is called:

  * :func:`scale_accum`       — df32 (hi, lo) compensated accumulation.
    The operation sequence is EXACTLY ``accumulate._scale_accum_df32``
    (int32 low-8-bit split, scale, TwoSum, full TwoSum renormalization),
    so the fused epilogue is bit-identical to the unfused jnp epilogue.
  * :func:`scale_accum_plain` — plain f32/f64 accumulator (f64 interprets
    on CPU; on TPU use df32), matching ``accumulate._scale_accum_plain``.

plus their constant-grid (Ozaki-II) twins :func:`scale_accum_const` /
:func:`scale_accum_const_plain`: the oz2 exponent ladder collapses the
per-row/col scale vectors to ONE scalar per batch element, so the const
kernels take a (B, 1, 1) scale (every tile pinned to the same element by
its BlockSpec — nothing streamed) and perform one multiply where the
per-row kernels perform two.  ``scale_accum_const_plain`` also accepts an
int64 product word (the f64/x64 ladder), which the f64 accumulator
converts exactly by the ladder's 52-bit word budget.  The operation
sequences mirror ``accumulate._oz2_accum_df32`` / ``_oz2_accum_plain``
bit for bit.

:func:`unscale` is the fast2 (improved-scaling) epilogue: ONE pass
applying the exact two-sided power-of-two unscale ``X * srow * scol``
after the ladder — the same two multiplies, in the same order, as
``accumulate._oz2_unscale``'s ``_outer_scale``, so it is bit-identical
to the inline jnp epilogue (the multiplies are exact anyway: the fast2
row/col factors are powers of two).

All are batched: a leading grid axis maps batch elements, with per-batch
scale vectors — the same layout convention as ``kernels.group_gemm``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BP = 512


def _two_sum(a, b):
    """Knuth TwoSum: a + b = s + e exactly (identical to accumulate's)."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def _scale_accum_kernel(p32_ref, srow_ref, scol_ref, hi_in_ref, lo_in_ref,
                        hi_ref, lo_ref):
    """(1, bm, bp) tile: df32 accumulate the scaled int32 product."""
    p = p32_ref[...]
    # exact int32 -> (hi, lo) f32 split via low-8-bit clear
    p_hi = (p >> 8) << 8
    p_lo = p - p_hi
    srow = srow_ref[...]  # (1, bm, 1), power of two (group 2^e folded in)
    scol = scol_ref[...]  # (1, 1, bp), power of two
    x_hi = p_hi.astype(jnp.float32) * srow * scol
    x_lo = p_lo.astype(jnp.float32) * srow * scol
    # the df32_add_df sequence: TwoSum the hi limbs, fold errors into lo,
    # full-TwoSum renormalize (bit-identical to the jnp epilogue)
    hi, err = _two_sum(hi_in_ref[...], x_hi)
    lo = lo_in_ref[...] + err + x_lo
    hi2, lo2 = _two_sum(hi, lo)
    hi_ref[...] = hi2
    lo_ref[...] = lo2


def _scale_accum_plain_kernel(p32_ref, srow_ref, scol_ref, c_in_ref, c_ref):
    """(1, bm, bp) tile: plain accumulate in c's dtype (f64 on CPU)."""
    p = p32_ref[...]
    c = c_in_ref[...]
    c_ref[...] = c + p.astype(c.dtype) * srow_ref[...] * scol_ref[...]


def _scale_accum_const_kernel(p_ref, s_ref, hi_in_ref, lo_in_ref,
                              hi_ref, lo_ref):
    """(1, bm, bp) tile: df32 accumulate the int32 ladder word scaled by
    ONE scalar (same sequence as ``accumulate._oz2_accum_df32``)."""
    p = p_ref[...]
    p_hi = (p >> 8) << 8
    p_lo = p - p_hi
    s = s_ref[...]  # (1, 1, 1) power-of-two scalar
    x_hi = p_hi.astype(jnp.float32) * s
    x_lo = p_lo.astype(jnp.float32) * s
    hi, err = _two_sum(hi_in_ref[...], x_hi)
    lo = lo_in_ref[...] + err + x_lo
    hi2, lo2 = _two_sum(hi, lo)
    hi_ref[...] = hi2
    lo_ref[...] = lo2


def _scale_accum_const_plain_kernel(p_ref, s_ref, c_in_ref, c_ref):
    """(1, bm, bp) tile: plain accumulate of an int32/int64 ladder word
    scaled by one scalar (``accumulate._oz2_accum_plain``)."""
    c = c_in_ref[...]
    c_ref[...] = c + p_ref[...].astype(c.dtype) * s_ref[...]


def _unscale_kernel(x_ref, srow_ref, scol_ref, out_ref):
    """(1, bm, bp) tile: ``out = x * srow * scol`` — the fast2 two-sided
    power-of-two unscale (both multiplies exact; the multiply order
    matches ``accumulate._outer_scale`` for bit-identity)."""
    out_ref[...] = x_ref[...] * srow_ref[...] * scol_ref[...]


def _block_specs(bm: int, bp: int):
    return [
        pl.BlockSpec((1, bm, bp), lambda b, i, j: (b, i, j)),
        pl.BlockSpec((1, bm, 1), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, 1, bp), lambda b, i, j: (b, 0, j)),
    ]


def _block_specs_const(bm: int, bp: int):
    return [
        pl.BlockSpec((1, bm, bp), lambda b, i, j: (b, i, j)),
        pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, 0, 0)),
    ]


@functools.partial(jax.jit, static_argnames=("bm", "bp", "interpret"))
def scale_accum(p32: jax.Array, srow: jax.Array, scol: jax.Array,
                c_hi: jax.Array, c_lo: jax.Array, *, bm: int = DEFAULT_BM,
                bp: int = DEFAULT_BP, interpret: bool = False):
    """(c_hi, c_lo) += srow * float(p32) * scol, compensated.  Returns new
    (c_hi, c_lo); buffers are donated (aliased) so the update is in-place.

    p32 (B, m, p) int32; srow (B, m, 1); scol (B, 1, p); c_hi/c_lo
    (B, m, p) f32.  Rank-2 operands are accepted as the B=1 special case.
    """
    if p32.ndim == 2:
        hi, lo = scale_accum(p32[None], srow[None], scol[None], c_hi[None],
                             c_lo[None], bm=bm, bp=bp, interpret=interpret)
        return hi[0], lo[0]
    B, m, p = p32.shape
    assert m % bm == 0 and p % bp == 0, (p32.shape, bm, bp)
    assert srow.shape == (B, m, 1) and scol.shape == (B, 1, p), \
        (srow.shape, scol.shape)
    grid = (B, m // bm, p // bp)
    out_spec = pl.BlockSpec((1, bm, bp), lambda b, i, j: (b, i, j))
    return pl.pallas_call(
        _scale_accum_kernel,
        grid=grid,
        in_specs=_block_specs(bm, bp) + [out_spec, out_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((B, m, p), jnp.float32),
                   jax.ShapeDtypeStruct((B, m, p), jnp.float32)],
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(p32, srow, scol, c_hi, c_lo)


@functools.partial(jax.jit, static_argnames=("bm", "bp", "interpret"))
def scale_accum_plain(p32: jax.Array, srow: jax.Array, scol: jax.Array,
                      c: jax.Array, *, bm: int = DEFAULT_BM,
                      bp: int = DEFAULT_BP, interpret: bool = False):
    """c += srow * float(p32) * scol in ``c.dtype`` (plain accumulator);
    same batched layout and aliasing contract as :func:`scale_accum`."""
    if p32.ndim == 2:
        return scale_accum_plain(p32[None], srow[None], scol[None], c[None],
                                 bm=bm, bp=bp, interpret=interpret)[0]
    B, m, p = p32.shape
    assert m % bm == 0 and p % bp == 0, (p32.shape, bm, bp)
    assert srow.shape == (B, m, 1) and scol.shape == (B, 1, p), \
        (srow.shape, scol.shape)
    grid = (B, m // bm, p // bp)
    out_spec = pl.BlockSpec((1, bm, bp), lambda b, i, j: (b, i, j))
    return pl.pallas_call(
        _scale_accum_plain_kernel,
        grid=grid,
        in_specs=_block_specs(bm, bp) + [out_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, m, p), c.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(p32, srow, scol, c)


@functools.partial(jax.jit, static_argnames=("bm", "bp", "interpret"))
def unscale(x: jax.Array, srow: jax.Array, scol: jax.Array, *,
            bm: int = DEFAULT_BM, bp: int = DEFAULT_BP,
            interpret: bool = False):
    """``x * srow * scol`` in ``x.dtype`` — the fast2 post-ladder
    unscale.  x (B, m, p) float; srow (B, m, 1); scol (B, 1, p), both
    power-of-two vectors (the fast2 equilibration factors), so the
    result is exact.  The df32 caller runs it twice (hi and lo limbs:
    a common power-of-two scale preserves the pair invariant)."""
    B, m, p = x.shape
    assert m % bm == 0 and p % bp == 0, (x.shape, bm, bp)
    assert srow.shape == (B, m, 1) and scol.shape == (B, 1, p), \
        (srow.shape, scol.shape)
    grid = (B, m // bm, p // bp)
    out_spec = pl.BlockSpec((1, bm, bp), lambda b, i, j: (b, i, j))
    return pl.pallas_call(
        _unscale_kernel,
        grid=grid,
        in_specs=_block_specs(bm, bp),
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, m, p), x.dtype),
        interpret=interpret,
    )(x, srow, scol)


@functools.partial(jax.jit, static_argnames=("bm", "bp", "interpret"))
def scale_accum_const(p32: jax.Array, s: jax.Array, c_hi: jax.Array,
                      c_lo: jax.Array, *, bm: int = DEFAULT_BM,
                      bp: int = DEFAULT_BP, interpret: bool = False):
    """(c_hi, c_lo) += s * float(p32), compensated, with ONE scalar scale
    per batch element (the oz2 ladder window).  p32 (B, m, p) int32;
    s (B, 1, 1) f32 power of two; aliasing as :func:`scale_accum`."""
    B, m, p = p32.shape
    assert m % bm == 0 and p % bp == 0, (p32.shape, bm, bp)
    assert s.shape == (B, 1, 1), s.shape
    grid = (B, m // bm, p // bp)
    out_spec = pl.BlockSpec((1, bm, bp), lambda b, i, j: (b, i, j))
    return pl.pallas_call(
        _scale_accum_const_kernel,
        grid=grid,
        in_specs=_block_specs_const(bm, bp) + [out_spec, out_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((B, m, p), jnp.float32),
                   jax.ShapeDtypeStruct((B, m, p), jnp.float32)],
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(p32, s, c_hi, c_lo)


@functools.partial(jax.jit, static_argnames=("bm", "bp", "interpret"))
def scale_accum_const_plain(p: jax.Array, s: jax.Array, c: jax.Array, *,
                            bm: int = DEFAULT_BM, bp: int = DEFAULT_BP,
                            interpret: bool = False):
    """c += s * float(p) in ``c.dtype`` with one scalar scale per batch
    element; ``p`` may be int32 or int64 (the f64/x64 ladder word)."""
    B, m, pp = p.shape
    assert m % bm == 0 and pp % bp == 0, (p.shape, bm, bp)
    assert s.shape == (B, 1, 1), s.shape
    grid = (B, m // bm, pp // bp)
    out_spec = pl.BlockSpec((1, bm, bp), lambda b, i, j: (b, i, j))
    return pl.pallas_call(
        _scale_accum_const_plain_kernel,
        grid=grid,
        in_specs=_block_specs_const(bm, bp) + [out_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, m, pp), c.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(p, s, c)
