"""Pallas TPU kernel: fused epilogue for step (iv) — convert + scale + add.

Naive accumulation materializes, per term: an INT32->float convert, two
diagonal scalings, and an add — four HBM-bound element passes (this is the
"accumulation in FP64" bar that costs 40-50 % of ozIMMU's runtime, Figs 2-3).
This kernel fuses all of them into ONE pass:

    C_hi, C_lo += two_sum(scale_row * float(P32) * scale_col * 2^e)

with a double-float (hi, lo) accumulator carried in HBM and updated in VMEM
(input_output_aliasing -> in-place).  One read of P32 + read/write of C per
term instead of four.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BP = 512


def _scale_accum_kernel(p32_ref, srow_ref, scol_ref, hi_in_ref, lo_in_ref,
                        hi_ref, lo_ref):
    """(bm, bp) tile: df32 accumulate the scaled int32 product."""
    p = p32_ref[...]
    # exact int32 -> (hi, lo) f32 split via low-8-bit clear
    p_hi = (p >> 8) << 8
    p_lo = p - p_hi
    srow = srow_ref[...]  # (bm, 1), power of two * 2^e folded in
    scol = scol_ref[...]  # (1, bp), power of two
    x_hi = p_hi.astype(jnp.float32) * srow * scol
    x_lo = p_lo.astype(jnp.float32) * srow * scol
    # TwoSum(c_hi, x_hi) then fold errors into lo
    a = hi_in_ref[...]
    s = a + x_hi
    bb = s - a
    err = (a - (s - bb)) + (x_hi - bb)
    lo = lo_in_ref[...] + err + x_lo
    # renormalize (fast two-sum)
    hi2 = s + lo
    lo2 = lo - (hi2 - s)
    hi_ref[...] = hi2
    lo_ref[...] = lo2


@functools.partial(jax.jit, static_argnames=("bm", "bp", "interpret"))
def scale_accum(p32: jax.Array, srow: jax.Array, scol: jax.Array,
                c_hi: jax.Array, c_lo: jax.Array, *, bm: int = DEFAULT_BM,
                bp: int = DEFAULT_BP, interpret: bool = False):
    """(c_hi, c_lo) += srow * float(p32) * scol, compensated.  Returns new
    (c_hi, c_lo); buffers are donated (aliased) so the update is in-place."""
    m, p = p32.shape
    assert m % bm == 0 and p % bp == 0, (p32.shape, bm, bp)
    assert srow.shape == (m, 1) and scol.shape == (1, p)
    grid = (m // bm, p // bp)
    return pl.pallas_call(
        _scale_accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bp), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((m, p), jnp.float32),
                   jax.ShapeDtypeStruct((m, p), jnp.float32)],
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(p32, srow, scol, c_hi, c_lo)
