"""Pallas TPU kernels for the scheme's three hot spots:

  * split_fused      — steps (i)/(ii): k-slice extraction in one HBM pass
  * group_gemm       — steps (iii)+(iv) merged: int8 GEMM with int32 VMEM
                       group accumulation (Alg. 6/7 on the MXU)
  * scale_accum      — step (iv) epilogue: fused convert+scale+compensated-add
  * flash_attention  — fused online-softmax attention fwd (removes the
                       O(L^2) HBM score traffic identified in §Perf Cell A)

Each has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes with
interpret=True (this container is CPU-only; TPU is the deploy target).
"""
