"""Pallas TPU kernel: INT8 group GEMM with INT32 VMEM accumulation.

The TPU-native realization of Alg. 6/7 (group-wise error-free accumulation):
all slice-pair products of an anti-diagonal group share one power-of-two
exponent, so their sum

    C32[b] = sum_{g=1..G} A8[b, g] @ B8[b, g]   (exact in INT32 while G <= r)

is performed INSIDE the matmul unit's accumulator.  Here the accumulator is
an explicit (bm, bp) INT32 VMEM tile that lives across the whole reduction
(grid axes g and n), i.e. the group sum costs ZERO extra passes over HBM —
the paper's entire point, expressed in the TPU memory hierarchy.

Grid: (B, m/bm, p/bp, G, n/bn) — the leading axis is the *batch* axis (one
independent GEMM per batched contraction element, e.g. attention heads or
MoE experts); the last two axes are reduction axes.  The output block
index_map ignores the reduction axes, so Pallas keeps the C tile resident in
VMEM while g and kn iterate (TPU grid order is sequential, minor-to-major
last axis fastest); it DOES depend on the batch axis, so each batch element
gets a fresh accumulator (init fires at g == kn == 0 for every b).

MXU alignment: bm/bp multiples of 128, bn a multiple of 128 (int8 lane
tiling is (32, 128); 128 keeps both operand tiles aligned).  Callers pick
tiles from the planner's static-shape autotune table
(``repro.core.plan.kernel_blocks`` via ``repro.kernels.ops.group_gemm``);
the DEFAULT_* here are only the bare-kernel fallbacks.

Rank-3 ``(G, m, n)`` operands are accepted as the unbatched special case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BP = 128
DEFAULT_BN = 512


def _group_gemm_kernel(a_ref, b_ref, c_ref):
    """One (bm, bn) x (bn, bp) int8 MAC into the resident int32 C tile."""
    g = pl.program_id(3)
    kn = pl.program_id(4)

    @pl.when((g == 0) & (kn == 0))
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[0] += jax.lax.dot_general(
        a_ref[0, 0], b_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bp", "bn", "interpret"))
def group_gemm(a8: jax.Array, b8: jax.Array, *, bm: int = DEFAULT_BM,
               bp: int = DEFAULT_BP, bn: int = DEFAULT_BN,
               interpret: bool = False) -> jax.Array:
    """sum_g a8[..., g, :, :] @ b8[..., g, :, :] -> int32.

    a8: (B, G, m, n) or (G, m, n) int8; b8: (B, G, n, p) or (G, n, p) int8.
    m/n/p must be multiples of the tiles (ops.py pads).  Caller guarantees
    G <= r (eq. 12) so INT32 cannot overflow — the sum is exact.  Returns
    (B, m, p) (or (m, p) for rank-3 inputs).
    """
    if a8.ndim == 3:
        return group_gemm(a8[None], b8[None], bm=bm, bp=bp, bn=bn,
                          interpret=interpret)[0]
    B, G, m, n = a8.shape
    B2, G2, n2, p = b8.shape
    assert B == B2 and G == G2 and n == n2, (a8.shape, b8.shape)
    assert m % bm == 0 and p % bp == 0 and n % bn == 0, (a8.shape, bm, bp, bn)
    grid = (B, m // bm, p // bp, G, n // bn)
    return pl.pallas_call(
        _group_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda b, i, j, g, kn: (b, g, i, kn)),
            pl.BlockSpec((1, 1, bn, bp), lambda b, i, j, g, kn: (b, g, kn, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bp), lambda b, i, j, g, kn: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, m, p), jnp.int32),
        interpret=interpret,
    )(a8, b8)
