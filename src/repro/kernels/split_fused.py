"""Pallas TPU kernel: fused k-slice extraction (paper steps i/ii).

The splitting step is memory-bound: Alg. 3/5/8 as written make k passes over
the operand (k HBM reads + k writes).  On GH200/RTX4090 the paper shows
"split A"/"split B" at 15-30 % of total time.  This kernel reads each input
tile into VMEM ONCE and emits all k INT8 slices from registers — an HBM
traffic reduction of ~k x for the read side (beyond-paper optimization; the
CUDA ozIMMU splits per-slice).

Row scales are precomputed by a cheap rowmax pass (one read, negligible next
to the extraction); the kernel consumes the per-row *reciprocal grid* and
performs either truncation (bitmask, Alg. 3) or round-to-nearest-even with
constant ratio (Alg. 8) extraction, entirely in the VPU.

Constant-grid mode (``const_grid=True``, the Ozaki-II shared-grid splits):
the reciprocal grid is ONE scalar for the whole matrix — a (1, 1) operand
whose BlockSpec pins every tile to the same element, so the per-row scale
vector is never materialized or streamed.  The extraction body is
unchanged (the scalar broadcasts), hence bit-identical to the per-row
kernel fed a constant vector.

The fast2 (improved-scaling) oz2 modes need NO kernel of their own: their
equilibrated digits are bitwise the per-row splitter's, so the wrapper
(``repro.kernels.ops.split_fused``) routes them through the per-row grid
path and only attaches the constant equilibrated base ``gbase = 2``.

Sign-magnitude mode (``mode="sm"``, the ozimmu_sm variants): floor
extraction with the sign carried only by the leading digit — the wrapper
passes ``invgrid = 2^(beta-1) / anchor`` so the first integer part is the
signed leading digit and every residual stays in [0, 1); trailing digits
are unsigned magnitudes stored mod 2^8 (``splitting.sm_decode``).

Layout: grid over (m/bm, n/bn) tiles; input tile (bm, bn) f32 in VMEM;
output (k, bm, bn) int8 in VMEM.  bn is a multiple of 128 (lane width),
bm a multiple of 8 (f32 sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BN = 512


def _split_kernel(a_ref, invgrid_ref, out_ref, *, k: int, beta: int,
                  mode: str):
    """Extract k slices of one (bm, bn) tile.

    a_ref:       (bm, bn) float — input tile (f32 on TPU; the interpret
                 path also runs f64 for the paper-faithful DGEMM emulation)
    invgrid_ref: (bm, 1)  float — 1 / grid_1 per row (power of two), or
                 (1, 1) in const-grid (oz2) mode — either broadcasts
    out_ref:     (k, bm, bn) int8 — slice digits
    """
    a = a_ref[...]
    inv = invgrid_ref[...]  # (bm, 1)
    two_beta = jnp.asarray(2.0 ** beta, a.dtype)
    # Normalize so slice-1 digits are the integer part (scale is a power of
    # two: exact).
    r = a * inv
    if mode == "bitmask":
        # r in (-2^beta, 2^beta) after normalization by grid = base*2^-beta
        for s in range(k):
            d = jnp.trunc(r)
            out_ref[s, :, :] = d.astype(jnp.int8)
            r = (r - d) * two_beta  # exact: subtraction aligned, pow2 scale
    elif mode == "sm":
        # sign-magnitude (splitting.split_sm): invgrid = 2^(beta-1)/anchor,
        # so floor(r) is the signed leading digit and every residual is
        # NONNEGATIVE — trailing digits are unsigned magnitudes in
        # [0, 2^beta - 1], stored mod 2^8 in the int8 output (decode with
        # splitting.sm_decode).  Same exact pow2-multiply + x - floor(x)
        # sequence as the library splitter: bit-identical digits.
        dmax = jnp.asarray(2.0 ** beta - 1.0, a.dtype)
        d = jnp.floor(r)
        out_ref[0, :, :] = d.astype(jnp.int8)
        r = (r - d) * two_beta
        for s in range(1, k):
            # min-clamp mirrors the library splitter: a tiny-negative lead
            # residual rounds to exactly 1.0, whose true digit cascade is
            # all 2^beta - 1 (bit-identical — see splitting._sm_extract)
            d = jnp.minimum(jnp.floor(r), dmax)
            out_ref[s, :, :] = jnp.where(d > 127.0, d - 256.0,
                                         d).astype(jnp.int8)
            r = (r - d) * two_beta
    else:  # round-to-nearest-even, constant ratio (Alg. 8)
        # native RN-even op (the paper's sigma trick is a CUDA workaround and
        # is unsafe under XLA:CPU fast-math constant folding — see core)
        for s in range(k):
            d = jnp.round(r)
            out_ref[s, :, :] = d.astype(jnp.int8)
            r = (r - d) * two_beta
    # residual bits beyond k*beta are discarded (the scheme's truncation V_k)


@functools.partial(jax.jit, static_argnames=("k", "beta", "mode", "bm", "bn",
                                             "const_grid", "interpret"))
def split_fused(a: jax.Array, invgrid: jax.Array, *, k: int, beta: int,
                mode: str = "rn_const", bm: int = DEFAULT_BM,
                bn: int = DEFAULT_BN, const_grid: bool = False,
                interpret: bool = False) -> jax.Array:
    """All-k-slice extraction of ``a`` (m, n) f32 with per-row 1/grid.

    Returns (k, m, n) int8.  ``invgrid`` must be ``1 / grid`` where
    ``grid = base * 2^-beta`` (bitmask) or ``mu`` (rn_const) — see ops.py,
    which also handles padding to tile multiples.  With
    ``const_grid=True``, ``invgrid`` is a (1, 1) scalar shared by every
    row (the oz2 constant-scaling mode).
    """
    m, n = a.shape
    assert m % bm == 0 and n % bn == 0, (a.shape, bm, bn)
    if const_grid:
        assert invgrid.shape == (1, 1), invgrid.shape
        inv_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    else:
        assert invgrid.shape == (m, 1)
        inv_spec = pl.BlockSpec((bm, 1), lambda i, j: (i, 0))
    grid = (m // bm, n // bn)
    kernel = functools.partial(_split_kernel, k=k, beta=beta, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            inv_spec,
        ],
        out_specs=pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((k, m, n), jnp.int8),
        interpret=interpret,
    )(a, invgrid)
