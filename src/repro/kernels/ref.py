"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def split_fused_ref(a: jax.Array, invgrid: jax.Array, *, k: int, beta: int,
                    mode: str = "rn_const") -> jax.Array:
    """Oracle for kernels.split_fused: (k, m, n) int8 digits."""
    two_beta = jnp.asarray(2.0 ** beta, a.dtype)
    r = a * invgrid
    outs = []
    if mode == "bitmask":
        for _ in range(k):
            d = jnp.trunc(r)
            outs.append(d.astype(jnp.int8))
            r = (r - d) * two_beta
    else:
        for _ in range(k):
            d = jnp.round(r)
            outs.append(d.astype(jnp.int8))
            r = (r - d) * two_beta
    return jnp.stack(outs)


def group_gemm_ref(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """Oracle for kernels.group_gemm: sum_g a8[g] @ b8[g] in int32."""
    prods = jax.lax.dot_general(
        a8, b8, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)
    return jnp.sum(prods, axis=0, dtype=jnp.int32)


def _two_sum(a, b):
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def scale_accum_ref(p32, srow, scol, c_hi, c_lo):
    """Oracle for kernels.scale_accum (df32 compensated accumulate) —
    the exact ``accumulate._scale_accum_df32`` operation sequence."""
    p = p32
    p_hi = (p >> 8) << 8
    p_lo = p - p_hi
    x_hi = p_hi.astype(jnp.float32) * srow * scol
    x_lo = p_lo.astype(jnp.float32) * srow * scol
    hi, err = _two_sum(c_hi, x_hi)
    lo = c_lo + err + x_lo
    return _two_sum(hi, lo)


def scale_accum_plain_ref(p32, srow, scol, c):
    """Oracle for kernels.scale_accum_plain (plain f64/f32 accumulate)."""
    return c + p32.astype(c.dtype) * srow * scol


def flash_attention_ref(q, k, v, *, group=1, causal=True, window=None,
                        lk=None, q_offset=0):
    """Oracle for kernels.flash_attention: naive full-softmax attention in
    the kernel's (BH, L, D) layout with GQA group mapping."""
    BH, Lq, D = q.shape
    Lk = k.shape[1]
    lk = Lk if lk is None else lk
    kg = jnp.repeat(k, group, axis=0)
    vg = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bsd->bqs", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) * D ** -0.5
    q_pos = jnp.arange(Lq)[:, None] + q_offset
    k_pos = jnp.arange(Lk)[None, :]
    mask = k_pos < lk
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqs,bsd->bqd", p.astype(vg.dtype), vg)
    return o.astype(q.dtype)
