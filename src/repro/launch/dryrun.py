import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below may now import jax.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_7b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun                   # the full table

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` with:
  memory_analysis (bytes per device), cost_analysis (FLOPs/bytes),
  per-kind collective bytes, the three roofline terms, and metadata.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from dataclasses import replace as dataclasses_replace
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, optim
from repro.configs import SHAPES
from repro.distributed import compat
from repro.distributed.sharding import spec_tree, use_rules
from repro.launch import hlo_cost, roofline, steps
from repro.launch.mesh import make_production_mesh, mesh_rules
from repro.models import api

# Per-arch training knobs for the big cells: microbatch count at train_4k
# (global batch 256).  Derived from the memory iteration in EXPERIMENTS.md.
TRAIN_MICROBATCHES = {
    "deepseek_v2_236b": 16,
    "llama32_vision_11b": 8,
    "deepseek_moe_16b": 8,
    "deepseek_7b": 8,
    "recurrentgemma_9b": 8,
    "starcoder2_3b": 4,
    "phi4_mini_3_8b": 4,
    "internlm2_1_8b": 2,
    "mamba2_780m": 2,
    "seamless_m4t_medium": 2,
}

# master_f32 off for the very large configs (memory table in EXPERIMENTS.md)
NO_MASTER = {"deepseek_v2_236b", "llama32_vision_11b"}

# 236B-scale state-dtype policy on a single 256-chip pod (16 GiB/chip):
# bf16 params (f32 update computed on the fly), bf16 moments, bf16 grad
# accumulation, remat_block 10.  Documented trade-off in EXPERIMENTS §Perf;
# on >=2 pods the f32 policy fits via ZeRO over (pod, data).
# bf16 moments crash XLA:CPU ("Invalid binary instruction opcode
# copy" check failure) — a CPU-backend bug; policy documented in
# EXPERIMENTS §Perf, moments stay f32 in the dry-run.
TRAIN_STATE_DTYPE = {}
TRAIN_ACCUM_DTYPE = {}
PARAM_BF16 = set()
REMAT_BLOCK = {"deepseek_v2_236b": 10}


def opt_config_for(arch: str) -> optim.OptConfig:
    return optim.OptConfig(master_f32=arch not in NO_MASTER)


def _serving_dtype(pshapes):
    """Serving loads a bf16 checkpoint (params are never updated)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if jnp.issubdtype(s.dtype, jnp.floating) else s, pshapes)


def lower_cell(arch: str, shape_name: str, mesh, *, engine: str = "bf16",
               donate: bool = True, extra_overrides=None):
    """Lower one cell on ``mesh``; returns (lowered, meta dict)."""
    overrides = dict(extra_overrides or {})
    if shape_name == "train_4k" and arch in REMAT_BLOCK:
        overrides.setdefault("remat_block", REMAT_BLOCK[arch])
    cfg = configs.get_config(arch, engine_spec=engine, **overrides)
    shape = SHAPES[shape_name]
    rules = mesh_rules(mesh, arch)
    model = api.get_model(cfg)
    t0 = time.time()

    with compat.set_mesh(mesh), use_rules(rules):
        pshapes, axes = steps.params_shapes(cfg)
        n_params = roofline.count_params(pshapes)
        p_spec = spec_tree(axes, rules)

        if shape.kind == "train":
            if arch in PARAM_BF16:
                pshapes = _serving_dtype(pshapes)
            opt_cfg = opt_config_for(arch)
            state_dt = jnp.dtype(TRAIN_STATE_DTYPE.get(arch, "float32"))
            opt_cfg = dataclasses_replace(opt_cfg,
                                          state_dtype=str(state_dt))
            opt_axes = optim.zero_axes(axes, pshapes,
                                       mesh.shape.get("data", 1))
            tcfg = steps.TrainConfig(
                microbatches=TRAIN_MICROBATCHES.get(arch, 1),
                accum_dtype=TRAIN_ACCUM_DTYPE.get(arch, "float32"))
            train_step = steps.make_train_step(cfg, opt_cfg, tcfg,
                                               opt_axes=opt_axes)
            m_spec = spec_tree(opt_axes, rules)
            state_spec = steps.TrainState(
                p_spec,
                optim.OptState(m_spec, m_spec,
                               m_spec if opt_cfg.master_f32 else None, P()),
                P())
            state_shapes = jax.eval_shape(
                lambda: steps.TrainState(
                    pshapes,
                    optim.OptState(
                        jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                            s.shape, state_dt), pshapes),
                        jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                            s.shape, state_dt), pshapes),
                        (jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                            s.shape, jnp.float32), pshapes)
                         if opt_cfg.master_f32 else None),
                        jax.ShapeDtypeStruct((), jnp.int32)),
                    jax.ShapeDtypeStruct((), jnp.int32)))
            batch = steps.batch_specs(cfg, shape)
            batch_spec = {k: P(rules["batch"]) for k in batch}
            state_spec = steps.evenize(state_spec, state_shapes, mesh)
            batch_spec = steps.evenize(batch_spec, batch, mesh)
            fn = jax.jit(
                train_step,
                in_shardings=(steps.named(mesh, state_spec),
                              steps.named(mesh, batch_spec)),
                out_shardings=(steps.named(mesh, state_spec), None),
                donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_shapes, batch)

        elif shape.kind == "prefill":
            pshapes = _serving_dtype(pshapes)      # bf16 serving checkpoint
            prefill = steps.make_prefill_step(cfg)
            batch = steps.batch_specs(cfg, shape)
            batch_spec = {k: P(rules["batch"]) for k in batch}
            p_spec_e = steps.evenize(p_spec, pshapes, mesh)
            batch_spec = steps.evenize(batch_spec, batch, mesh)
            fn = jax.jit(prefill,
                         in_shardings=(steps.named(mesh, p_spec_e),
                                       steps.named(mesh, batch_spec)),
                         out_shardings=None)
            lowered = fn.lower(pshapes, batch)

        else:  # decode
            pshapes = _serving_dtype(pshapes)      # bf16 serving checkpoint
            decode = steps.make_decode_step(cfg)
            cache, tokens, cur_len = steps.decode_input_specs(cfg, shape)
            cache_spec = spec_tree(model.cache_axes(cfg), rules)
            p_spec_e = steps.evenize(p_spec, pshapes, mesh)
            cache_spec = steps.evenize(cache_spec, cache, mesh)
            tok_spec = steps.evenize(P(rules["cache_batch"]), tokens, mesh)
            fn = jax.jit(
                decode,
                in_shardings=(steps.named(mesh, p_spec_e),
                              steps.named(mesh, cache_spec),
                              NamedSharding(mesh, tok_spec),
                              NamedSharding(mesh, P())),
                out_shardings=None,
                donate_argnums=(1,) if donate else ())
            lowered = fn.lower(pshapes, cache, tokens, cur_len)

    meta = {"arch": arch, "shape": shape_name, "n_params": n_params,
            "lower_s": time.time() - t0}
    return lowered, cfg, shape, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir=None, engine: str = "bf16", verbose: bool = True,
             extra_overrides=None):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    lowered, cfg, shape, meta = lower_cell(arch, shape_name, mesh,
                                           engine=engine,
                                           extra_overrides=extra_overrides)
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()          # loop-blind; recorded for ref
    hlo = compiled.as_text()
    percore = hlo_cost.analyze(hlo)          # loop-aware per-device totals
    flops = percore["flops"] * chips
    byts = percore["bytes"] * chips
    coll = {k: v * chips
            for k, v in percore["collective_operand_bytes"].items()}
    ici = percore["collective_ici_bytes"] * chips
    n_active = roofline.active_params(cfg, meta["n_params"])
    mflops = roofline.model_flops_for(cfg, shape, meta["n_params"], n_active)

    rl = roofline.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes=ici, coll_breakdown={k: int(v) for k, v in coll.items()},
        model_flops=mflops)

    mem_attrs = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_attrs[attr] = int(v)

    xla_flops, xla_bytes = roofline.cost_flops_bytes(cost)
    record = {
        **meta,
        "mesh": mesh_name, "chips": chips, "engine": engine,
        "compile_s": compile_s,
        "memory_analysis": mem_attrs or str(mem),
        "hlo_flops_global": flops, "hlo_bytes_global": byts,
        "collective_operand_bytes": {k: int(v) for k, v in coll.items()},
        "collective_ici_bytes": ici,
        "dot_flops_global": percore["dot_flops"] * chips,
        "xla_cost_analysis_flops_looplblind": xla_flops,
        "xla_cost_analysis_bytes_loopblind": xla_bytes,
        "roofline": rl.to_dict(),
        "n_active_params": n_active,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"compile {compile_s:.1f}s  "
              f"flops {flops:.3e}  bytes {byts:.3e}  "
              f"coll {sum(coll.values()):.3e}  "
              f"bottleneck {rl.bottleneck}  "
              f"t_bound {rl.t_bound * 1e3:.3f} ms  "
              f"mfu_bound {rl.mfu_bound:.3f}")
        print("  memory_analysis:", mem_attrs or mem)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--engine", default="bf16")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    cells = (list(configs.arch_shape_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        skips = configs.skipped_shapes(arch)
        if shape in skips:
            print(f"[{arch} x {shape}] SKIP: {skips[shape]}")
            continue
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            if args.skip_existing and args.out and os.path.exists(
                    os.path.join(args.out,
                                 f"{arch}__{shape}__{mesh_name}.json")):
                print(f"[{arch} x {shape} x {mesh_name}] exists, skipping")
                continue
            try:
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         engine=args.engine)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
