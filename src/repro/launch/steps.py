"""Step functions + input specs for every (arch x shape) cell.

``make_train_step`` builds the jit-able ``train_step(state, batch)`` with
microbatched gradient accumulation (scan), optional int8 gradient
compression across the pod axis, and ZeRO-1 sharded optimizer updates.
``make_prefill_step`` / ``make_decode_step`` build the serving steps.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the cell — weak-type-correct, shardable, no allocation —
used by the multi-pod dry-run and the roofline benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import SHAPES, Shape
from repro.distributed.sharding import (get_rules, logical_to_pspec,
                                        spec_tree, shard, use_rules)
from repro.models import api
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1            # gradient-accumulation chunks per step
    accum_dtype: str = "float32"     # grad accumulation buffer dtype
    compress_pod_grads: bool = False # int8+EF all-reduce across "pod"


class TrainState(NamedTuple):
    params: Any
    opt: optim.OptState
    step: jax.Array


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------

def make_state_axes(cfg: ModelConfig, params_shape, axes, opt_cfg,
                    zero_divisor: int):
    """Logical-axes trees for (params, opt state) incl. ZeRO augmentation."""
    opt_axes = optim.zero_axes(axes, params_shape, zero_divisor)
    master_axes = opt_axes if opt_cfg.master_f32 else None
    return axes, opt_axes, master_axes


def state_specs(cfg: ModelConfig, axes, opt_axes, opt_cfg, rules):
    """PartitionSpec pytree matching TrainState."""
    p_spec = spec_tree(axes, rules)
    m_spec = spec_tree(opt_axes, rules)
    master = m_spec if opt_cfg.master_f32 else None
    return TrainState(p_spec,
                      optim.OptState(m_spec, jax.tree.map(lambda s: s, m_spec),
                                     master, P()),
                      P())


def init_state(rng, cfg: ModelConfig, opt_cfg: optim.OptConfig,
               zero_divisor: int = 1):
    model = api.get_model(cfg)
    params, axes = model.init(rng, cfg)
    shapes = jax.tree.map(lambda x: x, params)
    _, opt_axes, _ = make_state_axes(cfg, shapes, axes, opt_cfg, zero_divisor)
    opt_state = optim.init(params, opt_axes if get_rules() else None, opt_cfg)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32)), axes, opt_axes


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: optim.OptConfig,
                    tcfg: TrainConfig = TrainConfig(), opt_axes=None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    model = api.get_model(cfg)
    acc_dt = jnp.dtype(tcfg.accum_dtype)

    def loss_fn(params, mb):
        logits = model.forward(params, cfg, mb)
        return api.next_token_loss(logits, mb["tokens"])

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        n_mb = tcfg.microbatches

        if n_mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            def split_mb(x):
                # STRIDED split: device d owns a contiguous slab of the batch
                # axis, so reshape(n_mb, B/n_mb) would put whole microbatches
                # onto a fraction of the data axis (measured: 2x activation
                # footprint + resharding).  Strided assignment keeps every
                # microbatch evenly spread across the data axis.
                B = x.shape[0]
                assert B % n_mb == 0, (B, n_mb)
                return x.reshape(B // n_mb, n_mb, *x.shape[1:]).swapaxes(0, 1)

            mbs = jax.tree.map(split_mb, batch)
            mbs = jax.tree.map(lambda x: shard(x, None, "batch"), mbs)

            def accum(carry, mb):
                loss_c, grads_c = carry
                # keep each microbatch's activations data-sharded
                mb = jax.tree.map(lambda x: shard(x, "batch"), mb)
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), grads_c, grads)
                return (loss_c + loss, grads), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params)
            (loss, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zeros), mbs)
            loss = loss / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)

        new_params, new_opt, metrics = optim.step(
            grads, state.params, state.opt, opt_cfg, state_axes=opt_axes)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    """prefill(params, batch) -> last-position logits (B, vocab)."""
    model = api.get_model(cfg)

    def prefill_step(params, batch):
        logits = model.forward(params, cfg, batch)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """decode(params, cache, tokens, cur_len) -> (next_token, logits, cache)."""
    model = api.get_model(cfg)

    def decode_step(params, cache, tokens, cur_len):
        logits, cache = model.decode_step(params, cfg, cache, tokens, cur_len)
        # argmax over the LOGICAL vocab (pad columns never sampled)
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, Any]:
    B, L = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((B, L), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_seq, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct((B, L, cfg.d_model), jnp.float32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: Shape):
    """(cache, tokens, cur_len) ShapeDtypeStructs for serve_step."""
    model = api.get_model(cfg)
    B, L = shape.global_batch, shape.seq_len
    ctx = None
    if cfg.family == "vlm":
        ctx = jax.ShapeDtypeStruct((B, cfg.vision_seq, cfg.d_model),
                                   jnp.float32)
    if cfg.family == "encdec":
        ctx = jax.ShapeDtypeStruct((B, L, cfg.d_model), jnp.float32)
    cache = jax.eval_shape(
        lambda: model.init_cache(cfg, B, L, params=None,
                                 ctx=None if ctx is None else None))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cur_len = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, cur_len


def input_specs(cfg: ModelConfig, shape_name: str):
    """All model inputs of the (cfg, shape) cell as ShapeDtypeStructs."""
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def params_shapes(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical axes) without allocating.

    ``axes`` leaves are strings (not arrays) so they ride out of
    ``eval_shape`` through a closure side-channel."""
    model = api.get_model(cfg)
    box = {}

    def f(r):
        p, a = model.init(r, cfg)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["axes"]


def named(mesh, spec_pytree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_pytree,
        is_leaf=lambda x: isinstance(x, P))


def evenize(spec_pytree, shapes_pytree, mesh):
    """Drop mesh axes from arg PartitionSpecs where the dim isn't divisible.

    jit arg shardings require exact divisibility (unlike constraints, which
    pad).  E.g. the ``long_500k`` cell has global_batch=1: its ``batch ->
    data`` rule is unsatisfiable and must fall back to replication for that
    dim; kv=8 heads can't split 16 ways; etc.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, shape):
        if not isinstance(spec, P):
            return spec
        dims = tuple(shape.shape) if hasattr(shape, "shape") else tuple(shape)
        out = []
        for i, entry in enumerate(spec):
            if entry is None or i >= len(dims):
                out.append(None if i >= len(dims) else entry)
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            keep = []
            prod = 1
            for ax in axes:
                if dims[i] % (prod * sizes[ax]) == 0:
                    keep.append(ax)
                    prod *= sizes[ax]
            out.append(None if not keep
                       else (keep[0] if len(keep) == 1 else tuple(keep)))
        return P(*out)

    return jax.tree.map(fix, spec_pytree, shapes_pytree,
                        is_leaf=lambda x: isinstance(x, P))
