"""Batched serving driver: continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2_1_8b \
        --smoke --batch 4 --prompt-len 32 --gen 32

Serving model: a slot-based continuous batcher.  Each of ``batch`` slots
holds one request; when a request finishes (EOS or budget), the slot is
refilled from the queue without stopping the decode loop — the standard
production pattern (vLLM-style), expressed with fixed shapes so a single
compiled ``decode_step`` serves throughout.  Prefill runs per-request via
teacher-forced decode of the prompt into the slot's cache region.
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import compat
from repro.distributed.sharding import use_rules
from repro.launch import steps as S
from repro.launch.mesh import mesh_rules, parse_mesh_spec
from repro.models import api


class Server:
    def __init__(self, cfg, params, max_len: int = 512, batch: int = 4):
        self.cfg, self.params = cfg, params
        self.model = api.get_model(cfg)
        self.max_len, self.batch = max_len, batch
        self._decode = jax.jit(
            lambda c, t, n: self.model.decode_step(params, cfg, c, t, n))

    def generate(self, prompts: List[np.ndarray], gen_tokens: int = 32,
                 ctx=None):
        """Greedy-decode a batch of token prompts (list of 1-D int arrays)."""
        B = len(prompts)
        assert B <= self.batch
        # pad batch to fixed slot count
        prompts = prompts + [prompts[-1]] * (self.batch - B)
        max_prompt = max(len(p) for p in prompts)
        cache = self.model.init_cache(self.cfg, self.batch, self.max_len,
                                      params=self.params, ctx=ctx)
        # prefill: teacher-force prompt tokens (per-position decode keeps a
        # single compiled step; a chunked prefill is the next optimization)
        toks = np.zeros((self.batch, max_prompt), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p  # left-aligned
        logits = None
        for t in range(max_prompt):
            logits, cache = self._decode(
                cache, jnp.asarray(toks[:, t:t + 1]),
                jnp.asarray(t + 1, jnp.int32))
        out = [list(p) for p in prompts]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for g in range(gen_tokens):
            for i in range(self.batch):
                out[i].append(int(cur[i]))
            logits, cache = self._decode(
                cache, cur[:, None], jnp.asarray(max_prompt + g + 1,
                                                 jnp.int32))
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return [np.asarray(o) for o in out[:B]]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--engine", "--matmul_engine", dest="engine",
                    default="bf16",
                    help="matmul engine spec, e.g. bf16, ozimmu_h-8:df32@model "
                         "or ozimmu_h-auto:df32:fused (auto-k planner + fused "
                         "Pallas pipeline; docs/engine.md)")
    ap.add_argument("--mesh", default=None,
                    help="mesh spec: 'data=2,model=4', 'single_pod', "
                         "'multi_pod'; default no mesh (single device)")
    args = ap.parse_args(argv)

    mesh = parse_mesh_spec(args.mesh)
    rules = mesh_rules(mesh, args.arch) if mesh is not None else None
    import contextlib
    mesh_ctx = (compat.set_mesh(mesh) if mesh is not None
                else contextlib.nullcontext())
    cfg = configs.get_config(args.arch, smoke=True, engine_spec=args.engine)
    oz_cfg = cfg.engine.ozimmu_config
    if oz_cfg is not None:
        from repro.core import plan
        print(f"[serve] engine {args.engine}: "
              f"{plan.describe_config(oz_cfg, cfg.d_model, cfg.d_model, cfg.d_model)}")
    with mesh_ctx, use_rules(rules):
        model = api.get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        ctx = None
        if cfg.family == "vlm":
            ctx = jnp.zeros((args.batch, cfg.vision_seq, cfg.d_model),
                            jnp.float32)
        if cfg.family == "encdec":
            from repro.models import encdec
            frames = jnp.zeros((args.batch, args.prompt_len, cfg.d_model),
                               jnp.float32)
            ctx = encdec.encode(params, cfg, frames)
        server = Server(cfg, params, max_len=args.max_len, batch=args.batch)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len,
                                dtype=np.int32) for _ in range(args.batch)]
        t0 = time.time()
        outs = server.generate(prompts, gen_tokens=args.gen, ctx=ctx)
        dt = time.time() - t0
    total_new = args.gen * args.batch
    print(f"[serve] {args.arch}: {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, batch={args.batch})")
    print("[serve] sample continuation:", outs[0][-args.gen:][:16])


if __name__ == "__main__":
    main()
