"""Serving driver over the continuous-batching runtime.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2_1_8b \
        --requests 8 --slots 4 --prompt-len 32 --gen 32 \
        --engine ozimmu_h-8:df32 --page-block 16

The heavy lifting lives in :mod:`repro.serving` (docs/serving.md): a
slot-based continuous batcher with bucketed batched prefill (mixed-length
prompts share one compiled call), an optional block-paged KV pool
(``--page-block``), and — for ozimmu engines — the persistent weight
split-cache: every projection weight is frozen into its int8 digit
slices once at startup, so decode steps skip the B-side splitter
entirely (bit-identical; the dominant per-step splitting cost at
decode).  ``--no-presplit`` disables the cache for A/B comparison.
"""
from __future__ import annotations

import argparse
import contextlib
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import compat
from repro.distributed.sharding import use_rules
from repro.launch.mesh import mesh_rules, parse_mesh_spec
from repro.models import api
from repro.obs import export as obs_export
from repro.obs import tracing as obs_tracing
from repro.serving import ServingRuntime


def make_runtime(cfg, params, *, slots: int, max_len: int,
                 page_block: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 presplit: Optional[bool] = None, ctx=None) -> ServingRuntime:
    return ServingRuntime(cfg, params, slots=slots, max_len=max_len,
                          page_block=page_block, prefill_chunk=prefill_chunk,
                          prefix_cache=prefix_cache, presplit=presplit,
                          ctx=ctx)


def slot_context(cfg, params, prompt_len: int):
    """Static single-slot context for the vlm/encdec families (shared
    across slots, matching the pre-runtime driver)."""
    if cfg.family == "vlm":
        return jnp.zeros((1, cfg.vision_seq, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jnp.zeros((1, prompt_len, cfg.d_model), jnp.float32)
        return encdec.encode(params, cfg, frames)
    return None


def dump_metrics(path: str, runtime: ServingRuntime,
                 final: bool = False) -> None:
    """Write the unified metrics document (global registry merged with the
    runtime's private serving registry, plus the plan ledger) to ``path``.
    Final dumps embed the serving summary and decode-observed counters."""
    snap = obs_export.unified_snapshot(runtime.metrics.registry)
    extra = None
    if final:
        extra = {"serving_summary": runtime.metrics.summary()}
        if runtime.decode_observed is not None:
            extra["decode_observed"] = runtime.decode_observed
    text = obs_export.to_json(snap, extra=extra)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text + "\n")
    import os
    os.replace(tmp, path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4,
                    help="decode slots (compiled batch dimension)")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests to serve (default: slots, i.e. one "
                         "full wave)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-block", type=int, default=None,
                    help="positions per KV block: enables the paged "
                         "KV-cache pool (every family; state leaves stay "
                         "resident per the family descriptor)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="max prompt tokens fed per slot per scheduler "
                         "round (chunked prefill; default whole-prompt)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cache shared prompt prefixes as frozen paged "
                         "blocks (requires --page-block)")
    ap.add_argument("--no-presplit", action="store_true",
                    help="disable the weight split-cache (A/B baseline; "
                         "ozimmu engines only)")
    ap.add_argument("--engine", "--matmul_engine", dest="engine",
                    default="bf16",
                    help="matmul engine spec, e.g. bf16, ozimmu_h-8:df32@model "
                         "or ozimmu_h-auto:df32:fused (auto-k planner + fused "
                         "Pallas pipeline; docs/engine.md)")
    ap.add_argument("--mesh", default=None,
                    help="mesh spec: 'data=2,model=4', 'single_pod', "
                         "'multi_pod'; default no mesh (single device)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the unified metrics document (registry "
                         "snapshot + plan ledger + serving summary) to "
                         "PATH; with --metrics-every also periodically "
                         "during the run")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="dump --metrics-json every N scheduler rounds "
                         "(0 = final dump only)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax profiler trace of the serving "
                         "loop into DIR (view with TensorBoard/Perfetto)")
    args = ap.parse_args(argv)
    n_requests = args.requests if args.requests is not None else args.slots

    mesh = parse_mesh_spec(args.mesh)
    rules = mesh_rules(mesh, args.arch) if mesh is not None else None
    mesh_ctx = (compat.set_mesh(mesh) if mesh is not None
                else contextlib.nullcontext())
    cfg = configs.get_config(args.arch, smoke=True, engine_spec=args.engine)
    oz_cfg = cfg.engine.ozimmu_config
    if oz_cfg is not None:
        from repro.core import plan
        print(f"[serve] engine {args.engine}: "
              f"{plan.describe_config(oz_cfg, cfg.d_model, cfg.d_model, cfg.d_model)}")
    with mesh_ctx, use_rules(rules):
        model = api.get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        ctx = slot_context(cfg, params, args.prompt_len)
        runtime = make_runtime(
            cfg, params, slots=args.slots, max_len=args.max_len,
            page_block=args.page_block, prefill_chunk=args.prefill_chunk,
            prefix_cache=args.prefix_cache,
            presplit=False if args.no_presplit else None, ctx=ctx)
        if runtime.split_cache is not None:
            st = runtime.split_cache.stats
            print(f"[serve] split-cache: froze {st.misses} weight splits "
                  f"({st.cached_bytes / 1e6:.2f} MB resident)")
        from repro.core import plan as _plan
        if len(_plan.get_ledger()):
            print(f"[serve] planner: {_plan.get_ledger().describe()}")
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len,
                                dtype=np.int32) for _ in range(n_requests)]
        t0 = time.time()
        reqs = [runtime.submit(p, args.gen) for p in prompts]
        with obs_tracing.profile(args.profile_dir):
            rounds = 0
            while runtime.step():
                rounds += 1
                if (args.metrics_json and args.metrics_every
                        and rounds % args.metrics_every == 0):
                    dump_metrics(args.metrics_json, runtime)
        runtime.run()  # no rounds left; finalizes the metrics window
        outs = [np.concatenate([r.prompt,
                                np.asarray(r.generated, np.int32)])
                for r in reqs]
        dt = time.time() - t0
    s = runtime.metrics.summary()
    if args.metrics_json:
        dump_metrics(args.metrics_json, runtime, final=True)
        print(f"[serve] metrics written to {args.metrics_json}")
    print(f"[serve] {args.arch}: {s['tokens_generated']} tokens from "
          f"{s['requests']['finished']} requests in {dt:.2f}s "
          f"({s['tokens_per_s']:.1f} tok/s, slots={args.slots}, "
          f"prefill_calls={s['prefill_calls']}, "
          f"evictions={s['evictions']})")
    if s["ttft_s"]["mean"] is not None:
        print(f"[serve] TTFT mean {s['ttft_s']['mean']:.3f}s "
              f"p95 {s['ttft_s']['p95']:.3f}s; queue depth max "
              f"{s['queue_depth']['max']}")
    if s["split_cache"] is not None:
        sc = s["split_cache"]
        print(f"[serve] split-cache: weight-split hit rate "
              f"{sc['weight_split_hit_rate']:.2f}, "
              f"{sc['avoided_split_bytes'] / 1e6:.2f} MB of decode-time "
              f"re-splitting avoided")
    if s.get("prefix_cache") is not None:
        pc = s["prefix_cache"]
        print(f"[serve] prefix-cache: hit rate {pc['hit_rate']:.2f} "
              f"({pc['hit_tokens']} prefill tokens aliased, "
              f"{pc['entries']} entries)")
    if runtime.decode_observed is not None:
        obs = runtime.decode_observed
        print(f"[serve] observed per decode step: "
              f"{obs['contractions']:.0f} contractions, "
              f"{obs['int8_gemms']:.0f} int8 GEMMs "
              f"({obs['int8_gemms_presplit']:.0f} on presplit weights), "
              f"{obs['highprec_adds']:.0f} high-precision adds")
    print("[serve] sample continuation:",
          outs[0][-args.gen:][:16].tolist())
    return s


if __name__ == "__main__":
    main()
