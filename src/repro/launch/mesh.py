"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initializes, while tests/benches run on the single real CPU device.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: Optional[int] = None):
    """Small mesh over however many (host) devices tests forced."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: Optional[str]):
    """CLI mesh grammar: ``"data=2,model=4"`` (ordered ``axis=size`` pairs)
    or the named presets ``"single_pod"`` / ``"multi_pod"``; ``None`` / ""
    -> no mesh (single device).

    Axis names must be mesh-rule axes the rest of the stack knows
    ("pod", "data", "model"); sizes must multiply to at most the available
    device count.  Returns a Mesh or None.
    """
    if not spec:
        return None
    if spec == "single_pod":
        return make_production_mesh()
    if spec == "multi_pod":
        return make_production_mesh(multi_pod=True)
    axes, sizes = [], []
    for part in spec.split(","):
        name, _, size = part.partition("=")
        name, size = name.strip(), size.strip()
        if name not in ("pod", "data", "model") or not size.isdigit() \
                or int(size) < 1 or name in axes:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected unique 'axis=size' pairs "
                f"with axes from pod/data/model and size >= 1, got {part!r}")
        axes.append(name)
        sizes.append(int(size))
    ndev = len(jax.devices())
    total = 1
    for s in sizes:
        total *= s
    if total > ndev:
        raise ValueError(f"mesh spec {spec!r} needs {total} devices, "
                         f"only {ndev} available")
    return jax.make_mesh(tuple(sizes), tuple(axes))


def mesh_rules(mesh, arch: Optional[str] = None):
    """Pick the logical->mesh rule table for a mesh (+ per-arch overrides)."""
    from repro.distributed.sharding import MULTI_POD_RULES, SINGLE_POD_RULES
    rules = dict(MULTI_POD_RULES if "pod" in mesh.axis_names
                 else SINGLE_POD_RULES)
    if arch is not None:
        from repro import configs
        rules.update(configs.rules_overrides(arch))
    return rules
