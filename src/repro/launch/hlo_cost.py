"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE (trip counts
are ignored), which silently undercounts scanned programs by orders of
magnitude (layer scans, microbatch scans, flash-attention chunk scans).
This module re-derives FLOPs / HBM-traffic / collective bytes by walking the
computation graph and multiplying loop bodies by their
``backend_config={"known_trip_count": ...}`` (emitted by XLA for all
jax.lax.scan-derived loops).

All numbers are PER-DEVICE (the SPMD module is the per-device program);
callers multiply by chip count for global figures.

Conventions:
  * flops: dots count 2*result_elems*K exactly; cheap elementwise ops count
    1 flop/element; bookkeeping ops (bitcast, tuple, GTE, ...) count 0.
  * bytes: per materialized instruction, operands + output (the standard
    "bytes accessed" convention); fusion bodies are NOT expanded (a fusion
    reads its operands and writes its output once — that is the point of
    fusion).  This is an upper-bound HBM-traffic proxy: VMEM-resident reuse
    between instructions is not modeled.
  * collectives: per kind, summed operand bytes (the assignment's metric)
    plus a ring-model ICI traffic estimate used for the roofline term:
        all-reduce       2 * operand * (N-1)/N
        all-gather       result  * (N-1)/N
        reduce-scatter   operand * (N-1)/N
        all-to-all       operand * (N-1)/N
        collective-permute  operand
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_ELEMWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "tanh", "logistic", "sine", "cosine", "power",
    "floor", "ceil", "round-nearest-even", "round-nearest-afz", "sign",
    "compare", "select", "clamp", "and", "or", "xor", "not", "remainder",
    "atan2", "cbrt", "erf", "expm1", "log1p",
}

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "rng-get-and-update-state",
    "get-dimension-size", "domain", "opt-barrier",
}

_NO_TRAFFIC = _ZERO_COST | {"broadcast", "iota", "reshape"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr/param name -> type string


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
                       r"(?:\{[^}]*\})?))")


def _split_type_rest(s: str) -> Tuple[str, str]:
    """'f32[2]{1,0} dot(%a, %b), attrs' -> ('f32[2]{1,0}', 'dot(%a...')."""
    s = s.strip()
    if s.startswith("("):
        depth = 0
        for i, c in enumerate(s):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return s[:i + 1], s[i + 1:].strip()
    i = s.find(" ")
    return s[:i], s[i + 1:].strip()


def _parse_call(rest: str) -> Tuple[str, List[str], str]:
    """'dot(%a, %b), attrs' -> ('dot', ['a', 'b'], attrs)."""
    i = rest.find("(")
    opcode = rest[:i].strip()
    depth = 0
    for j in range(i, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                break
    args = rest[i + 1:j]
    attrs = rest[j + 1:].lstrip(", ")
    operands = re.findall(r"%([\w.\-]+)", args)
    return opcode, operands, attrs


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):  # computation header or module line
            if line.startswith("HloModule"):
                continue
            if line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line)
                if m:
                    cur = Computation(m.group(1), [], {})
                    comps[cur.name] = cur
                    if line.startswith("ENTRY"):
                        entry_name = cur.name
                    for pname, ptype in _PARAM_RE.findall(m.group(2)):
                        cur.symbols[pname] = ptype
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        if line.strip() == "}" or line.strip().startswith("}"):
            cur = None
            continue
        m = _INSTR_LINE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        try:
            type_str, callpart = _split_type_rest(rest)
            opcode, operands, attrs = _parse_call(callpart)
        except Exception:
            continue
        cur.symbols[name] = type_str
        cur.instrs.append(Instr(name, type_str, opcode, operands, attrs))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"(?:true_computation=%?([\w.\-]+).*?"
                          r"false_computation=%?([\w.\-]+)|"
                          r"branch_computations=\{([^}]*)\})")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _group_size(attrs: str) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _lookup(comps, comp: Computation, name: str) -> str:
    if name in comp.symbols:
        return comp.symbols[name]
    for c in comps.values():
        if name in c.symbols:
            return c.symbols[name]
    return ""


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_operand_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_ici_bytes: float = 0.0
    dot_flops: float = 0.0
    int8_dot_flops: float = 0.0   # dots with s8/u8 operands (2x MXU peak)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.dot_flops += mult * other.dot_flops
        self.int8_dot_flops += mult * other.int8_dot_flops
        self.coll_ici_bytes += mult * other.coll_ici_bytes
        for k in _COLLECTIVES:
            self.coll_operand_bytes[k] += mult * other.coll_operand_bytes[k]


class HloCostModel:
    def __init__(self, text: str, track_top: bool = False):
        self.comps = parse_hlo(text)
        self._memo: Dict[Tuple[str, bool], CostTotals] = {}
        self.track_top = track_top
        self.top: Dict[Tuple[str, str], float] = {}

    def entry_totals(self) -> CostTotals:
        if not self.track_top:
            return self._comp_cost("__entry__", fusion_ctx=False)
        # slower path: walk with explicit multipliers for attribution
        tot = CostTotals()
        self._walk("__entry__", False, 1.0, tot)
        return tot

    def _walk(self, comp_name, fusion_ctx, mult, tot):
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                trip = 1
                m = _TRIP_RE.search(ins.attrs)
                if m:
                    trip = int(m.group(1))
                b = _BODY_RE.search(ins.attrs)
                c = _COND_RE.search(ins.attrs)
                if b:
                    self._walk(b.group(1), False, mult * trip, tot)
                if c:
                    self._walk(c.group(1), False, mult * trip, tot)
                continue
            sub = CostTotals()
            self._instr_cost(comp, ins, sub, fusion_ctx)
            tot.add(sub, mult)
            if sub.bytes and not fusion_ctx:
                meta = ""
                if "metadata=" in ins.attrs:
                    i = ins.attrs.find("op_name=")
                    if i >= 0:
                        meta = ins.attrs[i + 9:i + 90].split('"')[0]
                key = (op + " " + ins.type_str.split("{")[0][:40], meta[-60:])
                self.top[key] = self.top.get(key, 0.0) + mult * sub.bytes
            if op == "fusion":
                pass  # flops recursed inside _instr_cost already

    # ------------------------------------------------------------------
    def _comp_cost(self, comp_name: str, fusion_ctx: bool) -> CostTotals:
        key = (comp_name, fusion_ctx)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        tot = CostTotals()
        if comp is None:
            self._memo[key] = tot
            return tot
        # insert early to break cycles (shouldn't happen in HLO, but safe)
        self._memo[key] = tot
        for ins in comp.instrs:
            self._instr_cost(comp, ins, tot, fusion_ctx)
        return tot

    def _instr_cost(self, comp, ins: Instr, tot: CostTotals,
                    fusion_ctx: bool):
        op = ins.opcode
        base = op.replace("-start", "").replace("-done", "")

        if base in _COLLECTIVES:
            if op.endswith("-done"):
                return
            operand_b = sum(_type_bytes(_lookup(self.comps, comp, o))
                            for o in ins.operands)
            result_b = _type_bytes(ins.type_str)
            n = _group_size(ins.attrs)
            frac = (n - 1) / n if n > 1 else 0.0
            tot.coll_operand_bytes[base] += operand_b
            if base == "all-reduce":
                tot.coll_ici_bytes += 2.0 * operand_b * frac
            elif base == "all-gather":
                tot.coll_ici_bytes += result_b * frac
            elif base in ("reduce-scatter", "all-to-all"):
                tot.coll_ici_bytes += operand_b * frac
            else:  # collective-permute
                tot.coll_ici_bytes += operand_b
            if not fusion_ctx:
                tot.bytes += operand_b + result_b
            return

        if op == "while":
            trip = 1
            m = _TRIP_RE.search(ins.attrs)
            if m:
                trip = int(m.group(1))
            body = _BODY_RE.search(ins.attrs)
            cond = _COND_RE.search(ins.attrs)
            if body:
                tot.add(self._comp_cost(body.group(1), False), trip)
            if cond:
                tot.add(self._comp_cost(cond.group(1), False), trip)
            return

        if op == "fusion":
            calls = _CALLS_RE.search(ins.attrs)
            if calls:
                inner = self._comp_cost(calls.group(1), True)
                tot.flops += inner.flops
                tot.dot_flops += inner.dot_flops
                tot.coll_ici_bytes += inner.coll_ici_bytes
                for k in _COLLECTIVES:
                    tot.coll_operand_bytes[k] += inner.coll_operand_bytes[k]
            if not fusion_ctx:
                operand_b = sum(_type_bytes(_lookup(self.comps, comp, o))
                                for o in ins.operands)
                tot.bytes += operand_b + _type_bytes(ins.type_str)
            return

        if op in ("call", "async-start", "custom-call"):
            target = _TO_APPLY_RE.search(ins.attrs) or \
                _CALLS_RE.search(ins.attrs)
            if target:
                tot.add(self._comp_cost(target.group(1), fusion_ctx), 1.0)
            return

        if op == "conditional":
            m = _BRANCHES_RE.search(ins.attrs)
            branches = []
            if m:
                if m.group(1):
                    branches = [m.group(1), m.group(2)]
                elif m.group(3):
                    branches = re.findall(r"%([\w.\-]+)", m.group(3))
            if branches:
                costs = [self._comp_cost(b, fusion_ctx) for b in branches]
                best = max(costs, key=lambda c: c.flops + c.bytes)
                tot.add(best, 1.0)
            return

        # ---- leaf ops ----
        if op == "dot":
            k = 1
            m = _CONTRACT_RE.search(ins.attrs)
            lhs_t = _lookup(self.comps, comp, ins.operands[0]) \
                if ins.operands else ""
            dims = _first_shape_dims(lhs_t)
            if m and m.group(1):
                for idx in m.group(1).split(","):
                    i = int(idx)
                    if i < len(dims):
                        k *= dims[i]
            flops = 2.0 * _type_elems(ins.type_str) * k
            tot.flops += flops
            tot.dot_flops += flops
            if lhs_t.startswith("s8") or lhs_t.startswith("u8"):
                tot.int8_dot_flops += flops
        elif op == "convolution":
            # rare in this codebase; approximate as 2 * out * K via operand
            lhs_t = _lookup(self.comps, comp, ins.operands[1]) \
                if len(ins.operands) > 1 else ""
            k = max(1, _type_elems(lhs_t) // max(
                1, _first_shape_dims(lhs_t)[0] if _first_shape_dims(lhs_t)
                else 1))
            tot.flops += 2.0 * _type_elems(ins.type_str) * k
        elif op in ("reduce", "reduce-window"):
            if ins.operands:
                tot.flops += _type_elems(
                    _lookup(self.comps, comp, ins.operands[0]))
        elif op in _ELEMWISE_1FLOP:
            tot.flops += _type_elems(ins.type_str)
        elif op in _ZERO_COST:
            pass

        if not fusion_ctx and op not in _NO_TRAFFIC:
            operand_b = sum(_type_bytes(_lookup(self.comps, comp, o))
                            for o in ins.operands)
            tot.bytes += operand_b + _type_bytes(ins.type_str)


def analyze(hlo_text: str) -> dict:
    """Per-device totals from optimized HLO text."""
    model = HloCostModel(hlo_text)
    t = model.entry_totals()
    return {
        "flops": t.flops,
        "dot_flops": t.dot_flops,
        "int8_dot_flops": t.int8_dot_flops,
        "bytes": t.bytes,
        "collective_operand_bytes": dict(t.coll_operand_bytes),
        "collective_ici_bytes": t.coll_ici_bytes,
    }
