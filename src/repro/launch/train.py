"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b \
        --smoke --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Production posture on a real cluster: same entry point per host
(jax.distributed.initialize from the plugin environment), production mesh
from launch.mesh, host-sharded pipeline, async checkpointing, and
restart-resume — on restart the driver finds the latest checkpoint, restores
(resharding onto the current mesh if it changed — elastic), and continues
from the saved step.  On this CPU container it runs the reduced configs
(--smoke) for the examples/tests.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.checkpoint import Checkpointer
from repro.data import DataConfig, Pipeline
from repro.distributed import compat
from repro.distributed.sharding import use_rules
from repro.launch import steps as S
from repro.launch.mesh import mesh_rules, parse_mesh_spec
from repro.models import api


def train(arch: str, *, smoke: bool = True, n_steps: int = 100,
          global_batch: int = 8, seq_len: int = 256,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          microbatches: int = 1, engine: str = "bf16",
          mesh=None, seed: int = 0, log_every: int = 10,
          lr: float = 3e-3, profile_dir: Optional[str] = None,
          print_fn=print):
    cfg = configs.get_config(arch, smoke=smoke, engine_spec=engine)
    oz_cfg = cfg.engine.ozimmu_config
    if oz_cfg is not None:
        from repro.core import plan
        print_fn(f"[train] engine {engine}: "
                 f"{plan.describe_config(oz_cfg, cfg.d_model, cfg.d_model, cfg.d_model)}")
    model = api.get_model(cfg)
    opt_cfg = optim.OptConfig(lr=lr, warmup_steps=min(20, n_steps // 5 + 1),
                              total_steps=n_steps)
    tcfg = S.TrainConfig(microbatches=microbatches)

    rules = mesh_rules(mesh, arch) if mesh is not None else None
    data_cfg = DataConfig(seq_len=seq_len, global_batch=global_batch,
                          vocab=cfg.vocab, seed=seed,
                          vision_seq=cfg.vision_seq if cfg.family == "vlm" else 0,
                          frames=seq_len if cfg.family == "encdec" else 0,
                          d_model=cfg.d_model)
    pipe = Pipeline(data_cfg, host_id=jax.process_index(),
                    num_hosts=jax.process_count())

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None

    import contextlib
    mesh_ctx = (compat.set_mesh(mesh) if mesh is not None
                else contextlib.nullcontext())
    with mesh_ctx, use_rules(rules):
        rng = jax.random.PRNGKey(seed)
        state, axes, opt_axes = S.init_state(
            rng, cfg, opt_cfg,
            zero_divisor=(mesh.shape.get("data", 1) if mesh else 1))
        start_step = 0
        if ckpt and ckpt.latest_step() is not None:
            state, start_step = ckpt.restore(state)
            print_fn(f"[train] resumed from step {start_step}")

        train_step = jax.jit(
            S.make_train_step(cfg, opt_cfg, tcfg, opt_axes=opt_axes),
            donate_argnums=(0,))

        from repro.core import plan as _plan
        from repro.obs import tracing as _tracing
        losses = []
        t0 = time.time()
        with _tracing.profile(profile_dir):
            for step in range(start_step, n_steps):
                batch = {k: jnp.asarray(v) for k, v in
                         pipe.batch_at(step).items()}
                state, metrics = train_step(state, batch)
                losses.append(float(metrics["loss"]))
                if step == start_step and len(_plan.get_ledger()):
                    # the first step traced every contraction: the ledger
                    # now holds one row per auto-k decision of the program
                    print_fn(f"[train] planner: "
                             f"{_plan.get_ledger().describe()}")
                if log_every and (step + 1) % log_every == 0:
                    dt = (time.time() - t0) / log_every
                    print_fn(f"[train] step {step + 1:5d}  "
                             f"loss {losses[-1]:.4f}  "
                             f"gnorm {float(metrics['grad_norm']):.3f}  "
                             f"lr {float(metrics['lr']):.2e}  "
                             f"{dt * 1e3:.0f} ms/step")
                    t0 = time.time()
                if ckpt and (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(n_steps, state, blocking=True)
    return state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--engine", "--matmul_engine", dest="engine",
                    default="bf16",
                    help="matmul engine spec, e.g. bf16, ozimmu_h-8:df32@model "
                         "or ozimmu_h-auto:df32:fused (auto-k planner + fused "
                         "Pallas pipeline; docs/engine.md)")
    ap.add_argument("--mesh", default=None,
                    help="mesh spec: 'data=2,model=4', 'single_pod', "
                         "'multi_pod'; default no mesh (single device)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax profiler trace of the training "
                         "loop into DIR (view with TensorBoard/Perfetto)")
    args = ap.parse_args(argv)
    _, losses = train(args.arch, smoke=args.smoke, n_steps=args.steps,
                      global_batch=args.batch, seq_len=args.seq,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      microbatches=args.microbatches, engine=args.engine,
                      mesh=parse_mesh_spec(args.mesh),
                      lr=args.lr, profile_dir=args.profile_dir)
    k = max(1, len(losses) // 10)
    print(f"[train] first-{k} mean loss {np.mean(losses[:k]):.4f}  "
          f"last-{k} mean loss {np.mean(losses[-k:]):.4f}")


if __name__ == "__main__":
    main()
