"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs        / (chips * PEAK_FLOPS)
    memory     = HLO_bytes        / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the optimized HLO text: the summed operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.

Hardware model (TPU-v5e-like, per chip): 197 TFLOP/s bf16, 394 TOP/s int8,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape token: bf16[128,512]{1,0}  /  f32[]  /  (tuple, ...) handled per-element
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s+(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if kind + "-done(" in line:
            continue  # -done carries no new payload (counted at -start)
        # operand shapes = every shape token after the '(' of the call
        call = line[m.end() - 1:]
        shapes = _SHAPE_RE.findall(call)
        if not shapes:
            # fall back to result shape(s) before '='
            shapes = _SHAPE_RE.findall(line[:m.start()])
        out[kind] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    peak_flops: float = PEAK_BF16

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """No-overlap step-time lower bound."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def compute_fraction(self) -> float:
        """How compute-bound the cell is (1.0 = at the compute roofline)."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU: model flops over peak during t_bound."""
        denom = self.chips * self.peak_flops * self.t_bound
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 t_bound=self.t_bound, compute_fraction=self.compute_fraction,
                 useful_flops_fraction=self.useful_flops_fraction,
                 mfu_bound=self.mfu_bound)
        return d


def cost_flops_bytes(cost: Optional[dict]):
    """Extract (flops, bytes) from compiled.cost_analysis()."""
    if not cost:
        return 0.0, 0.0
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in cost.items()
                   if k.startswith("bytes accessed"))
    return flops, byts


def count_params(shapes_tree) -> int:
    import jax
    return sum(int(s.size if hasattr(s, "size") else 0)
               for s in jax.tree.leaves(shapes_tree))


def model_flops_for(cfg, shape, n_params: int, n_active: Optional[int] = None):
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active params."""
    n = n_active if n_active is not None else n_params
    B, L = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * B * L
    if shape.kind == "prefill":
        return 2.0 * n * B * L
    return 2.0 * n * B  # decode: one token per row


def active_params(cfg, n_params: int) -> int:
    """Active parameters per token (MoE: shared + topk routed)."""
    if cfg.n_experts:
        # routed expert params
        per_expert = 3 * cfg.d_model * cfg.d_ff_expert * cfg.n_layers
        routed_total = per_expert * cfg.n_experts
        routed_active = per_expert * cfg.topk
        return n_params - routed_total + routed_active
    return n_params
