"""Error-free cross-device reductions for the sharded Ozaki emulation.

When a contraction is sharded over a mesh axis, every device holds a
*partial* product and the cross-device sum is exactly the kind of
"high-precision matrix addition" whose count the paper's Alg. 6/7 works to
minimize.  Doing that sum as a plain f32 ``psum`` throws away the accuracy
the scheme just paid for (the reduction rounds at 2^-24 while the
accumulator carries ~2^-48 or better).  This module provides the two
reductions that keep the scheme's invariants (see docs/distributed.md):

  * :func:`psum_exact_int32` — sum INT32 slice/group partials across
    devices *before* any float conversion.  Bit-exact: each device's
    partial over its n_i local contraction columns is bounded by
    ``n_i * (2^beta - 1)^2`` and the partials sum to the unsharded product,
    so every intermediate stays under the same ``n * (2^beta - 1)^2 < 2^31``
    bound that eq. (4)/(12) of the paper guarantees for the unsharded GEMM
    — integer addition is associative, no overflow, no rounding.

  * :func:`psum_df32` / :func:`psum_compensated` — TwoSum-compensated
    reduction of partial high-precision accumulators (the ``partial=True``
    output of ``matmul_naive`` / ``matmul_group_ef``).  One collective for
    the whole GEMM instead of one per slice product; error-free in the
    two-float representation (each pairwise merge is a Dekker add whose
    rounding error is captured in the ``lo`` limb), with a single rounding
    at the final ``to_float``.

All functions must be called *inside* ``shard_map`` (they use named-axis
collectives).  The gather-then-fold formulation makes the reduction order
deterministic and identical on every device — the device index, not the
reduction topology, orders the fold.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.accumulate import DF32, df32_add_df, _two_sum

__all__ = ["psum_exact_int32", "psum_df32", "psum_compensated",
           "pmax_scales"]


def pmax_scales(v: jax.Array, axis_name: str) -> jax.Array:
    """Elementwise max of per-row/col |a| maxima across the mesh axis.

    Used as the splitters' ``rowmax_reduce`` hook so every shard of a
    contraction-sharded operand extracts digits on the SAME power-of-two
    grid as the unsharded run — the precondition for summing INT32
    partials exactly (and for bitwise equality with the unsharded path).
    """
    return lax.pmax(v, axis_name)


def psum_exact_int32(p: jax.Array, axis_name: str) -> jax.Array:
    """Exact cross-device sum of INT32 partial slice/group products.

    ``p`` may be a single product or a stacked ``(G, *batch, m, p)`` tensor
    of all products of a GEMM (one collective for the whole scheme).  The
    no-overflow argument requires that the *global* contraction length was
    used for beta (eq. 4) and r (eq. 12) — the sharded engine path does
    this — so the sum of partials equals the unsharded INT32 product
    bit for bit.
    """
    if p.dtype != jnp.int32:
        raise TypeError(f"psum_exact_int32 needs int32 partials, got "
                        f"{p.dtype}; float partials lose exactness")
    return lax.psum(p, axis_name)


def psum_df32(c: DF32, axis_name: str) -> DF32:
    """Error-free ``psum`` of a DF32 (two-float) partial accumulator.

    All-gathers both limbs over the axis and folds the per-device partials
    with compensated (TwoSum) double-float addition in device order —
    deterministic and identical on every member of the axis.  The result
    stays unevaluated (hi, lo); round once, at the very end, via
    ``.to_float``.
    """
    his = lax.all_gather(c.hi, axis_name)   # (D, *shape)
    los = lax.all_gather(c.lo, axis_name)
    acc = DF32(his[0], los[0])
    for i in range(1, his.shape[0]):
        acc = df32_add_df(acc, DF32(his[i], los[i]))
    return acc


def psum_compensated(x: jax.Array, axis_name: str) -> jax.Array:
    """Compensated ``psum`` of a plain float partial accumulator.

    For ``f64``/``f32`` partial accumulators: all-gather, then a Neumaier
    fold — the running error term absorbs what each addition rounds away,
    and is added back once at the end.  Strictly no less accurate than
    ``lax.psum`` and deterministic across devices; use ``psum_df32`` when
    the partials are already two-float pairs.
    """
    parts = lax.all_gather(x, axis_name)    # (D, *shape)
    s = parts[0]
    e = jnp.zeros_like(s)
    for i in range(1, parts.shape[0]):
        s, err = _two_sum(s, parts[i])
        e = e + err
    return s + e
