from repro.distributed.sharding import (SINGLE_POD_RULES, MULTI_POD_RULES,
                                        use_rules, get_rules, shard,
                                        logical_to_pspec, spec_tree)
from repro.distributed import collectives, compat
