"""Logical-axis sharding rules (MaxText-style).

Model code annotates params/activations with *logical* axis names
("embed", "mlp", "heads", "batch", ...).  A rules table — chosen by the
launcher per mesh — maps logical names to mesh axes.  Model code never
mentions physical axes, so the same model runs on the single-pod
(data, model) mesh, the multi-pod (pod, data, model) mesh, or a laptop
(no mesh: every annotation is a no-op).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

Rules = dict  # logical axis name -> mesh axis | tuple | None

# Default rules for the production meshes.  "batch" spans the pure-DP axes
# (pod + data); tensor-parallel dims map to "model"; ZeRO-1 optimizer-state
# sharding additionally uses "data" (see optim/).
SINGLE_POD_RULES: Rules = {
    "batch": ("data",),
    "seq": None,
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "kv_lora": None,
    "state": None,
    "conv": None,
    "layers": None,
    "cache_batch": ("data",),
    "cache_heads": None,
    "cache_hd": None,
    "zero": ("data",),
}

MULTI_POD_RULES: Rules = dict(SINGLE_POD_RULES)
MULTI_POD_RULES.update({
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),
    "zero": ("pod", "data"),
})

_state = threading.local()


def get_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = get_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def logical_to_pspec(axes: Sequence[Optional[str]],
                     rules: Optional[Rules] = None) -> P:
    rules = rules if rules is not None else get_rules()
    if rules is None:
        return P()
    out, used = [], set()
    for ax in axes:
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            out.append(None)
            continue
        mesh_axes = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        # a mesh axis may appear at most once in a PartitionSpec
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        used.update(mesh_axes)
        if not mesh_axes:
            out.append(None)
        elif len(mesh_axes) == 1:
            out.append(mesh_axes[0])
        else:
            out.append(mesh_axes)
    return P(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without mesh+rules).

    Requires the mesh installed via ``repro.distributed.compat.set_mesh``
    (a plain ``with mesh:`` does NOT set the abstract mesh on modern JAX
    and this silently no-ops)."""
    if get_rules() is None:
        return x
    from repro.distributed.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh.empty:
        return x
    axes = axes[:x.ndim]  # tolerate rank-reduced call sites (hint semantics)
    spec = logical_to_pspec(axes)
    # drop mesh axes that aren't on the current mesh (e.g. "pod" on 1 pod)
    names = set(mesh.axis_names)

    def keep(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            e2 = tuple(a for a in e if a in names)
            return e2 if e2 else None
        return e if e in names else None

    spec = P(*(keep(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, spec)


def spec_tree(axes_tree, rules: Optional[Rules] = None):
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_pspec(axes, rules), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x))
