"""JAX mesh/shard_map API shims — one import site for both API generations.

The distributed layer targets the modern mesh API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.shard_map`` with ``axis_names`` /
``check_vma``; JAX >= 0.6).  Older runtimes (the 0.4.x line this container
ships) expose the same machinery under different names:

  ===========================  ==========================================
  modern                       0.4.x equivalent
  ===========================  ==========================================
  ``jax.set_mesh(m)``          ``with m:`` + ``mesh_lib.set_abstract_mesh``
  ``sharding.get_abstract_mesh``  ``jax._src.mesh.get_abstract_mesh`` (may
                               return ``()`` when nothing is installed)
  ``jax.shard_map``            ``jax.experimental.shard_map.shard_map``
                               (``check_rep``/``auto`` instead of
                               ``check_vma``/``axis_names``)
  ===========================  ==========================================

Everything in repro that touches a mesh goes through this module, so model
and launch code reads as if the modern API were always present.  The shims
resolve at call time (not import time) and are no-ops on modern JAX.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

try:  # modern JAX keeps AbstractMesh here; 0.4.x under jax._src.mesh
    from jax._src import mesh as _mesh_lib
except ImportError:  # pragma: no cover - very old/strange builds
    _mesh_lib = None

__all__ = ["EMPTY_MESH", "get_abstract_mesh", "get_concrete_mesh",
           "set_mesh", "shard_map"]


class _EmptyMesh:
    """Stand-in with the AbstractMesh surface used by repro code."""

    empty = True
    axis_names = ()
    shape = {}

    def __repr__(self):
        return "EmptyMesh()"


EMPTY_MESH = _EmptyMesh()


def get_abstract_mesh():
    """The abstract mesh installed by :func:`set_mesh`.

    Always returns an object with ``.empty`` / ``.axis_names`` / ``.shape``
    (``EMPTY_MESH`` outside any mesh context), so call sites never branch on
    the JAX version or on ``None``.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is None and _mesh_lib is not None:
        getter = getattr(_mesh_lib, "get_abstract_mesh", None)
    if getter is None:
        return EMPTY_MESH
    mesh = getter()
    # 0.4.x returns () (the raw thread-local default) when nothing is set
    if mesh is None or not hasattr(mesh, "empty"):
        return EMPTY_MESH
    return mesh


def get_concrete_mesh() -> Optional[jax.sharding.Mesh]:
    """The physical mesh installed by :func:`set_mesh`, or None."""
    getter = getattr(jax.sharding, "get_concrete_mesh", None)
    if getter is not None:
        mesh = getter()
        return None if mesh is None or getattr(mesh, "empty", False) else mesh
    if _mesh_lib is not None:
        env = _mesh_lib.thread_resources.env.physical_mesh
        return None if env.empty else env
    return None


@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh):
    """Install ``mesh`` as both the physical and the abstract mesh.

    Modern JAX: delegates to ``jax.set_mesh``.  0.4.x: enters the plain
    ``with mesh:`` context (what ``with_sharding_constraint`` and
    ``shard_map`` read) AND sets the abstract mesh (what ``shard()`` and
    the engine's mesh-native path read) — ``with mesh:`` alone does not.
    """
    modern = getattr(jax, "set_mesh", None)
    if modern is not None:
        with modern(mesh):
            yield mesh
        return
    with contextlib.ExitStack() as stack:
        stack.enter_context(mesh)
        if _mesh_lib is not None and hasattr(_mesh_lib, "set_abstract_mesh"):
            stack.enter_context(
                _mesh_lib.set_abstract_mesh(mesh.abstract_mesh))
        yield mesh


def _resolve_mesh(mesh):
    """shard_map on 0.4.x needs a concrete Mesh; accept abstract ones."""
    if mesh is None or (_mesh_lib is not None
                        and isinstance(mesh, _mesh_lib.AbstractMesh)):
        concrete = get_concrete_mesh()
        if concrete is None:
            raise ValueError(
                "shard_map needs a mesh: none passed and none installed "
                "(use repro.distributed.compat.set_mesh)")
        return concrete
    return mesh


def shard_map(f, mesh=None, in_specs=None, out_specs=None, axis_names=None,
              check_vma: bool = True):
    """``jax.shard_map`` with the modern signature on every JAX version.

    ``axis_names`` — the mesh axes the body is manual over (all of them
    when None); on 0.4.x this maps to the complementary ``auto`` set.
    ``check_vma`` maps to 0.4.x's ``check_rep``.
    """
    modern = getattr(jax, "shard_map", None)
    if modern is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return modern(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _legacy
    mesh = _resolve_mesh(mesh)
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
    if axis_names is not None:
        kwargs["auto"] = frozenset(set(mesh.axis_names) - set(axis_names))
    return _legacy(f, **kwargs)
