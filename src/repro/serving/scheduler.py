"""Host-side continuous-batching scheduler.

Pure-Python request/slot bookkeeping — no jax — so the policy layer is
unit-testable without a model.  The runtime owns the device work; this
module decides *which* requests occupy *which* of the fixed decode slots
when.

Model: a fixed array of ``n_slots`` decode slots (the compiled decode
step's batch dimension).  Requests queue FIFO; a finishing request frees
its slot, which the next queued request takes WITHOUT stopping the
decode loop (vLLM-style continuous batching).  Newly admitted requests
are prefilled in batched groups bucketed by prompt length so
mixed-length prompts share one compiled prefill call.

Eviction (paged-KV pool pressure): the *latest-admitted* active slot is
preempted — its blocks are freed and its request goes back to the FRONT
of the queue carrying the tokens generated so far (recompute-style
preemption: re-prefill of prompt+generated).  Latest-victim + front
requeue preserves FIFO fairness: the earliest-arrived requests are never
starved by later arrivals.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = ["Request", "Slot", "Scheduler", "bucket_pow2"]


def bucket_pow2(plen: int, floor: int = 8) -> int:
    """Smallest power of two >= plen (>= floor) — the prefill bucket."""
    b = floor
    while b < plen:
        b *= 2
    return b


@dataclasses.dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""

    rid: int
    prompt: np.ndarray                  # (plen,) int32 token ids
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # filled by the runtime
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    prefills: int = 0                   # >1 means it was evicted+resumed

    def prefill_tokens(self) -> np.ndarray:
        """Tokens to teacher-force at (re-)admission: the prompt plus any
        tokens already generated before an eviction.  The prefill's
        last-position logits then predict the next new token."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new:
            return True
        return bool(self.generated) and self.eos_id is not None \
            and self.generated[-1] == self.eos_id


@dataclasses.dataclass
class Slot:
    """State of one decode slot."""

    request: Optional[Request] = None
    pos: int = 0                        # tokens currently in the cache
    last_token: int = 0                 # next token to feed the decode step
    admit_seq: int = -1                 # admission order (eviction picks max)
    prefilled: int = 0                  # prefill tokens already in the cache
    #   (< prefill_target means mid-chunked-prefill: the slot is occupied
    #    but must NOT decode yet; a prefix-cache hit starts it above zero
    #    — the aliased positions never run a forward pass)
    prefill_target: int = 0             # len(prefill_tokens()) AT ADMISSION
    #   (frozen: prefill_tokens() itself grows as the slot decodes, so
    #    comparing against it live would keep the slot prefill-pending
    #    forever and push every generated token through a 1-token
    #    prefill chunk instead of the decode step)

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefill_done(self) -> bool:
        return self.request is not None and \
            self.prefilled >= self.prefill_target


class Scheduler:
    """FIFO continuous batching over a fixed slot array.

    ``bucket``: ``"pow2"`` groups prefills by next-power-of-two prompt
    length (attention-cache families — shorter prompts right-pad inside
    the shared compiled call); ``"exact"`` groups by exact length (state
    families — SSM/LRU states integrate every fed token, so prompts in a
    shared call must be the same length); or any ``len -> bucket``
    callable.
    """

    def __init__(self, n_slots: int,
                 bucket: Union[str, Callable[[int], int]] = "pow2"):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self._rid = itertools.count()
        self._admit_seq = itertools.count()
        self.finished: List[Request] = []
        self.evictions = 0
        if callable(bucket):
            self.bucket_fn = bucket
        elif bucket == "pow2":
            self.bucket_fn = bucket_pow2
        elif bucket == "exact":
            self.bucket_fn = lambda plen: plen
        else:
            raise ValueError(f"unknown bucket policy {bucket!r}")

    # -- submission / admission ------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int,
               eos_id: Optional[int] = None, arrival: float = 0.0,
               ) -> Request:
        req = Request(next(self._rid),
                      np.asarray(prompt, np.int32).reshape(-1),
                      int(max_new), eos_id, arrival)
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        self.queue.append(req)
        return req

    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue head; returns the new
        (slot_index, request) pairs, still needing prefill."""
        admissions = []
        for i, slot in enumerate(self.slots):
            if not self.queue:
                break
            if slot.free:
                req = self.queue.popleft()
                req.prefills += 1
                self.slots[i] = Slot(request=req, pos=0,
                                     admit_seq=next(self._admit_seq),
                                     prefill_target=len(
                                         req.prefill_tokens()))
                admissions.append((i, req))
        self._check()
        return admissions

    def prefill_groups(self, admissions: List[Tuple[int, Request]]
                       ) -> List[Tuple[int, List[Tuple[int, Request]]]]:
        """Group admissions by prefill bucket: [(bucket_len, pairs)].
        Every pair in a group shares one compiled prefill call."""
        groups: Dict[int, List[Tuple[int, Request]]] = {}
        for slot_idx, req in admissions:
            b = self.bucket_fn(len(req.prefill_tokens()))
            groups.setdefault(b, []).append((slot_idx, req))
        return sorted(groups.items())

    # -- chunked prefill -------------------------------------------------

    def pending_prefill(self) -> List[Tuple[int, "Request"]]:
        """Occupied slots whose prefill is not complete (newly admitted,
        or mid-chunk), in slot order — each takes ONE chunk per round."""
        return [(i, s.request) for i, s in enumerate(self.slots)
                if s.request is not None and not s.prefill_done]

    def chunk_groups(self, plans: List[Tuple[int, Request, int]]
                     ) -> List[Tuple[int, List[Tuple[int, Request, int]]]]:
        """Group (slot, request, chunk_len) plans by the bucket of the
        CHUNK length: [(bucket_len, plans)] — every plan in a group
        shares one compiled call (right-aligned inside the bucket)."""
        groups: Dict[int, List[Tuple[int, Request, int]]] = {}
        for slot_idx, req, clen in plans:
            groups.setdefault(self.bucket_fn(clen), []).append(
                (slot_idx, req, clen))
        return sorted(groups.items())

    def on_chunk(self, slot_idx: int, n: int):
        """A non-final prefill chunk fed ``n`` more tokens into the
        slot's cache (no token produced; the slot stays non-decoding)."""
        slot = self.slots[slot_idx]
        assert slot.request is not None, f"slot {slot_idx} is free"
        slot.prefilled += int(n)
        assert slot.prefilled < slot.prefill_target, \
            "final chunk must go through on_prefilled"
        self._check()

    # -- decode progress -------------------------------------------------

    def on_prefilled(self, slot_idx: int, first_token: int,
                     now: float = 0.0) -> bool:
        """Record the prefill result: cache holds the prefilled tokens,
        ``first_token`` is the first new generation (not yet in cache).
        Returns True when that token already finished the request."""
        slot = self.slots[slot_idx]
        assert slot.request is not None, f"slot {slot_idx} is free"
        slot.pos = slot.prefill_target
        slot.prefilled = slot.pos
        return self._accept_token(slot_idx, first_token, now)

    def on_token(self, slot_idx: int, token: int, now: float = 0.0) -> bool:
        """One decode step produced ``token`` for this slot (the PREVIOUS
        last_token is now in the cache).  Returns True when the request
        finished (slot released)."""
        slot = self.slots[slot_idx]
        assert slot.request is not None, f"slot {slot_idx} is free"
        slot.pos += 1
        return self._accept_token(slot_idx, token, now)

    def _accept_token(self, slot_idx: int, token: int, now: float) -> bool:
        slot = self.slots[slot_idx]
        req = slot.request
        if req.first_token_at is None:
            req.first_token_at = now
        req.generated.append(int(token))
        slot.last_token = int(token)
        if req.done:
            req.finished_at = now
            self.finished.append(req)
            self.slots[slot_idx] = Slot()
            self._check()
            return True
        return False

    # -- eviction --------------------------------------------------------

    def pick_victim(self, protect: Optional[int] = None) -> Optional[int]:
        """Latest-admitted active slot (FIFO-fair preemption), optionally
        protecting one slot index; None when no evictable slot exists."""
        best, best_seq = None, -1
        for i, slot in enumerate(self.slots):
            if slot.free or i == protect:
                continue
            if slot.admit_seq > best_seq:
                best, best_seq = i, slot.admit_seq
        return best

    def evict(self, slot_idx: int) -> Request:
        """Preempt a slot: its request returns to the FRONT of the queue
        carrying its generated tokens (re-prefill resumes it)."""
        slot = self.slots[slot_idx]
        assert slot.request is not None, f"slot {slot_idx} is free"
        req = slot.request
        self.slots[slot_idx] = Slot()
        self.queue.appendleft(req)
        self.evictions += 1
        self._check()
        return req

    # -- inspection ------------------------------------------------------

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def decode_slots(self) -> List[int]:
        """Slots eligible for a decode step: occupied AND fully prefilled
        (mid-chunk slots are excluded until their final chunk lands)."""
        return [i for i, s in enumerate(self.slots) if s.prefill_done]

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def all_done(self) -> bool:
        return not self.queue and not self.active_slots()

    def _check(self):
        """Slot-leak invariant: every slot is free xor owns exactly one
        live request, and no request is both queued and slotted."""
        owned = [s.request.rid for s in self.slots if s.request is not None]
        assert len(owned) == len(set(owned)), f"request in two slots: {owned}"
        queued = {r.rid for r in self.queue}
        assert not (queued & set(owned)), "request both queued and slotted"
        assert len(owned) + sum(s.free for s in self.slots) == \
            len(self.slots), "slot leak"
        for i, s in enumerate(self.slots):
            limit = 0 if s.free else len(s.request.prefill_tokens())
            assert 0 <= s.prefilled <= limit, \
                f"slot {i} prefilled {s.prefilled} outside [0, {limit}]"
