"""Prefix KV cache: frozen shared prefixes served by block aliasing.

The serving-level analogue of the PR 5 weight split-cache, one level up
the stack: the split-cache amortizes *weight splitting* across requests,
this module amortizes *prefill* across requests that share a token
prefix (the system-prompt regime — millions of requests re-running the
identical forward pass over the identical tokens).

A publication freezes a slot's state after it consumed the first ``m``
prompt tokens (``m`` block-aligned, and at most ``len(prompt) - 1`` so a
hit still has at least one suffix token to feed — the final prefill
call's last-position logits are the first-token prediction):

* **paged leaves** — the slot's first ``m / block`` pool blocks are
  published by *reference* (:meth:`PagedKV.share_blocks`), not copied:
  the entry holds a refcount on each physical block.  A later request
  whose prompt starts with the same ``m`` tokens adopts those block ids
  straight into its table (:meth:`PagedKV.adopt_blocks`) — prefill for
  the aliased positions becomes a host-side table write.  The pool's
  copy-on-write (`cow_for_write`) keeps aliasing sound if any writer
  ever reaches a shared block (ring-wrap of windowed caches; the aligned
  publication geometry means straight-line suffix writes never do).
* **state leaves** — recurrent conv/ssm/lru rows have no per-position
  structure to alias, so the entry stores a single-slot *snapshot* taken
  exactly at the ``m``-token boundary; a hit restores it.  The runtime
  forces a chunk boundary at ``m`` during the cold prefill precisely so
  this snapshot exists.

Keying mirrors ``SplitCache``: ``(config name, family, engine spec,
mesh key)`` + the prefix length + the prefix token bytes.  The engine
spec inside the key is what keeps a deterministic engine and its
``:prob`` twin from ever aliasing each other's blocks — numerically
different pipelines must miss, not hit.

Bitwise contract: a hit is bitwise-identical to the cold path because
the adopted blocks/snapshot were produced by the same jitted chunk
calls over the same tokens the cold path would run (chunk-splitting a
teacher-forced scan is exact; see docs/serving.md).

Memory model: entries pin blocks only by refcount — blocks also
referenced by a live slot cost nothing extra; a fully private entry
costs ``m / block`` blocks.  Under pool pressure the runtime releases
LRU entries *before* preempting any live request; a bounded entry count
(``max_entries``) caps the table itself.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.split_cache import _mesh_key
from repro.obs import registry as _obs
from repro.serving.kvcache import PagedKV

__all__ = ["PrefixCache", "PrefixEntry", "PrefixStats", "config_key"]


def config_key(cfg) -> Tuple:
    """The non-token half of the prefix key.  Engine spec and mesh ride
    in it so numerically distinct pipelines (det vs ``:prob``, different
    shardings) can never alias one another's cached prefixes."""
    return (cfg.name, cfg.family, cfg.engine_spec, _mesh_key())


@dataclasses.dataclass
class PrefixEntry:
    """One frozen prefix: shared block refs + state snapshot."""

    key: Tuple
    length: int                       # prefix tokens covered
    blocks: List[int]                 # shared refs into the pool
    state: Dict[str, Any]             # single-slot state-leaf snapshot
    hits: int = 0


@dataclasses.dataclass
class PrefixStats:
    hits: int = 0
    misses: int = 0
    hit_tokens: int = 0               # prefill tokens served by aliasing
    inserted: int = 0
    evicted: int = 0                  # dropped (LRU cap or pool pressure)

    def as_dict(self) -> Dict[str, Any]:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "hit_tokens": self.hit_tokens,
            "inserted": self.inserted,
            "evicted": self.evicted,
        }


class PrefixCache:
    """LRU table of frozen prefixes over ONE :class:`PagedKV` pool.

    Bound to a pool because entries hold physical block ids — they mean
    nothing in another runtime's pool.  The config half of the key is
    still carried per entry (and checked on lookup) so a deliberately
    mis-shared cache fails closed: foreign-spec lookups miss.
    """

    def __init__(self, paged: PagedKV, cfg, max_entries: int = 128):
        self.paged = paged
        self.block = paged.block
        self.key0 = config_key(cfg)
        self.max_entries = max_entries
        # OrderedDict in LRU order: front = coldest, popped first
        self.entries: "OrderedDict[Tuple, PrefixEntry]" = OrderedDict()
        self.stats = PrefixStats()

    # -- keying ----------------------------------------------------------

    def _key(self, tokens, m: int, key0: Optional[Tuple] = None) -> Tuple:
        toks = np.asarray(tokens[:m], np.int32)
        return (self.key0 if key0 is None else key0, m, toks.tobytes())

    def max_publish_len(self, plen: int) -> int:
        """Longest publishable prefix of a ``plen``-token prompt: the
        largest block multiple <= plen - 1 (0 = too short to publish)."""
        return ((plen - 1) // self.block) * self.block

    # -- lookup / adoption ----------------------------------------------

    def lookup(self, tokens, key0: Optional[Tuple] = None
               ) -> Optional[PrefixEntry]:
        """Longest frozen prefix of ``tokens`` (block-aligned, leaving
        >= 1 suffix token), or None.  Counts a hit or a miss."""
        m = self.max_publish_len(len(tokens))
        while m >= self.block:
            e = self.entries.get(self._key(tokens, m, key0))
            if e is not None:
                self.entries.move_to_end(e.key)
                e.hits += 1
                self.stats.hits += 1
                self.stats.hit_tokens += m
                if _obs.enabled():
                    reg = _obs.get_registry()
                    reg.inc("prefix_cache.hits", 1)
                    reg.inc("prefix_cache.hit_tokens", m)
                return e
            m -= self.block
        self.stats.misses += 1
        if _obs.enabled():
            _obs.get_registry().inc("prefix_cache.misses", 1)
        return None

    def adopt(self, slot: int, entry: PrefixEntry) -> int:
        """Install a frozen prefix into an empty slot: alias the blocks,
        restore the state snapshot.  Returns the prefix length (the
        slot's starting ``prefilled``)."""
        self.paged.adopt_blocks(slot, entry.blocks)
        self.paged.restore_state(slot, entry.state)
        return entry.length

    # -- publication -----------------------------------------------------

    def publish(self, tokens, m: int, slot: int) -> int:
        """Freeze the first ``m`` tokens from ``slot`` (whose cache holds
        them, fully written back).  Stateless families also publish every
        shorter aligned length — partial overlaps (two prompts sharing
        only the first blocks) then still hit; state families publish
        only ``m``, the one boundary a snapshot exists for.  Returns the
        number of entries inserted."""
        assert 0 < m <= len(tokens) - 1 and m % self.block == 0, \
            f"unpublishable prefix length {m} for {len(tokens)} tokens"
        state = self.paged.snapshot_state(slot)
        lengths = [m] if self.paged.state_names else \
            range(m, 0, -self.block)
        inserted = 0
        for length in lengths:
            key = self._key(tokens, length)
            if key in self.entries:
                self.entries.move_to_end(key)   # refreshed, not replaced
                continue
            nb = min(length // self.block, self.paged.blocks_per_slot)
            blocks = self.paged.share_blocks(slot, nb)
            self.entries[key] = PrefixEntry(key, length, blocks, state)
            inserted += 1
            self.stats.inserted += 1
            if _obs.enabled():
                _obs.get_registry().inc("prefix_cache.inserted", 1)
        while len(self.entries) > self.max_entries:
            self.release_one()
        return inserted

    # -- eviction --------------------------------------------------------

    def release_one(self) -> bool:
        """Drop the LRU entry, releasing its block refs (blocks whose
        refcount hits zero return to the free list).  False when empty —
        the runtime then falls back to preempting a live slot."""
        if not self.entries:
            return False
        _, e = self.entries.popitem(last=False)
        self.paged.release_blocks(e.blocks)
        self.stats.evicted += 1
        if _obs.enabled():
            _obs.get_registry().inc("prefix_cache.evicted", 1)
        return True

    def clear(self):
        while self.release_one():
            pass

    def reset_stats(self):
        """Fresh counting window (entries stay — steady-state metrics)."""
        self.stats = PrefixStats()

    def __len__(self) -> int:
        return len(self.entries)

    def summary(self) -> Dict[str, Any]:
        d = self.stats.as_dict()
        d["entries"] = len(self.entries)
        return d
