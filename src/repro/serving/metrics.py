"""Serving metrics: tokens/s, TTFT, queue depth, split-cache savings.

Counters are plain host-side Python updated by the runtime loop; the
summary is one JSON-able dict so the bench harness and the serve driver
report the same numbers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

__all__ = ["ServingMetrics"]


def _pct(sorted_vals: List[float], q: float) -> Optional[float]:
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[idx]


@dataclasses.dataclass
class ServingMetrics:
    now: Any = time.monotonic         # injectable clock (virtual-time tests)

    started_at: Optional[float] = None
    stopped_at: Optional[float] = None
    requests_submitted: int = 0
    requests_finished: int = 0
    tokens_generated: int = 0
    prefill_tokens: int = 0
    decode_steps: int = 0
    prefill_calls: int = 0
    prefill_chunks: int = 0           # non-final chunk calls (chunked mode)
    evictions: int = 0
    ttft: List[float] = dataclasses.field(default_factory=list)
    latency: List[float] = dataclasses.field(default_factory=list)
    queue_depth_samples: List[int] = dataclasses.field(default_factory=list)
    split_cache: Optional[Dict[str, Any]] = None
    prefix_cache: Optional[Dict[str, Any]] = None

    def start(self):
        if self.started_at is None:
            self.started_at = self.now()

    def stop(self):
        self.stopped_at = self.now()

    @property
    def elapsed(self) -> float:
        if self.started_at is None:
            return 0.0
        end = self.stopped_at if self.stopped_at is not None else self.now()
        return max(end - self.started_at, 1e-9)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.elapsed

    def record_finish(self, req, end_time: float):
        self.requests_finished += 1
        if req.first_token_at is not None:
            self.ttft.append(req.first_token_at - req.arrival)
        self.latency.append(end_time - req.arrival)

    def sample_queue(self, depth: int):
        self.queue_depth_samples.append(int(depth))

    def summary(self) -> Dict[str, Any]:
        ttft = sorted(self.ttft)
        lat = sorted(self.latency)
        qd = self.queue_depth_samples
        return {
            "requests": {"submitted": self.requests_submitted,
                         "finished": self.requests_finished},
            "tokens_generated": self.tokens_generated,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": self.prefill_chunks,
            "evictions": self.evictions,
            "elapsed_s": round(self.elapsed, 4),
            "tokens_per_s": round(self.tokens_per_s, 2),
            "ttft_s": {"mean": (sum(ttft) / len(ttft)) if ttft else None,
                       "p50": _pct(ttft, 0.5), "p95": _pct(ttft, 0.95)},
            "latency_s": {"mean": (sum(lat) / len(lat)) if lat else None,
                          "p95": _pct(lat, 0.95)},
            "queue_depth": {"max": max(qd) if qd else 0,
                            "mean": (sum(qd) / len(qd)) if qd else 0.0},
            "split_cache": self.split_cache,
            "prefix_cache": self.prefix_cache,
        }
