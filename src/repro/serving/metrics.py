"""Serving metrics: tokens/s, TTFT, queue depth, split-cache savings.

Rebased onto :class:`repro.obs.registry.MetricsRegistry`: every counter
and distribution lives in a **private** registry instance (names under
``serving.*``), and the public :meth:`ServingMetrics.summary` dict is a
view over it.  Private, not the process-global one, because summaries
are per-measurement-window: tests and benches interleave several
runtimes (and call ``reset_metrics`` between passes), and their numbers
must never bleed into each other.  The unified export merges this
registry with the global one (``repro.obs.export.unified_snapshot``).

Counters are host-side, updated by the runtime loop; the summary is one
JSON-able dict so the bench harness and the serve driver report the
same numbers.  Percentiles are linear-interpolation
(:func:`repro.obs.registry.percentile`), exact at small N — the old
nearest-rank-with-rounding skewed high there (p50 of [1,2,3,4] was 3).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from repro.obs.registry import MetricsRegistry, hist_stats, percentile

__all__ = ["ServingMetrics"]

_COUNTERS = ("requests_submitted", "requests_finished", "tokens_generated",
             "prefill_tokens", "decode_steps", "prefill_calls",
             "prefill_chunks", "evictions")

# per-round timing histograms (seconds), recorded by the runtime loop
TIMING_HISTS = ("decode_step", "prefill_call", "eviction", "cow_copy")


def _counter(name: str):
    key = f"serving.{name}"

    def get(self) -> int:
        return int(self.registry.value(key))

    def set_(self, value: int):
        self.registry.inc(key, value - self.registry.value(key))

    return property(get, set_)


class ServingMetrics:
    """One measurement window's serving counters over a private registry.

    The constructor keeps the historical dataclass-style signature
    (``ServingMetrics(now=...)``); counters read/write through the
    registry so ``m.decode_steps += 1`` works unchanged."""

    def __init__(self, now=time.monotonic,
                 registry: Optional[MetricsRegistry] = None):
        self.now = now                  # injectable clock (virtual-time
                                        # tests share it with the registry)
        self.registry = registry if registry is not None \
            else MetricsRegistry(now=now)
        self.started_at: Optional[float] = None
        self.stopped_at: Optional[float] = None
        self.split_cache: Optional[Dict[str, Any]] = None
        self.prefix_cache: Optional[Dict[str, Any]] = None

    requests_submitted = _counter("requests_submitted")
    requests_finished = _counter("requests_finished")
    tokens_generated = _counter("tokens_generated")
    prefill_tokens = _counter("prefill_tokens")
    decode_steps = _counter("decode_steps")
    prefill_calls = _counter("prefill_calls")
    prefill_chunks = _counter("prefill_chunks")  # non-final chunk calls
    evictions = _counter("evictions")

    # -- distributions ---------------------------------------------------

    @property
    def ttft(self) -> List[float]:
        return list(self.registry.hist_values("serving.ttft_s"))

    @property
    def latency(self) -> List[float]:
        return list(self.registry.hist_values("serving.latency_s"))

    @property
    def queue_depth_samples(self) -> List[int]:
        return [int(v) for v in
                self.registry.hist_values("serving.queue_depth")]

    def observe_timing(self, phase: str, seconds: float):
        """One per-round phase timing (``phase`` in :data:`TIMING_HISTS`:
        decode_step / prefill_call / eviction / cow_copy)."""
        self.registry.observe(f"serving.{phase}_s", seconds)

    def timer(self, phase: str):
        """Context manager recording its elapsed time as
        :meth:`observe_timing` (uses the injectable clock)."""
        return self.registry.timer(f"serving.{phase}_s")

    # -- lifecycle -------------------------------------------------------

    def start(self):
        if self.started_at is None:
            self.started_at = self.now()

    def stop(self):
        self.stopped_at = self.now()

    @property
    def elapsed(self) -> float:
        if self.started_at is None:
            return 0.0
        end = self.stopped_at if self.stopped_at is not None else self.now()
        return max(end - self.started_at, 1e-9)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.elapsed

    def record_finish(self, req, end_time: float):
        self.requests_finished += 1
        if req.first_token_at is not None:
            self.registry.observe("serving.ttft_s",
                                  req.first_token_at - req.arrival)
        self.registry.observe("serving.latency_s", end_time - req.arrival)

    def sample_queue(self, depth: int):
        self.registry.observe("serving.queue_depth", int(depth))

    # -- the public view -------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        ttft = self.ttft
        lat = self.latency
        qd = self.queue_depth_samples
        timings = {}
        for phase in TIMING_HISTS:
            stats = hist_stats(
                self.registry.hist_values(f"serving.{phase}_s"))
            if stats is not None:
                timings[phase] = {k: stats[k] for k in
                                  ("count", "mean", "p50", "p95", "p99",
                                   "max")}
        return {
            "requests": {"submitted": self.requests_submitted,
                         "finished": self.requests_finished},
            "tokens_generated": self.tokens_generated,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": self.prefill_chunks,
            "evictions": self.evictions,
            "elapsed_s": round(self.elapsed, 4),
            "tokens_per_s": round(self.tokens_per_s, 2),
            "ttft_s": {"mean": (sum(ttft) / len(ttft)) if ttft else None,
                       "p50": _pct(ttft, 0.5), "p95": _pct(ttft, 0.95),
                       "p99": _pct(ttft, 0.99)},
            "latency_s": {"mean": (sum(lat) / len(lat)) if lat else None,
                          "p95": _pct(lat, 0.95), "p99": _pct(lat, 0.99)},
            "queue_depth": {"max": max(qd) if qd else 0,
                            "mean": (sum(qd) / len(qd)) if qd else 0.0,
                            "p95": _pct(qd, 0.95) if qd else 0.0},
            "timings_s": timings,
            "split_cache": self.split_cache,
            "prefix_cache": self.prefix_cache,
        }


def _pct(vals: List[float], q: float) -> Optional[float]:
    """Linear-interpolation percentile, None on empty input (the summary
    contract for windows that finished no requests)."""
    if not vals:
        return None
    return percentile(vals, q)
