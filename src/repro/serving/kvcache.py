"""Per-slot cache operations + block-paged KV-cache pool.

Two layers:

:class:`SlotCacheOps` — family-generic *monolithic* slot operations,
driven by each model's ``cache_axes`` (the ``"cache_batch"`` logical
axis marks the slot dimension of every cache leaf, wherever it sits —
axis 1 for the dense/MoE/encdec stacks, axis 2 for the vlm group nesting
and the hybrid conv/lru states).  Used by the runtime to freeze
non-participating slots around a prefill call (functional
snapshot-select, no model changes) and to reset a slot at admission.

:class:`PagedKV` — a block-paged pool replacing the monolithic
``(layers, slots, max_len, ...)`` buffers.  Which leaves page is a
**per-family state descriptor** (:data:`STATE_DESCRIPTORS`): every cache
leaf is either

``paged``
    a sequence-indexed buffer ``(*lead, slot, seq, *tail)`` — the
    attention K/V stacks (dense/moe/vlm/hybrid), the MLA latent rows,
    the encdec decoder K/V.  These live in the pool: ``n_blocks`` blocks
    of ``block`` positions per leaf, with a host-side block table per
    slot, blocks allocated on demand as the sequence grows.

``state``
    a constant-size per-slot row with NO sequence axis — the mamba2
    conv/ssm states, the recurrentgemma conv/lru states, and the
    admission-time context caches (encdec/vlm cross-KV, computed once
    from the encoder memory / image embeds and read-only during decode).
    There is nothing to page; they stay resident ``(*lead, slots,
    *tail)``, reset from a single-slot template at admission and merged
    per active slot after each step (a mid-prefill neighbour's recurrent
    state must never take a decode step's garbage).

Pool memory scales with the sum of *live* sequence lengths (rounded up
to blocks) instead of ``slots x max_len``; a finishing request frees its
blocks immediately, and pool pressure triggers scheduler eviction
instead of OOM.

Blocks are **reference-counted**: the prefix cache
(:mod:`repro.serving.prefix_cache`) aliases a frozen prefix's blocks
into a new slot's table instead of re-running prefill, so one physical
block can appear in several tables.  All write paths go through
:meth:`PagedKV.cow_for_write` first — a shared block is copied to a
fresh private block before the write lands (copy-on-write), so aliased
readers never observe another slot's divergence.  With block-aligned
prefix lengths the hot paths never actually trigger a copy (suffix
writes start exactly at the first non-shared block); the CoW is the
safety net that makes aliasing unconditionally safe (ring-wrap writes of
windowed caches included).

The decode step still consumes a contiguous ``(…, slot, seq, …)`` view:
``gather`` materializes it from the pool (a copy — the correctness-first
realization; a paged-attention kernel reading the pool in place is the
obvious next optimization and slots behind the same interface), the
model runs unchanged, and ``scatter_rows`` writes back exactly the one
row per active slot the decode step appended.  Unallocated table entries
point at block 0; reads through them see unrelated bytes, which is safe
because attention masks every position >= the slot's current length, and
writes never go through them (decode writes only at allocated positions;
inactive slots are redirected to a dedicated trash block).
Per-token paged-vs-monolithic equivalence is asserted in
tests/test_serving.py and tests/test_prefix_cache.py for every family.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ring_row_index

__all__ = ["SlotCacheOps", "PagedKV", "STATE_DESCRIPTORS",
           "state_descriptor"]


# -- per-family state descriptor --------------------------------------------
#
# Leaf name -> kind for every serving family.  "paged" leaves carry a
# sequence axis right of the slot axis and live in the block pool;
# "state" leaves are constant-size per-slot rows (recurrent states,
# admission-time cross-KV context) that stay resident.  A family absent
# here (or a cache leaf absent from its entry) cannot serve paged —
# ``supported()`` says so instead of mis-paging it.

STATE_DESCRIPTORS: Dict[str, Dict[str, str]] = {
    "dense":   {"k": "paged", "v": "paged"},
    "moe":     {"k": "paged", "v": "paged"},
    "mla_moe": {"latent": "paged", "k_rope": "paged"},
    "vlm":     {"k": "paged", "v": "paged",
                "cross_k": "state", "cross_v": "state"},
    "encdec":  {"k": "paged", "v": "paged",
                "cross_k": "state", "cross_v": "state"},
    "ssm":     {"conv": "state", "ssm": "state"},
    "hybrid":  {"k": "paged", "v": "paged",
                "conv": "state", "lru": "state",
                "tail_conv": "state", "tail_lru": "state"},
}


def state_descriptor(cfg) -> Dict[str, str]:
    """The family's leaf-name -> {"paged", "state"} map (KeyError for a
    family without one — then only the monolithic cache serves it)."""
    return STATE_DESCRIPTORS[cfg.family]


def _axes_tree(model, cfg):
    if getattr(model, "cache_axes", None) is None:
        return None
    return model.cache_axes(cfg)


def _pathkey(path) -> Tuple[str, ...]:
    return tuple(str(k) for k in path)


def _leaf_axes(axes_tree, cache) -> Dict[Tuple, Tuple]:
    """{stringified leaf path: logical axes tuple} for the cache tree."""
    is_ax = lambda x: isinstance(x, tuple)
    flat_cache = jax.tree_util.tree_flatten_with_path(cache)[0]
    if axes_tree is None:
        return {_pathkey(path): None for path, _ in flat_cache}
    flat_axes = jax.tree_util.tree_flatten_with_path(
        axes_tree, is_leaf=is_ax)[0]
    ax = {_pathkey(path): v for path, v in flat_axes}
    return {_pathkey(path): ax.get(_pathkey(path))
            for path, _ in flat_cache}


def _slot_axis(axes: Optional[Tuple]) -> int:
    if axes is None:
        return 1          # every family's default cache layout
    return axes.index("cache_batch")


class SlotCacheOps:
    """Family-generic per-slot select / reset on a monolithic cache."""

    def __init__(self, cfg, model):
        self.cfg, self.model = cfg, model
        self._axes = _axes_tree(model, cfg)
        self._select = jax.jit(self._select_impl)

    def _slot_axes_for(self, cache) -> List[int]:
        la = _leaf_axes(self._axes, cache)
        return [_slot_axis(v) for v in la.values()]

    def _select_impl(self, new_cache, old_cache, mask):
        """Per-slot select: leaves of ``new_cache`` where ``mask`` is set
        (along each leaf's slot axis), ``old_cache`` elsewhere — the
        functional freeze of non-participating slots."""
        axes = self._slot_axes_for(new_cache)
        flat_new, tree = jax.tree_util.tree_flatten(new_cache)
        flat_old = jax.tree_util.tree_flatten(old_cache)[0]
        out = []
        for new, old, ax in zip(flat_new, flat_old, axes):
            shape = [1] * new.ndim
            shape[ax] = mask.shape[0]
            out.append(jnp.where(mask.reshape(shape), new, old))
        return jax.tree_util.tree_unflatten(tree, out)

    def select_slots(self, new_cache, old_cache, mask: jax.Array):
        return self._select(new_cache, old_cache, mask)

    def reset_slot(self, cache, slot_idx: int, template):
        """Write a freshly initialized single-slot cache (``template``,
        from ``init_cache(cfg, 1, ...)``) into slot ``slot_idx``."""
        axes = self._slot_axes_for(cache)
        flat_c, tree = jax.tree_util.tree_flatten(cache)
        flat_t = jax.tree_util.tree_flatten(template)[0]
        out = []
        idx = jnp.asarray(slot_idx, jnp.int32)  # x64: keep s32 indices
        for leaf, one, ax in zip(flat_c, flat_t, axes):
            one = jax.lax.index_in_dim(one, 0, ax, keepdims=False)
            out.append(jax.lax.dynamic_update_index_in_dim(
                leaf, one.astype(leaf.dtype), idx, axis=ax))
        return jax.tree_util.tree_unflatten(tree, out)


class PagedKV:
    """Block-paged pool + host-side block tables (see module docstring).

    Every cache leaf is classified by the family's state descriptor:
    ``paged`` leaves (all sharing one sequence length) live in the pool,
    ``state`` leaves stay resident per slot.  The last pool block (id
    ``n_blocks``) is the write trash for inactive slots and is never
    allocated.  ``params``/``ctx``/``template`` feed the state leaves of
    the context families (encdec/vlm): ``ctx`` is the already-batched
    per-slot context for shape inference, ``template`` the concrete
    single-slot cache the state leaves are initialized and reset from.
    """

    def __init__(self, cfg, model, n_slots: int, max_len: int,
                 block: int = 16, n_blocks: Optional[int] = None,
                 params=None, ctx=None, template=None):
        self.cfg, self.model = cfg, model
        self.n_slots = n_slots
        desc = state_descriptor(cfg)
        # shapes only — materializing the monolithic cache here would
        # transiently double KV memory, the very regime paging avoids
        cache = jax.eval_shape(
            lambda: model.init_cache(cfg, n_slots, max_len,
                                     params=params, ctx=ctx))
        if not isinstance(cache, dict):
            raise ValueError("paged KV expects a flat dict cache")
        unknown = sorted(set(cache) - set(desc))
        if unknown:
            raise ValueError(f"cache leaves {unknown} missing from the "
                             f"{cfg.family!r} state descriptor")
        axes = _leaf_axes(_axes_tree(model, cfg), cache)
        self._slot_ax = {name: _slot_axis(axes[("['%s']" % name,)])
                         for name in cache}
        self.kinds = {name: desc[name] for name in cache}
        self.paged_names = sorted(n for n, k in self.kinds.items()
                                  if k == "paged")
        self.state_names = sorted(n for n, k in self.kinds.items()
                                  if k == "state")
        seqs = {cache[n].shape[self._slot_ax[n] + 1]
                for n in self.paged_names}
        if len(seqs) > 1:
            raise ValueError(f"paged KV needs one shared sequence length "
                             f"across paged leaves, got {sorted(seqs)}")
        self.seq_len = seqs.pop() if seqs else 0
        if self.seq_len % block != 0:
            raise ValueError(f"block={block} must divide the cache length "
                             f"{self.seq_len}")
        self.block = block
        self.blocks_per_slot = self.seq_len // block
        if n_blocks is None:
            n_blocks = n_slots * self.blocks_per_slot
        if not self.paged_names:
            n_blocks = 0          # pure-state family: nothing to page
        self.n_blocks = n_blocks
        # host-side tables: unallocated entries point at block 0 (read-
        # only garbage, masked by attention); trash block id = n_blocks.
        self.tables = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        self.allocated = np.zeros((n_slots,), np.int32)    # blocks per slot
        self.free_blocks: List[int] = list(range(n_blocks - 1, -1, -1))
        # per-block reference counts: >1 means the block is aliased
        # (prefix cache and/or several slot tables) and must copy-on-write
        self.refcount = np.zeros((max(n_blocks, 1),), np.int32)
        self.cow_copies = 0
        self._shapes = cache
        self.pool = {}
        for name in self.paged_names:
            leaf, ax = cache[name], self._slot_ax[name]
            lead, tail = leaf.shape[:ax], leaf.shape[ax + 2:]
            self.pool[name] = jnp.zeros(
                lead + (self.n_blocks + 1, self.block) + tail, leaf.dtype)
        # resident state leaves, tiled from the single-slot template (the
        # same template admission resets a slot from — bitwise identical
        # to a batched init_cache, whose per-slot context rows repeat the
        # shared single-slot ctx)
        self.state: Dict[str, jax.Array] = {}
        self.state_template: Dict[str, jax.Array] = {}
        if self.state_names:
            if template is None:
                raise ValueError(f"family {cfg.family!r} has state leaves "
                                 f"{self.state_names}; PagedKV needs the "
                                 f"single-slot template")
            for name in self.state_names:
                ax = self._slot_ax[name]
                t = template[name]
                self.state_template[name] = t
                reps = [1] * t.ndim
                reps[ax] = n_slots
                self.state[name] = jnp.tile(t, reps)
        self._gather = jax.jit(self._gather_impl)
        self._scatter_rows = jax.jit(self._scatter_rows_impl)
        self._copy_block = jax.jit(self._copy_block_impl)
        self._reset_state = jax.jit(self._reset_state_impl)
        self._snap_state = jax.jit(self._snap_state_impl)
        self._restore_state = jax.jit(self._restore_state_impl)
        self._span_fns = {}

    # -- support probe ---------------------------------------------------

    @staticmethod
    def supported(cfg, model, max_len: int, params=None, ctx=None) -> bool:
        """Whether this (family, max_len) pair can serve paged: a state
        descriptor covering every cache leaf, and one shared sequence
        length across the paged leaves.  ``params``/``ctx`` are needed
        for the context families whose init derives cross-KV shapes."""
        desc = STATE_DESCRIPTORS.get(cfg.family)
        if desc is None:
            return False
        cache = jax.eval_shape(
            lambda: model.init_cache(cfg, 1, max_len, params=params,
                                     ctx=ctx))
        if not isinstance(cache, dict) or set(cache) - set(desc):
            return False
        axes = _leaf_axes(_axes_tree(model, cfg), cache)
        seqs = set()
        for name, leaf in cache.items():
            ax = _slot_axis(axes[("['%s']" % name,)])
            if desc[name] != "paged":
                continue
            if leaf.ndim < ax + 2:
                return False
            seqs.add(leaf.shape[ax + 1])
        return len(seqs) <= 1

    # -- device ops ------------------------------------------------------

    def _gather_impl(self, pool, tables, state):
        """(pool, (S, bps) tables, state) -> the full contiguous cache
        dict the model's decode step consumes."""
        out = dict(state)
        for name in self.paged_names:
            pleaf = pool[name]
            ax = self._slot_ax[name]
            g = jnp.take(pleaf, tables, axis=ax)  # (*lead, S, bps, blk, *tail)
            lead = pleaf.shape[:ax]
            tail = pleaf.shape[ax + 2:]
            out[name] = g.reshape(
                lead + (self.n_slots, self.seq_len) + tail)
        return out

    def _scatter_rows_impl(self, pool, tables, cache, cur_len, active,
                           state):
        """Write back what one decode step changed: the one appended row
        per active slot for paged leaves (position ``(cur_len-1) mod
        seq`` — ``layers.ring_row_index``, the same arithmetic the
        monolithic ``cache_update_row`` uses — redirected to the trash
        block for inactive slots), and a per-active-slot merge for state
        leaves (inactive and mid-prefill slots keep their old state)."""
        new_pool = dict(pool)
        if self.paged_names:
            pos = ring_row_index(cur_len, self.seq_len)
            blk_idx = pos // self.block
            off = pos % self.block
            blk = jnp.take_along_axis(tables, blk_idx[:, None],
                                      axis=1)[:, 0]
            blk = jnp.where(active, blk, self.n_blocks)  # trash if inactive
            for name in self.paged_names:
                pleaf, cleaf = pool[name], cache[name]
                ax = self._slot_ax[name]
                sl = (slice(None),) * ax
                rows = cleaf[sl + (jnp.arange(self.n_slots), pos)]
                new_pool[name] = pleaf.at[sl + (blk, off)].set(
                    rows.astype(pleaf.dtype))
        new_state = {}
        for name in self.state_names:
            ax = self._slot_ax[name]
            shape = [1] * cache[name].ndim
            shape[ax] = self.n_slots
            new_state[name] = jnp.where(
                active.reshape(shape), cache[name].astype(state[name].dtype),
                state[name])
        return new_pool, new_state

    def _copy_block_impl(self, pool, src, dst):
        """Device copy of one pool block (the copy-on-write body)."""
        out = dict(pool)
        for name in self.paged_names:
            pleaf = pool[name]
            ax = self._slot_ax[name]
            row = jax.lax.dynamic_index_in_dim(pleaf, src, axis=ax,
                                               keepdims=False)
            out[name] = jax.lax.dynamic_update_index_in_dim(
                pleaf, row, dst, axis=ax)
        return out

    def _reset_state_impl(self, state, slot_idx, template):
        out = dict(state)
        for name in self.state_names:
            ax = self._slot_ax[name]
            one = jax.lax.index_in_dim(template[name], 0, ax,
                                       keepdims=False)
            out[name] = jax.lax.dynamic_update_index_in_dim(
                state[name], one.astype(state[name].dtype), slot_idx,
                axis=ax)
        return out

    def _snap_state_impl(self, state, slot_idx):
        return {name: jax.lax.dynamic_index_in_dim(
                    state[name], slot_idx, axis=self._slot_ax[name],
                    keepdims=True)
                for name in self.state_names}

    def _restore_state_impl(self, state, slot_idx, snap):
        out = dict(state)
        for name in self.state_names:
            ax = self._slot_ax[name]
            one = jax.lax.index_in_dim(snap[name], 0, ax, keepdims=False)
            out[name] = jax.lax.dynamic_update_index_in_dim(
                state[name], one.astype(state[name].dtype), slot_idx,
                axis=ax)
        return out

    def _scatter_span_fn(self, n_span: int):
        """jitted writer of ``n_span`` consecutive blocks of one slot
        (prefill write-back, starting at block operand ``row0/block``),
        memoized per span length on the instance (a functools.lru_cache
        on the bound method would pin the pool)."""
        cached = self._span_fns.get(n_span)
        if cached is not None:
            return cached

        def impl(pool, cache, slot_idx, block_ids, row0):
            out = dict(pool)
            for name in self.paged_names:
                pleaf, cleaf = pool[name], cache[name]
                ax = self._slot_ax[name]
                sl = (slice(None),) * ax
                span = jax.lax.dynamic_index_in_dim(
                    cleaf, slot_idx, axis=ax, keepdims=False)
                lead = cleaf.shape[:ax]
                tail = cleaf.shape[ax + 2:]
                span = jax.lax.dynamic_slice_in_dim(
                    span, row0, n_span * self.block, axis=ax)
                span = span.reshape(lead + (n_span, self.block) + tail)
                out[name] = pleaf.at[sl + (block_ids,)].set(
                    span.astype(pleaf.dtype))
            return out
        fn = self._span_fns[n_span] = jax.jit(impl)
        return fn

    # -- host-side block management --------------------------------------

    def ensure(self, slot: int, length: int) -> bool:
        """Allocate blocks so positions [0, length) are writable; False
        when the pool is exhausted (caller evicts and retries)."""
        if not self.paged_names:
            return True           # pure-state family: nothing to allocate
        need = -(-min(length, self.seq_len) // self.block)
        if need > self.blocks_per_slot:
            raise ValueError(f"sequence length {length} exceeds the slot "
                             f"capacity {self.seq_len}")
        if need > self.n_blocks:
            # evicting every other slot could never free enough — without
            # this check the scheduler would requeue/readmit forever
            raise ValueError(f"sequence length {length} needs {need} "
                             f"blocks but the pool holds only "
                             f"{self.n_blocks}; raise page_blocks")
        while self.allocated[slot] < need:
            if not self.free_blocks:
                return False
            b = self.free_blocks.pop()
            self.tables[slot, self.allocated[slot]] = b
            self.allocated[slot] += 1
            self.refcount[b] = 1
        return True

    def free_slot(self, slot: int):
        n = int(self.allocated[slot])
        self._release(int(b) for b in self.tables[slot, :n])
        self.tables[slot, :] = 0
        self.allocated[slot] = 0

    def _release(self, blocks):
        for b in blocks:
            self.refcount[b] -= 1
            assert self.refcount[b] >= 0, f"refcount underflow on block {b}"
            if self.refcount[b] == 0:
                self.free_blocks.append(b)

    # -- prefix aliasing (repro.serving.prefix_cache) --------------------

    def adopt_blocks(self, slot: int, blocks: Sequence[int]):
        """Alias shared blocks (a frozen prefix) into the FRONT of an
        empty slot's table — prefill for those positions becomes this
        table write instead of a forward pass."""
        assert int(self.allocated[slot]) == 0, "adopt into a used slot"
        for j, b in enumerate(blocks):
            self.tables[slot, j] = int(b)
            self.refcount[int(b)] += 1
        self.allocated[slot] = len(blocks)

    def share_blocks(self, slot: int, n_blocks: int) -> List[int]:
        """Take shared references on the slot's first ``n_blocks`` blocks
        (prefix-cache publication); the caller owns the new references
        and must release_blocks() them eventually."""
        assert n_blocks <= int(self.allocated[slot])
        blocks = [int(b) for b in self.tables[slot, :n_blocks]]
        for b in blocks:
            self.refcount[b] += 1
        return blocks

    def release_blocks(self, blocks: Sequence[int]):
        """Drop shared references taken by share_blocks/adopt_blocks."""
        self._release(int(b) for b in blocks)

    def cow_for_write(self, slot: int, block_idxs) -> bool:
        """Copy-on-write: before writing through the given table indices
        of ``slot``, replace any SHARED physical block (refcount > 1)
        with a private copy.  False when the pool has no free block for
        the copy (caller frees/evicts and retries)."""
        for j in sorted({int(i) for i in block_idxs}):
            b = int(self.tables[slot, j])
            if self.refcount[b] <= 1:
                continue
            if not self.free_blocks:
                return False
            nb = self.free_blocks.pop()
            self.pool = self._copy_block(self.pool,
                                         jnp.asarray(b, jnp.int32),
                                         jnp.asarray(nb, jnp.int32))
            self.refcount[b] -= 1
            self.refcount[nb] = 1
            self.tables[slot, j] = nb
            self.cow_copies += 1
        return True

    @property
    def free_block_count(self) -> int:
        return len(self.free_blocks)

    @property
    def live_blocks(self) -> int:
        """Blocks holding at least one reference (conservation probe:
        live + free == n_blocks always)."""
        return int((self.refcount[:self.n_blocks] > 0).sum())

    def device_tables(self) -> jax.Array:
        return jnp.asarray(self.tables)

    # -- high-level ops the runtime uses ---------------------------------

    def gather(self, tables: jax.Array):
        return self._gather(self.pool, tables, self.state)

    def scatter_rows(self, tables, cache, cur_len, active):
        self.pool, self.state = self._scatter_rows(
            self.pool, tables, cache, cur_len, active, self.state)

    def set_state_from(self, cache):
        """Adopt the state leaves of a (already slot-selected) cache view
        — the prefill write-back for the non-paged leaves."""
        if self.state_names:
            self.state = {n: cache[n] for n in self.state_names}

    def reset_state_slot(self, slot: int):
        """Admission-time state reset from the single-slot template (the
        paged counterpart of SlotCacheOps.reset_slot; paged leaves need
        no reset — stale rows are masked or overwritten)."""
        if self.state_names:
            self.state = self._reset_state(
                self.state, jnp.asarray(slot, jnp.int32),
                self.state_template)

    def snapshot_state(self, slot: int) -> Dict[str, jax.Array]:
        """Single-slot copy of the state leaves (prefix-cache snapshot at
        a chunk boundary)."""
        return dict(self._snap_state(self.state,
                                     jnp.asarray(slot, jnp.int32)))

    def restore_state(self, slot: int, snap: Dict[str, jax.Array]):
        if self.state_names:
            self.state = self._restore_state(
                self.state, jnp.asarray(slot, jnp.int32), snap)

    def write_slot_prefix(self, slot: int, cache, length: int,
                          start: int = 0):
        """Persist positions [start, length) of ``slot`` from a
        contiguous cache view into the slot's allocated blocks (prefill /
        chunk write-back).  ``start`` skips blocks already persisted by
        earlier chunks (and, crucially, never rewrites ALIASED prefix
        blocks below it)."""
        if not self.paged_names:
            return
        length = min(length, self.seq_len)
        start = min(start, length)
        b0 = start // self.block
        nb_used = -(-length // self.block)
        n_span = nb_used - b0
        if n_span <= 0:
            return
        assert nb_used <= int(self.allocated[slot]), (nb_used,
                                                      self.allocated[slot])
        if not self.cow_for_write(slot, range(b0, nb_used)):
            raise RuntimeError("pool exhausted during copy-on-write "
                               "span write")   # caller sized the pool
        fn = self._scatter_span_fn(n_span)
        self.pool = fn(self.pool, cache, jnp.asarray(slot, jnp.int32),
                       jnp.asarray(self.tables[slot, b0:nb_used]),
                       jnp.asarray(b0 * self.block, jnp.int32))
