"""Per-slot cache operations + block-paged KV-cache pool.

Two layers:

:class:`SlotCacheOps` — family-generic *monolithic* slot operations,
driven by each model's ``cache_axes`` (the ``"cache_batch"`` logical
axis marks the slot dimension of every cache leaf, wherever it sits —
axis 1 for the dense/MoE/encdec stacks, axis 2 for the vlm group nesting
and the hybrid conv/lru states).  Used by the runtime to freeze
non-participating slots around a prefill call (functional
snapshot-select, no model changes) and to reset a slot at admission.

:class:`PagedKV` — a block-paged pool replacing the monolithic
``(layers, slots, max_len, ...)`` buffers for the attention-cache
families whose every leaf shares the layout ``(*lead, slot, seq, *tail)``
with one sequence length (dense, moe, mla_moe, encdec).  The pool stores
``n_blocks`` blocks of ``block`` positions per leaf; each slot owns a
block table (host-side) with blocks allocated on demand as its sequence
grows.  Memory no longer scales as ``slots x max_len`` but as the sum of
*live* sequence lengths (rounded up to blocks); a finishing request
frees its blocks immediately, and pool pressure triggers scheduler
eviction instead of OOM.

The decode step still consumes a contiguous ``(…, slot, seq, …)`` view:
``gather`` materializes it from the pool (a copy — the correctness-first
realization; a paged-attention kernel reading the pool in place is the
obvious next optimization and slots behind the same interface), the
model runs unchanged, and ``scatter_rows`` writes back exactly the one
row per active slot the decode step appended.  Unallocated table entries
point at block 0; reads through them see unrelated bytes, which is safe
because attention masks every position >= the slot's current length, and
writes never go through them (decode writes only at allocated positions;
inactive slots are redirected to a dedicated trash block).
Per-token paged-vs-monolithic equivalence is asserted in
tests/test_serving.py.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SlotCacheOps", "PagedKV"]


def _axes_tree(model, cfg):
    if getattr(model, "cache_axes", None) is None:
        return None
    return model.cache_axes(cfg)


def _pathkey(path) -> Tuple[str, ...]:
    return tuple(str(k) for k in path)


def _leaf_axes(axes_tree, cache) -> Dict[Tuple, Tuple]:
    """{stringified leaf path: logical axes tuple} for the cache tree."""
    is_ax = lambda x: isinstance(x, tuple)
    flat_cache = jax.tree_util.tree_flatten_with_path(cache)[0]
    if axes_tree is None:
        return {_pathkey(path): None for path, _ in flat_cache}
    flat_axes = jax.tree_util.tree_flatten_with_path(
        axes_tree, is_leaf=is_ax)[0]
    ax = {_pathkey(path): v for path, v in flat_axes}
    return {_pathkey(path): ax.get(_pathkey(path))
            for path, _ in flat_cache}


def _slot_axis(axes: Optional[Tuple]) -> int:
    if axes is None:
        return 1          # every family's default cache layout
    return axes.index("cache_batch")


class SlotCacheOps:
    """Family-generic per-slot select / reset on a monolithic cache."""

    def __init__(self, cfg, model):
        self.cfg, self.model = cfg, model
        self._axes = _axes_tree(model, cfg)
        self._select = jax.jit(self._select_impl)

    def _slot_axes_for(self, cache) -> List[int]:
        la = _leaf_axes(self._axes, cache)
        return [_slot_axis(v) for v in la.values()]

    def _select_impl(self, new_cache, old_cache, mask):
        """Per-slot select: leaves of ``new_cache`` where ``mask`` is set
        (along each leaf's slot axis), ``old_cache`` elsewhere — the
        functional freeze of non-participating slots."""
        axes = self._slot_axes_for(new_cache)
        flat_new, tree = jax.tree_util.tree_flatten(new_cache)
        flat_old = jax.tree_util.tree_flatten(old_cache)[0]
        out = []
        for new, old, ax in zip(flat_new, flat_old, axes):
            shape = [1] * new.ndim
            shape[ax] = mask.shape[0]
            out.append(jnp.where(mask.reshape(shape), new, old))
        return jax.tree_util.tree_unflatten(tree, out)

    def select_slots(self, new_cache, old_cache, mask: jax.Array):
        return self._select(new_cache, old_cache, mask)

    def reset_slot(self, cache, slot_idx: int, template):
        """Write a freshly initialized single-slot cache (``template``,
        from ``init_cache(cfg, 1, ...)``) into slot ``slot_idx``."""
        axes = self._slot_axes_for(cache)
        flat_c, tree = jax.tree_util.tree_flatten(cache)
        flat_t = jax.tree_util.tree_flatten(template)[0]
        out = []
        idx = jnp.asarray(slot_idx, jnp.int32)  # x64: keep s32 indices
        for leaf, one, ax in zip(flat_c, flat_t, axes):
            one = jax.lax.index_in_dim(one, 0, ax, keepdims=False)
            out.append(jax.lax.dynamic_update_index_in_dim(
                leaf, one.astype(leaf.dtype), idx, axis=ax))
        return jax.tree_util.tree_unflatten(tree, out)


class PagedKV:
    """Block-paged pool + host-side block tables (see module docstring).

    Supported cache layouts: every leaf ``(*lead, slot, seq, *tail)``
    with the same ``seq`` length (``supported()`` checks).  The last pool
    block (id ``n_blocks``) is the write trash for inactive slots and is
    never allocated.
    """

    def __init__(self, cfg, model, n_slots: int, max_len: int,
                 block: int = 16, n_blocks: Optional[int] = None):
        self.cfg, self.model = cfg, model
        self.n_slots = n_slots
        # shapes only — materializing the monolithic cache here would
        # transiently double KV memory, the very regime paging avoids
        cache = jax.eval_shape(
            lambda: model.init_cache(cfg, n_slots, max_len))
        axes = _leaf_axes(_axes_tree(model, cfg), cache)
        self._slot_ax = {p: _slot_axis(v) for p, v in axes.items()}
        seqs = {leaf.shape[self._slot_ax[p] + 1]
                for (p, leaf) in jax.tree_util.tree_flatten_with_path(
                    cache)[0]
                for p in [tuple(str(k) for k in p)]}
        if len(seqs) != 1:
            raise ValueError(f"paged KV needs one shared sequence length "
                             f"across cache leaves, got {sorted(seqs)}")
        self.seq_len = seqs.pop()
        if self.seq_len % block != 0:
            raise ValueError(f"block={block} must divide the cache length "
                             f"{self.seq_len}")
        self.block = block
        self.blocks_per_slot = self.seq_len // block
        if n_blocks is None:
            n_blocks = n_slots * self.blocks_per_slot
        self.n_blocks = n_blocks
        # host-side tables: unallocated entries point at block 0 (read-
        # only garbage, masked by attention); trash block id = n_blocks.
        self.tables = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        self.allocated = np.zeros((n_slots,), np.int32)    # blocks per slot
        self.free_blocks: List[int] = list(range(n_blocks - 1, -1, -1))
        self._flat_paths = [tuple(str(k) for k in p) for p, _ in
                            jax.tree_util.tree_flatten_with_path(cache)[0]]
        self._tree = jax.tree_util.tree_structure(cache)
        self.pool = self._pool_from(cache)
        self._gather = jax.jit(self._gather_impl)
        self._scatter_rows = jax.jit(self._scatter_rows_impl)
        self._span_fns = {}

    # -- support probe ---------------------------------------------------

    @staticmethod
    def supported(cfg, model, max_len: int) -> bool:
        if cfg.family not in ("dense", "moe", "mla_moe"):
            # vlm nests slots under a group axis with a second sequence
            # length (vision cross-KV); encdec/vlm cross caches are
            # admission-time context writes spanning the whole sequence,
            # which would force full allocation and defeat paging; the
            # ssm/hybrid states are constant-size (nothing to page).
            return False
        cache = jax.eval_shape(lambda: model.init_cache(cfg, 1, max_len))
        axes = _leaf_axes(_axes_tree(model, cfg), cache)
        seqs = set()
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            p = tuple(str(k) for k in path)
            ax = _slot_axis(axes[p])
            if leaf.ndim < ax + 2:
                return False
            seqs.add(leaf.shape[ax + 1])
        return len(seqs) == 1

    # -- device ops ------------------------------------------------------

    def _pool_leaves(self, cache_like):
        flat = jax.tree_util.tree_flatten(cache_like)[0]
        return list(zip(self._flat_paths, flat))

    def _pool_from(self, cache):
        """Zeroed pool with one block-paged buffer per cache leaf (shapes
        taken from the monolithic layout's ShapeDtypeStructs); nothing is
        allocated initially — slot contents are written at prefill."""
        out = []
        for path, leaf in self._pool_leaves(cache):
            ax = self._slot_ax[path]
            lead, tail = leaf.shape[:ax], leaf.shape[ax + 2:]
            pool = jnp.zeros(lead + (self.n_blocks + 1, self.block) + tail,
                             leaf.dtype)
            out.append(pool)
        return jax.tree_util.tree_unflatten(self._tree, out)

    def _gather_impl(self, pool, tables):
        """(pool, (S, bps) tables) -> contiguous (*lead, S, seq, *tail)."""
        out = []
        for path, pleaf in self._pool_leaves(pool):
            ax = self._slot_ax[path]
            g = jnp.take(pleaf, tables, axis=ax)  # (*lead, S, bps, blk, *tail)
            lead = pleaf.shape[:ax]
            tail = pleaf.shape[ax + 2:]
            out.append(g.reshape(lead + (self.n_slots, self.seq_len) + tail))
        return jax.tree_util.tree_unflatten(self._tree, out)

    def _scatter_rows_impl(self, pool, tables, cache, cur_len, active):
        """Write back the one row per slot the decode step appended:
        position ``(cur_len - 1) mod seq``, redirected to the trash block
        for inactive slots."""
        pos = (cur_len - 1) % self.seq_len
        blk_idx = pos // self.block
        off = pos % self.block
        blk = jnp.take_along_axis(tables, blk_idx[:, None], axis=1)[:, 0]
        blk = jnp.where(active, blk, self.n_blocks)     # trash for inactive
        out = []
        for (path, pleaf), (_, cleaf) in zip(self._pool_leaves(pool),
                                             self._pool_leaves(cache)):
            ax = self._slot_ax[path]
            sl = (slice(None),) * ax
            rows = cleaf[sl + (jnp.arange(self.n_slots), pos)]
            out.append(pleaf.at[sl + (blk, off)].set(
                rows.astype(pleaf.dtype)))
        return jax.tree_util.tree_unflatten(self._tree, out)

    def _scatter_span_fn(self, nb_used: int):
        """jitted writer of a slot's first ``nb_used`` blocks (admission /
        prefill write-back), memoized per span length on the instance
        (a functools.lru_cache on the bound method would pin the pool)."""
        cached = self._span_fns.get(nb_used)
        if cached is not None:
            return cached

        def impl(pool, cache, slot_idx, block_ids):
            out = []
            for (path, pleaf), (_, cleaf) in zip(self._pool_leaves(pool),
                                                 self._pool_leaves(cache)):
                ax = self._slot_ax[path]
                sl = (slice(None),) * ax
                span = jax.lax.dynamic_index_in_dim(
                    cleaf, slot_idx, axis=ax, keepdims=False)
                lead = cleaf.shape[:ax]
                tail = cleaf.shape[ax + 2:]
                span = jax.lax.slice_in_dim(
                    span, 0, nb_used * self.block, axis=ax)
                span = span.reshape(lead + (nb_used, self.block) + tail)
                out.append(pleaf.at[sl + (block_ids,)].set(
                    span.astype(pleaf.dtype)))
            return jax.tree_util.tree_unflatten(self._tree, out)
        fn = self._span_fns[nb_used] = jax.jit(impl)
        return fn

    # -- host-side block management --------------------------------------

    def ensure(self, slot: int, length: int) -> bool:
        """Allocate blocks so positions [0, length) are writable; False
        when the pool is exhausted (caller evicts and retries)."""
        need = -(-length // self.block)
        if need > self.blocks_per_slot:
            raise ValueError(f"sequence length {length} exceeds the slot "
                             f"capacity {self.seq_len}")
        if need > self.n_blocks:
            # evicting every other slot could never free enough — without
            # this check the scheduler would requeue/readmit forever
            raise ValueError(f"sequence length {length} needs {need} "
                             f"blocks but the pool holds only "
                             f"{self.n_blocks}; raise page_blocks")
        while self.allocated[slot] < need:
            if not self.free_blocks:
                return False
            b = self.free_blocks.pop()
            self.tables[slot, self.allocated[slot]] = b
            self.allocated[slot] += 1
        return True

    def free_slot(self, slot: int):
        n = int(self.allocated[slot])
        self.free_blocks.extend(int(b) for b in self.tables[slot, :n])
        self.tables[slot, :] = 0
        self.allocated[slot] = 0

    @property
    def free_block_count(self) -> int:
        return len(self.free_blocks)

    def device_tables(self) -> jax.Array:
        return jnp.asarray(self.tables)

    # -- high-level ops the runtime uses ---------------------------------

    def gather(self, tables: jax.Array):
        return self._gather(self.pool, tables)

    def scatter_rows(self, tables, cache, cur_len, active):
        self.pool = self._scatter_rows(self.pool, tables, cache,
                                       cur_len, active)

    def write_slot_prefix(self, slot: int, cache, length: int):
        """Persist positions [0, length) of ``slot`` from a contiguous
        cache view into the slot's allocated blocks (prefill / admission
        write-back)."""
        nb_used = -(-length // self.block)
        if nb_used == 0:
            return
        assert nb_used <= int(self.allocated[slot]), (nb_used,
                                                      self.allocated[slot])
        fn = self._scatter_span_fn(nb_used)
        self.pool = fn(self.pool, cache, jnp.asarray(slot, jnp.int32),
                       jnp.asarray(self.tables[slot, :nb_used]))
