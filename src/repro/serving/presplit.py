"""Freeze static model weights into their spec-resolved Ozaki splits.

``wrap_params`` walks a parameter tree and replaces every weight leaf
that the model layers consume through the plain projection contraction
``x[..., n] @ w[n, p]`` with a :class:`repro.core.engine.PresplitWeight`
— the original array bundled with its frozen int8 digit slices and
scales from a :class:`repro.core.split_cache.SplitCache`.  The engine
then skips the B-side splitter on every decode step (bit-identical; see
``core/split_cache.py``), which removes the dominant per-step splitting
cost: at decode the activations are a (B, 1, d) sliver while the weights
are the full (d, p) matrices.

Which leaves wrap is a *name-based* contract with the model layers: the
keys below are exactly the projection weights each family contracts via
``engine(x, w)`` (see the family modules).  Leaves with extra leading
axes (the layer-stacked parameters a ``lax.scan`` slices, the vlm
group/self nesting) are split per stack element in one batched call and
stored with the stack axes leading, so the scan's per-layer slicing of
the pytree yields exactly the per-layer wrapper.  Anything else — the
embedding table (a gather), MoE routers (f32 ``jnp.dot`` by design),
expert-batched MoE weights (a different dimension-numbers pattern) — is
left untouched; the wrapper's engine-side dnums guard would make
wrapping them a silent no-op anyway, this just avoids dead cache
entries.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import split_cache as sc
from repro.core import splitting
from repro.core.engine import MatmulEngine, PresplitWeight

__all__ = ["WRAP_KEYS", "wrap_params", "wrappable_paths",
           "wrapped_weight_bytes"]

# projection weights consumed as engine(x, w) — contract w's axis 0
WRAP_KEYS = frozenset({
    "wq", "wk", "wv", "wo",                    # GQA attention
    "w_gate", "w_up", "w_down",                # MLPs (dense + shared expert)
    "w_dkv", "w_krope", "w_q", "w_uk", "w_uv", "w_o",   # MLA
    "w_in", "w_x", "w_out",                    # SSM / recurrent blocks
    "lm_head",
})


def _wrappable(path: Tuple[str, ...], leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
        return False
    if path[-1] not in WRAP_KEYS:
        return False
    # expert-batched MoE weights live under .../moe/{w_gate,w_up,w_down}
    # and contract expert-batched (a different dnums); the shared expert
    # under .../moe/shared/... is a plain projection and does wrap.
    if "moe" in path[:-1] and "shared" not in path[:-1]:
        return False
    return True


def wrappable_paths(params) -> list:
    """The parameter paths ``wrap_params`` would freeze (introspection)."""
    found = []

    def walk(tree, path):
        if isinstance(tree, dict):
            for key in tree:
                walk(tree[key], path + (key,))
        elif isinstance(tree, (list, tuple)):
            for i, sub in enumerate(tree):
                walk(sub, path + (str(i),))
        elif tree is not None and _wrappable(path, tree):
            found.append(path)

    walk(params, ())
    return found


def _stacked_rhs_dnums(ndim: int):
    """dnums describing a stacked weight (*stack, n, p) as the rhs of a
    stack-batched projection: contract axis ndim-2, batch the stack axes.
    (The lhs half is a placeholder with matching arity — only the rhs
    half determines the canonical split layout and the cache key.)"""
    stack = tuple(range(ndim - 2))
    return (((len(stack),), (ndim - 2,)), (stack, stack))


def freeze_weight(w: jax.Array, engine: MatmulEngine,
                  cache: sc.SplitCache) -> PresplitWeight:
    """One leaf (*stack, n, p) -> PresplitWeight with stack-leading splits."""
    cfg = engine.ozimmu_config
    compute = jnp.float64 if cfg.accum_dtype == "f64" and \
        jax.config.jax_enable_x64 else jnp.float32
    nstack = w.ndim - 2
    # the cache keys/anchors on `w` itself and casts internally (keying
    # on a throwaway cast array would drop the entry at once); the
    # stack_leading layout is stored directly so the cached entry IS the
    # wrapper's storage — stack axes lead, lax.scan slices per layer.
    sp = cache.get(w, _stacked_rhs_dnums(w.ndim), cfg, dtype=compute,
                   layout="stack_leading")
    k = int(sp.digits.shape[nstack])
    return PresplitWeight(w, sp.digits, sp.scale, sp.base, sp.gbase,
                          int(sp.beta), cfg.split, k)


def wrapped_weight_bytes(wrapped_params, engine: MatmulEngine) -> int:
    """Compute-dtype bytes of the weights whose splits are frozen in a
    ``wrap_params`` output — the splitter-input volume every step SKIPS
    (the ``avoided_split_bytes`` metric counts it once per consumed
    position)."""
    if not engine.is_ozimmu:
        return 0
    oz = engine.ozimmu_config
    itemsize = 8 if (oz.accum_dtype == "f64"
                     and jax.config.jax_enable_x64) else 4
    return sum(
        int(np.prod(w.array.shape)) * itemsize
        for w in jax.tree_util.tree_leaves(
            wrapped_params,
            is_leaf=lambda x: isinstance(x, PresplitWeight))
        if isinstance(w, PresplitWeight))


def wrap_params(params, engine: MatmulEngine,
                cache: Optional[sc.SplitCache] = None):
    """Return ``(wrapped_params, cache)`` — a copy of the tree with every
    wrappable projection weight frozen through ``cache`` (created when
    None).  Non-ozimmu engines return the tree untouched.

    Re-wrapping after a weight update is exactly this call again: updated
    leaves are new arrays (new identity ⇒ cache miss ⇒ fresh split, and
    the dropped old arrays take their cache entries with them via the
    weakref anchors); unchanged leaves hit the cache.
    """
    if cache is None:
        cache = sc.SplitCache()
    if not engine.is_ozimmu:
        return params, cache

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, path + (str(i),))
                              for i, v in enumerate(tree))
        if tree is not None and _wrappable(path, tree):
            return freeze_weight(tree, engine, cache)
        return tree

    return walk(params, ()), cache
