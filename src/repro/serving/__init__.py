"""Serving runtime: continuous batching + persistent weight split-cache.

The inference-side system layer over the emulated-GEMM engine
(docs/serving.md):

* :mod:`repro.serving.scheduler`  — host-side FIFO continuous batching
  (slot admission / eviction, bucketed prefill + chunk grouping).
* :mod:`repro.serving.kvcache`    — block-paged KV-cache pool (per-family
  state descriptors, copy-on-write block aliasing) plus the
  family-generic per-slot cache operations.
* :mod:`repro.serving.prefix_cache` — frozen shared prompt prefixes
  served by block-table aliasing instead of a forward pass.
* :mod:`repro.serving.presplit`   — freezes static weight matrices into
  their spec-resolved int8 splits (``repro.core.split_cache``) so decode
  steps skip the B-side splitter entirely.
* :mod:`repro.serving.metrics`    — tokens/s, TTFT, queue depth,
  split-cache and prefix-cache savings.
* :mod:`repro.serving.runtime`    — :class:`ServingRuntime`, the engine
  room tying them together around jitted chunk/decode steps.
"""
from repro.serving.kvcache import PagedKV
from repro.serving.metrics import ServingMetrics
from repro.serving.prefix_cache import PrefixCache
from repro.serving.runtime import ServingRuntime
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ServingRuntime", "ServingMetrics", "Request", "Scheduler",
           "PagedKV", "PrefixCache"]
