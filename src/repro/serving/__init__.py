"""Serving runtime: continuous batching + persistent weight split-cache.

The inference-side system layer over the emulated-GEMM engine
(docs/serving.md):

* :mod:`repro.serving.scheduler`  — host-side FIFO continuous batching
  (slot admission / eviction, bucketed prefill grouping).
* :mod:`repro.serving.kvcache`    — block-paged KV-cache pool plus the
  family-generic per-slot cache operations.
* :mod:`repro.serving.presplit`   — freezes static weight matrices into
  their spec-resolved int8 splits (``repro.core.split_cache``) so decode
  steps skip the B-side splitter entirely.
* :mod:`repro.serving.metrics`    — tokens/s, TTFT, queue depth,
  split-cache savings.
* :mod:`repro.serving.runtime`    — :class:`ServingRuntime`, the engine
  room tying them together around jitted prefill/decode steps.
"""
from repro.serving.metrics import ServingMetrics
from repro.serving.runtime import ServingRuntime
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ServingRuntime", "ServingMetrics", "Request", "Scheduler"]
