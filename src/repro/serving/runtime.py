"""ServingRuntime — the continuous-batching inference engine room.

Ties together the scheduler (host policy), the per-slot / paged caches,
the prefix cache, the presplit weight wrapping, and ONE family of jitted
device steps with a prefill-chunk/decode mode switch:

* ``decode``: one token for every decode-ready slot, each at its OWN
  sequence position (the per-slot ``cur_len`` vector the model families
  accept).  Free and mid-prefill slots compute garbage that either a
  ``cur == 0`` no-op (attention rows), a per-active-slot merge (paged
  state leaves), or a per-slot select (monolithic state families under
  chunking) discards — ONE compiled step serves any occupancy pattern.
* ``chunk`` (per bucket length Lb): a ``lax.scan`` of the decode step
  over Lb positions, teacher-forcing a SLICE of each participating
  prompt RIGHT-ALIGNED in the bucket, starting from ``base`` tokens
  already resident in the slot's cache (``cur = base + i - start + 1``).
  With ``prefill_chunk=None`` the slice is the whole prompt and this IS
  the PR 5 monolithic prefill; with a chunk size C, each scheduler round
  feeds at most C prompt tokens per pending slot and then decodes the
  resident slots — a long prompt no longer stalls everyone's TTFT
  (docs/serving.md derives the TTFT model).  Splitting the scan is
  bitwise-exact: the scan body is the same per-token function either
  way, and each chunk call resumes from exactly the cache the previous
  one wrote.  Slots not in the call are frozen functionally (a per-slot
  select on a cache copy — no model support needed).  The final chunk's
  last-position logits are the slot's first-token prediction.  State
  families (ssm/hybrid) bucket by exact length: their recurrent states
  integrate every fed token, so right-padding can't be masked after the
  fact.

The prefix cache (``repro.serving.prefix_cache``): with paged KV on, a
request whose prompt starts with a previously-published prefix ADOPTS
the frozen pool blocks by table aliasing (plus a state-snapshot restore
for recurrent leaves) and prefills only the suffix — bitwise-identical
to a cold prefill because the frozen blocks were written by the same
jitted chunk calls over the same tokens.  Copy-on-write in the pool
keeps aliased blocks sound if a ring-wrap write ever reaches one.

The weight split-cache: with an ozimmu engine, ``wrap_params`` freezes
every projection weight's int8 digit slices once (eagerly, through
``repro.core.split_cache.SplitCache``), and every jitted step consumes
the wrapped tree — decode-time B-side splitting drops out entirely,
bit-identical to the unwrapped path.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import use_rules
from repro.models import api
from repro.serving import presplit as presplit_mod
from repro.serving.kvcache import PagedKV, SlotCacheOps, STATE_DESCRIPTORS
from repro.obs import registry as _obs
from repro.serving.metrics import ServingMetrics
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ServingRuntime"]

_STATE_FAMILIES = ("ssm", "hybrid")


def _has_state_leaves(cfg) -> bool:
    desc = STATE_DESCRIPTORS.get(cfg.family)
    return desc is not None and "state" in desc.values()


class ServingRuntime:
    """Continuous-batching server over one model + parameter set.

    Args:
      cfg: ModelConfig (the engine spec rides inside it).
      params: model parameters (raw; wrapped internally when presplit).
      slots: decode-slot count (the compiled batch dimension).
      max_len: per-slot cache capacity (prompt + generation budget).
      page_block: positions per KV block — enables the paged pool
        (every family; pure-state families page nothing but gain the
        per-slot state machinery); None keeps the monolithic cache.
      page_blocks: pool size in blocks (default: full capacity,
        slots * max_len / page_block; smaller values exercise eviction).
      prefill_chunk: max prompt tokens fed per slot per scheduler round;
        None prefills whole prompts in one call (the PR 5 behavior).
      prefix_cache: True builds a :class:`PrefixCache` over the paged
        pool (requires ``page_block``); an existing instance bound to
        this runtime's pool is also accepted.
      presplit: freeze weight splits (default: on for ozimmu engines).
      ctx: static per-slot context for the vlm/encdec families, shaped
        for ONE slot (the runtime shares it across slots, matching the
        pre-runtime serve driver).
      now: clock (injectable for deterministic tests).
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 128,
                 page_block: Optional[int] = None,
                 page_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: Union[bool, PrefixCache] = False,
                 presplit: Optional[bool] = None, ctx=None,
                 now=time.monotonic):
        self.cfg, self.model = cfg, api.get_model(cfg)
        self.n_slots, self.max_len = slots, max_len
        self.ctx = ctx
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        engine = cfg.engine
        self.split_cache = None
        self._wrapped_bytes = 0       # weight bytes whose split is frozen
        self._avoided_split_bytes = 0  # splitter input bytes skipped so far
        use_presplit = engine.is_ozimmu if presplit is None else presplit
        if use_presplit and engine.is_ozimmu:
            self.params, self.split_cache = presplit_mod.wrap_params(
                params, engine)
            self._wrapped_bytes = presplit_mod.wrapped_weight_bytes(
                self.params, engine)
        else:
            self.params = params
        self.sched = Scheduler(
            slots, bucket="exact" if cfg.family in _STATE_FAMILIES
            else "pow2")
        self.ops = SlotCacheOps(cfg, self.model)
        self.metrics = ServingMetrics(now=now)
        self._now = now
        # trace-time emulation counts of ONE decode step (captured around
        # the first, compiling, decode call — a compiled step replays the
        # same contractions every execution).  None until a step traced
        # with obs enabled; persists across reset_metrics.
        self.decode_observed: Optional[Dict[str, float]] = None

        batch_ctx = None if ctx is None else jnp.concatenate(
            [ctx] * slots, axis=0)
        # single-slot template: the admission reset source (monolithic
        # always; paged only for families with resident state leaves).
        # Built with sharding rules disabled: a batch-of-1 cache cannot
        # satisfy a `cache_batch -> data` rule (jit arg shardings need
        # exact divisibility); the replicated template scatters into the
        # sharded cache under GSPMD fine.
        self._template_full = None
        if page_block is None or _has_state_leaves(cfg):
            with use_rules(None):
                self._template_full = self.model.init_cache(
                    cfg, 1, max_len, params=self.params, ctx=ctx)
        self.paged: Optional[PagedKV] = None
        if page_block is not None:
            if not PagedKV.supported(cfg, self.model, max_len,
                                     params=self.params, ctx=ctx):
                raise ValueError(
                    f"paged KV unsupported for family {cfg.family!r} "
                    f"(see repro.serving.kvcache); use page_block=None")
            self.paged = PagedKV(cfg, self.model, slots, max_len,
                                 block=page_block, n_blocks=page_blocks,
                                 params=self.params, ctx=batch_ctx,
                                 template=self._template_full)
            self.cache = None
        else:
            self.cache = self.model.init_cache(cfg, slots, max_len,
                                               params=self.params,
                                               ctx=batch_ctx)
        self.prefix: Optional[PrefixCache] = None
        # NOT a truthiness test: an empty PrefixCache instance has
        # len() == 0 and would silently disable itself
        if isinstance(prefix_cache, PrefixCache) or prefix_cache:
            if self.paged is None:
                raise ValueError("the prefix cache aliases paged blocks; "
                                 "it requires page_block")
            self.prefix = prefix_cache if isinstance(
                prefix_cache, PrefixCache) else PrefixCache(self.paged, cfg)
            if self.prefix.paged is not self.paged:
                raise ValueError("prefix cache bound to another pool")
        # monolithic decode must freeze mid-prefill slots' recurrent
        # states under chunking (attention rows are already cur==0
        # no-ops; paged state leaves merge per active slot instead)
        self._decode_select = (prefill_chunk is not None
                               and self.paged is None
                               and cfg.family in _STATE_FAMILIES)
        # host-side per-slot decode state
        self._cur = np.ones((slots,), np.int32)
        self._last_tok = np.zeros((slots,), np.int32)
        self._decode = jax.jit(self._decode_impl)
        self._decode_paged = jax.jit(self._decode_paged_impl)
        self._prefill_fns = {}
        self._evictions_at_reset = 0
        from repro.core.engine import presplit_trace_counts
        self._presplit_counts0 = presplit_trace_counts()
        self._presplit_rate = None    # measured once steps have traced

    # ------------------------------------------------------------------
    # jitted step bodies
    # ------------------------------------------------------------------

    def _step(self, params, cache, toks, cur):
        logits, new_cache = self.model.decode_step(params, self.cfg, cache,
                                                   toks, cur)
        nxt = jnp.argmax(logits[:, -1, :self.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        return nxt, new_cache

    def _decode_impl(self, params, cache, toks, cur, active):
        # no per-slot select by default: inactive slots carry cur == 0,
        # which makes their cache-row writes no-ops
        # (layers.cache_update_row); their other leaves may take garbage,
        # but every leaf is reset from the template at admission before
        # reuse.  The exception is chunked state families (see
        # _decode_select) — a mid-prefill slot's recurrent state is live
        # and must not integrate a decode step.
        nxt, new_cache = self._step(params, cache, toks, cur)
        if self._decode_select:
            new_cache = self.ops.select_slots(new_cache, cache, active)
        return nxt, new_cache

    def _decode_paged_impl(self, params, pool, state, tables, toks, cur,
                           active):
        paged = self.paged
        cache = paged._gather(pool, tables, state)
        nxt, new_cache = self._step(params, cache, toks, cur)
        pool, state = paged._scatter_rows(pool, tables, new_cache, cur,
                                          active, state)
        return nxt, pool, state

    def _chunk_body(self, params, cache, toks, start, base, newmask):
        """scan of the decode step over the bucket; each participating
        slot's chunk is right-aligned and resumes ``base`` tokens in."""
        Lb = toks.shape[1]

        def body(c, i):
            cur = jnp.where(newmask & (i >= start), base + i - start + 1, 0)
            tok = jax.lax.dynamic_slice_in_dim(toks, i, 1, axis=1)
            logits, c = self.model.decode_step(params, self.cfg, c, tok,
                                               cur)
            return c, logits[:, -1]

        cache, logits = jax.lax.scan(body, cache, jnp.arange(Lb))
        nxt = jnp.argmax(logits[-1][:, :self.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        return nxt, cache

    # per-instance memoization by bucket length (NOT functools.lru_cache
    # on the bound method — a class-level cache keyed on self would pin
    # every runtime, its params, and its cache alive for process life)
    def _prefill_fn(self, Lb: int):
        fn = self._prefill_fns.get(Lb)
        if fn is None:
            def impl(params, cache, toks, start, base, newmask):
                nxt, after = self._chunk_body(params, cache, toks,
                                              start, base, newmask)
                return nxt, self.ops.select_slots(after, cache, newmask)
            fn = self._prefill_fns[Lb] = jax.jit(impl)
        return fn

    def _prefill_paged_fn(self, Lb: int):
        fn = self._prefill_fns.get(("paged", Lb))
        if fn is None:
            def impl(params, pool, state, tables, toks, start, base,
                     newmask):
                cache0 = self.paged._gather(pool, tables, state)
                nxt, after = self._chunk_body(params, cache0, toks,
                                              start, base, newmask)
                return nxt, self.ops.select_slots(after, cache0, newmask)
            fn = self._prefill_fns[("paged", Lb)] = jax.jit(impl)
        return fn

    # ------------------------------------------------------------------
    # host loop
    # ------------------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int,
               eos_id: Optional[int] = None,
               arrival: Optional[float] = None) -> Request:
        plen = len(prompt)
        if plen + max_new > self.max_len and \
                self.cfg.family not in _STATE_FAMILIES and \
                not self.cfg.window:
            raise ValueError(f"prompt({plen}) + max_new({max_new}) exceeds "
                             f"max_len={self.max_len}")
        req = self.sched.submit(prompt, max_new, eos_id=eos_id,
                                arrival=self._now() if arrival is None
                                else arrival)
        self.metrics.requests_submitted += 1   # after validation
        return req

    def _pool_pressure(self, protect: int) -> bool:
        """Free pool blocks: LRU prefix entries go first (cache entries
        are cheaper to lose than live progress), then the scheduler
        preempts a slot.  False when ``protect`` itself was evicted."""
        t0 = self._now()
        try:
            if self.prefix is not None and self.prefix.release_one():
                return True
            victim = self.sched.pick_victim(protect=protect)
            if victim is None:
                victim = protect    # nothing else to take — preempt self
            self.sched.evict(victim)
            self.paged.free_slot(victim)
            return victim != protect
        finally:
            self.metrics.observe_timing("eviction", self._now() - t0)

    def _alloc_or_evict(self, slot: int, length: int) -> bool:
        """Paged block allocation with eviction pressure; False when the
        requesting slot itself was evicted."""
        if self.paged is None:
            return True
        while not self.paged.ensure(slot, length):
            if not self._pool_pressure(slot):
                return False
        return True

    def _cow_or_evict(self, slot: int, block_idxs) -> bool:
        """Copy-on-write with eviction pressure (a copy needs one free
        block); False when the requesting slot itself was evicted."""
        block_idxs = list(block_idxs)
        copies0 = self.paged.cow_copies
        t0 = self._now()
        try:
            while not self.paged.cow_for_write(slot, block_idxs):
                if not self._pool_pressure(slot):
                    return False
            return True
        finally:
            if self.paged.cow_copies > copies0:
                self.metrics.observe_timing("cow_copy", self._now() - t0)

    # -- admission -------------------------------------------------------

    def _on_admit(self, slot: int, req: Request):
        """Per-slot cache preparation at admission: template reset, or a
        prefix-cache adoption that starts the slot mid-prefill."""
        if self.paged is None:
            self.cache = self.ops.reset_slot(self.cache, slot,
                                             self._template_full)
            return
        entry = None if self.prefix is None else \
            self.prefix.lookup(req.prefill_tokens())
        if entry is not None:
            # prefill for the aliased positions is this table write
            self.sched.slots[slot].prefilled = self.prefix.adopt(slot,
                                                                 entry)
        else:
            self.paged.reset_state_slot(slot)

    # -- chunked prefill -------------------------------------------------

    def _plan_chunks(self) -> List[Tuple[int, Request, int]]:
        """One (slot, request, chunk_len) plan per pending-prefill slot.
        The chunk is the whole remaining prefill unless ``prefill_chunk``
        caps it; a publishable prompt additionally forces a boundary at
        its aligned publication length so the prefix snapshot exists."""
        plans = []
        for slot, req in self.sched.pending_prefill():
            total = len(req.prefill_tokens())
            done = self.sched.slots[slot].prefilled
            clen = total - done
            if self.prefill_chunk is not None:
                clen = min(clen, self.prefill_chunk)
            if self.prefix is not None and not req.generated:
                m_pub = self.prefix.max_publish_len(total)
                if done < m_pub:
                    clen = min(clen, m_pub - done)
            plans.append((slot, req, clen))
        return plans

    def _span_args(self, done: int, clen: int) -> Tuple[int, int]:
        """(length, start) for the pool write-back of a chunk that fed
        positions [done, done+clen): the straight span, or the whole
        ring when the chunk wrapped a windowed cache."""
        seq = self.paged.seq_len
        end = done + clen
        if done >= seq or end > seq:
            return seq, 0
        return end, done

    def _do_prefill_round(self):
        """Feed ONE chunk into every pending-prefill slot (grouped by
        chunk-length bucket so mixed lengths share compiled calls);
        final chunks produce the slot's first token."""
        plans = self._plan_chunks()
        if not plans:
            return
        for Lb, group in self.sched.chunk_groups(plans):
            # paged: allocate blocks for the chunk first (may evict
            # group members — drop those from this call), then privatize
            # any shared block the write-back span will touch
            ready = []
            for slot, req, clen in group:
                if self.sched.slots[slot].request is not req:
                    continue    # evicted by an earlier bucket this round
                done = self.sched.slots[slot].prefilled
                if not self._alloc_or_evict(slot, done + clen):
                    continue
                if self.paged is not None and self.paged.paged_names:
                    length, start = self._span_args(done, clen)
                    b0 = start // self.paged.block
                    nb = -(-length // self.paged.block)
                    if not self._cow_or_evict(slot, range(b0, nb)):
                        continue
                ready.append((slot, req, clen))
            # a later allocation may have evicted an earlier group member
            ready = [(s, r, c) for s, r, c in ready
                     if self.sched.slots[s].request is r]
            if not ready:
                continue
            toks = np.zeros((self.n_slots, Lb), np.int32)
            start = np.full((self.n_slots,), Lb, np.int32)
            base = np.zeros((self.n_slots,), np.int32)
            newmask = np.zeros((self.n_slots,), bool)
            for slot, req, clen in ready:
                done = self.sched.slots[slot].prefilled
                pt = req.prefill_tokens()
                toks[slot, Lb - clen:] = pt[done:done + clen]
                start[slot] = Lb - clen
                base[slot] = done
                newmask[slot] = True
            t0 = self._now()
            if self.paged is not None:
                fn = self._prefill_paged_fn(Lb)
                nxt, after = fn(self.params, self.paged.pool,
                                self.paged.state,
                                self.paged.device_tables(),
                                jnp.asarray(toks), jnp.asarray(start),
                                jnp.asarray(base), jnp.asarray(newmask))
                for slot, req, clen in ready:
                    done = self.sched.slots[slot].prefilled
                    length, span_start = self._span_args(done, clen)
                    self.paged.write_slot_prefix(slot, after, length,
                                                 start=span_start)
                self.paged.set_state_from(after)
            else:
                fn = self._prefill_fn(Lb)
                nxt, self.cache = fn(self.params, self.cache,
                                     jnp.asarray(toks), jnp.asarray(start),
                                     jnp.asarray(base),
                                     jnp.asarray(newmask))
            nxt = np.asarray(nxt)
            now = self._now()
            self.metrics.prefill_calls += 1
            self.metrics.observe_timing("prefill_call", now - t0)
            # every scanned position consumes every frozen weight split
            self._avoided_split_bytes += Lb * self._wrapped_bytes
            for slot, req, clen in ready:
                done = self.sched.slots[slot].prefilled
                total = len(req.prefill_tokens())
                self.metrics.prefill_tokens += clen
                if done + clen < total:
                    self.sched.on_chunk(slot, clen)
                    self.metrics.prefill_chunks += 1
                    self._maybe_publish(slot, req)
                    continue
                self.metrics.tokens_generated += 1  # the first new token
                finished = self.sched.on_prefilled(slot, int(nxt[slot]),
                                                   now)
                self._cur[slot] = self.sched.slots[slot].pos + 1 \
                    if not finished else 1
                self._last_tok[slot] = int(nxt[slot])
                if finished:
                    self._finish(slot, req, now)

    def _maybe_publish(self, slot: int, req: Request):
        """Publish the frozen prefix when a chunk boundary lands exactly
        on the prompt's aligned publication length (fresh prompts only —
        eviction resumes carry generated tokens and re-hit instead)."""
        if self.prefix is None or req.generated:
            return
        m_pub = self.prefix.max_publish_len(len(req.prompt))
        if m_pub >= self.prefix.block and \
                self.sched.slots[slot].prefilled == m_pub:
            self.prefix.publish(req.prompt, m_pub, slot)

    def _finish(self, slot: int, req: Request, now: float):
        if self.paged is not None:
            self.paged.free_slot(slot)
        self.metrics.record_finish(req, now)

    def _do_decode(self):
        active_idx = self.sched.decode_slots()
        if not active_idx:
            return
        if self.paged is not None:
            # this step writes row cur-1, so the slot needs `cur`
            # positions allocated, and the written block privatized
            survivors = []
            for slot in active_idx:
                if self.sched.slots[slot].request is None:
                    continue    # evicted back by pressure from a peer
                cur = int(self._cur[slot])
                if not self._alloc_or_evict(slot, cur):
                    continue
                if self.paged.paged_names:
                    pos = (cur - 1) % self.paged.seq_len
                    if not self._cow_or_evict(slot,
                                              [pos // self.paged.block]):
                        continue
                survivors.append(slot)
            active_idx = [s for s in survivors
                          if self.sched.slots[s].request is not None]
            if not active_idx:
                return
        active = np.zeros((self.n_slots,), bool)
        active[active_idx] = True
        # per-slot position of the token being written this step; 0 for
        # idle slots = "write nothing" (cache_update_row no-op)
        cur = np.where(active, self._cur, 0).astype(np.int32)
        toks = self._last_tok[:, None].astype(np.int32)
        cap = None
        if self.decode_observed is None and _obs.enabled():
            cap = _obs.get_registry().snapshot()
        t0 = self._now()
        if self.paged is not None:
            nxt, pool, state = self._decode_paged(
                self.params, self.paged.pool, self.paged.state,
                self.paged.device_tables(), jnp.asarray(toks),
                jnp.asarray(cur), jnp.asarray(active))
            self.paged.pool, self.paged.state = pool, state
        else:
            nxt, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(cur), jnp.asarray(active))
        nxt = np.asarray(nxt)
        now = self._now()
        if cap is not None:
            d = _obs.get_registry().snapshot().diff(cap)
            self.decode_observed = {
                "contractions": d.total("emulation.calls"),
                "int8_gemms": d.total("emulation.int8_gemms"),
                "int8_gemms_presplit": d.total("emulation.int8_gemms",
                                               presplit=1),
                "highprec_adds": d.total("emulation.highprec_adds"),
            }
        self.metrics.decode_steps += 1
        self.metrics.observe_timing("decode_step", now - t0)
        self._avoided_split_bytes += self._wrapped_bytes
        for slot in active_idx:
            req = self.sched.slots[slot].request
            self.metrics.tokens_generated += 1
            finished = self.sched.on_token(slot, int(nxt[slot]), now)
            if finished:
                self._finish(slot, req, now)
            else:
                self._cur[slot] = self.sched.slots[slot].pos + 1
                self._last_tok[slot] = int(nxt[slot])

    def step(self) -> bool:
        """One scheduler round: admit new requests, feed one prefill
        chunk per pending slot, then decode one token for every
        fully-prefilled slot.  Returns False when idle."""
        if self.sched.all_done:
            return False
        self.metrics.start()
        self.metrics.sample_queue(self.sched.queue_depth)
        for slot, req in self.sched.admit():
            self._on_admit(slot, req)
        self._do_prefill_round()
        self._do_decode()
        return True

    def run(self, max_steps: Optional[int] = None) -> Dict[str, Any]:
        """Drive the loop until every submitted request finished (or
        ``max_steps`` scheduler rounds); returns the metrics summary."""
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self.metrics.stop()
        # evictions within THIS metrics window (reset_metrics snapshots)
        self.metrics.evictions = self.sched.evictions - \
            self._evictions_at_reset
        if self.prefix is not None:
            self.metrics.prefix_cache = self.prefix.summary()
        if self.split_cache is not None:
            d = self.split_cache.stats.as_dict()
            # MEASURED hit rate from the engine's trace-time consumption
            # counters: the fraction of wrapped-weight contractions whose
            # frozen split actually applied (a silent `usable_split`
            # fallback — dnums/spec/dtype drift — lowers it, which is
            # what the bench gate exists to catch).  Compiled steps count
            # once at trace time; a window with no fresh traces (warm
            # replay after reset_metrics) keeps the last measured rate.
            from repro.core.engine import presplit_trace_counts
            counts = presplit_trace_counts()
            d_used = counts["used"] - self._presplit_counts0["used"]
            d_fb = counts["fallback"] - self._presplit_counts0["fallback"]
            if d_used + d_fb:
                self._presplit_rate = d_used / (d_used + d_fb)
            rate = self._presplit_rate
            if rate is None:
                rate = 1.0 if self._wrapped_bytes else 0.0
            d.update({
                "frozen_weight_bytes": self._wrapped_bytes,
                "avoided_split_bytes": self._avoided_split_bytes,
                "weight_split_hit_rate": rate,
            })
            self.metrics.split_cache = d
        return self.metrics.summary()

    def reset_metrics(self):
        """Fresh metrics window (e.g. timing a steady-state pass after a
        warm-up replay compiled every bucket).  Scheduler, caches, jit
        caches, and prefix-cache ENTRIES are untouched — the runtime
        keeps serving; prefix hit counters restart with the window."""
        self.metrics = ServingMetrics(now=self._now)
        self._avoided_split_bytes = 0
        self._evictions_at_reset = self.sched.evictions
        if self.prefix is not None:
            self.prefix.reset_stats()

    # convenience for tests / examples ---------------------------------

    def generate(self, prompts: List[np.ndarray], max_new: int,
                 eos_id: Optional[int] = None) -> List[np.ndarray]:
        """Submit a batch and run to completion; returns prompt+generated
        per request, in submission order."""
        reqs = [self.submit(p, max_new, eos_id=eos_id) for p in prompts]
        self.run()
        return [np.concatenate([r.prompt,
                                np.asarray(r.generated, np.int32)])
                for r in reqs]
