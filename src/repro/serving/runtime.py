"""ServingRuntime — the continuous-batching inference engine room.

Ties together the scheduler (host policy), the per-slot / paged caches,
the presplit weight wrapping, and two jitted device steps:

* ``decode``: one token for every active slot, each at its OWN sequence
  position (the per-slot ``cur_len`` vector the model families accept).
  Free slots compute garbage that a per-slot select discards, so ONE
  compiled step serves any occupancy pattern.
* ``prefill`` (per bucket length Lb): a ``lax.scan`` of the decode step
  over Lb positions, teacher-forcing the prompts of the newly admitted
  slots RIGHT-ALIGNED in the bucket — every prompt ends at the last scan
  step, so one compiled call serves mixed prompt lengths and its final
  logits are every new slot's first-token prediction (TTFT is one call
  after admission).  Slots not being prefilled are frozen functionally:
  the scan runs on a cache copy and a per-slot select keeps their old
  state (bitwise — no model support needed).  State families
  (ssm/hybrid) bucket by exact length instead: their recurrent states
  integrate every fed token, so right-padding can't be masked after the
  fact (docs/serving.md).

The weight split-cache: with an ozimmu engine, ``wrap_params`` freezes
every projection weight's int8 digit slices once (eagerly, through
``repro.core.split_cache.SplitCache``), and every jitted step consumes
the wrapped tree — decode-time B-side splitting drops out entirely,
bit-identical to the unwrapped path.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import use_rules
from repro.models import api
from repro.serving import presplit as presplit_mod
from repro.serving.kvcache import PagedKV, SlotCacheOps
from repro.serving.metrics import ServingMetrics
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ServingRuntime"]

_STATE_FAMILIES = ("ssm", "hybrid")


class ServingRuntime:
    """Continuous-batching server over one model + parameter set.

    Args:
      cfg: ModelConfig (the engine spec rides inside it).
      params: model parameters (raw; wrapped internally when presplit).
      slots: decode-slot count (the compiled batch dimension).
      max_len: per-slot cache capacity (prompt + generation budget).
      page_block: positions per KV block — enables the paged pool
        (attention-cache families only); None keeps the monolithic cache.
      page_blocks: pool size in blocks (default: full capacity,
        slots * max_len / page_block; smaller values exercise eviction).
      presplit: freeze weight splits (default: on for ozimmu engines).
      ctx: static per-slot context for the vlm/encdec families, shaped
        for ONE slot (the runtime shares it across slots, matching the
        pre-runtime serve driver).
      now: clock (injectable for deterministic tests).
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 128,
                 page_block: Optional[int] = None,
                 page_blocks: Optional[int] = None,
                 presplit: Optional[bool] = None, ctx=None,
                 now=time.monotonic):
        self.cfg, self.model = cfg, api.get_model(cfg)
        self.n_slots, self.max_len = slots, max_len
        self.ctx = ctx
        engine = cfg.engine
        self.split_cache = None
        self._wrapped_bytes = 0       # weight bytes whose split is frozen
        self._avoided_split_bytes = 0  # splitter input bytes skipped so far
        use_presplit = engine.is_ozimmu if presplit is None else presplit
        if use_presplit and engine.is_ozimmu:
            self.params, self.split_cache = presplit_mod.wrap_params(
                params, engine)
            oz = engine.ozimmu_config
            itemsize = 8 if (oz.accum_dtype == "f64"
                             and jax.config.jax_enable_x64) else 4
            from repro.core.engine import PresplitWeight
            self._wrapped_bytes = sum(
                int(np.prod(w.array.shape)) * itemsize
                for w in jax.tree_util.tree_leaves(
                    self.params,
                    is_leaf=lambda x: isinstance(x, PresplitWeight))
                if isinstance(w, PresplitWeight))
        else:
            self.params = params
        self.sched = Scheduler(
            slots, bucket="exact" if cfg.family in _STATE_FAMILIES
            else "pow2")
        self.ops = SlotCacheOps(cfg, self.model)
        self.metrics = ServingMetrics(now=now)
        self._now = now

        batch_ctx = None if ctx is None else jnp.concatenate(
            [ctx] * slots, axis=0)
        self.paged: Optional[PagedKV] = None
        if page_block is not None:
            if not PagedKV.supported(cfg, self.model, max_len):
                raise ValueError(
                    f"paged KV unsupported for family {cfg.family!r} "
                    f"(see repro.serving.kvcache); use page_block=None")
            self.paged = PagedKV(cfg, self.model, slots, max_len,
                                 block=page_block, n_blocks=page_blocks)
            self.cache = None
        else:
            self.cache = self.model.init_cache(cfg, slots, max_len,
                                               params=self.params,
                                               ctx=batch_ctx)
        # single-slot templates are built with sharding rules disabled: a
        # batch-of-1 cache cannot satisfy a `cache_batch -> data` rule
        # (jit arg shardings need exact divisibility); the replicated
        # template scatters into the sharded cache under GSPMD fine.
        with use_rules(None):
            self._template_full = None if self.paged is not None else \
                self.model.init_cache(cfg, 1, max_len, params=self.params,
                                      ctx=ctx)
        # host-side per-slot decode state
        self._cur = np.ones((slots,), np.int32)
        self._last_tok = np.zeros((slots,), np.int32)
        self._decode = jax.jit(self._decode_impl)
        self._decode_paged = jax.jit(self._decode_paged_impl)
        self._prefill_fns = {}
        self._evictions_at_reset = 0
        from repro.core.engine import presplit_trace_counts
        self._presplit_counts0 = presplit_trace_counts()
        self._presplit_rate = None    # measured once steps have traced

    # ------------------------------------------------------------------
    # jitted step bodies
    # ------------------------------------------------------------------

    def _step(self, params, cache, toks, cur):
        logits, new_cache = self.model.decode_step(params, self.cfg, cache,
                                                   toks, cur)
        nxt = jnp.argmax(logits[:, -1, :self.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        return nxt, new_cache

    def _decode_impl(self, params, cache, toks, cur, active):
        # no per-slot select here: inactive slots carry cur == 0, which
        # makes their cache-row writes no-ops (layers.cache_update_row);
        # their other leaves may take garbage, but every leaf is reset
        # from the template at admission before reuse.  A select would
        # cost one full pass over every cache leaf per decoded token.
        del active
        return self._step(params, cache, toks, cur)

    def _decode_paged_impl(self, params, pool, tables, toks, cur, active):
        paged = self.paged
        cache = paged._gather(pool, tables)
        nxt, new_cache = self._step(params, cache, toks, cur)
        pool = paged._scatter_rows(pool, tables, new_cache, cur, active)
        return nxt, pool

    def _prefill_body(self, params, cache, toks, start, newmask):
        """scan of the decode step over the bucket; right-aligned."""
        Lb = toks.shape[1]

        def body(c, i):
            cur = jnp.where(newmask & (i >= start), i - start + 1, 0)
            tok = jax.lax.dynamic_slice_in_dim(toks, i, 1, axis=1)
            logits, c = self.model.decode_step(params, self.cfg, c, tok,
                                               cur)
            return c, logits[:, -1]

        cache, logits = jax.lax.scan(body, cache, jnp.arange(Lb))
        nxt = jnp.argmax(logits[-1][:, :self.cfg.vocab],
                         axis=-1).astype(jnp.int32)
        return nxt, cache

    # per-instance memoization by bucket length (NOT functools.lru_cache
    # on the bound method — a class-level cache keyed on self would pin
    # every runtime, its params, and its cache alive for process life)
    def _prefill_fn(self, Lb: int):
        fn = self._prefill_fns.get(Lb)
        if fn is None:
            def impl(params, cache, toks, start, newmask):
                nxt, after = self._prefill_body(params, cache, toks,
                                                start, newmask)
                return nxt, self.ops.select_slots(after, cache, newmask)
            fn = self._prefill_fns[Lb] = jax.jit(impl)
        return fn

    def _prefill_paged_fn(self, Lb: int):
        fn = self._prefill_fns.get(("paged", Lb))
        if fn is None:
            def impl(params, pool, tables, toks, start, newmask):
                cache0 = self.paged._gather(pool, tables)
                nxt, after = self._prefill_body(params, cache0, toks,
                                                start, newmask)
                return nxt, self.ops.select_slots(after, cache0, newmask)
            fn = self._prefill_fns[("paged", Lb)] = jax.jit(impl)
        return fn

    # ------------------------------------------------------------------
    # host loop
    # ------------------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int,
               eos_id: Optional[int] = None,
               arrival: Optional[float] = None) -> Request:
        plen = len(prompt)
        if plen + max_new > self.max_len and \
                self.cfg.family not in _STATE_FAMILIES and \
                not self.cfg.window:
            raise ValueError(f"prompt({plen}) + max_new({max_new}) exceeds "
                             f"max_len={self.max_len}")
        req = self.sched.submit(prompt, max_new, eos_id=eos_id,
                                arrival=self._now() if arrival is None
                                else arrival)
        self.metrics.requests_submitted += 1   # after validation
        return req

    def _alloc_or_evict(self, slot: int, length: int) -> bool:
        """Paged block allocation with eviction pressure; False when the
        requesting slot itself was evicted."""
        if self.paged is None:
            return True
        while not self.paged.ensure(slot, length):
            victim = self.sched.pick_victim(protect=slot)
            if victim is None:
                victim = slot       # nothing else to take — preempt self
            self.sched.evict(victim)
            self.paged.free_slot(victim)
            if victim == slot:
                return False
        return True

    def _do_prefills(self, admissions: List[Tuple[int, Request]]):
        for Lb, group in self.sched.prefill_groups(admissions):
            group = list(group)
            # paged: allocate blocks for the prompts first (may evict
            # group members — drop those from this prefill call)
            ready = []
            for slot, req in group:
                if self.sched.slots[slot].request is not req:
                    continue    # evicted by an earlier bucket this round
                n_pref = len(req.prefill_tokens())
                if self._alloc_or_evict(slot, n_pref):
                    ready.append((slot, req))
            # a later allocation may have evicted an earlier group member
            ready = [(s, r) for s, r in ready
                     if self.sched.slots[s].request is r]
            if not ready:
                continue
            toks = np.zeros((self.n_slots, Lb), np.int32)
            start = np.full((self.n_slots,), Lb, np.int32)
            newmask = np.zeros((self.n_slots,), bool)
            for slot, req in ready:
                pt = req.prefill_tokens()
                toks[slot, Lb - len(pt):] = pt
                start[slot] = Lb - len(pt)
                newmask[slot] = True
            if self.paged is not None:
                fn = self._prefill_paged_fn(Lb)
                tables = self.paged.device_tables()
                nxt, after = fn(self.params, self.paged.pool, tables,
                                jnp.asarray(toks), jnp.asarray(start),
                                jnp.asarray(newmask))
                for slot, req in ready:
                    self.paged.write_slot_prefix(
                        slot, after, len(req.prefill_tokens()))
            else:
                # reset the slots to a fresh template (clears stale cache
                # rows; writes the vlm/encdec cross-KV context)
                for slot, _ in ready:
                    self.cache = self.ops.reset_slot(
                        self.cache, slot, self._template_full)
                fn = self._prefill_fn(Lb)
                nxt, self.cache = fn(self.params, self.cache,
                                     jnp.asarray(toks), jnp.asarray(start),
                                     jnp.asarray(newmask))
            nxt = np.asarray(nxt)
            now = self._now()
            self.metrics.prefill_calls += 1
            # every scanned position consumes every frozen weight split
            self._avoided_split_bytes += Lb * self._wrapped_bytes
            for slot, req in ready:
                self.metrics.prefill_tokens += len(req.prefill_tokens())
                self.metrics.tokens_generated += 1  # the first new token
                finished = self.sched.on_prefilled(slot, int(nxt[slot]),
                                                   now)
                self._cur[slot] = self.sched.slots[slot].pos + 1 \
                    if not finished else 1
                self._last_tok[slot] = int(nxt[slot])
                if finished:
                    self._finish(slot, req, now)

    def _finish(self, slot: int, req: Request, now: float):
        if self.paged is not None:
            self.paged.free_slot(slot)
        self.metrics.record_finish(req, now)

    def _do_decode(self):
        active_idx = self.sched.active_slots()
        if not active_idx:
            return
        active = np.zeros((self.n_slots,), bool)
        active[active_idx] = True
        # per-slot position of the token being written this step; 0 for
        # idle slots = "write nothing" (cache_update_row no-op)
        cur = np.where(active, self._cur, 0).astype(np.int32)
        if self.paged is not None:
            # this step writes row cur-1, so the slot needs `cur` positions
            survivors = [slot for slot in active_idx
                         if self._alloc_or_evict(slot, int(cur[slot]))]
            survivors = [s for s in survivors
                         if self.sched.slots[s].request is not None]
            if len(survivors) != len(active_idx):
                active[:] = False
                active[survivors] = True
                active_idx = survivors
                if not active_idx:
                    return
        toks = self._last_tok[:, None].astype(np.int32)
        if self.paged is not None:
            tables = self.paged.device_tables()
            nxt, pool = self._decode_paged(
                self.params, self.paged.pool, tables, jnp.asarray(toks),
                jnp.asarray(cur), jnp.asarray(active))
            self.paged.pool = pool
        else:
            nxt, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(cur), jnp.asarray(active))
        nxt = np.asarray(nxt)
        now = self._now()
        self.metrics.decode_steps += 1
        self._avoided_split_bytes += self._wrapped_bytes
        for slot in active_idx:
            req = self.sched.slots[slot].request
            self.metrics.tokens_generated += 1
            finished = self.sched.on_token(slot, int(nxt[slot]), now)
            if finished:
                self._finish(slot, req, now)
            else:
                self._cur[slot] = self.sched.slots[slot].pos + 1
                self._last_tok[slot] = int(nxt[slot])

    def step(self) -> bool:
        """One scheduler round: admit + prefill new requests, then decode
        one token for every active slot.  Returns False when idle."""
        if self.sched.all_done:
            return False
        self.metrics.start()
        self.metrics.sample_queue(self.sched.queue_depth)
        admissions = self.sched.admit()
        if admissions:
            self._do_prefills(admissions)
        self._do_decode()
        return True

    def run(self, max_steps: Optional[int] = None) -> Dict[str, Any]:
        """Drive the loop until every submitted request finished (or
        ``max_steps`` scheduler rounds); returns the metrics summary."""
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self.metrics.stop()
        # evictions within THIS metrics window (reset_metrics snapshots)
        self.metrics.evictions = self.sched.evictions - \
            self._evictions_at_reset
        if self.split_cache is not None:
            d = self.split_cache.stats.as_dict()
            # MEASURED hit rate from the engine's trace-time consumption
            # counters: the fraction of wrapped-weight contractions whose
            # frozen split actually applied (a silent `usable_split`
            # fallback — dnums/spec/dtype drift — lowers it, which is
            # what the bench gate exists to catch).  Compiled steps count
            # once at trace time; a window with no fresh traces (warm
            # replay after reset_metrics) keeps the last measured rate.
            from repro.core.engine import presplit_trace_counts
            counts = presplit_trace_counts()
            d_used = counts["used"] - self._presplit_counts0["used"]
            d_fb = counts["fallback"] - self._presplit_counts0["fallback"]
            if d_used + d_fb:
                self._presplit_rate = d_used / (d_used + d_fb)
            rate = self._presplit_rate
            if rate is None:
                rate = 1.0 if self._wrapped_bytes else 0.0
            d.update({
                "frozen_weight_bytes": self._wrapped_bytes,
                "avoided_split_bytes": self._avoided_split_bytes,
                "weight_split_hit_rate": rate,
            })
            self.metrics.split_cache = d
        return self.metrics.summary()

    def reset_metrics(self):
        """Fresh metrics window (e.g. timing a steady-state pass after a
        warm-up replay compiled every bucket).  Scheduler, caches, and
        jit caches are untouched — the runtime keeps serving."""
        self.metrics = ServingMetrics(now=self._now)
        self._avoided_split_bytes = 0
        self._evictions_at_reset = self.sched.evictions

    # convenience for tests / examples ---------------------------------

    def generate(self, prompts: List[np.ndarray], max_new: int,
                 eos_id: Optional[int] = None) -> List[np.ndarray]:
        """Submit a batch and run to completion; returns prompt+generated
        per request, in submission order."""
        reqs = [self.submit(p, max_new, eos_id=eos_id) for p in prompts]
        self.run()
        return [np.concatenate([r.prompt,
                                np.asarray(r.generated, np.int32)])
                for r in reqs]
