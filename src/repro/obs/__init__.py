"""Observability layer: metrics registry, profiler tracing, exporters.

The package is import-light on purpose — ``repro.obs.registry`` pulls in
nothing outside the standard library, so core modules can record metrics
without creating import cycles.  See docs/observability.md.
"""

from repro.obs.registry import (  # noqa: F401
    MetricsRegistry,
    Snapshot,
    enabled,
    get_registry,
    set_enabled,
)
