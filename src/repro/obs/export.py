"""Exporters: Prometheus text exposition + JSON, with a format lint.

The JSON document is :meth:`repro.obs.registry.Snapshot.as_dict` plus
optional sidecars (the planner ledger, a serving summary) — the payload
``launch/serve.py --metrics-json`` writes and the CI smoke parses.

The Prometheus exporter emits the text exposition format (one ``# TYPE``
per metric family, counters suffixed ``_total``, histograms rendered as
summaries with ``quantile`` labels).  :func:`lint_prometheus` /
:func:`parse_prometheus` validate and round-trip the output — the test
suite's format gate, so a drive-by rename can't silently break scrapes.
"""

from __future__ import annotations

import json
import re
from typing import Any, Dict, Optional, Tuple

from repro.obs.registry import (MetricsRegistry, Snapshot, hist_stats,
                                percentile)

__all__ = ["to_json", "metrics_document", "to_prometheus",
           "lint_prometheus", "parse_prometheus", "unified_snapshot"]

_QUANTILES = (0.5, 0.95, 0.99)


def unified_snapshot(*extra: "MetricsRegistry | Snapshot") -> Snapshot:
    """The process-global registry's snapshot merged with any extra
    registries/snapshots (per-runtime serving registries, typically)."""
    from repro.obs import registry as _reg
    snap = _reg.get_registry().snapshot()
    for e in extra:
        if e is None:
            continue
        snap = snap.merge(e if isinstance(e, Snapshot) else e.snapshot())
    return snap


def metrics_document(snap: Snapshot, *, ledger: bool = True,
                     extra: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
    """The full JSON document: snapshot + plan-ledger summary + extras."""
    doc = snap.as_dict()
    if ledger:
        from repro.core import plan as _plan
        doc["plan_ledger"] = _plan.get_ledger().summary()
    if extra:
        doc.update(extra)
    return doc


def to_json(snap: Snapshot, *, ledger: bool = True,
            extra: Optional[Dict[str, Any]] = None, indent: int = 2) -> str:
    return json.dumps(metrics_document(snap, ledger=ledger, extra=extra),
                      indent=indent, sort_keys=True)


# -- Prometheus text exposition ------------------------------------------

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_OK = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
# one sample line: name{labels} value   (labels optional)
_SAMPLE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"(?:[^\"\\\n]|\\.)*\""
    r"(?:,[a-zA-Z_][a-zA-Z0-9_]*=\"(?:[^\"\\\n]|\\.)*\")*\})?"
    r" (-?(?:[0-9.eE+-]+|Inf|NaN))$")
_TYPES = ("counter", "gauge", "summary", "histogram", "untyped")


def _prom_name(name: str, prefix: str) -> str:
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", f"{prefix}_{name}" if prefix
                 else name)
    if not _NAME_OK.match(out):
        out = "_" + out
    return out


def _prom_escape(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _label_str(labels: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{_prom_escape(v)}"' for k, v in labels
             if _LABEL_OK.match(k)]
    if extra:
        parts = [extra] + parts
    return "{" + ",".join(parts) + "}" if parts else ""


def to_prometheus(snap: Snapshot, prefix: str = "repro") -> str:
    """Render a snapshot in the Prometheus text exposition format."""
    lines = []
    by_name: Dict[str, list] = {}
    for (name, labels), v in sorted(snap.counters.items()):
        by_name.setdefault(_prom_name(name, prefix) + "_total",
                           []).append(("counter", labels, v))
    for (name, labels), v in sorted(snap.gauges.items()):
        by_name.setdefault(_prom_name(name, prefix),
                           []).append(("gauge", labels, v))
    for pname, rows in sorted(by_name.items()):
        lines.append(f"# TYPE {pname} {rows[0][0]}")
        for _, labels, v in rows:
            lines.append(f"{pname}{_label_str(labels)} {_fmt(v)}")
    for (name, labels), vals in sorted(snap.hists.items()):
        pname = _prom_name(name, prefix)
        lines.append(f"# TYPE {pname} summary")
        for q in _QUANTILES:
            qlabel = 'quantile="%s"' % q
            lines.append(f"{pname}{_label_str(labels, qlabel)} "
                         f"{_fmt(percentile(vals, q))}")
        lines.append(f"{pname}_sum{_label_str(labels)} {_fmt(sum(vals))}")
        lines.append(f"{pname}_count{_label_str(labels)} {len(vals)}")
    return "\n".join(lines) + "\n" if lines else ""


def _fmt(v: float) -> str:
    if float(v).is_integer() and abs(v) < 2 ** 53:
        return str(int(v))
    return repr(float(v))


def lint_prometheus(text: str) -> None:
    """Validate exposition-format text; raises ValueError with the
    offending line.  Checks: sample-line grammar, metric/label name
    charset, exactly one ``# TYPE`` per family declared before its first
    sample, and a known type keyword."""
    declared: Dict[str, str] = {}
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                raise ValueError(f"line {i}: malformed TYPE line: {line!r}")
            _, _, name, typ = parts
            if not _NAME_OK.match(name):
                raise ValueError(f"line {i}: bad metric name {name!r}")
            if typ not in _TYPES:
                raise ValueError(f"line {i}: unknown type {typ!r}")
            if name in declared:
                raise ValueError(f"line {i}: duplicate TYPE for {name!r}")
            declared[name] = typ
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {i}: malformed sample line: {line!r}")
        name = m.group(1)
        base = name
        for suffix in ("_sum", "_count", "_bucket"):
            if name.endswith(suffix) and name[:-len(suffix)] in declared:
                base = name[:-len(suffix)]
        if base not in declared:
            raise ValueError(f"line {i}: sample {name!r} has no preceding "
                             f"# TYPE declaration")
        float(m.group(3))  # value must parse


def parse_prometheus(text: str) -> Dict[str, float]:
    """Parse sample lines into ``{"name{labels}": value}`` (validated
    first) — the exporter round-trip used by tests."""
    lint_prometheus(text)
    out: Dict[str, float] = {}
    for line in text.splitlines():
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        out[m.group(1) + (m.group(2) or "")] = float(m.group(3))
    return out
