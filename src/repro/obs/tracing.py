"""Profiler tracing: named emulation phases + run-level trace capture.

Two scope flavors, both no-ops when the obs layer is disabled:

* :func:`phase_scope` — ``jax.named_scope``: attaches a name to the ops
  staged under it, so xprof/Perfetto shows ``ozimmu/split``,
  ``ozimmu/group_gemm``, ``ozimmu/ladder``, ``ozimmu/scale_accum``
  blocks inside the compiled program.  Pure metadata: the lowered HLO
  computes the same values in the same order, which keeps the bitwise
  contract (tests assert identity with obs on vs off).  Works for both
  the XLA path and the fused Pallas pipeline — the kernel calls are
  staged under the same scopes.
* :func:`host_scope` — ``jax.profiler.TraceAnnotation``: brackets *host*
  work (splitter dispatch, cache freezes) on the profiler timeline.

Run-level capture: :func:`profile` brackets a whole run with
``jax.profiler.start_trace/stop_trace`` (the ``--profile-dir`` flag on
serve/train).  Failures to start the profiler degrade to a warning, not
a crash — observability must never take the workload down.
"""

from __future__ import annotations

import contextlib
import sys
from typing import Optional

import jax

from repro.obs import registry as _registry

__all__ = ["phase_scope", "host_scope", "profile", "PHASES"]

# The emulation pipeline's phase names (docs/observability.md): every
# scope this module emits is ozimmu/<one of these>.
PHASES = ("split", "group_gemm", "ladder", "scale_accum")

_NULL = contextlib.nullcontext()


def phase_scope(name: str):
    """In-graph scope naming the ops staged under it (trace-time only;
    compiled executions carry the name for free)."""
    if not _registry.enabled():
        return _NULL
    return jax.named_scope(f"ozimmu/{name}")


def host_scope(name: str):
    """Host-side profiler annotation (shows up on the python thread's
    timeline during an active trace)."""
    if not _registry.enabled():
        return _NULL
    try:
        return jax.profiler.TraceAnnotation(f"ozimmu/{name}")
    except Exception:  # profiler backend unavailable
        return _NULL


@contextlib.contextmanager
def profile(trace_dir: Optional[str]):
    """Bracket a run with ``jax.profiler.start_trace/stop_trace`` when
    ``trace_dir`` is set; plain passthrough when None."""
    if not trace_dir:
        yield
        return
    started = False
    try:
        jax.profiler.start_trace(trace_dir)
        started = True
    except Exception as e:  # missing profiler deps / double start
        print(f"[obs] profiler trace unavailable ({e}); continuing "
              f"without", file=sys.stderr)
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception as e:
                print(f"[obs] profiler stop_trace failed ({e})",
                      file=sys.stderr)
