"""Process-wide metrics registry: labeled counters, gauges, histograms.

Stdlib-only (no jax import) so any layer — core, kernels, serving,
launch — can record without import cycles.  All recording happens on the
host at eager/trace time; nothing here ever enters a jitted graph, which
is what keeps instrumented numerics bitwise-identical to uninstrumented
runs (tests/test_obs.py asserts this).

Two registries matter in practice:

* the process-global default (``get_registry()``) — emulation-core
  counters (``emulation.*``, ``split_cache.*``, ``prefix_cache.*``,
  ``plan.*``) accumulate here;
* per-:class:`~repro.serving.metrics.ServingMetrics` private instances —
  serving counters must not bleed between interleaved runtimes, so each
  metrics window owns its own registry and the exporters merge the two.

Disabled mode is a true no-op: hot call sites gate on :func:`enabled`
(a module-level bool read), and every mutator early-returns before
touching locks or dicts.  ``tests/test_obs.py`` asserts the disabled
registry records nothing and benchmarks show no measurable overhead.
"""

from __future__ import annotations

import contextlib
import math
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "MetricsRegistry", "Snapshot", "get_registry", "set_registry",
    "enabled", "set_enabled", "disabled", "percentile", "hist_stats",
]

# (metric name, canonicalised labels) — the registry's row key.  Labels
# are sorted (k, str(v)) pairs so kwarg order never splits a series.
Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, Any]) -> Key:
    if not labels:
        return (name, ())
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


# -- percentiles ---------------------------------------------------------

def percentile(values: Iterable[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default method), q in
    [0, 1].  Unlike nearest-rank-with-rounding this is exact at small N:
    percentile([1, 2, 3, 4], 0.5) == 2.5, not 3."""
    vals = sorted(values)
    if not vals:
        raise ValueError("percentile of empty sequence")
    if len(vals) == 1:
        return float(vals[0])
    pos = q * (len(vals) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


def hist_stats(values: Iterable[float]) -> Optional[Dict[str, float]]:
    """Summary block for one histogram series (None when empty)."""
    vals = list(values)
    if not vals:
        return None
    return {
        "count": len(vals),
        "sum": float(sum(vals)),
        "mean": float(sum(vals) / len(vals)),
        "min": float(min(vals)),
        "max": float(max(vals)),
        "p50": percentile(vals, 0.50),
        "p95": percentile(vals, 0.95),
        "p99": percentile(vals, 0.99),
    }


# -- snapshots -----------------------------------------------------------

class Snapshot:
    """Immutable copy of a registry's state at one instant.

    Supports ``diff`` (counter deltas + histogram suffixes since an older
    snapshot — histograms only ever append, so the suffix is exact),
    ``merge`` (union of two registries for the unified export), and
    ``as_dict`` (the JSON document ``--metrics-json`` writes)."""

    def __init__(self, counters: Dict[Key, float], gauges: Dict[Key, float],
                 hists: Dict[Key, Tuple[float, ...]], taken_at: float = 0.0):
        self.counters = counters
        self.gauges = gauges
        self.hists = hists
        self.taken_at = taken_at

    # accessors ----------------------------------------------------------

    def value(self, name: str, **labels: Any) -> float:
        return self.counters.get(_key(name, labels), 0.0)

    def gauge(self, name: str, **labels: Any) -> Optional[float]:
        return self.gauges.get(_key(name, labels))

    def hist_values(self, name: str, **labels: Any) -> Tuple[float, ...]:
        return self.hists.get(_key(name, labels), ())

    def total(self, name: str, **labels: Any) -> float:
        """Sum of a counter across every label set that carries all of
        the given ``labels`` (all series of ``name`` when none given)."""
        want = set(_key(name, labels)[1])
        return sum(v for (n, ls), v in self.counters.items()
                   if n == name and want.issubset(ls))

    def names(self) -> List[str]:
        seen = []
        for d in (self.counters, self.gauges, self.hists):
            for n, _ in d:
                if n not in seen:
                    seen.append(n)
        return sorted(seen)

    # algebra ------------------------------------------------------------

    def diff(self, older: "Snapshot") -> "Snapshot":
        counters = {}
        for k, v in self.counters.items():
            d = v - older.counters.get(k, 0.0)
            if d:
                counters[k] = d
        gauges = dict(self.gauges)
        hists = {}
        for k, vals in self.hists.items():
            prev = len(older.hists.get(k, ()))
            if len(vals) > prev:
                hists[k] = vals[prev:]
        return Snapshot(counters, gauges, hists, self.taken_at)

    def merge(self, other: "Snapshot") -> "Snapshot":
        counters = dict(self.counters)
        for k, v in other.counters.items():
            counters[k] = counters.get(k, 0.0) + v
        gauges = dict(self.gauges)
        gauges.update(other.gauges)
        hists = dict(self.hists)
        for k, vals in other.hists.items():
            hists[k] = hists.get(k, ()) + vals
        return Snapshot(counters, gauges, hists,
                        max(self.taken_at, other.taken_at))

    # export -------------------------------------------------------------

    @staticmethod
    def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
        if not labels:
            return ""
        return "{%s}" % ",".join(f"{k}={v}" for k, v in labels)

    def as_dict(self) -> Dict[str, Any]:
        """JSON-able document.  ``totals`` sums each counter across its
        label sets — the stable surface CI smoke assertions key on."""
        counters: Dict[str, Dict[str, float]] = {}
        for (name, labels), v in sorted(self.counters.items()):
            counters.setdefault(name, {})[self._label_str(labels) or "total"] = v
        gauges: Dict[str, Dict[str, float]] = {}
        for (name, labels), v in sorted(self.gauges.items()):
            gauges.setdefault(name, {})[self._label_str(labels) or "total"] = v
        hists: Dict[str, Dict[str, Any]] = {}
        for (name, labels), vals in sorted(self.hists.items()):
            hists.setdefault(name, {})[self._label_str(labels) or "total"] = \
                hist_stats(vals)
        totals = {}
        for (name, _), v in self.counters.items():
            totals[name] = totals.get(name, 0.0) + v
        return {"taken_at": self.taken_at, "totals": totals,
                "counters": counters, "gauges": gauges, "histograms": hists}


# -- the registry --------------------------------------------------------

class MetricsRegistry:
    """Thread-safe labeled counters / gauges / histograms.

    The clock is injectable (``now``) so timing histograms are testable
    against a virtual clock — the serving runtime threads its own
    ``_now`` through, matching its deterministic-time test harness."""

    def __init__(self, now: Callable[[], float] = time.monotonic,
                 enabled: bool = True):
        self.now = now
        self._enabled = enabled
        self._lock = threading.Lock()
        self._counters: Dict[Key, float] = {}
        self._gauges: Dict[Key, float] = {}
        self._hists: Dict[Key, List[float]] = {}

    # enable / disable ---------------------------------------------------

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self):
        self._enabled = True

    def disable(self):
        self._enabled = False

    # recording ----------------------------------------------------------

    def inc(self, name: str, value: float = 1.0, **labels: Any):
        if not self._enabled:
            return
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value

    def gauge(self, name: str, value: float, **labels: Any):
        if not self._enabled:
            return
        k = _key(name, labels)
        with self._lock:
            self._gauges[k] = float(value)

    def observe(self, name: str, value: float, **labels: Any):
        if not self._enabled:
            return
        k = _key(name, labels)
        with self._lock:
            self._hists.setdefault(k, []).append(float(value))

    @contextlib.contextmanager
    def timer(self, name: str, **labels: Any):
        if not self._enabled:
            yield
            return
        t0 = self.now()
        try:
            yield
        finally:
            self.observe(name, self.now() - t0, **labels)

    # reads --------------------------------------------------------------

    def value(self, name: str, **labels: Any) -> float:
        with self._lock:
            return self._counters.get(_key(name, labels), 0.0)

    def gauge_value(self, name: str, **labels: Any) -> Optional[float]:
        with self._lock:
            return self._gauges.get(_key(name, labels))

    def hist_values(self, name: str, **labels: Any) -> Tuple[float, ...]:
        with self._lock:
            return tuple(self._hists.get(_key(name, labels), ()))

    def total(self, name: str, **labels: Any) -> float:
        return self.snapshot().total(name, **labels)

    def snapshot(self) -> Snapshot:
        with self._lock:
            return Snapshot(dict(self._counters), dict(self._gauges),
                            {k: tuple(v) for k, v in self._hists.items()},
                            taken_at=self.now())

    def reset(self):
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    def is_empty(self) -> bool:
        with self._lock:
            return not (self._counters or self._gauges or self._hists)


# -- process-global default ---------------------------------------------

_REGISTRY = MetricsRegistry()
_ENABLED = True  # mirrored module-level for the cheapest hot-path gate


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests); returns the old one."""
    global _REGISTRY
    old, _REGISTRY = _REGISTRY, reg
    return old


def enabled() -> bool:
    """The gate hot call sites check before building labels — a plain
    module-global read, so disabled mode costs one bool test."""
    return _ENABLED and _REGISTRY._enabled


def set_enabled(on: bool):
    global _ENABLED
    _ENABLED = bool(on)
    (_REGISTRY.enable if on else _REGISTRY.disable)()


@contextlib.contextmanager
def disabled():
    """Scoped kill switch (used by the overhead assertion in tests)."""
    prev = _ENABLED
    set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)
