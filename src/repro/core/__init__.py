"""Core: the paper's contribution — Ozaki-scheme GEMM emulation on int8 MMUs."""
from repro.core.splitting import (Split, compute_beta, compute_beta_sm,
                                  compute_r, split_bitmask, split_rn,
                                  split_rn_const, split_sm, sm_decode,
                                  split_oz2, split_oz2_bitmask,
                                  split_oz2_fast2, split_oz2_bitmask_fast2,
                                  reconstruct, residual)
from repro.core.accumulate import (int8_gemm, matmul_naive, matmul_group_ef,
                                   matmul_oz2, DF32, num_highprec_adds,
                                   oz2_num_pairs, oz2_num_highprec_adds)
from repro.core.plan import (DEFAULT_TARGET_EPS, Plan, plan_contraction,
                             kernel_blocks)
from repro.core.ozimmu import (OzimmuConfig, VARIANTS, ozimmu_matmul,
                               ozimmu_dot_general, parse_spec)
from repro.core.engine import MatmulEngine, make_engine
