"""Slice extraction ("splitting") for the Ozaki scheme on integer MMUs.

Implements the three splitting strategies from the paper:

  * ``split_bitmask``   — Alg. 3 (Ootomo et al. 2024): truncate consecutive
    beta-bit groups of the sign-magnitude mantissa.  Digits in [-(2^b-1), 2^b-1].
  * ``split_rn``        — Alg. 5 (proposed, "RN"): round-to-nearest extraction
    with a per-slice re-scaled grid (the classic ``(a + sigma) - sigma`` trick).
    Digits in [-2^(b-1), 2^(b-1)].
  * ``split_rn_const``  — Alg. 8 (proposed, for "H"): round-to-nearest with a
    *fixed* base scale and constant grid ratio 2^-beta per slice, so slice
    scales stay a geometric sequence and group-wise error-free accumulation
    (Alg. 6/7) applies.

plus the *sign-magnitude* strategy of the cuBLASDx DGEMM-emulation line
(the ``ozimmu_sm_{b,h}`` variants):

  * ``split_sm``       — two's-complement fixed-point decomposition with
    the sign carried ONLY by the leading slice: the leading digit is
    ``floor(v * 2^(beta-1))`` of the normalized value ``v = a / base``
    (signed, full int8 range at beta = 8), every trailing digit is the
    *unsigned* ``floor`` of the nonnegative residual (``[0, 2^beta - 1]``,
    stored mod-2^8 in int8 — decode with :func:`sm_decode`).  Because the
    decomposition is a plain positional number system (no per-element
    sign vector), slice products contract through the integer MMU
    unchanged, and the k digits cover ``beta*k - 1`` bits of mantissa —
    at ``beta = 8`` that is ``8k - 1`` bits versus the signed splitters'
    ``7k``, the (k-1)-bit saving that lets ``auto`` pick a strictly
    smaller k at equal ``target_eps``.  Scales stay the geometric
    sequence of the bitmask/rn_const splits (``scale[s] = base' *
    2^(-beta*s)`` with ``base' = 4 * 2^floor(log2 rowmax)``), so
    group-wise error-free accumulation applies unchanged.

plus the two *constant-scaling* strategies of the Ozaki-II line ("Error
Analysis of Matrix Multiplication Emulation Using Ozaki-II Scheme", Uchino
et al.; "Improved Scaling for Fast Mode of Ozaki Scheme II", Kawakami &
Takahashi):

  * ``split_oz2``       — round-to-nearest extraction on ONE power-of-two
    digit grid shared by the whole matrix (per batch element), derived from
    the global |a| maximum instead of per-row maxima.
  * ``split_oz2_bitmask`` — the truncation analogue (Alg. 3 digits on the
    shared grid).
  * ``split_oz2_fast2`` / ``split_oz2_bitmask_fast2`` — the *improved
    scaling* of Kawakami & Takahashi (spec token ``:fast2``): every row
    (column for ``axis=1``) is first equilibrated by its own power of two
    ``rho_i`` (exact), so the shared grid of the equilibrated matrix is
    the CONSTANT ``gbase = 2``, and the per-row factors ride along in
    ``Split.base`` (``base_i = rho_i * gbase``) for the exact two-sided
    unscale ``C = diag(base_A/gbase) C_hat diag(base_B/gbase)`` applied
    by ``matmul_oz2`` after the ladder.  Because equilibration is a
    power-of-two rescale, the digits are bitwise THE per-row splitter's
    digits (``split_rn_const`` / ``split_bitmask``) — only the ladder's
    interpretation changes — so the truncation error is anchored at each
    row's own magnitude, recovering near-full-mode accuracy at fast-mode
    cost (docs/algorithms.md#improved-fast-mode-scaling-fast2).

The shared grid is what makes the oz2 accumulation path
(``repro.core.accumulate.matmul_oz2``) able to fold every slice-pair scale
into a single scalar exponent ladder; the price (for the plain oz2 splits)
is that the truncation error is anchored at the *global* magnitude, not
each row's own (see docs/algorithms.md#ozaki-scheme-ii) — the fast2 splits
above remove exactly that price.  Constant-scaling splits carry the
scalar base in ``Split.gbase``; the plain oz2 ``scale``/``base`` fields
broadcast it so every per-row consumer keeps working unchanged, while the
fast2 splits keep per-row ``scale``/``base`` (the reconstruct/residual
contract stays per-row, i.e. tight).

All three return a :class:`Split` with the unified convention

    A  ≈  sum_s  diag(scale[s]) @ digits[s]          (axis=0, row scales)
    A  ≈  sum_s  digits[s] @ diag(scale[s])          (axis=1, column scales)

and, for the geometric strategies (bitmask / rn_const),

    scale[s] = base * 2^(-beta * s),   s = 1..k,

so that a product slice-pair (s, t) carries the scale
``baseA (x) baseB * 2^(-beta * (s+t))`` — a function of the group index
``g = s + t`` only, which is what makes the INT32 group accumulation of
Alg. 6/7 error free.

Everything is rounding-exact by construction (see tests/test_splitting.py):
the digit extraction uses only power-of-two scalings, truncation/rounding to
representable grids, and exact residual subtraction (Dekker).  No ``log2`` is
evaluated — exponents come from ``frexp`` (the paper warns that log-based
exponent computation "occasionally returns erroneous results").

The geometric strategies (bitmask / rn_const) also exist as a one-HBM-pass
Pallas kernel (``repro.kernels.split_fused``, wrapper
``repro.kernels.ops.split_fused``) producing bit-identical digits and
scales; ``OzimmuConfig.use_pallas == "fused"`` routes extraction through
it.  The adaptive RN strategy cannot fuse — it re-derives the grid from
each residual's row maxima, i.e. it *requires* the k extra passes that
Alg. 8 exists to remove.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Split",
    "compute_beta",
    "compute_beta_sm",
    "beta_for",
    "compute_r",
    "split_bitmask",
    "split_rn",
    "split_rn_const",
    "split_sm",
    "sm_decode",
    "sm_decode_slice",
    "split_oz2",
    "split_oz2_bitmask",
    "split_oz2_fast2",
    "split_oz2_bitmask_fast2",
    "reconstruct",
]


class Split(NamedTuple):
    """k int8 slices of a (possibly batched) matrix plus per-slice scales.

    Attributes:
      digits: ``(k, *batch, m, n)`` int8 slice matrices.  The matrix lives in
              the trailing two axes; any leading axes are batch dimensions
              (splitting is purely row/column-local, so batching is free).
      scale:  ``(k, *batch, r)`` per-slice scale vector (r = rows for
              ``axis=0``, columns for ``axis=1``); always a power of two.
      base:   ``(*batch, r)`` geometric base such that
              ``scale[s] = base * 2^(-beta*(s+1))`` (0-indexed s), or ``None``
              for the adaptive RN strategy.
      beta:   bits per slice.
      axis:   0 if ``scale`` indexes rows of the matrix, 1 for columns.
      gbase:  ``(*batch,)`` scalar geometric base for the constant-scaling
              (oz2) strategies — every entry of ``base`` equals it, so the
              slice-pair scales collapse to one exponent ladder per batch
              element.  ``None`` for the per-row/col strategies.
      signmag: sign-magnitude storage convention (``split_sm``): slice 0 is
              a signed two's-complement leading digit, slices 1..k-1 are
              UNSIGNED magnitudes in ``[0, 2^beta - 1]`` stored mod 2^8 in
              the int8 array — consumers must widen through
              :func:`sm_decode` before any arithmetic.  False for every
              signed-digit strategy.
    """

    digits: jax.Array
    scale: jax.Array
    base: Optional[jax.Array]
    beta: int
    axis: int
    gbase: Optional[jax.Array] = None
    signmag: bool = False


def compute_beta(n: int) -> int:
    """beta = min(7, floor((31 - log2 n) / 2)) — eq. (4) of the paper.

    Uses the exact integer ceil(log2 n) so the INT32 no-overflow guarantee
    ``n * (2^beta - 1)^2 < 2^31`` holds for every n, not only powers of two.
    """
    if n <= 0:
        raise ValueError(f"contraction length must be positive, got {n}")
    clog2 = max(1, (n - 1).bit_length())  # ceil(log2 n), >= 1
    beta = min(7, (31 - clog2) // 2)
    if beta < 1:
        raise ValueError(f"n={n} too large for int8 Ozaki scheme (beta < 1)")
    return beta


def compute_beta_sm(n: int) -> int:
    """beta for the sign-magnitude strategy: min(8, floor((31-log2 n)/2)).

    Sign-magnitude digits use the FULL int8 range (the leading digit spans
    [-2^(beta-1), 2^(beta-1)-1], trailing magnitudes [0, 2^beta - 1]) — no
    bit is reserved for a per-digit sign — so beta caps at 8 instead of 7.
    The INT32 no-overflow bound is the same ``n * (2^beta - 1)^2 < 2^31``
    as :func:`compute_beta` (every digit magnitude is strictly below
    2^beta): at beta = 8, clog2(n) <= 15 gives
    ``2^15 * 255^2 = 2,130,739,200 < 2^31``.
    """
    if n <= 0:
        raise ValueError(f"contraction length must be positive, got {n}")
    clog2 = max(1, (n - 1).bit_length())
    beta = min(8, (31 - clog2) // 2)
    if beta < 1:
        raise ValueError(f"n={n} too large for int8 Ozaki scheme (beta < 1)")
    return beta


# splits using the sign-magnitude storage convention (Split.signmag=True)
SM_SPLITS = ("sm",)


def is_signmag(split: str) -> bool:
    return split in SM_SPLITS


def beta_for(split: str, n: int) -> int:
    """Slice width of a splitting strategy at contraction length n — the
    single dispatch point for the sign-magnitude family's wider slices."""
    return compute_beta_sm(n) if split in SM_SPLITS else compute_beta(n)


def compute_r(n: int, beta: int, digit_bits: Optional[int] = None) -> int:
    """Slice-pair products summable in INT32 without overflow — eq. (12).

    Default (``digit_bits=None``): the paper's
    ``r = max(1, 2^(31 - 2*beta - ceil(log2 n)))`` for bitmask digits,
    whose magnitude is STRICTLY below 2^beta (``<= 2^beta - 1``), so
    ``r * n * (2^beta - 1)^2 < 2^31`` holds with the power-of-two r.

    With an explicit ``digit_bits`` the digits are taken to ATTAIN the
    closed endpoint ±2^digit_bits (round-to-nearest digits do: an exact
    half-grid residual rounds to ±2^(beta-1)).  Then the power-of-two r
    would allow a chunk sum of exactly +2^31 — one past INT32_MAX — on
    adversarial constant-sign operands, so one pair is shaved off:
    ``r = 2^(31 - 2*digit_bits - ceil(log2 n)) - 1`` (floored at 1; a
    single pair is always safe because eq. (4) keeps
    ``n * 2^(2*digit_bits) <= 2^30``).  Net: RN callers passing
    ``beta - 1`` still get ~4x the bitmask group size.
    """
    clog2 = max(1, (n - 1).bit_length())
    if digit_bits is None:
        return max(1, 2 ** max(0, 31 - 2 * beta - clog2))
    return max(1, 2 ** max(0, 31 - 2 * digit_bits - clog2) - 1)


# splits whose digits lie in [-2^(beta-1), 2^(beta-1)] (round-to-nearest);
# the rest span the full +-(2^beta - 1) truncation range
RN_SPLITS = ("rn", "rn_const", "oz2_rn", "oz2_rn_fast2")


def digit_bits(split: str, beta: int) -> int:
    """Digit magnitude bits of a splitting strategy (the single source of
    truth for the r / ladder-word accounting)."""
    return beta - 1 if split in RN_SPLITS else beta


def _mantissa_bits(dtype) -> int:
    if dtype == jnp.float64:
        return 53
    if dtype == jnp.float32:
        return 24
    raise ValueError(f"unsupported input dtype for Ozaki splitting: {dtype}")


def _rowmax(a: jax.Array, axis: int) -> jax.Array:
    """max_j |a_ij| along the non-scale matrix axis; shape (*batch, r)."""
    return jnp.max(jnp.abs(a), axis=-1 if axis == 0 else -2)


def _contract_len(a: jax.Array, axis: int) -> int:
    """Length of the contraction axis: columns for axis=0 (A), rows for
    axis=1 (B)."""
    return a.shape[-1] if axis == 0 else a.shape[-2]


def _pow2_floor(x: jax.Array) -> jax.Array:
    """2^floor(log2 x) elementwise (x > 0); 1.0 where x == 0."""
    m, e = jnp.frexp(x)  # x = m * 2^e, m in [0.5, 1)
    out = jnp.ldexp(jnp.ones_like(x), e - 1)
    return jnp.where(x == 0, jnp.ones_like(x), out)


def _pow2_ceil(x: jax.Array) -> jax.Array:
    """2^ceil(log2 x) elementwise (x > 0); 1.0 where x == 0."""
    m, e = jnp.frexp(x)
    e = jnp.where(m == 0.5, e - 1, e)  # exact powers of two: ceil == floor
    out = jnp.ldexp(jnp.ones_like(x), e)
    return jnp.where(x == 0, jnp.ones_like(x), out)


def _bcast(v: jax.Array, axis: int) -> jax.Array:
    """Broadcast a (*batch, r) per-row/col vector against the matrix."""
    return v[..., :, None] if axis == 0 else v[..., None, :]


def _geo_scales(base: jax.Array, beta: int, k: int) -> jax.Array:
    """scale[s] = base * 2^(-beta*(s+1)), shape (k, *batch, r)."""
    exps = jnp.asarray([2.0 ** (-beta * s) for s in range(1, k + 1)],
                       base.dtype)
    return base[None] * exps.reshape((k,) + (1,) * base.ndim)


def split_bitmask(a: jax.Array, k: int, *, beta: Optional[int] = None,
                  axis: int = 0,
                  rowmax_reduce: Optional[Callable] = None) -> Split:
    """Alg. 3 — bit-mask splitting, expressed in pure float arithmetic.

    Equivalent to masking consecutive beta-bit groups of the sign-magnitude
    fixed-point representation of ``a / 2^(floor(log2 rowmax)+1)``:
    truncation toward zero keeps exactly the leading bits, and the residual
    update is exact (difference of a float and its truncation).

    Accepts leading batch dimensions: ``a`` is ``(*batch, m, n)`` and every
    row/column scale is computed per batch element.

    ``rowmax_reduce`` widens every row/col |a| maximum before scales are
    derived from it (e.g. ``lax.pmax`` over a mesh axis when the
    contraction dimension is sharded, so all shards agree on one digit
    grid).  Must be monotone and exact (max of maxima); identity when None.
    """
    if beta is None:
        beta = compute_beta(_contract_len(a, axis))
    rowmax = _rowmax(a, axis)
    if rowmax_reduce is not None:
        rowmax = rowmax_reduce(rowmax)
    base = 2.0 * _pow2_floor(rowmax)                    # scale[s] = base * 2^(-beta*s)
    digits = _bitmask_extract(a, base, beta, k, axis)
    return Split(digits, _geo_scales(base, beta, k), base, beta, axis)


def _bitmask_extract(a: jax.Array, base: jax.Array, beta: int, k: int,
                     axis: int) -> jax.Array:
    """The Alg. 3 truncation loop against a given (per-row or broadcast
    constant) power-of-two ``base``; returns ``(k, *batch, m, n)`` int8."""
    two_beta = jnp.asarray(2.0 ** beta, a.dtype)
    r = a * _bcast(1.0 / base, axis)                    # exact: base is a power of two
    digits = []
    for _ in range(k):
        r = r * two_beta
        d = jnp.trunc(r)
        r = r - d                                       # exact
        digits.append(d.astype(jnp.int8))               # |d| <= 2^beta - 1 <= 127
    return jnp.stack(digits)


def _rn_extract(r: jax.Array, grid: jax.Array, axis: int):
    """One round-to-nearest extraction: returns (slice_value, new_residual).

    The paper's Alg. 5/8 uses the ``(a + sigma) - sigma`` trick (sigma =
    0.75 * 2^53 * mu) because CUDA lacks a cheap round-to-grid.  XLA/TPU has a
    native round-to-nearest-even op, so we express the *semantics* directly:

        s = round_nearest_even(r / grid) * grid

    Division/multiplication by the power-of-two grid is exact, so this is
    bit-identical to the sigma trick — and, unlike the trick, cannot be
    algebraically simplified away by the compiler (XLA:CPU folds
    ``(x + c) - c -> x`` for literal c under its default fast-math).
    The residual subtraction is exact (Dekker/fast-two-sum condition).
    """
    g = _bcast(grid, axis)
    s = jnp.round(r * (1.0 / g)) * g
    return s, r - s


def split_rn(a: jax.Array, k: int, *, beta: Optional[int] = None,
             axis: int = 0,
             rowmax_reduce: Optional[Callable] = None) -> Split:
    """Alg. 5 — round-to-nearest splitting with per-slice adaptive rescaling.

    Each slice rounds the residual to the nearest multiple of
    ``2^ceil(log2 rowmax(residual)) * 2^(1-beta)``; digits lie in
    [-2^(beta-1), 2^(beta-1)].  Scales are *not* geometric across slices
    (``base is None``), so only naive accumulation (Alg. 4) applies — this is
    the "ozIMMU_RN" configuration of the paper.  Batched like
    :func:`split_bitmask`.

    ``rowmax_reduce`` applies per slice (the adaptive grid depends on the
    *residual's* row maxima, which must be agreed on globally every
    extraction step when the contraction axis is sharded).
    """
    if beta is None:
        beta = compute_beta(_contract_len(a, axis))
    grid_factor = 2.0 ** (1 - beta)

    r = a
    digits, scales = [], []
    for _ in range(k):
        rowmax = _rowmax(r, axis)
        if rowmax_reduce is not None:
            rowmax = rowmax_reduce(rowmax)
        grid = _pow2_ceil(rowmax) * grid_factor
        s, r = _rn_extract(r, grid, axis)
        d = s * _bcast(1.0 / grid, axis)                # exact integer in [-64, 64]
        digits.append(d.astype(jnp.int8))
        scales.append(grid)
    return Split(jnp.stack(digits), jnp.stack(scales), None, beta, axis)


def split_rn_const(a: jax.Array, k: int, *, beta: Optional[int] = None,
                   axis: int = 0,
                   rowmax_reduce: Optional[Callable] = None) -> Split:
    """Alg. 8 — round-to-nearest splitting with constant grid ratio 2^-beta.

    The base scale ``mu = 2^ceil(log2 rowmax) * 2^(1-beta)`` is computed once
    (one pass over the matrix instead of k); slice s rounds the residual to
    grid ``mu * 2^(-beta*(s-1))``.  Slice scales form the geometric sequence
    required by group-wise error-free accumulation — the "ozIMMU_H" splitting.
    Batched like :func:`split_bitmask`; ``rowmax_reduce`` as there (one
    reduction — the single rowmax pass is this splitting's selling point,
    and it stays a single collective when sharded).
    """
    if beta is None:
        beta = compute_beta(_contract_len(a, axis))
    rowmax = _rowmax(a, axis)
    if rowmax_reduce is not None:
        rowmax = rowmax_reduce(rowmax)
    mu = _pow2_ceil(rowmax) * (2.0 ** (1 - beta))
    digits = _rn_const_extract(a, mu, beta, k, axis)
    # scale[s] = mu * 2^(-beta*(s-1)) = (mu * 2^beta) * 2^(-beta*s)
    base = mu * (2.0 ** beta)
    return Split(digits, _geo_scales(base, beta, k), base, beta, axis)


def _rn_const_extract(a: jax.Array, mu: jax.Array, beta: int, k: int,
                      axis: int) -> jax.Array:
    """The Alg. 8 RN loop against a given (per-row or broadcast constant)
    power-of-two first grid ``mu``; returns ``(k, *batch, m, n)`` int8."""
    two_beta = jnp.asarray(2.0 ** beta, a.dtype)
    r = a
    grid = mu
    digits = []
    for _ in range(k):
        s, r = _rn_extract(r, grid, axis)
        d = s * _bcast(1.0 / grid, axis)
        digits.append(d.astype(jnp.int8))
        grid = grid * (1.0 / two_beta)
    return jnp.stack(digits)


def split_sm(a: jax.Array, k: int, *, beta: Optional[int] = None,
             axis: int = 0,
             rowmax_reduce: Optional[Callable] = None) -> Split:
    """Sign-magnitude splitting (``ozimmu_sm_b`` / ``ozimmu_sm_h``).

    Two's-complement fixed-point decomposition of the normalized value
    ``v = a / anchor`` with ``anchor = 2 * 2^floor(log2 rowmax)`` (so
    ``|v| < 1`` STRICTLY, even when rowmax is itself a power of two):

        d_1  = floor(v * 2^(beta-1))          in [-2^(beta-1), 2^(beta-1)-1]
        r_1  = v * 2^(beta-1) - d_1           in [0, 1)   — nonnegative!
        d_s  = floor(r_{s-1} * 2^beta)        in [0, 2^beta - 1],  s >= 2

    The sign lives ONLY in the leading digit (``a < 0  <=>  d_1 < 0``);
    every trailing digit is an unsigned magnitude, so k digits cover
    ``beta*k - 1`` mantissa bits — at beta = 8 (``compute_beta_sm``) that
    is 8k-1 bits versus the 7k of the beta-7 signed splitters, the
    (k-1)-bit saving the planner exploits.  Because the decomposition is
    an exact positional number system (every step is a pow2 multiply plus
    an exact ``x - floor(x)``), slice-pair products reconstruct signed
    results exactly through plain integer GEMMs — no per-element sign
    fixup in the accumulation.

    Storage: digits are stored mod 2^8 in one int8 array (trailing values
    above 127 wrap negative); consumers widen through :func:`sm_decode`.
    Scales stay the geometric contract ``scale[s] = base * 2^(-beta*s)``
    with the stored ``Split.base = 2 * anchor``, so group-wise error-free
    accumulation and the oz2-style scale folds apply unchanged.  Batched /
    ``rowmax_reduce`` like :func:`split_bitmask` (one reduction).
    """
    if beta is None:
        beta = compute_beta_sm(_contract_len(a, axis))
    rowmax = _rowmax(a, axis)
    if rowmax_reduce is not None:
        rowmax = rowmax_reduce(rowmax)
    anchor = 2.0 * _pow2_floor(rowmax)
    digits = _sm_extract(a, anchor, beta, k, axis)
    # leading grid = anchor * 2^(1-beta) = (2*anchor) * 2^(-beta)
    base = 2.0 * anchor
    return Split(digits, _geo_scales(base, beta, k), base, beta, axis,
                 signmag=True)


def _sm_extract(a: jax.Array, anchor: jax.Array, beta: int, k: int,
                axis: int) -> jax.Array:
    """The sign-magnitude extraction loop against a per-row power-of-two
    ``anchor > rowmax``; returns ``(k, *batch, m, n)`` int8 (trailing
    slices stored mod 2^8)."""
    two_beta = jnp.asarray(2.0 ** beta, a.dtype)
    dmax = jnp.asarray(2.0 ** beta - 1.0, a.dtype)
    r = a * _bcast(1.0 / anchor, axis)              # exact; |r| < 1 strictly
    r = r * jnp.asarray(2.0 ** (beta - 1), a.dtype)
    d = jnp.floor(r)                                # signed leading digit
    r = r - d                                       # r in [0, 1); rounds to
    #   exactly 1.0 only for tiny-negative r (1 - eps, eps < 2^-p, is not
    #   representable) — the clamp below then emits the true all-(2^beta-1)
    #   digit cascade of the infinite-precision extraction
    digits = [d.astype(jnp.int8)]                   # in [-2^(b-1), 2^(b-1)-1]
    for _ in range(k - 1):
        r = r * two_beta
        d = jnp.minimum(jnp.floor(r), dmax)         # in [0, 2^beta - 1]
        r = r - d                                   # exact
        digits.append(jnp.where(d > 127.0, d - 256.0, d).astype(jnp.int8))
    return jnp.stack(digits)


def sm_decode(digits: jax.Array) -> jax.Array:
    """Widen stored sign-magnitude digits ``(k, ...)`` int8 -> int16 values:
    slice 0 stays signed, slices 1..k-1 un-wrap to [0, 2^beta - 1]."""
    w = digits.astype(jnp.int16)
    if w.shape[0] <= 1:
        return w
    t = w[1:]
    return jnp.concatenate([w[:1], jnp.where(t < 0, t + 256, t)], axis=0)


def sm_decode_slice(d: jax.Array, s: int) -> jax.Array:
    """Widen ONE stored slice (0-indexed position ``s``) to int16 values."""
    w = d.astype(jnp.int16)
    return w if s == 0 else jnp.where(w < 0, w + 256, w)


def _global_base(a: jax.Array, axis: int,
                 rowmax_reduce: Optional[Callable]) -> jax.Array:
    """Per-batch-element global |a| maximum, broadcast back to the per-row
    (``axis=0``) / per-column (``axis=1``) vector shape ``(*batch, r)``.

    Reduced via the per-row maxima so the ``rowmax_reduce`` hook (a mesh
    ``pmax`` over contraction shards) composes exactly as in the per-row
    splitters: every shard sees the same global maximum, hence the same
    shared digit grid.
    """
    rowmax = _rowmax(a, axis)
    if rowmax_reduce is not None:
        rowmax = rowmax_reduce(rowmax)
    return jnp.broadcast_to(jnp.max(rowmax, axis=-1, keepdims=True),
                            rowmax.shape)


def split_oz2(a: jax.Array, k: int, *, beta: Optional[int] = None,
              axis: int = 0,
              rowmax_reduce: Optional[Callable] = None) -> Split:
    """Ozaki-II constant scaling, round-to-nearest digits (``oz2_h``).

    One power-of-two grid ``mu = 2^ceil(log2 max|a|) * 2^(1-beta)`` for the
    WHOLE matrix (per batch element): the RN extraction of Alg. 8 runs
    against it, so every row's slices live on a single shared exponent
    ladder and a slice-pair product's scale is the *scalar*
    ``gbaseA * gbaseB * 2^(-beta*(s+t))`` — the precondition for the oz2
    exponent-ladder accumulation (``accumulate.matmul_oz2``).  Digits in
    [-2^(beta-1), 2^(beta-1)].  Batched like :func:`split_bitmask`;
    ``rowmax_reduce`` as there (one reduction, then a local max over rows).
    """
    if beta is None:
        beta = compute_beta(_contract_len(a, axis))
    gmax = _global_base(a, axis, rowmax_reduce)
    mu = _pow2_ceil(gmax) * (2.0 ** (1 - beta))
    digits = _rn_const_extract(a, mu, beta, k, axis)
    base = mu * (2.0 ** beta)
    return Split(digits, _geo_scales(base, beta, k), base, beta, axis,
                 gbase=base[..., 0])


def split_oz2_bitmask(a: jax.Array, k: int, *, beta: Optional[int] = None,
                      axis: int = 0,
                      rowmax_reduce: Optional[Callable] = None) -> Split:
    """Ozaki-II constant scaling, truncation digits (``oz2_b``).

    Alg. 3's bit-mask extraction against the shared global grid
    ``base = 2 * 2^floor(log2 max|a|)``.  Digits in [-(2^beta-1), 2^beta-1];
    same ladder structure as :func:`split_oz2`.
    """
    if beta is None:
        beta = compute_beta(_contract_len(a, axis))
    gmax = _global_base(a, axis, rowmax_reduce)
    base = 2.0 * _pow2_floor(gmax)
    digits = _bitmask_extract(a, base, beta, k, axis)
    return Split(digits, _geo_scales(base, beta, k), base, beta, axis,
                 gbase=base[..., 0])


def _with_fast2_gbase(s: Split) -> Split:
    """Attach the constant equilibrated-grid base ``gbase = 2`` to a
    per-row split (the fast2 contract).

    ``base_i = rho_i * 2`` for both per-row strategies (``rho_i =
    2^ceil(log2 rowmax_i)`` for RN, ``2^floor(log2 rowmax_i)`` for
    truncation), so ``base_i / gbase`` recovers the exact power-of-two
    equilibration factor ``rho_i`` that ``matmul_oz2`` unscales by.
    """
    return s._replace(gbase=jnp.full(s.base.shape[:-1], 2.0,
                                     s.base.dtype))


def split_oz2_fast2(a: jax.Array, k: int, *, beta: Optional[int] = None,
                    axis: int = 0,
                    rowmax_reduce: Optional[Callable] = None) -> Split:
    """Ozaki-II improved fast-mode scaling, RN digits (``oz2_h ... :fast2``).

    Kawakami & Takahashi's rescaling: equilibrate every row by its own
    power of two ``rho_i = 2^ceil(log2 rowmax_i)``, then run the constant
    scaling of :func:`split_oz2` on the equilibrated matrix — whose
    shared grid is the CONSTANT ``mu = 2^(1-beta)``, i.e.
    ``gbase = 2``.  Since the equilibration is exact, the digits are
    bitwise identical to :func:`split_rn_const`'s (no extra pass); the
    Split carries the per-row ``base`` (``rho_i * gbase``) so the ladder
    consumer can apply the exact two-sided unscale after accumulation.
    The truncation error is anchored per row — near-full-mode accuracy
    at fast-mode cost.  Batched / ``rowmax_reduce`` like
    :func:`split_rn_const` (one reduction; shards agree on every row's
    grid, hence on the constant equilibrated grid).
    """
    return _with_fast2_gbase(split_rn_const(a, k, beta=beta, axis=axis,
                                            rowmax_reduce=rowmax_reduce))


def split_oz2_bitmask_fast2(a: jax.Array, k: int, *,
                            beta: Optional[int] = None, axis: int = 0,
                            rowmax_reduce: Optional[Callable] = None
                            ) -> Split:
    """Improved fast-mode scaling, truncation digits (``oz2_b ... :fast2``).

    The truncation analogue of :func:`split_oz2_fast2`: equilibration by
    ``rho_i = 2^floor(log2 rowmax_i)`` gives the equilibrated constant
    base ``2 * 2^floor(log2 rowmax_hat)`` = ``gbase = 2``; digits are
    bitwise :func:`split_bitmask`'s.
    """
    return _with_fast2_gbase(split_bitmask(a, k, beta=beta, axis=axis,
                                           rowmax_reduce=rowmax_reduce))


def reconstruct(split: Split, dtype=None) -> jax.Array:
    """sum_s diag(scale[s]) @ digits[s] (or the axis=1 transpose form)."""
    dt = dtype or split.scale.dtype
    digits = sm_decode(split.digits) if split.signmag else split.digits
    d = digits.astype(dt)
    if split.axis == 0:
        return jnp.sum(d * split.scale[..., :, None], axis=0)
    return jnp.sum(d * split.scale[..., None, :], axis=0)


def residual(split: Split, a: jax.Array) -> jax.Array:
    """Truncation error V_k = A - sum_s A_s (== W_k for axis=1).

    Reconstructs in a wide accumulator: summing round-to-nearest slices in
    f32 rounds away the very residual being measured (RN partial sums need
    more mantissa bits than f32 has; bitmask prefix sums are exact), so for
    f32 inputs the slice sum runs in f64 when x64 is enabled.
    """
    wide = jnp.float64 if jax.config.jax_enable_x64 else a.dtype
    return (a.astype(wide) - reconstruct(split, wide)).astype(a.dtype)
