"""Persistent weight split-cache for emulated GEMMs.

At inference the B operand of almost every emulated contraction is a
*static* weight matrix, yet the scheme re-runs the splitter on it every
decode step — re-deriving identical int8 digit slices, identical
power-of-two scales, and (for the oz2 variants) the identical shared
grid.  The :class:`SplitCache` freezes a static operand into its
spec-resolved :class:`~repro.core.splitting.Split` ONCE, keyed by
``(array identity, spec, dimension_numbers, mesh)``, and the
``rhs_presplit=`` path of :func:`repro.core.ozimmu.ozimmu_dot_general`
then skips the B-side splitter entirely — bit-identical to the uncached
path (the splitters are deterministic, rounding-exact float arithmetic;
freezing just hoists the identical computation out of the step).

Memory model (docs/serving.md): the cached entry holds the ``k`` int8
digit slices plus the scale vectors — ``k * bytes(B) / 8`` for f64
weights (``k/8`` of the operand), ``k/4`` for f32.  Re-splitting instead
costs a read of B plus a write of the same ``k`` slices *per call*, so
the cache pays for itself after a single decode step and eliminates the
B-side split phase from every step after.

Keying / invalidation:

* identity is ``id(array)`` guarded by a ``weakref`` — when the weight
  array is deleted (donated, updated by an optimizer step, re-wrapped),
  its entries drop out of the cache automatically, so a recycled ``id``
  can never alias a stale split.  Arrays that do not support weak
  references are kept alive by a strong reference instead (correct, but
  such entries only leave the cache via :meth:`clear`).
* the spec key carries everything the digits/scales depend on: the
  splitting strategy, the *resolved* slice count k, beta (from the
  global contraction length), and the operand dtype.  Same weights under
  a different spec are a miss by construction.
* the mesh key (axis names x sizes of the installed abstract mesh) keeps
  entries from leaking across mesh contexts.  The cached Split itself is
  mesh-independent — it is computed from the full operand, and the
  mesh-native path shards the cached digits along the contraction axis
  inside ``shard_map`` (the per-shard digits equal what the
  ``rowmax_reduce`` pmax-agreed shard-local splitter would produce, so
  the ``@mesh`` path stays bitwise identical too).

Auto-k (``...-auto`` specs) is resolved at freeze time with
:func:`resolved_k` — the *static* mantissa-coverage plan, which is
exactly what the planner resolves to inside a ``jit`` trace (serving
steps are jitted; there are no concrete operands to probe).  The frozen
k therefore matches the k the uncached jitted call would pick.
"""
from __future__ import annotations

import dataclasses
import threading
import weakref
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import splitting
from repro.core.splitting import Split
from repro.obs import registry as _obs

__all__ = ["SplitCache", "CacheStats", "resolved_k", "presplit_rhs",
           "split_nbytes"]


def resolved_k(cfg, n: int, dtype) -> int:
    """The slice count a serving-time (jitted) call resolves to.

    Fixed-k configs return ``cfg.k``.  ``auto`` configs resolve the
    static mantissa-coverage plan of ``repro.core.plan.choose_k`` with no
    probed operand gaps — identical to what ``plan.auto_k`` returns for
    tracers, so a cached split and the uncached jitted path agree on k.
    ``target_eps_mode`` rides along: a ``:prob`` config resolves the
    probabilistic static plan's (smaller) k, and because the resolved k
    is part of :func:`_cfg_key`, its entries never alias a deterministic
    plan's entries at a different k.
    """
    if not getattr(cfg, "auto_k", False):
        return cfg.k
    from repro.core import plan
    mantissa = plan._MANTISSA.get(np.dtype(dtype), 24)
    beta = splitting.beta_for(cfg.split, n)
    k, needed = plan.choose_k_bits(
        n, beta,
        cfg.target_eps if cfg.target_eps is not None
        else plan.DEFAULT_TARGET_EPS,
        split=cfg.split, mantissa=mantissa,
        fast=getattr(cfg, "fast", False),
        mode=getattr(cfg, "target_eps_mode", "deterministic"),
        delta=getattr(cfg, "target_delta", None))
    # m=p=0: the freeze-time resolution sees only the contraction length
    plan.record_decision(cfg, m=0, n=n, p=0, k=k, beta=beta,
                         needed=needed, probed=False,
                         source="split_cache")
    return k


def presplit_rhs(b: jax.Array, dimension_numbers, cfg) -> Split:
    """Freeze the rhs of ``dot_general(a, b, dimension_numbers)`` under
    ``cfg`` into its canonical column-scale Split.

    ``b`` must already be in the emulation's compute dtype (the engine
    casts operands before contracting; cast before freezing).  The split
    runs against the canonical ``(*batch, n, p)`` layout — the same
    transpose/reshape ``ozimmu_dot_general`` performs — with beta from
    the total contraction length, so the digits are bit-identical to
    what the in-call splitter would produce.
    """
    from repro.core import ozimmu
    b3, n = ozimmu.canonical_rhs(b, ozimmu._canonicalize_dnums(
        dimension_numbers))
    k = resolved_k(cfg, n, b3.dtype)
    beta = splitting.beta_for(cfg.split, n)
    splitter = ozimmu._SPLITTERS[cfg.split]
    return splitter(b3, k, beta=beta, axis=1)


def stack_leading(sp: Split, nstack: int) -> Split:
    """Re-layout a batched Split for the ``PresplitWeight`` wrapper: the
    ``nstack`` leading batch (layer-stack) axes move in front of the k
    axis — ``digits (*stack, k, n, p)``, ``scale (*stack, k, p)`` — so a
    ``lax.scan`` over the stacked parameter tree slices the split per
    layer.  NOTE: the result no longer follows the ``Split`` field
    contract (k is not leading); it is a storage layout for wrappers,
    not an operand for the accumulate routines."""
    if nstack == 0:
        return sp
    import jax.numpy as jnp
    return Split(jnp.moveaxis(sp.digits, 0, nstack),
                 jnp.moveaxis(sp.scale, 0, nstack),
                 sp.base, sp.beta, sp.axis, gbase=sp.gbase,
                 signmag=sp.signmag)


def split_nbytes(sp: Split) -> int:
    """Device bytes a cached Split occupies (digits + scales + bases)."""
    total = sp.digits.nbytes + sp.scale.nbytes
    if sp.base is not None:
        total += sp.base.nbytes
    if sp.gbase is not None:
        total += sp.gbase.nbytes
    return total


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    cached_bytes: int = 0      # resident bytes of cached splits
    hit_bytes: int = 0         # splitter input bytes avoided (sum of
                               # operand nbytes over hits) — the "split
                               # work saved" counter of serving metrics

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "cached_bytes": self.cached_bytes,
                "hit_bytes": self.hit_bytes,
                "hit_rate": round(self.hit_rate, 6)}


def _cfg_key(cfg, k: int, dtype) -> Tuple:
    return (cfg.split, int(k), str(np.dtype(dtype)),
            bool(getattr(cfg, "fast", False)))


def _mesh_key() -> Tuple:
    try:
        from repro.distributed import compat
        mesh = compat.get_abstract_mesh()
        if mesh.empty:
            return ()
        return tuple(sorted(dict(mesh.shape).items()))
    except Exception:
        return ()


class SplitCache:
    """Freeze-once cache of spec-resolved weight splits.

    Thread-safe, weakref-invalidated (see module docstring).  ``get``
    returns the cached :class:`Split` for ``(b, dimension_numbers, cfg)``
    or computes and stores it on a miss.
    """

    def __init__(self, max_entries: Optional[int] = None):
        self._entries: Dict[Tuple, Tuple[Split, int, Any]] = {}
        self._lock = threading.Lock()
        self._max = max_entries
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, b: jax.Array, dimension_numbers, cfg,
            dtype=None, layout: str = "k_leading") -> Split:
        """The frozen Split for ``b`` as the rhs of
        ``dot_general(·, b, dimension_numbers)`` under ``cfg``.

        ``dtype`` is the emulation's compute dtype when it differs from
        ``b.dtype`` (the engine casts operands before contracting): the
        cast happens *inside* — the entry stays keyed and
        weakref-anchored on the ORIGINAL array, so it survives across
        calls (a cast produces a throwaway array whose identity would
        otherwise invalidate the entry immediately).

        ``layout="stack_leading"`` stores (and returns) the
        :func:`stack_leading` wrapper layout instead — the cached entry
        IS the wrapper's storage, so a layer-stacked weight's digits are
        resident exactly once (a post-hoc ``moveaxis`` would keep both
        copies alive through the cache's strong reference).
        """
        if isinstance(b, jax.core.Tracer):
            raise TypeError(
                "SplitCache.get needs a concrete array: freeze weights "
                "eagerly (outside jit) and pass the Split into the "
                "jitted step via rhs_presplit / PresplitWeight")
        from repro.core import ozimmu
        if layout not in ("k_leading", "stack_leading"):
            raise ValueError(f"unknown split layout {layout!r}")
        dtype = np.dtype(b.dtype) if dtype is None else np.dtype(dtype)
        dnums = ozimmu._canonicalize_dnums(dimension_numbers)
        (_, bc), (_, bb) = dnums
        n = int(np.prod([b.shape[i] for i in bc], dtype=np.int64))
        k = resolved_k(cfg, n, dtype)
        key = (id(b), _cfg_key(cfg, k, dtype), dnums, _mesh_key(), layout)
        in_bytes = int(np.prod(b.shape, dtype=np.int64)) * dtype.itemsize
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.hits += 1
                self.stats.hit_bytes += in_bytes
                self._obs_event("hits", hit_bytes=in_bytes)
                return entry[0]
        bc_arr = b if np.dtype(b.dtype) == dtype else b.astype(dtype)
        sp = presplit_rhs(bc_arr, dnums, cfg)
        if layout == "stack_leading":
            sp = stack_leading(sp, len(bb))
        nbytes = split_nbytes(sp)
        anchor = self._anchor(b, key)
        with self._lock:
            # re-check: a concurrent miss may have inserted first — keep
            # one entry and count one miss (documented thread-safety)
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.hits += 1
                self.stats.hit_bytes += in_bytes
                self._obs_event("hits", hit_bytes=in_bytes)
                return entry[0]
            if self._max is not None and len(self._entries) >= self._max:
                self._evict_one_locked()
            self._entries[key] = (sp, nbytes, anchor)
            self.stats.misses += 1
            self.stats.cached_bytes += nbytes
            self._obs_event("misses")
        return sp

    def _obs_event(self, kind: str, hit_bytes: int = 0):
        """Mirror one stats transition into the process-global registry
        (cached_bytes rides along as a gauge).  The registry lock is a
        leaf — safe under ``self._lock``."""
        if not _obs.enabled():
            return
        reg = _obs.get_registry()
        reg.inc(f"split_cache.{kind}", 1)
        if hit_bytes:
            reg.inc("split_cache.hit_bytes", hit_bytes)
        reg.gauge("split_cache.cached_bytes", self.stats.cached_bytes)

    def _anchor(self, b, key):
        """A weakref that drops the entry when the array dies; falls back
        to a strong reference for non-weakrefable arrays."""
        def _on_dead(_ref, cache=weakref.ref(self), key=key):
            c = cache()
            if c is not None:
                c._drop(key, invalidated=True)
        try:
            return weakref.ref(b, _on_dead)
        except TypeError:
            return b

    def _drop(self, key, invalidated: bool = False):
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is not None:
                self.stats.cached_bytes -= entry[1]
                if invalidated:
                    self.stats.invalidations += 1
                    self._obs_event("invalidations")

    def _evict_one_locked(self):
        key = next(iter(self._entries))
        entry = self._entries.pop(key)
        self.stats.cached_bytes -= entry[1]

    def invalidate(self, b: jax.Array) -> int:
        """Drop every entry keyed on this array (as of the snapshot taken
        under the lock); returns the count."""
        with self._lock:
            keys = [k for k in self._entries if k[0] == id(b)]
        for k in keys:
            self._drop(k, invalidated=True)
        return len(keys)

    def clear(self):
        with self._lock:
            self._entries.clear()
            self.stats.cached_bytes = 0
