"""Rounding-error bounds from §5 of the paper, plus op-count accounting.

These are used by tests (the computed result must satisfy the bound) and by
the benchmark harness (predicted-vs-measured error).
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core.splitting import compute_beta, compute_beta_sm, compute_r

__all__ = [
    "unit_roundoff",
    "truncation_bound",
    "accumulation_terms_w",
    "error_bound_ozimmu",
    "error_bound_group_ef",
    "error_bound_rn",
    "error_bound_sm",
    "error_bound_oz2",
    "flop_counts",
]


def unit_roundoff(dtype) -> float:
    return {np.dtype(np.float64): 2.0 ** -53,
            np.dtype(np.float32): 2.0 ** -24}[np.dtype(dtype)]


def _gf(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """g f^T with g_i = ufp(max_j |a_ij|), f_j = ufp(max_i |b_ij|)."""
    def ufp(x):
        out = np.zeros_like(x)
        nz = x != 0
        out[nz] = 2.0 ** np.floor(np.log2(x[nz]))
        return out
    g = ufp(np.max(np.abs(a), axis=1))
    f = ufp(np.max(np.abs(b), axis=0))
    return np.outer(g, f)


def truncation_bound(a: np.ndarray, b: np.ndarray, k: int,
                     beta: int | None = None) -> np.ndarray:
    """|AB - sum_{s+t<=k+1} A_s B_t| <= 4(k+1) n 2^(-beta k) g f^T — eq. (18)."""
    n = a.shape[1]
    beta = beta or compute_beta(n)
    return 4.0 * (k + 1) * n * 2.0 ** (-beta * k) * _gf(a, b)


def accumulation_terms_w(k: int, r: int) -> int:
    """w = ceil(k/r) * (k - (r/2) * floor((k-1)/r)) — §5.2."""
    return math.ceil(k / r) * (k - (r / 2) * math.floor((k - 1) / r))


def error_bound_ozimmu(a: np.ndarray, b: np.ndarray, k: int,
                       u: float | None = None) -> np.ndarray:
    """Deterministic bound for Alg. 3+4 (without the k'_max sharpening):

        |AB - T_k| <= 4(k+1) n 2^(-beta k) g f^T + (k(k+1)/2 - 1) u |A||B|.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    tb = truncation_bound(a, b, k)
    return tb + (k * (k + 1) / 2 - 1) * u * (np.abs(a) @ np.abs(b))


def error_bound_group_ef(a: np.ndarray, b: np.ndarray, k: int,
                         u: float | None = None) -> np.ndarray:
    """Bound for Alg. 3+6: |AB - T| <= 4(k+1) n 2^(-beta k) g f^T + (w-1) u |A||B|."""
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta(n)
    w = accumulation_terms_w(k, compute_r(n, beta))
    return truncation_bound(a, b, k) + max(w - 1, 0) * u * (np.abs(a) @ np.abs(b))


def error_bound_rn(a: np.ndarray, b: np.ndarray, k: int,
                   u: float | None = None) -> np.ndarray:
    """Documented bound for the RN variants (ozIMMU_RN / ozIMMU_H).

    Same shape as eq. (18) with the grid anchored at ``2^ceil(log2 max)``
    (up to 2x the ufp anchor of the truncation variants) but only half-ulp
    per-slice rounding; the naive k(k+1)/2 accumulation term dominates the
    group-EF one, so one bound covers both.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta(n)
    tb = 4.0 * (k + 1) * n * 2.0 ** (-beta * k) * (2.0 * _gf(a, b))
    return tb + (k * (k + 1) / 2) * u * (np.abs(a) @ np.abs(b))


def error_bound_sm(a: np.ndarray, b: np.ndarray, k: int,
                   u: float | None = None) -> np.ndarray:
    """Documented bound for the sign-magnitude variants (ozimmu_sm_b/_h).

    The splitter anchors each row at ``anchor_i = 2 ufp(rowmax_i)`` (so
    the normalized value is strictly inside (-1, 1)) and extracts k
    digits of ``beta_sm = min(8, ...)`` bits, the leading one carrying
    the sign; the elementwise residual after k digits satisfies
    ``|V_A| <= anchor_i 2^(1 - beta k) = 4 g_i 2^(-beta k)`` — exactly
    2x the bitmask residual at equal beta (floor truncation against the
    doubled anchor), so eq. (18)'s band/truncation bound holds with the
    constant doubled:

        |AB - T_k| <= 8(k+1) n 2^(-beta_sm k) g f^T
                      + (k(k+1)/2) u |A||B|.

    The naive accumulation term (ozimmu_sm_b) dominates the group-EF one
    (ozimmu_sm_h, w - 1 adds), so one bound covers both — mirroring
    :func:`error_bound_rn`.  At beta_sm = 8 the truncation term is
    ~2^(k-1) times SMALLER than the beta-7 bound at equal k: the
    (k-1)-bit saving the planner turns into a smaller k.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta_sm(n)
    tb = 8.0 * (k + 1) * n * 2.0 ** (-beta * k) * _gf(a, b)
    return tb + (k * (k + 1) / 2) * u * (np.abs(a) @ np.abs(b))


def _global_anchor(x: np.ndarray) -> float:
    """A power of two >= max|x| (the oz2 shared-grid anchor; conservative
    by at most 2x when max|x| is itself a power of two)."""
    gmax = float(np.max(np.abs(x)))
    if gmax == 0.0:
        return 0.0
    _, e = np.frexp(gmax)
    return float(np.ldexp(1.0, int(e)))


def _row_anchor(x: np.ndarray, axis: int) -> np.ndarray:
    """Per-row (axis=1: per-column) power-of-two anchors >= the row maxima
    — the fast2 equilibrated-grid anchors (conservative by <= 2x each,
    like :func:`_global_anchor`); 0.0 for all-zero rows."""
    rmax = np.max(np.abs(x), axis=axis)
    out = np.zeros_like(rmax)
    nz = rmax > 0
    _, e = np.frexp(rmax[nz])
    out[nz] = np.ldexp(np.ones_like(rmax[nz]), e)
    return out


def error_bound_oz2(a: np.ndarray, b: np.ndarray, k: int,
                    fast: bool | str = True, u: float | None = None,
                    adds: int | None = None,
                    fast2: bool = False) -> np.ndarray:
    """Documented elementwise bound for the oz2 (constant-scaling) modes.

    With the shared grids anchored at ``EA = 2^ceil(log2 max|A|)`` (resp.
    EB), the splitting truncations satisfy ``|V_A| <= 2 EA 2^(-beta k)``
    elementwise (RN: half that), so

        |AB - T| <= 4 * 2^(-beta k) * (EA * colsum|B| + rowsum|A| * EB
                                       + n * EA * EB)        (truncation)
                  + [fast] 8 k n 2^(-beta k) * EA * EB       (dropped g>k+1)
                  + (adds - 1) u |A||B|
                  + 4 adds n u EA EB                         (accumulation)

    The last term is the conversion/rounding noise of the ladder-window
    terms themselves: a slice product's elementwise magnitude is bounded
    by ``n EA EB 2^(2 beta - beta g)`` — grid noise, NOT ``|A||B|`` — so
    the running accumulator transiently holds O(n EA EB) and each window
    add may round relative to that.  (Negligible for the f64/df32
    accumulators; it is what dominates plain-f32 accumulation on
    wide-spread operands.)

    The anchors are GLOBAL: unlike eq. (18)'s per-row ``g f^T``, rows far
    below the matrix maximum inherit the matrix-level absolute error — the
    price of constant scaling, and exactly what the adversarial oracle
    grid (tests/test_oracle.py) exercises.

    ``fast2=True`` (equivalently ``fast="fast2"``) selects the improved
    fast-mode scaling (Kawakami & Takahashi; spec token ``:fast2``): the
    per-row power-of-two equilibration anchors every truncation at the
    row's OWN magnitude, so the same bound holds with the scalar anchors
    ``EA``/``EB`` replaced by the per-row/col anchor vectors ``EA_i =
    2^ceil(log2 rowmax_i(A))`` / ``EB_j = 2^ceil(log2 colmax_j(B))`` —
    in particular the dropped-band term tightens from ``8 k n t EA EB``
    to the outer ``8 k n t EA_i EB_j``, which is what restores
    near-full-mode accuracy on wide-exponent-spread operands.  The
    ladder still evaluates the fast band, so the accumulation-count
    accounting is the fast-mode one.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta(n)
    fast2 = fast2 or fast == "fast2"
    if fast2:
        fast = True
        ea = _row_anchor(a, axis=1)[:, None]   # (m, 1)
        eb = _row_anchor(b, axis=0)[None, :]   # (1, p)
    else:
        ea, eb = _global_anchor(a), _global_anchor(b)
    t = 2.0 ** (-beta * k)
    colsum = np.sum(np.abs(b), axis=0)
    rowsum = np.sum(np.abs(a), axis=1)
    trunc = 4.0 * t * (ea * colsum[None, :] + rowsum[:, None] * eb
                       + n * ea * eb)
    dropped = 8.0 * k * n * t * ea * eb if fast else 0.0
    if adds is None:
        # conservative default: count the ladder windows of the WORST
        # configuration — truncation digit bits (smaller r, more chunks)
        # and the 31-bit int32 word (df32/f32 ladders, least folding) —
        # so one bound covers oz2_b/oz2_h under every accumulator.  Pass
        # the actual count for a tighter bound.
        from repro.core.accumulate import oz2_num_highprec_adds
        r = compute_r(n, beta, beta)
        adds = oz2_num_highprec_adds(k, r, beta, n, fast, beta,
                                     word_bits=31)
    accum = (max(adds - 1, 0) * u * (np.abs(a) @ np.abs(b))
             + 4.0 * adds * n * u * ea * eb)
    return trunc + dropped + accum


def flop_counts(m: int, n: int, p: int, k: int, *, group_ef: bool,
                r: int | None = None) -> dict:
    """Operation accounting for the roofline/perf model.

    Returns int8 MAC count, high-precision (accumulate) element ops, and
    split element passes — the three cost centers of the scheme.
    """
    beta = compute_beta(n)
    r = r or compute_r(n, beta)
    n_pairs = k * (k + 1) // 2
    int8_macs = n_pairs * m * n * p
    if group_ef:
        from repro.core.accumulate import num_highprec_adds
        hp_terms = num_highprec_adds(k, r, True)
    else:
        hp_terms = n_pairs
    # each high-precision term: int32->float convert + 2 diag scalings + add
    hp_elem_ops = hp_terms * m * p * 4
    split_elem_passes = 2 * k  # k extraction passes over each operand
    return dict(beta=beta, r=r, int8_macs=int8_macs, hp_terms=hp_terms,
                hp_elem_ops=hp_elem_ops, split_elem_passes=split_elem_passes)
