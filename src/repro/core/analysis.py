"""Rounding-error bounds from §5 of the paper, plus op-count accounting.

These are used by tests (the computed result must satisfy the bound) and by
the benchmark harness (predicted-vs-measured error).

Two bound families live here:

* the **deterministic** worst-case bounds (eq. (18) and its variant
  refinements) — every rounding/truncation error aligned adversarially;
* their **probabilistic** twins (``prob_error_bound_*``), following the
  analysis of Abdelfattah, Dongarra, Fasi, Mikaitis & Tisseur, *Analysis
  of Floating-Point Matrix Multiplication Computed via Integer
  Arithmetic* (arXiv 2506.11277): modeling the per-term splitting
  truncations and accumulation roundings as mean-independent bounded
  random variables, a Hoeffding/Azuma concentration argument replaces
  every "sum of N error terms" factor ``N`` by
  ``lambda(delta) * sqrt(N)`` with ``lambda(delta) =
  sqrt(2 ln(2/delta))``, valid with probability at least ``1 - delta``
  per entry.  ``delta = 0`` makes ``lambda`` infinite and the effective
  factor falls back to ``N`` — the deterministic bound is the exact
  ``delta = 0`` limit, bitwise (the same float expressions evaluate).

The probabilistic model is sharp for the round-to-nearest splits
(``rn``/``rn_const``/``oz2_rn``): their per-slice errors are symmetric
half-ulp roundings, the mean-independence hypothesis of 2506.11277.  The
directed-truncation splits (bitmask, sign-magnitude floor extraction)
have sign-biased residuals on adversarial operands, where sums grow
linearly, not like sqrt(N); their probabilistic bounds hold under the
random-operand model (symmetric element signs re-center the residuals)
and the *planner* additionally charges back a calibrated bias bit for
them (``repro.core.plan``).
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core.splitting import compute_beta, compute_beta_sm, compute_r

__all__ = [
    "unit_roundoff",
    "DEFAULT_DELTA",
    "effective_terms",
    "truncation_bound",
    "accumulation_terms_w",
    "error_bound_ozimmu",
    "error_bound_group_ef",
    "error_bound_rn",
    "error_bound_sm",
    "error_bound_oz2",
    "prob_error_bound_ozimmu",
    "prob_error_bound_group_ef",
    "prob_error_bound_rn",
    "prob_error_bound_sm",
    "prob_error_bound_oz2",
    "flop_counts",
]


def unit_roundoff(dtype) -> float:
    return {np.dtype(np.float64): 2.0 ** -53,
            np.dtype(np.float32): 2.0 ** -24}[np.dtype(dtype)]


# Default per-entry failure probability of the probabilistic bounds and
# of the planner's ``target_eps_mode="probabilistic"``: one entry in a
# million runs of a 1k x 1k output, and the concentration constant
# lambda = sqrt(2 ln(2/delta)) ~ 5.4 stays narrow (3 bits).
DEFAULT_DELTA = 2.0 ** -20


def effective_terms(count, delta: float):
    """Effective error-term count under the probabilistic model.

    A sum of ``count`` mean-independent error terms, each bounded by
    ``eps_term``, is at most ``count * eps_term`` deterministically but —
    by Hoeffding's inequality (2506.11277, Thm. 3.2 shape) — at most
    ``sqrt(2 ln(2/delta) * count) * eps_term`` with probability at least
    ``1 - delta``.  Returns ``min(count, lambda(delta) * sqrt(count))``
    as a float; ``delta <= 0`` returns ``float(count)`` (the
    deterministic limit, exact for every count in range here).
    """
    c = float(count)
    if delta <= 0.0:
        return c
    if not delta < 1.0:
        raise ValueError(f"delta must be < 1, got {delta}")
    return min(c, math.sqrt(2.0 * math.log(2.0 / delta) * c))


def _gf(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """g f^T with g_i = ufp(max_j |a_ij|), f_j = ufp(max_i |b_ij|)."""
    def ufp(x):
        out = np.zeros_like(x)
        nz = x != 0
        out[nz] = 2.0 ** np.floor(np.log2(x[nz]))
        return out
    g = ufp(np.max(np.abs(a), axis=1))
    f = ufp(np.max(np.abs(b), axis=0))
    return np.outer(g, f)


def truncation_bound(a: np.ndarray, b: np.ndarray, k: int,
                     beta: int | None = None,
                     delta: float = 0.0) -> np.ndarray:
    """|AB - sum_{s+t<=k+1} A_s B_t| <= 4(k+1) n 2^(-beta k) g f^T — eq. (18).

    ``delta > 0``: the n-term truncation sum concentrates; ``n`` is
    replaced by ``effective_terms(n, delta)`` and the bound holds with
    probability >= 1 - delta per entry (under the mean-independent
    residual model; see the module docstring for where that is sharp).
    """
    n = a.shape[1]
    beta = beta or compute_beta(n)
    return 4.0 * (k + 1) * effective_terms(n, delta) \
        * 2.0 ** (-beta * k) * _gf(a, b)


def accumulation_terms_w(k: int, r: int) -> int:
    """w = ceil(k/r) * (k - (r/2) * floor((k-1)/r)) — §5.2."""
    return math.ceil(k / r) * (k - (r / 2) * math.floor((k - 1) / r))


def error_bound_ozimmu(a: np.ndarray, b: np.ndarray, k: int,
                       u: float | None = None,
                       delta: float = 0.0) -> np.ndarray:
    """Deterministic bound for Alg. 3+4 (without the k'_max sharpening):

        |AB - T_k| <= 4(k+1) n 2^(-beta k) g f^T + (k(k+1)/2 - 1) u |A||B|.

    ``delta > 0`` applies :func:`effective_terms` to both error-term
    counts (the n-term truncation sum and the k(k+1)/2 - 1 accumulation
    roundings); per-entry failure probability <= delta.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    tb = truncation_bound(a, b, k, delta=delta)
    adds = effective_terms(k * (k + 1) / 2 - 1, delta)
    return tb + adds * u * (np.abs(a) @ np.abs(b))


def error_bound_group_ef(a: np.ndarray, b: np.ndarray, k: int,
                         u: float | None = None,
                         delta: float = 0.0) -> np.ndarray:
    """Bound for Alg. 3+6: |AB - T| <= 4(k+1) n 2^(-beta k) g f^T + (w-1) u |A||B|."""
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta(n)
    w = accumulation_terms_w(k, compute_r(n, beta))
    adds = effective_terms(max(w - 1, 0), delta)
    return truncation_bound(a, b, k, delta=delta) \
        + adds * u * (np.abs(a) @ np.abs(b))


def error_bound_rn(a: np.ndarray, b: np.ndarray, k: int,
                   u: float | None = None,
                   delta: float = 0.0) -> np.ndarray:
    """Documented bound for the RN variants (ozIMMU_RN / ozIMMU_H).

    Same shape as eq. (18) with the grid anchored at ``2^ceil(log2 max)``
    (up to 2x the ufp anchor of the truncation variants) but only half-ulp
    per-slice rounding; the naive k(k+1)/2 accumulation term dominates the
    group-EF one, so one bound covers both.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta(n)
    tb = 4.0 * (k + 1) * effective_terms(n, delta) \
        * 2.0 ** (-beta * k) * (2.0 * _gf(a, b))
    adds = effective_terms(k * (k + 1) / 2, delta)
    return tb + adds * u * (np.abs(a) @ np.abs(b))


def error_bound_sm(a: np.ndarray, b: np.ndarray, k: int,
                   u: float | None = None,
                   delta: float = 0.0) -> np.ndarray:
    """Documented bound for the sign-magnitude variants (ozimmu_sm_b/_h).

    The splitter anchors each row at ``anchor_i = 2 ufp(rowmax_i)`` (so
    the normalized value is strictly inside (-1, 1)) and extracts k
    digits of ``beta_sm = min(8, ...)`` bits, the leading one carrying
    the sign; the elementwise residual after k digits satisfies
    ``|V_A| <= anchor_i 2^(1 - beta k) = 4 g_i 2^(-beta k)`` — exactly
    2x the bitmask residual at equal beta (floor truncation against the
    doubled anchor), so eq. (18)'s band/truncation bound holds with the
    constant doubled:

        |AB - T_k| <= 8(k+1) n 2^(-beta_sm k) g f^T
                      + (k(k+1)/2) u |A||B|.

    The naive accumulation term (ozimmu_sm_b) dominates the group-EF one
    (ozimmu_sm_h, w - 1 adds), so one bound covers both — mirroring
    :func:`error_bound_rn`.  At beta_sm = 8 the truncation term is
    ~2^(k-1) times SMALLER than the beta-7 bound at equal k: the
    (k-1)-bit saving the planner turns into a smaller k.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta_sm(n)
    tb = 8.0 * (k + 1) * effective_terms(n, delta) \
        * 2.0 ** (-beta * k) * _gf(a, b)
    adds = effective_terms(k * (k + 1) / 2, delta)
    return tb + adds * u * (np.abs(a) @ np.abs(b))


def _global_anchor(x: np.ndarray) -> float:
    """A power of two >= max|x| (the oz2 shared-grid anchor; conservative
    by at most 2x when max|x| is itself a power of two)."""
    gmax = float(np.max(np.abs(x)))
    if gmax == 0.0:
        return 0.0
    _, e = np.frexp(gmax)
    return float(np.ldexp(1.0, int(e)))


def _row_anchor(x: np.ndarray, axis: int) -> np.ndarray:
    """Per-row (axis=1: per-column) power-of-two anchors >= the row maxima
    — the fast2 equilibrated-grid anchors (conservative by <= 2x each,
    like :func:`_global_anchor`); 0.0 for all-zero rows."""
    rmax = np.max(np.abs(x), axis=axis)
    out = np.zeros_like(rmax)
    nz = rmax > 0
    _, e = np.frexp(rmax[nz])
    out[nz] = np.ldexp(np.ones_like(rmax[nz]), e)
    return out


def error_bound_oz2(a: np.ndarray, b: np.ndarray, k: int,
                    fast: bool | str = True, u: float | None = None,
                    adds: int | None = None,
                    fast2: bool = False,
                    delta: float = 0.0) -> np.ndarray:
    """Documented elementwise bound for the oz2 (constant-scaling) modes.

    With the shared grids anchored at ``EA = 2^ceil(log2 max|A|)`` (resp.
    EB), the splitting truncations satisfy ``|V_A| <= 2 EA 2^(-beta k)``
    elementwise (RN: half that), so

        |AB - T| <= 4 * 2^(-beta k) * (EA * colsum|B| + rowsum|A| * EB
                                       + n * EA * EB)        (truncation)
                  + [fast] 8 k n 2^(-beta k) * EA * EB       (dropped g>k+1)
                  + (adds - 1) u |A||B|
                  + 4 adds n u EA EB                         (accumulation)

    The last term is the conversion/rounding noise of the ladder-window
    terms themselves: a slice product's elementwise magnitude is bounded
    by ``n EA EB 2^(2 beta - beta g)`` — grid noise, NOT ``|A||B|`` — so
    the running accumulator transiently holds O(n EA EB) and each window
    add may round relative to that.  (Negligible for the f64/df32
    accumulators; it is what dominates plain-f32 accumulation on
    wide-spread operands.)

    The anchors are GLOBAL: unlike eq. (18)'s per-row ``g f^T``, rows far
    below the matrix maximum inherit the matrix-level absolute error — the
    price of constant scaling, and exactly what the adversarial oracle
    grid (tests/test_oracle.py) exercises.

    ``fast2=True`` (equivalently ``fast="fast2"``) selects the improved
    fast-mode scaling (Kawakami & Takahashi; spec token ``:fast2``): the
    per-row power-of-two equilibration anchors every truncation at the
    row's OWN magnitude, so the same bound holds with the scalar anchors
    ``EA``/``EB`` replaced by the per-row/col anchor vectors ``EA_i =
    2^ceil(log2 rowmax_i(A))`` / ``EB_j = 2^ceil(log2 colmax_j(B))`` —
    in particular the dropped-band term tightens from ``8 k n t EA EB``
    to the outer ``8 k n t EA_i EB_j``, which is what restores
    near-full-mode accuracy on wide-exponent-spread operands.  The
    ladder still evaluates the fast band, so the accumulation-count
    accounting is the fast-mode one.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta(n)
    fast2 = fast2 or fast == "fast2"
    if fast2:
        fast = True
        ea = _row_anchor(a, axis=1)[:, None]   # (m, 1)
        eb = _row_anchor(b, axis=0)[None, :]   # (1, p)
    else:
        ea, eb = _global_anchor(a), _global_anchor(b)
    t = 2.0 ** (-beta * k)
    n_eff = effective_terms(n, delta)
    colsum = np.sum(np.abs(b), axis=0)
    rowsum = np.sum(np.abs(a), axis=1)
    # each of the three truncation contributions and the dropped band is
    # an n-term sum of bounded residual products, so the probabilistic
    # model replaces its n factor (explicit in the n*EA*EB / dropped
    # terms, inside colsum/rowsum for the cross terms — rescaled by
    # n_eff/n there) by effective_terms(n, delta).
    trunc = 4.0 * t * ((ea * colsum[None, :] + rowsum[:, None] * eb)
                       * (n_eff / n) + n_eff * ea * eb)
    dropped = 8.0 * k * n_eff * t * ea * eb if fast else 0.0
    if adds is None:
        # conservative default: count the ladder windows of the WORST
        # configuration — truncation digit bits (smaller r, more chunks)
        # and the 31-bit int32 word (df32/f32 ladders, least folding) —
        # so one bound covers oz2_b/oz2_h under every accumulator.  Pass
        # the actual count for a tighter bound.
        from repro.core.accumulate import oz2_num_highprec_adds
        r = compute_r(n, beta, beta)
        adds = oz2_num_highprec_adds(k, r, beta, n, fast, beta,
                                     word_bits=31)
    accum = (effective_terms(max(adds - 1, 0), delta) * u
             * (np.abs(a) @ np.abs(b))
             + 4.0 * effective_terms(adds, delta) * n_eff * u * ea * eb)
    return trunc + dropped + accum


def prob_error_bound_ozimmu(a: np.ndarray, b: np.ndarray, k: int,
                            delta: float = DEFAULT_DELTA,
                            u: float | None = None) -> np.ndarray:
    """Probabilistic twin of :func:`error_bound_ozimmu` (arXiv 2506.11277
    model; per-entry failure probability <= ``delta``).  ``delta=0``
    recovers the deterministic bound bitwise."""
    return error_bound_ozimmu(a, b, k, u=u, delta=delta)


def prob_error_bound_group_ef(a: np.ndarray, b: np.ndarray, k: int,
                              delta: float = DEFAULT_DELTA,
                              u: float | None = None) -> np.ndarray:
    """Probabilistic twin of :func:`error_bound_group_ef`."""
    return error_bound_group_ef(a, b, k, u=u, delta=delta)


def prob_error_bound_rn(a: np.ndarray, b: np.ndarray, k: int,
                        delta: float = DEFAULT_DELTA,
                        u: float | None = None) -> np.ndarray:
    """Probabilistic twin of :func:`error_bound_rn` — the sharp case of
    the model: half-ulp RN slice roundings are symmetric and
    mean-independent, exactly the 2506.11277 hypothesis."""
    return error_bound_rn(a, b, k, u=u, delta=delta)


def prob_error_bound_sm(a: np.ndarray, b: np.ndarray, k: int,
                        delta: float = DEFAULT_DELTA,
                        u: float | None = None) -> np.ndarray:
    """Probabilistic twin of :func:`error_bound_sm`.  Holds under the
    random-operand model (symmetric signs re-center the one-sided floor
    truncations); the planner charges a calibrated bias for this split
    on top (``repro.core.plan``)."""
    return error_bound_sm(a, b, k, u=u, delta=delta)


def prob_error_bound_oz2(a: np.ndarray, b: np.ndarray, k: int,
                         fast: bool | str = True,
                         delta: float = DEFAULT_DELTA,
                         u: float | None = None,
                         adds: int | None = None,
                         fast2: bool = False) -> np.ndarray:
    """Probabilistic twin of :func:`error_bound_oz2`."""
    return error_bound_oz2(a, b, k, fast=fast, u=u, adds=adds,
                           fast2=fast2, delta=delta)


def flop_counts(m: int, n: int, p: int, k: int, *, group_ef: bool,
                r: int | None = None) -> dict:
    """Operation accounting for the roofline/perf model.

    Returns int8 MAC count, high-precision (accumulate) element ops, and
    split element passes — the three cost centers of the scheme.
    """
    beta = compute_beta(n)
    r = r or compute_r(n, beta)
    n_pairs = k * (k + 1) // 2
    int8_macs = n_pairs * m * n * p
    if group_ef:
        from repro.core.accumulate import num_highprec_adds
        hp_terms = num_highprec_adds(k, r, True)
    else:
        hp_terms = n_pairs
    # each high-precision term: int32->float convert + 2 diag scalings + add
    hp_elem_ops = hp_terms * m * p * 4
    split_elem_passes = 2 * k  # k extraction passes over each operand
    return dict(beta=beta, r=r, int8_macs=int8_macs, hp_terms=hp_terms,
                hp_elem_ops=hp_elem_ops, split_elem_passes=split_elem_passes)
