"""Rounding-error bounds from §5 of the paper, plus op-count accounting.

These are used by tests (the computed result must satisfy the bound) and by
the benchmark harness (predicted-vs-measured error).
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core.splitting import compute_beta, compute_r

__all__ = [
    "unit_roundoff",
    "truncation_bound",
    "accumulation_terms_w",
    "error_bound_ozimmu",
    "error_bound_group_ef",
    "flop_counts",
]


def unit_roundoff(dtype) -> float:
    return {np.dtype(np.float64): 2.0 ** -53,
            np.dtype(np.float32): 2.0 ** -24}[np.dtype(dtype)]


def _gf(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """g f^T with g_i = ufp(max_j |a_ij|), f_j = ufp(max_i |b_ij|)."""
    def ufp(x):
        out = np.zeros_like(x)
        nz = x != 0
        out[nz] = 2.0 ** np.floor(np.log2(x[nz]))
        return out
    g = ufp(np.max(np.abs(a), axis=1))
    f = ufp(np.max(np.abs(b), axis=0))
    return np.outer(g, f)


def truncation_bound(a: np.ndarray, b: np.ndarray, k: int,
                     beta: int | None = None) -> np.ndarray:
    """|AB - sum_{s+t<=k+1} A_s B_t| <= 4(k+1) n 2^(-beta k) g f^T — eq. (18)."""
    n = a.shape[1]
    beta = beta or compute_beta(n)
    return 4.0 * (k + 1) * n * 2.0 ** (-beta * k) * _gf(a, b)


def accumulation_terms_w(k: int, r: int) -> int:
    """w = ceil(k/r) * (k - (r/2) * floor((k-1)/r)) — §5.2."""
    return math.ceil(k / r) * (k - (r / 2) * math.floor((k - 1) / r))


def error_bound_ozimmu(a: np.ndarray, b: np.ndarray, k: int,
                       u: float | None = None) -> np.ndarray:
    """Deterministic bound for Alg. 3+4 (without the k'_max sharpening):

        |AB - T_k| <= 4(k+1) n 2^(-beta k) g f^T + (k(k+1)/2 - 1) u |A||B|.
    """
    u = u if u is not None else unit_roundoff(a.dtype)
    tb = truncation_bound(a, b, k)
    return tb + (k * (k + 1) / 2 - 1) * u * (np.abs(a) @ np.abs(b))


def error_bound_group_ef(a: np.ndarray, b: np.ndarray, k: int,
                         u: float | None = None) -> np.ndarray:
    """Bound for Alg. 3+6: |AB - T| <= 4(k+1) n 2^(-beta k) g f^T + (w-1) u |A||B|."""
    u = u if u is not None else unit_roundoff(a.dtype)
    n = a.shape[1]
    beta = compute_beta(n)
    w = accumulation_terms_w(k, compute_r(n, beta))
    return truncation_bound(a, b, k) + max(w - 1, 0) * u * (np.abs(a) @ np.abs(b))


def flop_counts(m: int, n: int, p: int, k: int, *, group_ef: bool,
                r: int | None = None) -> dict:
    """Operation accounting for the roofline/perf model.

    Returns int8 MAC count, high-precision (accumulate) element ops, and
    split element passes — the three cost centers of the scheme.
    """
    beta = compute_beta(n)
    r = r or compute_r(n, beta)
    n_pairs = k * (k + 1) // 2
    int8_macs = n_pairs * m * n * p
    if group_ef:
        from repro.core.accumulate import num_highprec_adds
        hp_terms = num_highprec_adds(k, r, True)
    else:
        hp_terms = n_pairs
    # each high-precision term: int32->float convert + 2 diag scalings + add
    hp_elem_ops = hp_terms * m * p * 4
    split_elem_passes = 2 * k  # k extraction passes over each operand
    return dict(beta=beta, r=r, int8_macs=int8_macs, hp_terms=hp_terms,
                hp_elem_ops=hp_elem_ops, split_elem_passes=split_elem_passes)
