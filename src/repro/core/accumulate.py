"""Slice-product evaluation + accumulation for the Ozaki scheme.

Two evaluation strategies from the paper:

  * ``matmul_naive``    — Alg. 4: one INT8 GEMM per slice pair (s, t) with
    s+t <= k+1, each converted to high precision, scaled, and added.
    k(k+1)/2 high-precision matrix additions.
  * ``matmul_group_ef`` — Alg. 6/7 (proposed): all pairs on an anti-diagonal
    group g = s+t share the exponent 2^(-beta*g), so they are summed
    *inside the integer matmul unit*.  On TPU we realize this by
    concatenating the group's slices along the contraction axis and issuing
    ONE int8 GEMM with inner dimension (g-1)*n — the MXU's INT32 accumulator
    performs the group reduction as part of the contraction (error-free for
    group sizes <= r, eq. (12); larger groups are chunked, reproducing
    Alg. 6's ``q == r`` flush).  k (or w, eq. for chunking) high-precision
    additions total.

High-precision accumulator modes:

  * ``f64``  — faithful to the paper (FP64 accumulation).  On TPU this is
    software-emulated; used for CPU validation and the DGEMM-emulation bench.
  * ``f32``  — plain f32 accumulation (sufficient for emulating f32 GEMMs
    when combined with EF grouping).
  * ``df32`` — double-float (two-float compensated) accumulation: TPU-native
    high-precision mode, ~2^-48 effective significand.  INT32 products are
    converted to an exact (hi, lo) f32 pair, scaled by powers of two
    (exact), and accumulated with Knuth TwoSum.  This is our beyond-paper
    replacement for FP64 accumulation on hardware without FP64 units.

Return contract (the distributed hooks):

Both matmuls take two optional hooks for mesh-sharded contractions
(see repro/distributed/collectives.py and docs/distributed.md):

  * ``product_reduce`` — applied ONCE to the stacked ``(G, *batch, m, p)``
    INT32 tensor of every slice/group product *before* any conversion or
    scaling.  With an exact int32 ``psum`` over the mesh axis this makes a
    contraction-sharded evaluation bit-identical to the unsharded one
    (integer addition is associative; the overflow bound is the global-n
    bound).  Identity when None.
  * ``partial=True`` — return the UNROUNDED accumulator instead of an
    array in ``out_dtype``: a :class:`DF32` (hi, lo) pair for
    ``accum="df32"``, the raw f64/f32 accumulator otherwise.  The caller
    owns the single final rounding — e.g. after an error-free cross-device
    reduction of per-shard partials.

Fused-epilogue hook (``scale_accum_fn``):

Both matmuls also accept a ``scale_accum_fn(prod, srow, scol, acc) -> acc``
hook that performs one convert+scale+add step — ``acc`` is the running
accumulator (:class:`DF32` or a plain f64/f32 array), ``prod`` the INT32
product, ``srow``/``scol`` the per-row/col power-of-two scales (any 2^e
group exponent already folded into ``srow``; exact).  The default hook is
the inline jnp epilogue below; ``repro.kernels.ops.scale_accum_update``
substitutes the one-HBM-pass Pallas kernel (the ``use_pallas="fused"``
path), which performs the bit-identical operation sequence.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.splitting import Split, compute_r

__all__ = [
    "int8_gemm",
    "matmul_naive",
    "matmul_group_ef",
    "DF32",
    "num_highprec_adds",
]


def int8_gemm(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """(*batch, m, n) int8 @ (*batch, n, p) int8 -> (*batch, m, p) int32.

    Exact barring overflow.  Leading axes are true ``dot_general`` batch
    dimensions, so batched GEMMs hit the MXU as one batched contraction
    instead of a python loop or a reshape-to-2D.
    """
    nb = a8.ndim - 2
    dims = (((a8.ndim - 1,), (nb,)), (tuple(range(nb)), tuple(range(nb))))
    return jax.lax.dot_general(a8, b8, dims,
                               preferred_element_type=jnp.int32)


# ---------------------------------------------------------------------------
# double-float (two-float) arithmetic — the TPU-native high-precision path
# ---------------------------------------------------------------------------

class DF32(NamedTuple):
    """Unevaluated sum hi + lo of two f32 arrays, |lo| <= ulp(hi)/2."""

    hi: jax.Array
    lo: jax.Array

    def to_float(self, dtype=jnp.float64) -> jax.Array:
        return self.hi.astype(dtype) + self.lo.astype(dtype)


def _two_sum(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Knuth TwoSum: a + b = s + e exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def df32_zero(shape, dtype=jnp.float32) -> DF32:
    z = jnp.zeros(shape, dtype)
    return DF32(z, z)


def df32_add(c: DF32, x: jax.Array) -> DF32:
    """c += x with compensated two-float accumulation."""
    hi, e = _two_sum(c.hi, x)
    lo = c.lo + e
    # cheap renormalization (fast-two-sum; hi dominates lo)
    hi2, e2 = _two_sum(hi, lo)
    return DF32(hi2, e2)


def df32_add_df(c: DF32, x: DF32) -> DF32:
    hi, e = _two_sum(c.hi, x.hi)
    lo = c.lo + e + x.lo
    hi2, e2 = _two_sum(hi, lo)
    return DF32(hi2, e2)


def int32_to_df32(p: jax.Array) -> DF32:
    """Exact int32 -> (hi, lo) f32 pair (f32 holds only 24 bits).

    Integer split: hi = p with the low 8 bits cleared (a multiple of 256 with
    <= 23 significant bits — exact in f32), lo = the low 8 bits.  Pure integer
    ops; no f64 round-trip, so it is TPU-native.
    """
    hi_int = (p >> 8) << 8
    lo_int = p - hi_int  # in [0, 255]
    return DF32(hi_int.astype(jnp.float32), lo_int.astype(jnp.float32))


# ---------------------------------------------------------------------------
# scaling helpers
# ---------------------------------------------------------------------------

def _outer_scale(p: jax.Array, sa: jax.Array, sb: jax.Array) -> jax.Array:
    """diag(sa) @ p @ diag(sb) per batch element; scales are powers of two
    (exact in fp).  p (*batch, m, p); sa (*batch, m); sb (*batch, p)."""
    return p * sa[..., :, None] * sb[..., None, :]


def _term_pairs(k: int) -> Sequence[Tuple[int, int]]:
    """Fast-mode slice pairs (1-indexed): s + t <= k + 1."""
    return [(s, g - s) for g in range(2, k + 2) for s in range(1, g)]


# ---------------------------------------------------------------------------
# per-term convert+scale+add — the default (inline jnp) epilogue hooks
# ---------------------------------------------------------------------------

def _scale_accum_df32(prod: jax.Array, srow: jax.Array, scol: jax.Array,
                      acc: DF32) -> DF32:
    """One df32 epilogue step: ``acc += srow * float(prod) * scol``,
    compensated.  ``srow``/``scol`` are f32 powers of two (any group
    exponent 2^e folded into ``srow`` — exact)."""
    term = int32_to_df32(prod)
    term = DF32(_outer_scale(term.hi, srow, scol),
                _outer_scale(term.lo, srow, scol))
    return df32_add_df(acc, term)


def _scale_accum_plain(prod: jax.Array, srow: jax.Array, scol: jax.Array,
                       acc: jax.Array) -> jax.Array:
    """One plain-accumulator epilogue step in ``acc.dtype`` (f64/f32)."""
    return acc + _outer_scale(prod.astype(acc.dtype), srow, scol)


def num_highprec_adds(k: int, r: int, group_ef: bool) -> int:
    """Number of high-precision matrix additions (paper's accounting)."""
    if not group_ef:
        return k * (k + 1) // 2
    total = 0
    for g in range(2, k + 2):
        total += -(-(g - 1) // r)  # ceil((g-1)/r) chunks for group g
    return total


# ---------------------------------------------------------------------------
# Alg. 4 — naive accumulation
# ---------------------------------------------------------------------------

def _reduce_products(prods, product_reduce: Optional[Callable]):
    """Apply ``product_reduce`` once to the stacked INT32 products.

    Stacking turns the per-product reductions into ONE collective for the
    whole GEMM; without a hook the list passes through untouched (no stack
    materialized on the default path).
    """
    if product_reduce is None:
        return prods
    reduced = product_reduce(jnp.stack(prods))
    if reduced.dtype != jnp.int32:
        raise TypeError(f"product_reduce must preserve int32 exactness, "
                        f"returned {reduced.dtype}")
    return [reduced[i] for i in range(len(prods))]


def matmul_naive(sa: Split, sb: Split, *, accum: str = "f64",
                 out_dtype=None, partial: bool = False,
                 product_reduce: Optional[Callable] = None,
                 scale_accum_fn: Optional[Callable] = None,
                 pair_gemm_fn: Optional[Callable] = None
                 ) -> Union[jax.Array, DF32]:
    """One INT8 GEMM + one high-precision scaled add per slice pair.

    Batched: digits may be ``(k, *batch, m, n)`` / ``(k, *batch, n, p)``;
    every slice-pair product is then ONE batched int8 ``dot_general``.
    ``pair_gemm_fn(s, t) -> int32`` overrides the per-pair GEMM (1-indexed
    slice pair; the Pallas hook of ``use_pallas``).  ``partial`` /
    ``product_reduce`` / ``scale_accum_fn``: see the module docstring.
    """
    assert sa.axis == 0 and sb.axis == 1, "A needs row scales, B column scales"
    k = sa.digits.shape[0]
    assert sb.digits.shape[0] == k
    out_shape = sa.digits.shape[1:-1] + (sb.digits.shape[-1],)
    out_dtype = out_dtype or sa.scale.dtype
    pairs = _term_pairs(k)
    gemm = pair_gemm_fn or (
        lambda s, t: int8_gemm(sa.digits[s - 1], sb.digits[t - 1]))
    prods = _reduce_products([gemm(s, t) for s, t in pairs], product_reduce)

    if accum == "df32":
        fn = scale_accum_fn or _scale_accum_df32
        acc = df32_zero(out_shape)
        for (s, t), prod in zip(pairs, prods):
            acc = fn(prod, sa.scale[s - 1].astype(jnp.float32),
                     sb.scale[t - 1].astype(jnp.float32), acc)
        return acc if partial else acc.to_float(out_dtype)

    acc_dtype = {"f64": jnp.float64, "f32": jnp.float32}[accum]
    fn = scale_accum_fn or _scale_accum_plain
    c = jnp.zeros(out_shape, acc_dtype)
    for (s, t), prod in zip(pairs, prods):
        c = fn(prod, sa.scale[s - 1].astype(acc_dtype),
               sb.scale[t - 1].astype(acc_dtype), c)
    return c if partial else c.astype(out_dtype)


# ---------------------------------------------------------------------------
# Alg. 6/7 — group-wise error-free accumulation
# ---------------------------------------------------------------------------

def _group_chunks(k: int, r: int):
    """Yield (g, [(s, t), ...]) chunks of size <= r per anti-diagonal group."""
    for g in range(2, k + 2):
        pairs = [(s, g - s) for s in range(1, g)]
        for i in range(0, len(pairs), r):
            yield g, pairs[i:i + r]


def group_gemm_concat(sa: Split, sb: Split, pairs) -> jax.Array:
    """sum_{(s,t) in pairs} A_s @ B_t as ONE int8 GEMM via contraction-axis
    concatenation — the TPU-native realization of Alg. 6's INT32 group sum.
    Batched digits concatenate along the trailing contraction axis."""
    a_cat = jnp.concatenate([sa.digits[s - 1] for s, _ in pairs], axis=-1)
    b_cat = jnp.concatenate([sb.digits[t - 1] for _, t in pairs], axis=-2)
    return int8_gemm(a_cat, b_cat)


def matmul_group_ef(sa: Split, sb: Split, *, accum: str = "f64",
                    out_dtype=None, r: Optional[int] = None,
                    group_gemm_fn=None, partial: bool = False,
                    product_reduce: Optional[Callable] = None,
                    scale_accum_fn: Optional[Callable] = None
                    ) -> Union[jax.Array, DF32]:
    """Group-wise error-free accumulation (Alg. 6; Alg. 7 when r >= k).

    Requires geometric slice scales (``base`` present): the combined scale of
    every pair in group g is ``baseA (x) baseB * 2^(-beta*g)``.
    ``partial`` / ``product_reduce``: see the module docstring — when the
    contraction axis is sharded, pass ``r`` computed from the GLOBAL
    contraction length so the per-group INT32 partials stay summable
    without overflow across devices.
    """
    assert sa.axis == 0 and sb.axis == 1
    if sa.base is None or sb.base is None:
        raise ValueError("group-EF accumulation needs geometric slice scales "
                         "(bitmask or rn_const splitting); got adaptive RN")
    k = sa.digits.shape[0]
    beta = sa.beta
    n = sa.digits.shape[-1]
    out_shape = sa.digits.shape[1:-1] + (sb.digits.shape[-1],)
    out_dtype = out_dtype or sa.scale.dtype
    if r is None:
        r = compute_r(n, beta)
    gg = group_gemm_fn or (lambda pairs: group_gemm_concat(sa, sb, pairs))
    chunks = list(_group_chunks(k, r))
    prods = _reduce_products([gg(pairs) for _, pairs in chunks],
                             product_reduce)

    # The 2^(-beta*g) group exponent folds into the row scale (exact:
    # powers of two), matching the fused kernel's srow contract.
    if accum == "df32":
        fn = scale_accum_fn or _scale_accum_df32
        acc = df32_zero(out_shape)
        base_a = sa.base.astype(jnp.float32)
        base_b = sb.base.astype(jnp.float32)
        for (g, _), prod in zip(chunks, prods):
            e = jnp.asarray(2.0 ** (-beta * g), jnp.float32)
            acc = fn(prod, base_a * e, base_b, acc)
        return acc if partial else acc.to_float(out_dtype)

    acc_dtype = {"f64": jnp.float64, "f32": jnp.float32}[accum]
    fn = scale_accum_fn or _scale_accum_plain
    c = jnp.zeros(out_shape, acc_dtype)
    base_a = sa.base.astype(acc_dtype)
    base_b = sb.base.astype(acc_dtype)
    for (g, _), prod in zip(chunks, prods):
        e = jnp.asarray(2.0 ** (-beta * g), acc_dtype)
        c = fn(prod, base_a * e, base_b, c)
    return c if partial else c.astype(out_dtype)
