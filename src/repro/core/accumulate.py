"""Slice-product evaluation + accumulation for the Ozaki scheme.

Three evaluation strategies — two from the source paper, plus the
Ozaki-II constant-scaling path (``matmul_oz2``, see its docstring and
docs/algorithms.md#ozaki-scheme-ii), which requires the shared-grid
splits of ``splitting.split_oz2``/``split_oz2_bitmask`` and folds every
slice-pair scale into one scalar exponent ladder per contraction.

Two evaluation strategies from the paper:

  * ``matmul_naive``    — Alg. 4: one INT8 GEMM per slice pair (s, t) with
    s+t <= k+1, each converted to high precision, scaled, and added.
    k(k+1)/2 high-precision matrix additions.
  * ``matmul_group_ef`` — Alg. 6/7 (proposed): all pairs on an anti-diagonal
    group g = s+t share the exponent 2^(-beta*g), so they are summed
    *inside the integer matmul unit*.  On TPU we realize this by
    concatenating the group's slices along the contraction axis and issuing
    ONE int8 GEMM with inner dimension (g-1)*n — the MXU's INT32 accumulator
    performs the group reduction as part of the contraction (error-free for
    group sizes <= r, eq. (12); larger groups are chunked, reproducing
    Alg. 6's ``q == r`` flush).  k (or w, eq. for chunking) high-precision
    additions total.

High-precision accumulator modes:

  * ``f64``  — faithful to the paper (FP64 accumulation).  On TPU this is
    software-emulated; used for CPU validation and the DGEMM-emulation bench.
  * ``f32``  — plain f32 accumulation (sufficient for emulating f32 GEMMs
    when combined with EF grouping).
  * ``df32`` — double-float (two-float compensated) accumulation: TPU-native
    high-precision mode, ~2^-48 effective significand.  INT32 products are
    converted to an exact (hi, lo) f32 pair, scaled by powers of two
    (exact), and accumulated with Knuth TwoSum.  This is our beyond-paper
    replacement for FP64 accumulation on hardware without FP64 units.

Return contract (the distributed hooks):

Both matmuls take two optional hooks for mesh-sharded contractions
(see repro/distributed/collectives.py and docs/distributed.md):

  * ``product_reduce`` — applied ONCE to the stacked ``(G, *batch, m, p)``
    INT32 tensor of every slice/group product *before* any conversion or
    scaling.  With an exact int32 ``psum`` over the mesh axis this makes a
    contraction-sharded evaluation bit-identical to the unsharded one
    (integer addition is associative; the overflow bound is the global-n
    bound).  Identity when None.
  * ``partial=True`` — return the UNROUNDED accumulator instead of an
    array in ``out_dtype``: a :class:`DF32` (hi, lo) pair for
    ``accum="df32"``, the raw f64/f32 accumulator otherwise.  The caller
    owns the single final rounding — e.g. after an error-free cross-device
    reduction of per-shard partials.

Fused-epilogue hook (``scale_accum_fn``):

Both matmuls also accept a ``scale_accum_fn(prod, srow, scol, acc) -> acc``
hook that performs one convert+scale+add step — ``acc`` is the running
accumulator (:class:`DF32` or a plain f64/f32 array), ``prod`` the INT32
product, ``srow``/``scol`` the per-row/col power-of-two scales (any 2^e
group exponent already folded into ``srow``; exact).  The default hook is
the inline jnp epilogue below; ``repro.kernels.ops.scale_accum_update``
substitutes the one-HBM-pass Pallas kernel (the ``use_pallas="fused"``
path), which performs the bit-identical operation sequence.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.splitting import Split, compute_r, sm_decode_slice
from repro.obs import tracing as _tracing

__all__ = [
    "int8_gemm",
    "gemm_slice",
    "matmul_naive",
    "matmul_group_ef",
    "matmul_oz2",
    "DF32",
    "num_highprec_adds",
    "oz2_num_pairs",
    "oz2_num_highprec_adds",
    "oz2_num_chunks",
    "ladder_width",
]


def int8_gemm(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """(*batch, m, n) int8 @ (*batch, n, p) int8 -> (*batch, m, p) int32.

    Exact barring overflow.  Leading axes are true ``dot_general`` batch
    dimensions, so batched GEMMs hit the MXU as one batched contraction
    instead of a python loop or a reshape-to-2D.
    """
    nb = a8.ndim - 2
    dims = (((a8.ndim - 1,), (nb,)), (tuple(range(nb)), tuple(range(nb))))
    return jax.lax.dot_general(a8, b8, dims,
                               preferred_element_type=jnp.int32)


def gemm_slice(sp: Split, i: int) -> jax.Array:
    """Slice ``i`` (0-indexed) of a split, widened for the integer GEMM.

    Signed-digit splits feed the int8 array straight through; the
    sign-magnitude storage convention (``Split.signmag``) widens to int16
    values first (slice 0 signed, the rest un-wrapped to [0, 2^beta - 1])
    — ``int8_gemm``'s int32 contraction is dtype-generic, and the
    no-overflow bound of ``compute_beta_sm`` covers the wider digits.
    """
    d = sp.digits[i]
    return sm_decode_slice(d, i) if sp.signmag else d


# ---------------------------------------------------------------------------
# double-float (two-float) arithmetic — the TPU-native high-precision path
# ---------------------------------------------------------------------------

class DF32(NamedTuple):
    """Unevaluated sum hi + lo of two f32 arrays, |lo| <= ulp(hi)/2."""

    hi: jax.Array
    lo: jax.Array

    def to_float(self, dtype=jnp.float64) -> jax.Array:
        return self.hi.astype(dtype) + self.lo.astype(dtype)


def _two_sum(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Knuth TwoSum: a + b = s + e exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def df32_zero(shape, dtype=jnp.float32) -> DF32:
    z = jnp.zeros(shape, dtype)
    return DF32(z, z)


def df32_add(c: DF32, x: jax.Array) -> DF32:
    """c += x with compensated two-float accumulation."""
    hi, e = _two_sum(c.hi, x)
    lo = c.lo + e
    # cheap renormalization (fast-two-sum; hi dominates lo)
    hi2, e2 = _two_sum(hi, lo)
    return DF32(hi2, e2)


def df32_add_df(c: DF32, x: DF32) -> DF32:
    hi, e = _two_sum(c.hi, x.hi)
    lo = c.lo + e + x.lo
    hi2, e2 = _two_sum(hi, lo)
    return DF32(hi2, e2)


def int32_to_df32(p: jax.Array) -> DF32:
    """Exact int32 -> (hi, lo) f32 pair (f32 holds only 24 bits).

    Integer split: hi = p with the low 8 bits cleared (a multiple of 256 with
    <= 23 significant bits — exact in f32), lo = the low 8 bits.  Pure integer
    ops; no f64 round-trip, so it is TPU-native.
    """
    hi_int = (p >> 8) << 8
    lo_int = p - hi_int  # in [0, 255]
    return DF32(hi_int.astype(jnp.float32), lo_int.astype(jnp.float32))


# ---------------------------------------------------------------------------
# scaling helpers
# ---------------------------------------------------------------------------

def _outer_scale(p: jax.Array, sa: jax.Array, sb: jax.Array) -> jax.Array:
    """diag(sa) @ p @ diag(sb) per batch element; scales are powers of two
    (exact in fp).  p (*batch, m, p); sa (*batch, m); sb (*batch, p)."""
    return p * sa[..., :, None] * sb[..., None, :]


def _term_pairs(k: int) -> Sequence[Tuple[int, int]]:
    """Fast-mode slice pairs (1-indexed): s + t <= k + 1."""
    return [(s, g - s) for g in range(2, k + 2) for s in range(1, g)]


# ---------------------------------------------------------------------------
# per-term convert+scale+add — the default (inline jnp) epilogue hooks
# ---------------------------------------------------------------------------

def _scale_accum_df32(prod: jax.Array, srow: jax.Array, scol: jax.Array,
                      acc: DF32) -> DF32:
    """One df32 epilogue step: ``acc += srow * float(prod) * scol``,
    compensated.  ``srow``/``scol`` are f32 powers of two (any group
    exponent 2^e folded into ``srow`` — exact)."""
    term = int32_to_df32(prod)
    term = DF32(_outer_scale(term.hi, srow, scol),
                _outer_scale(term.lo, srow, scol))
    return df32_add_df(acc, term)


def _scale_accum_plain(prod: jax.Array, srow: jax.Array, scol: jax.Array,
                       acc: jax.Array) -> jax.Array:
    """One plain-accumulator epilogue step in ``acc.dtype`` (f64/f32)."""
    return acc + _outer_scale(prod.astype(acc.dtype), srow, scol)


def num_highprec_adds(k: int, r: int, group_ef: bool) -> int:
    """Number of high-precision matrix additions (paper's accounting)."""
    if not group_ef:
        return k * (k + 1) // 2
    total = 0
    for g in range(2, k + 2):
        total += -(-(g - 1) // r)  # ceil((g-1)/r) chunks for group g
    return total


# ---------------------------------------------------------------------------
# Alg. 4 — naive accumulation
# ---------------------------------------------------------------------------

def _reduce_products(prods, product_reduce: Optional[Callable]):
    """Apply ``product_reduce`` once to the stacked INT32 products.

    Stacking turns the per-product reductions into ONE collective for the
    whole GEMM; without a hook the list passes through untouched (no stack
    materialized on the default path).
    """
    if product_reduce is None:
        return prods
    reduced = product_reduce(jnp.stack(prods))
    if reduced.dtype != jnp.int32:
        raise TypeError(f"product_reduce must preserve int32 exactness, "
                        f"returned {reduced.dtype}")
    return [reduced[i] for i in range(len(prods))]


def matmul_naive(sa: Split, sb: Split, *, accum: str = "f64",
                 out_dtype=None, partial: bool = False,
                 product_reduce: Optional[Callable] = None,
                 scale_accum_fn: Optional[Callable] = None,
                 pair_gemm_fn: Optional[Callable] = None
                 ) -> Union[jax.Array, DF32]:
    """One INT8 GEMM + one high-precision scaled add per slice pair.

    Batched: digits may be ``(k, *batch, m, n)`` / ``(k, *batch, n, p)``;
    every slice-pair product is then ONE batched int8 ``dot_general``.
    ``pair_gemm_fn(s, t) -> int32`` overrides the per-pair GEMM (1-indexed
    slice pair; the Pallas hook of ``use_pallas``).  ``partial`` /
    ``product_reduce`` / ``scale_accum_fn``: see the module docstring.
    """
    assert sa.axis == 0 and sb.axis == 1, "A needs row scales, B column scales"
    k = sa.digits.shape[0]
    assert sb.digits.shape[0] == k
    out_shape = sa.digits.shape[1:-1] + (sb.digits.shape[-1],)
    out_dtype = out_dtype or sa.scale.dtype
    pairs = _term_pairs(k)
    gemm = pair_gemm_fn or (
        lambda s, t: int8_gemm(gemm_slice(sa, s - 1), gemm_slice(sb, t - 1)))
    with _tracing.phase_scope("group_gemm"):
        prods = _reduce_products([gemm(s, t) for s, t in pairs],
                                 product_reduce)

    if accum == "df32":
        fn = scale_accum_fn or _scale_accum_df32
        acc = df32_zero(out_shape)
        with _tracing.phase_scope("scale_accum"):
            for (s, t), prod in zip(pairs, prods):
                acc = fn(prod, sa.scale[s - 1].astype(jnp.float32),
                         sb.scale[t - 1].astype(jnp.float32), acc)
        return acc if partial else acc.to_float(out_dtype)

    acc_dtype = {"f64": jnp.float64, "f32": jnp.float32}[accum]
    fn = scale_accum_fn or _scale_accum_plain
    c = jnp.zeros(out_shape, acc_dtype)
    with _tracing.phase_scope("scale_accum"):
        for (s, t), prod in zip(pairs, prods):
            c = fn(prod, sa.scale[s - 1].astype(acc_dtype),
                   sb.scale[t - 1].astype(acc_dtype), c)
    return c if partial else c.astype(out_dtype)


# ---------------------------------------------------------------------------
# Alg. 6/7 — group-wise error-free accumulation
# ---------------------------------------------------------------------------

def _group_chunks(k: int, r: int):
    """Yield (g, [(s, t), ...]) chunks of size <= r per anti-diagonal group."""
    for g in range(2, k + 2):
        pairs = [(s, g - s) for s in range(1, g)]
        for i in range(0, len(pairs), r):
            yield g, pairs[i:i + r]


def group_gemm_concat(sa: Split, sb: Split, pairs) -> jax.Array:
    """sum_{(s,t) in pairs} A_s @ B_t as ONE int8 GEMM via contraction-axis
    concatenation — the TPU-native realization of Alg. 6's INT32 group sum.
    Batched digits concatenate along the trailing contraction axis.
    Sign-magnitude splits widen per slice first (``gemm_slice``)."""
    a_cat = jnp.concatenate([gemm_slice(sa, s - 1) for s, _ in pairs],
                            axis=-1)
    b_cat = jnp.concatenate([gemm_slice(sb, t - 1) for _, t in pairs],
                            axis=-2)
    return int8_gemm(a_cat, b_cat)


def matmul_group_ef(sa: Split, sb: Split, *, accum: str = "f64",
                    out_dtype=None, r: Optional[int] = None,
                    group_gemm_fn=None, partial: bool = False,
                    product_reduce: Optional[Callable] = None,
                    scale_accum_fn: Optional[Callable] = None
                    ) -> Union[jax.Array, DF32]:
    """Group-wise error-free accumulation (Alg. 6; Alg. 7 when r >= k).

    Requires geometric slice scales (``base`` present): the combined scale of
    every pair in group g is ``baseA (x) baseB * 2^(-beta*g)``.
    ``partial`` / ``product_reduce``: see the module docstring — when the
    contraction axis is sharded, pass ``r`` computed from the GLOBAL
    contraction length so the per-group INT32 partials stay summable
    without overflow across devices.
    """
    assert sa.axis == 0 and sb.axis == 1
    if sa.base is None or sb.base is None:
        raise ValueError("group-EF accumulation needs geometric slice scales "
                         "(bitmask or rn_const splitting); got adaptive RN")
    k = sa.digits.shape[0]
    beta = sa.beta
    n = sa.digits.shape[-1]
    out_shape = sa.digits.shape[1:-1] + (sb.digits.shape[-1],)
    out_dtype = out_dtype or sa.scale.dtype
    if r is None:
        r = compute_r(n, beta)
    gg = group_gemm_fn or (lambda pairs: group_gemm_concat(sa, sb, pairs))
    chunks = list(_group_chunks(k, r))
    with _tracing.phase_scope("group_gemm"):
        prods = _reduce_products([gg(pairs) for _, pairs in chunks],
                                 product_reduce)

    # The 2^(-beta*g) group exponent folds into the row scale (exact:
    # powers of two), matching the fused kernel's srow contract.
    if accum == "df32":
        fn = scale_accum_fn or _scale_accum_df32
        acc = df32_zero(out_shape)
        base_a = sa.base.astype(jnp.float32)
        base_b = sb.base.astype(jnp.float32)
        with _tracing.phase_scope("scale_accum"):
            for (g, _), prod in zip(chunks, prods):
                e = jnp.asarray(2.0 ** (-beta * g), jnp.float32)
                acc = fn(prod, base_a * e, base_b, acc)
        return acc if partial else acc.to_float(out_dtype)

    acc_dtype = {"f64": jnp.float64, "f32": jnp.float32}[accum]
    fn = scale_accum_fn or _scale_accum_plain
    c = jnp.zeros(out_shape, acc_dtype)
    base_a = sa.base.astype(acc_dtype)
    base_b = sb.base.astype(acc_dtype)
    with _tracing.phase_scope("scale_accum"):
        for (g, _), prod in zip(chunks, prods):
            e = jnp.asarray(2.0 ** (-beta * g), acc_dtype)
            c = fn(prod, base_a * e, base_b, c)
    return c if partial else c.astype(out_dtype)


# ---------------------------------------------------------------------------
# Ozaki-II — constant scaling + exponent-ladder accumulation
# ---------------------------------------------------------------------------

def _clog2(x: int) -> int:
    return max(0, (int(x) - 1).bit_length())


def oz2_groups(k: int, fast):
    """Anti-diagonal group indices g = s + t evaluated by the oz2 modes.

    Full mode keeps every group of the k x k pair square (g = 2..2k) — the
    complete product of the two k-slice fixed-point approximations.  Fast
    mode (``fast`` truthy: ``True`` or ``"fast2"``) keeps the diagonal
    band g <= k + 1 only: on the shared grid the dropped pairs all lie at
    least ``beta * k`` bits below the (global, or per-row for fast2)
    product magnitude, i.e. at the splitting-truncation level itself.
    """
    return range(2, (k + 1 if fast else 2 * k) + 1)


def _oz2_group_pairs(k: int, g: int):
    return [(s, g - s) for s in range(max(1, g - k), min(k, g - 1) + 1)]


def oz2_num_pairs(k: int, fast: bool) -> int:
    """INT8 slice-pair GEMM count: k(k+1)/2 (fast band) or k^2 (full)."""
    return k * (k + 1) // 2 if fast else k * k


def _oz2_chunks(k: int, r: int, fast: bool):
    """Yield (g, [(s, t), ...]) chunks of size <= r, ascending g."""
    for g in oz2_groups(k, fast):
        pairs = _oz2_group_pairs(k, g)
        for i in range(0, len(pairs), r):
            yield g, pairs[i:i + r]


def ladder_width(n: int, k: int, beta: int, digit_bits: int,
                 word_bits: int) -> int:
    """How many consecutive anti-diagonal groups fold into ONE integer word.

    On the shared oz2 grid, group g's INT32 sum S_g carries the scalar
    exponent 2^(-beta*g), so c consecutive groups combine exactly as

        word = sum_j S_(g+j) << (beta * (c - 1 - j))

    |S_g| <= k * n * (2^digit_bits)^2 per group, hence the word needs
    ``clog2(k) + clog2(n) + 2*digit_bits + beta*(c-1) + 1`` bits.  The
    budget ``word_bits`` is 52 for an int64 word that must convert to f64
    exactly, 31 for an int32 word (the df32/f32 accumulators).
    """
    head = 1 + _clog2(k) + _clog2(n) + 2 * digit_bits
    return 1 + max(0, (word_bits - head) // beta)


def _ladder_windows(chunks, c: int):
    """Pack the ascending-g chunk list into windows spanning <= c groups."""
    windows = []
    for idx, (g, _) in enumerate(chunks):
        if windows and g - windows[-1][0][1] < c:
            windows[-1].append((idx, g))
        else:
            windows.append([(idx, g)])
    return windows


def oz2_num_highprec_adds(k: int, r: int, beta: int, n: int, fast: bool,
                          digit_bits: int, word_bits: int = 52) -> int:
    """High-precision adds of the oz2 path = number of ladder windows."""
    chunks = list(_oz2_chunks(k, r, fast))
    return len(_ladder_windows(chunks, ladder_width(n, k, beta, digit_bits,
                                                    word_bits)))


def oz2_num_chunks(k: int, r: int, fast: bool) -> int:
    """INT32 group-GEMM outputs the ladder folds (perf-model accounting:
    each is one product-tensor read in the accumulation pass)."""
    return sum(1 for _ in _oz2_chunks(k, r, fast))


def _oz2_scale(gbase_a: jax.Array, gbase_b: jax.Array, beta: int, g: int,
               dtype) -> jax.Array:
    """(*batch,) combined scalar scale ``gbaseA * gbaseB * 2^(-beta*g)``.

    The group exponent is split evenly over the two bases before the
    product so neither factor underflows on its own (2^(-beta*g) alone
    leaves the f32 range for full-mode g at large k); every factor is a
    power of two, so the arithmetic stays exact.
    """
    ea = jnp.asarray(2.0 ** (-beta * (g // 2)), dtype)
    eb = jnp.asarray(2.0 ** (-beta * (g - g // 2)), dtype)
    return (gbase_a.astype(dtype) * ea) * (gbase_b.astype(dtype) * eb)


def _oz2_accum_df32(word: jax.Array, scale: jax.Array, acc: DF32) -> DF32:
    """One ladder-window df32 step: ``acc += scale * float(word)`` with the
    exact low-8-bit int32 split (word is int32 in df32 mode)."""
    term = int32_to_df32(word)
    s = scale[..., None, None]
    return df32_add_df(acc, DF32(term.hi * s, term.lo * s))


def _oz2_accum_plain(word: jax.Array, scale: jax.Array,
                     acc: jax.Array) -> jax.Array:
    """One ladder-window plain step in ``acc.dtype`` (f64: the int64 word
    converts exactly by the ``word_bits <= 52`` budget)."""
    return acc + word.astype(acc.dtype) * scale[..., None, None]


def _oz2_unscale(acc, ra: jax.Array, rb: jax.Array):
    """The fast2 epilogue: ``C = diag(ra) C_hat diag(rb)``.

    ``ra``/``rb`` are the exact power-of-two equilibration factors
    ``base / gbase`` of the fast2 splits, so both multiplies are exact;
    for a df32 accumulator hi and lo scale by the same power of two,
    preserving the ``|lo| <= ulp(hi)/2`` invariant.  This is the default
    (inline jnp) implementation of ``matmul_oz2``'s ``unscale_fn`` hook
    (the fused path substitutes ``repro.kernels.ops.oz2_unscale_update``,
    bit-identical).
    """
    if isinstance(acc, DF32):
        ra32 = ra.astype(jnp.float32)
        rb32 = rb.astype(jnp.float32)
        return DF32(_outer_scale(acc.hi, ra32, rb32),
                    _outer_scale(acc.lo, ra32, rb32))
    return _outer_scale(acc, ra.astype(acc.dtype), rb.astype(acc.dtype))


def matmul_oz2(sa: Split, sb: Split, *, accum: str = "f64",
               out_dtype=None, fast: Union[bool, str] = False,
               r: Optional[int] = None,
               n_total: Optional[int] = None,
               digit_bits: Optional[int] = None, group_gemm_fn=None,
               partial: bool = False,
               product_reduce: Optional[Callable] = None,
               scale_accum_fn: Optional[Callable] = None,
               unscale_fn: Optional[Callable] = None
               ) -> Union[jax.Array, DF32]:
    """Ozaki-II evaluation on constant-scaling splits.

    Needs ``Split.gbase`` (the scalar shared-grid base of
    ``splitting.split_oz2`` / ``split_oz2_bitmask``).  Every slice pair in
    anti-diagonal group g carries the SCALAR scale
    ``gbaseA * gbaseB * 2^(-beta*g)``, so (i) groups are summed inside the
    INT32 matmul unit exactly as in Alg. 6/7 (concat GEMMs, chunked by r),
    and (ii) consecutive groups additionally fold into one integer word by
    exact shifts — the exponent ladder — before a SINGLE high-precision
    convert+scale+add per window (``ladder_width`` groups at a time).
    Fast mode (``fast=True``) evaluates the g <= k+1 band (k(k+1)/2
    pairs, the classic count); full mode all k^2 pairs.

    ``fast="fast2"`` selects the improved fast-mode scaling (Kawakami &
    Takahashi): the same g <= k+1 band, but on the fast2 splits
    (``splitting.split_oz2_fast2`` / ``split_oz2_bitmask_fast2``) whose
    shared grid is the equilibrated constant ``gbase = 2`` — the ladder
    computes ``C_hat = A_hat B_hat`` of the row/column-equilibrated
    operands, and the exact power-of-two factors ``ra = base_A / gbase``
    / ``rb = base_B / gbase`` are applied as one final two-sided
    diagonal unscale ``C = diag(ra) C_hat diag(rb)`` (exact, so it
    commutes with ``partial`` reduction and rounding).  ``unscale_fn(acc,
    ra, rb)`` overrides that epilogue (the fused Pallas hook
    ``repro.kernels.ops.oz2_unscale_update``; bit-identical).

    ``partial`` / ``product_reduce`` follow the module contract: the
    product psum applies to the stacked int32 chunk products BEFORE the
    ladder fold, so the int32 mesh strategy stays bit-identical.
    ``scale_accum_fn(word, scale, acc)`` is the oz2 fused-epilogue hook
    (``repro.kernels.ops.oz2_scale_accum_update``): ``word`` the folded
    int32/int64 window, ``scale`` the ``(*batch,)`` scalar power of two.
    ``digit_bits`` is the slice digit magnitude (beta for truncation
    splits, beta - 1 for RN — sizes r and the ladder windows); ``n_total``
    the GLOBAL contraction length when the operands are shards.
    """
    assert sa.axis == 0 and sb.axis == 1
    if sa.gbase is None or sb.gbase is None:
        raise ValueError("oz2 accumulation needs constant-scaling splits "
                         "(split_oz2 / split_oz2_bitmask); got per-row "
                         "scales")
    fast2 = fast == "fast2"
    if fast2 and (sa.base is None or sb.base is None):
        raise ValueError("fast2 needs the per-row bases of the fast2 "
                         "splits (split_oz2_fast2 / "
                         "split_oz2_bitmask_fast2)")
    k = sa.digits.shape[0]
    assert sb.digits.shape[0] == k
    beta = sa.beta
    n = n_total if n_total is not None else sa.digits.shape[-1]
    out_shape = sa.digits.shape[1:-1] + (sb.digits.shape[-1],)
    out_dtype = out_dtype or sa.scale.dtype
    if digit_bits is None:
        digit_bits = beta  # conservative: truncation digits span ±(2^beta-1)
    if r is None:
        r = compute_r(n, beta, digit_bits)
    use_i64 = accum == "f64" and jax.config.jax_enable_x64
    word_dtype = jnp.int64 if use_i64 else jnp.int32
    word_bits = 52 if use_i64 else 31
    c = ladder_width(n, k, beta, digit_bits, word_bits)

    gg = group_gemm_fn or (lambda pairs: group_gemm_concat(sa, sb, pairs))
    chunks = list(_oz2_chunks(k, r, fast))
    with _tracing.phase_scope("group_gemm"):
        prods = _reduce_products([gg(pairs) for _, pairs in chunks],
                                 product_reduce)
    windows = _ladder_windows(chunks, c)

    def fold(window):
        g_hi = window[-1][1]
        word = None
        for idx, g in window:
            t = prods[idx].astype(word_dtype)
            if g_hi != g:
                t = jnp.left_shift(t, beta * (g_hi - g))
            word = t if word is None else word + t
        return word, g_hi

    def unscale(acc):
        """The fast2 epilogue (identity otherwise): exact two-sided
        power-of-two unscale by the equilibration factors base/gbase."""
        if not fast2:
            return acc
        ra = sa.base * (1.0 / sa.gbase[..., None])
        rb = sb.base * (1.0 / sb.gbase[..., None])
        return (unscale_fn or _oz2_unscale)(acc, ra, rb)

    if accum == "df32":
        fn = scale_accum_fn or _oz2_accum_df32
        acc = df32_zero(out_shape)
        for window in windows:
            with _tracing.phase_scope("ladder"):
                word, g_hi = fold(window)
            with _tracing.phase_scope("scale_accum"):
                acc = fn(word, _oz2_scale(sa.gbase, sb.gbase, beta, g_hi,
                                          jnp.float32), acc)
        with _tracing.phase_scope("scale_accum"):
            acc = unscale(acc)
        return acc if partial else acc.to_float(out_dtype)

    acc_dtype = {"f64": jnp.float64, "f32": jnp.float32}[accum]
    fn = scale_accum_fn or _oz2_accum_plain
    acc = jnp.zeros(out_shape, acc_dtype)
    for window in windows:
        with _tracing.phase_scope("ladder"):
            word, g_hi = fold(window)
        with _tracing.phase_scope("scale_accum"):
            acc = fn(word, _oz2_scale(sa.gbase, sb.gbase, beta, g_hi,
                                      acc_dtype), acc)
    with _tracing.phase_scope("scale_accum"):
        acc = unscale(acc)
    return acc if partial else acc.astype(out_dtype)
