"""MatmulEngine — the pluggable GEMM backend every model layer contracts
through.

Specs (CLI flag ``--matmul_engine``):

  * ``bf16`` / ``f32`` / ``f64``      — native XLA dot in that compute dtype
  * ``ozimmu[-k]``, ``ozimmu_rn[-k]``, ``ozimmu_ef[-k]``, ``ozimmu_h[-k]``
    optionally ``:f64|:f32|:df32``    — Ozaki-scheme emulation (paper).
    ``k`` may be ``auto``: the execution planner (``repro.core.plan``)
    picks the smallest slice count meeting ``OzimmuConfig.target_eps``
    from the operands' probed exponent ranges (eager calls) or the
    static mantissa-coverage plan (inside jit).  ``...:prob`` (auto-k
    specs only, every variant) plans under the probabilistic eps model
    instead of the worst-case one — same target, failure probability
    ``target_delta`` (default 2^-20), strictly-no-larger (typically
    smaller) resolved k — see
    docs/algorithms.md#the-probabilistic-planner-prob.
  * ``ozimmu_sm_b[-k]``, ``ozimmu_sm_h[-k]`` — sign-magnitude slicing:
    unsigned magnitude digits with the sign folded into the leading
    slice, so trailing slices spend no sign bit and the grid widens to
    a full 8 bits (``splitting.compute_beta_sm``).  At equal target_eps
    the planner resolves a strictly smaller k (fewer int8 GEMMs) than
    ``ozimmu_h``; composes with ``:fused``/``@mesh``/presplit weights
    bit-identically — docs/algorithms.md#the-sign-magnitude-family-ozimmu_sm_.
  * ``oz2_b[-k]``, ``oz2_h[-k]`` optionally ``:fast`` or ``:fast2`` —
    Ozaki-II constant-scaling emulation: one shared digit grid per
    matrix, all slice-pair scales folded into a scalar exponent ladder
    (``core/accumulate.matmul_oz2``); ``:fast`` evaluates only the
    s + t <= k + 1 band; ``:fast2`` runs the same band with improved
    per-row power-of-two equilibration onto the shared grid (near
    full-mode accuracy on wide-dynamic-range operands, same int8 GEMM
    count — docs/algorithms.md#improved-fast-mode-scaling-fast2).  The
    two tokens are mutually exclusive and reject non-oz2 variants.
    Auto-k plans against the OS-II error model.
  * ``...:fused``                     — the one-HBM-pass Pallas pipeline:
    fused k-slice extraction, VMEM-resident group GEMMs, and the fused
    convert+scale+add epilogue; bit-identical to the XLA path and
    composable with every other token (e.g. ``ozimmu_h-auto:df32:fused``).
  * ``...@mesh_axis[/int32|/df32]``   — mesh-native sharded emulation: the
    contraction axis is sharded over the named mesh axis and the
    cross-device accumulation stays inside the scheme's exactness
    invariants (error-free int32 product psum by default, compensated
    df32 partial-accumulator reduction with ``/df32``) — see
    docs/distributed.md.  Ignored gracefully when no mesh is installed.

The engine is a small immutable object passed through model configs.  Two
entry points:

  * ``engine(x, w)`` — contract the last axis of ``x`` with the first axis
    of ``w`` (the shape every model projection reduces to).  Leading axes of
    ``x`` are free dims of a single ``dot_general``; nothing is reshaped to
    2-D on the way in.
  * ``engine.dot_general(lhs, rhs, dimension_numbers)`` — arbitrary batched
    contraction (attention scores, MoE expert GEMMs, ...).  For ozimmu
    specs this is :func:`repro.core.ozimmu.ozimmu_dot_general`: batch dims
    ride natively through the INT8 slice GEMMs and gradients flow through
    the emulated custom VJP.

Accumulator-dtype footgun (documented in docs/engine.md): an ozimmu spec
with ``accum_dtype="f64"`` only computes in f64 when ``jax_enable_x64`` is
on; otherwise the engine *silently* downgrades the compute dtype to f32
(f64 constants would be truncated by JAX anyway — doing it explicitly keeps
the emulation's exactness invariants intact).  Use ``:df32`` for
high-precision accumulation that does not depend on x64 mode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ozimmu, splitting

__all__ = ["MatmulEngine", "make_engine", "PresplitWeight"]

_NATIVE = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f64": jnp.float64}


class PresplitWeight:
    """A weight array bundled with its frozen Ozaki Split (serving).

    Registered as a pytree whose children are ``(array, digits, scale,
    base, gbase)``, so it rides through ``jit`` / ``lax.scan`` xs /
    ``vmap`` like any parameter leaf: a stacked wrapper (digits stored
    with the stack axes LEADING, ``(*stack, k, n, p)``) slices down to
    the per-layer wrapper automatically when the layer scan slices its
    leaves.  Model code passes it to the engine unchanged; the engine
    consumes the frozen split when the contraction matches the pattern
    the split was frozen for (``x[..., n] @ w[n, p]`` — the projection
    shape every model layer reduces to) and falls back to ``array``
    otherwise, so wrapping is always safe.

    Built by ``repro.serving.presplit.wrap_params`` from a
    ``repro.core.split_cache.SplitCache``.
    """

    __slots__ = ("array", "digits", "scale", "base", "gbase", "beta",
                 "split", "k")

    def __init__(self, array, digits, scale, base, gbase, beta: int,
                 split: str, k: int):
        self.array, self.digits, self.scale = array, digits, scale
        self.base, self.gbase = base, gbase
        self.beta, self.split, self.k = beta, split, k

    # array-facade so existing shape asserts keep working
    @property
    def shape(self):
        return self.array.shape

    @property
    def ndim(self):
        return self.array.ndim

    @property
    def dtype(self):
        return self.array.dtype

    def tree_flatten(self):
        return ((self.array, self.digits, self.scale, self.base,
                 self.gbase), (self.beta, self.split, self.k))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def usable_split(self, lhs, dimension_numbers, compute_dtype,
                     cfg) -> Optional[splitting.Split]:
        """The frozen Split iff it applies to this contraction, else None
        (the engine then uses ``array`` — e.g. a stacked wrapper consumed
        before the layer scan sliced it, or an unexpected dnums)."""
        (ac, bc), (ab, bb) = dimension_numbers
        simple = (tuple(ac) == (lhs.ndim - 1,) and tuple(bc) == (0,)
                  and not ab and not bb)
        if not (simple and self.array.ndim == 2 and self.digits.ndim == 3):
            return None
        if self.split != cfg.split or self.scale.dtype != compute_dtype:
            return None
        if not cfg.auto_k and self.k != cfg.k:
            return None
        n = self.array.shape[0]
        if self.beta != splitting.beta_for(self.split, n):
            return None
        return splitting.Split(self.digits, self.scale, self.base,
                               self.beta, 1, gbase=self.gbase,
                               signmag=splitting.is_signmag(self.split))


jax.tree_util.register_pytree_node(
    PresplitWeight,
    lambda w: w.tree_flatten(),
    PresplitWeight.tree_unflatten)


# Trace-time consumption counters: every engine contraction that received
# a PresplitWeight records whether the frozen split applied or fell back
# to re-splitting.  Incremented while TRACING (or on eager calls), so a
# compiled step that used the split at trace time uses it on every
# execution — the serving runtime turns the delta into the measured
# weight-split hit rate the bench gate checks (a hardcoded 1.0 would go
# vacuous the moment `usable_split` started silently falling back).
_PRESPLIT_COUNTS = {"used": 0, "fallback": 0}


def presplit_trace_counts() -> dict:
    return dict(_PRESPLIT_COUNTS)


@dataclasses.dataclass(frozen=True)
class MatmulEngine:
    spec: str = "bf16"

    @property
    def is_ozimmu(self) -> bool:
        return self.spec.split("@")[0].split("-")[0].split(":")[0] \
            not in _NATIVE

    @property
    def ozimmu_config(self) -> Optional[ozimmu.OzimmuConfig]:
        return ozimmu.parse_spec(self.spec) if self.is_ozimmu else None

    def local(self) -> "MatmulEngine":
        """This engine without the ``@mesh_axis`` suffix — single-device
        semantics, for use inside shard_map bodies (e.g. the all-to-all MoE
        dispatch) that already own the mesh axes."""
        return MatmulEngine(self.spec.split("@")[0]) if "@" in self.spec \
            else self

    def dot_general(self, lhs: jax.Array, rhs: jax.Array, dimension_numbers,
                    out_dtype=None) -> jax.Array:
        """Contract ``lhs`` with ``rhs`` under standard lax dimension
        numbers.  Returns ``lhs.dtype`` unless ``out_dtype`` is given (e.g.
        f32 attention scores feeding an online softmax).

        ``rhs`` may be a :class:`PresplitWeight` (serving): when the
        contraction matches the frozen split's pattern, the B-side
        splitter is skipped (bit-identical — see
        ``repro.core.split_cache``); otherwise the wrapped array is used
        like any weight."""
        if isinstance(lhs, PresplitWeight):
            lhs = lhs.array
        presplit = None
        if isinstance(rhs, PresplitWeight):
            rhs, presplit = rhs.array, rhs
        out_dtype = out_dtype or lhs.dtype
        if not self.is_ozimmu:
            dt = _NATIVE[self.spec]
            # accumulate in f32, except for the f64 reference spec — its
            # whole point is full f64 accumulation
            acc = jnp.float64 if dt == jnp.float64 else jnp.float32
            out = jax.lax.dot_general(
                lhs.astype(dt), rhs.astype(dt), dimension_numbers,
                preferred_element_type=acc)
            return out.astype(out_dtype)

        cfg = self.ozimmu_config
        # f64 accumulation needs x64 mode; otherwise downgrade (see module
        # docstring — the "silent f64 -> f32" footgun).
        compute_dtype = jnp.float64 if cfg.accum_dtype == "f64" and \
            jax.config.jax_enable_x64 else jnp.float32
        sp = None
        if presplit is not None:
            sp = presplit.usable_split(lhs, dimension_numbers,
                                       jnp.dtype(compute_dtype), cfg)
            _PRESPLIT_COUNTS["used" if sp is not None
                             else "fallback"] += 1
        out = ozimmu.ozimmu_dot_general(
            lhs.astype(compute_dtype), rhs.astype(compute_dtype),
            dimension_numbers, cfg, rhs_presplit=sp)
        return out.astype(out_dtype)

    def __call__(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """Contract x[..., n] with w[n, ...] -> out[..., ...]."""
        assert w.shape[0] == x.shape[-1], (x.shape, w.shape)
        return self.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())))


def make_engine(spec: str) -> MatmulEngine:
    eng = MatmulEngine(spec)
    if eng.is_ozimmu:
        ozimmu.parse_spec(spec)  # validate eagerly
    elif spec not in _NATIVE:
        # a native dtype with ozimmu-only decorations, e.g. "bf16@model"
        raise ValueError(f"native engine specs take no suffixes: {spec!r}")
    return eng
