"""MatmulEngine — the pluggable GEMM backend every model layer contracts
through.

Specs (CLI flag ``--matmul_engine``):

  * ``bf16`` / ``f32`` / ``f64``      — native XLA dot in that compute dtype
  * ``ozimmu[-k]``, ``ozimmu_rn[-k]``, ``ozimmu_ef[-k]``, ``ozimmu_h[-k]``
    optionally ``:f64|:f32|:df32``    — Ozaki-scheme emulation (paper).

The engine is a small immutable object passed through model configs; calling
it contracts the last axis of ``x`` with the first axis of ``w`` (the shape
every model projection in this repo reduces to).  For ozimmu specs the
operands are flattened to 2-D, emulated via INT8 slice GEMMs, and reshaped
back; gradients flow through the custom VJP.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ozimmu

__all__ = ["MatmulEngine", "make_engine"]

_NATIVE = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f64": jnp.float64}


@dataclasses.dataclass(frozen=True)
class MatmulEngine:
    spec: str = "bf16"

    @property
    def is_ozimmu(self) -> bool:
        return self.spec.split("-")[0].split(":")[0] not in _NATIVE

    @property
    def ozimmu_config(self) -> Optional[ozimmu.OzimmuConfig]:
        return ozimmu.parse_spec(self.spec) if self.is_ozimmu else None

    def __call__(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """Contract x[..., n] with w[n, ...] -> out[..., ...]."""
        if not self.is_ozimmu:
            dt = _NATIVE[self.spec]
            out = jax.lax.dot_general(
                x.astype(dt), w.astype(dt), (((x.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return out.astype(x.dtype)

        cfg = self.ozimmu_config
        n = x.shape[-1]
        assert w.shape[0] == n, (x.shape, w.shape)
        lead, tail = x.shape[:-1], w.shape[1:]
        x2 = x.reshape(-1, n)
        w2 = w.reshape(n, -1)
        compute_dtype = jnp.float64 if cfg.accum_dtype == "f64" and \
            jax.config.jax_enable_x64 else jnp.float32
        out = ozimmu.ozimmu_matmul(x2.astype(compute_dtype),
                                   w2.astype(compute_dtype), cfg)
        return out.reshape(*lead, *tail).astype(x.dtype)


def make_engine(spec: str) -> MatmulEngine:
    eng = MatmulEngine(spec)
    if eng.is_ozimmu:
        ozimmu.parse_spec(spec)  # validate eagerly
    return eng
