"""Per-contraction execution planner for the Ozaki-scheme emulation.

Two planning decisions are made here, both static per contraction:

**Accuracy-driven auto-k** (spec token ``auto``, e.g. ``ozimmu_h-auto``):
instead of a hand-picked slice count, the planner picks the smallest ``k``
whose modeled error stays under ``OzimmuConfig.target_eps`` (default
:data:`DEFAULT_TARGET_EPS`, ~f64-faithful).  The model follows the
exponent-distribution argument of *Improved Scaling for Fast Mode of Ozaki
Scheme II*: the splitting truncation after ``k`` slices is bounded by
``rowmax * 2^(1 - beta k)`` per element, so the bits the contraction needs
are the target bits plus every amplification the measured *elementwise
relative* error picks up on the way:

    needed = bits(target_eps)            # -log2 of the target bound
           + gap(A) + gap(B)             # probed operand exponent ranges:
                                         #   max row-max exponent minus the
                                         #   smallest per-row RMS exponent
                                         #   (output entries live at the
                                         #   row-RMS scale, the truncation
                                         #   at the row-max scale)
           + ceil(log2(m p))             # min |c_ij| over the output under
                                         #   random cancellation shrinks
                                         #   like 1/(m p)
           + ceil(log2(n)) / 2           # sqrt(n) CLT growth of |c| vs the
                                         #   n-term absolute error bound
           + guard                       # 2 bits; +5 for truncation
                                         #   splitting (bitmask digits round
                                         #   away-from-half a full ulp and
                                         #   waste the sign bit)
    k = ceil(needed / beta)

The probe runs on **concrete** operands (eager calls, benchmarks); under a
``jit`` trace there are no values to probe and the planner falls back to a
static, shape-only plan that covers the input mantissa
(``needed = t + ceil(log2 n) + guard``) — deterministic, and exactly the
paper's "emulate the input precision faithfully" posture.  Exponents come
from ``frexp`` as everywhere else in the repo (no float ``log2``).

**Probabilistic mode** (``OzimmuConfig.target_eps_mode="probabilistic"``,
spec token ``:prob``): the bit model above is worst-case in two places
that the probabilistic analysis of arXiv 2506.11277
(``analysis.prob_error_bound_*``) tightens with probability
``1 - delta`` (``delta`` = ``OzimmuConfig.target_delta``, default
:data:`repro.core.analysis.DEFAULT_DELTA` = 2^-20):

* probed path: the ``ceil(log2(m p))`` min-|c| cancellation charge is an
  order statistic of ~``m p`` near-independent CLT-scale entries; its
  tail is covered by half the bits plus the concentration constant
  ``lambda_bits(delta) = ceil(log2 sqrt(2 ln(2/delta)))`` (3 bits at the
  default delta), so the term becomes
  ``(clog2(m p) + 1)//2 + lambda_bits(delta) + bias``;
* static path: instead of charging worst-case n-growth
  (``ceil(log2 n)``) on top of mantissa coverage, the truncation sum
  concentrates like ``lambda sqrt(n)`` — matching the reference
  product's own accumulated-rounding growth — and the static charge
  collapses to ``max(lambda_bits(delta), guard) + extra + bias``.

``bias`` is a calibrated per-family charge-back for the
directed-truncation splits whose residuals are NOT mean-zero (the
2506.11277 hypothesis): 1 bit for the bitmask splits, 3 for
sign-magnitude (one-sided floor extraction plus the sign-folding
cascade correlating residuals within a row).  Both probabilistic
``needed`` values are clamped to never exceed the deterministic ones,
so ``k_prob <= k_det`` structurally; the dd oracle
(``tests/test_oracle.py -k prob``) calibrates the constants against
seeded ensembles at the claimed failure rate.  The static probabilistic
plan intentionally under-delivers an absolute 2^-40 target (it promises
faithful-mantissa coverage plus the concentration margin, not target
bits plus worst-case growth) — bounded by the shaved ``beta (k_det -
k_prob)`` bits and documented in
docs/algorithms.md#the-probabilistic-planner-prob.

**Kernel block autotuning**: a small static table mapping problem dims to
``(bm, bn, bp)`` Pallas tile sizes, ``lru_cache``-d like the jitted sharded
entry of ``core/ozimmu.py``, consumed by all three kernels through
``repro/kernels/ops.py``.  The table trades VMEM residency (input tile +
``k`` int8 slices + int32/df32 accumulator tiles must fit in ~16 MB)
against grid overhead; each kernel aligns the preferred tile to its own
sublane/lane multiple via :func:`tile`.

The planner's cost accounting reuses the paper's own accounting:
:func:`repro.core.accumulate.num_highprec_adds` for step (iv) and the
fast-mode pair count ``k(k+1)/2`` for step (iii) — see
``docs/algorithms.md#the-execution-planner-auto-k``.  The oz2 variants
get their own rows: ``k^2`` (full) / ``k(k+1)/2`` (fast) pairs, ladder-
window adds (``accumulate.oz2_num_highprec_adds``), and an eps model in
which the two probed operand gaps combine as ``max`` instead of sum (the
OS-II constant-scaling analysis — each truncation term carries only its
own operand's spread; the other operand enters via its RMS).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.accumulate import (num_highprec_adds, oz2_num_highprec_adds,
                                   oz2_num_pairs)
from repro.core.analysis import DEFAULT_DELTA
from repro.core.splitting import beta_for, compute_r, digit_bits

__all__ = ["DEFAULT_TARGET_EPS", "DEFAULT_DELTA", "Plan",
           "plan_contraction", "auto_k", "operand_gap_bits", "lambda_bits",
           "kernel_blocks", "tile", "describe_config",
           "PlanDecision", "PlanLedger", "get_ledger", "choose_k_bits"]

# ~f64-faithful: at or below the elementwise relative error a plain FP64
# GEMM measures on the paper's phi-matrix grid (1e-11..7e-12 there), with
# headroom for harder operands.  2^-40 ~= 9.1e-13.
DEFAULT_TARGET_EPS = 2.0 ** -40

_MANTISSA = {np.dtype(np.float64): 53, np.dtype(np.float32): 24}

# Slice counts outside this window are either meaningless (k < 2 cannot
# carry a residual) or pure waste (k*beta beyond mantissa + probe-able
# spread extracts all-zero digits).
K_MIN, K_MAX = 2, 16

_GUARD_BITS = 2
_TRUNC_EXTRA_BITS = 5  # bitmask splitting: ~1 ulp truncation + no sign bit
_SM_EXTRA_BITS = 2     # sign-magnitude: k slices cover beta*k - 1 bits (the
                       # sign occupies one leading-slice bit) + full-ulp
                       # floor truncation vs RN's half ulp


def _clog2(x: int) -> int:
    """Exact integer ceil(log2 x) for x >= 1."""
    return max(0, (int(x) - 1).bit_length())


def _exponents(v: np.ndarray) -> np.ndarray:
    """ceil(log2 v_i) per positive entry via frexp (no log2)."""
    _, e = np.frexp(v)
    return e


def operand_gap_bits(x, axis: int) -> int:
    """Probed exponent range of one operand: bits between the largest
    row-max and the smallest per-row RMS (rows for ``axis=0``, columns for
    ``axis=1``; leading axes are batch).  This is the amplification the
    elementwise relative error of the product inherits from the operand's
    dynamic range; clipped to the operand's mantissa width (spread beyond
    the mantissa is unrepresentable in the input to begin with).

    The O(m*n) reductions run where the operand lives (on device for jax
    arrays); only the per-row vectors come back to the host.
    """
    m_axis = -1 if axis == 0 else -2
    xp = np
    try:
        import jax
        import jax.numpy as jnp
        if isinstance(x, jax.Array):
            xp = jnp
    except ImportError:
        pass
    a = xp.abs(x)
    rowmax = np.asarray(a.max(axis=m_axis))
    rowrms = np.asarray(xp.sqrt(xp.mean(xp.square(a), axis=m_axis)))
    live = rowmax > 0
    if not live.any():
        return 0
    gap = int(_exponents(rowmax[live]).max()) \
        - int(_exponents(rowrms[live]).min())
    t = _MANTISSA.get(np.dtype(x.dtype), 24)
    return int(min(max(gap, 0), t))


def _bits_of(eps: float) -> int:
    if not (0.0 < eps < 1.0):
        raise ValueError(f"target_eps must be in (0, 1), got {eps}")
    return int(math.ceil(-math.log2(eps)))


def _clamp_k(k: int) -> int:
    return max(K_MIN, min(K_MAX, k))


_TRUNC_SPLITS = ("bitmask", "oz2_bitmask", "oz2_bitmask_fast2")
_SM_SPLITS = ("sm",)
_OZ2_SPLITS = ("oz2_rn", "oz2_bitmask", "oz2_rn_fast2",
               "oz2_bitmask_fast2")

_EPS_MODES = ("deterministic", "probabilistic")

# Charge-back for splits whose truncation residuals are NOT mean-zero
# (the concentration hypothesis): directed bitmask truncation biases one
# ulp direction per element sign; sign-magnitude floor extraction is
# one-sided AND its sign-folding cascade correlates residuals within a
# row.  Calibrated against the adversarial planner grid of
# tests/test_oracle.py (wide_spread / high-phi cells are where the
# uncorrected sqrt-model first breaks).
_PROB_BIAS_BITS = {"bitmask": 1, "oz2_bitmask": 1, "oz2_bitmask_fast2": 1,
                   "sm": 3}


def lambda_bits(delta: float) -> int:
    """``ceil(log2 sqrt(2 ln(2/delta)))`` — the Hoeffding concentration
    constant of the probabilistic eps model, in bits (3 at the default
    delta = 2^-20)."""
    if not (0.0 < delta < 1.0):
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    return max(1, int(math.ceil(
        math.log2(math.sqrt(2.0 * math.log(2.0 / delta))))))


def choose_k(n: int, beta: int, target_eps: float, *, split: str,
             mantissa: int, m: int = 1, p: int = 1,
             gap_a: Optional[int] = None, gap_b: Optional[int] = None,
             fast: Union[bool, str] = False, mode: str = "deterministic",
             delta: Optional[float] = None) -> int:
    """Smallest k meeting ``target_eps``; see :func:`choose_k_bits` for
    the full bit model (this is its first return value)."""
    return choose_k_bits(n, beta, target_eps, split=split,
                         mantissa=mantissa, m=m, p=p, gap_a=gap_a,
                         gap_b=gap_b, fast=fast, mode=mode, delta=delta)[0]


def choose_k_bits(n: int, beta: int, target_eps: float, *, split: str,
                  mantissa: int, m: int = 1, p: int = 1,
                  gap_a: Optional[int] = None, gap_b: Optional[int] = None,
                  fast: Union[bool, str] = False,
                  mode: str = "deterministic",
                  delta: Optional[float] = None) -> Tuple[int, int]:
    """``(k, needed)``: the smallest k meeting ``target_eps`` under the
    bit model above, plus the modeled bit requirement it covers (the
    audit ledger's ``needed_bits`` — ``k * beta - needed`` is the
    planner's slack at the resolved k, before :data:`K_MIN`/:data:`K_MAX`
    clamping).

    ``gap_a``/``gap_b`` are the probed operand exponent ranges; ``None``
    means "no concrete operands" (traced call) and selects the static
    mantissa-coverage plan.

    The oz2 splits (constant scaling) follow the OS-II error analysis
    instead: each truncation term inherits only its OWN operand's spread —
    the other operand enters through its column/row RMS, bounded by
    Cauchy-Schwarz — so the two probed gaps combine as ``max``, not sum
    (docs/algorithms.md#ozaki-scheme-ii).  Fast mode charges one extra bit
    for the dropped g > k+1 groups (they sit at the truncation level).
    The fast2 splits charge the same bit (``fast`` arrives as the
    config's raw fast-mode flag — a bool or ``"fast2"``): fast2's per-row-anchored error is
    elementwise <= the plain fast-mode error at equal k, so the resolved
    k is equal — never larger — and the ``target_eps`` guarantee carries
    over wherever plain fast mode met it.

    The sign-magnitude split charges :data:`_SM_EXTRA_BITS` (its k slices
    cover ``beta*k - 1`` mantissa bits, and its floor extraction truncates
    a full ulp where RN rounds half) — but its ``beta`` is 8, not 7, so
    at equal ``needed`` the resolved k is smaller: ``ceil((needed+2)/8)``
    vs ``ceil(needed/7)``, a strict win whenever needed >= ~50 (every f64
    target), the (k-1)-bit saving the family exists for.

    ``mode="probabilistic"`` resolves k under the concentration model
    (module docstring): the probed ``clog2(m p)`` charge becomes
    ``(clog2(m p)+1)//2 + lambda_bits(delta) + bias`` and the static
    plan covers ``mantissa + max(lambda_bits(delta), guard) + extra +
    bias``; both are clamped to the deterministic ``needed`` so the
    resolved k never exceeds the deterministic one.  ``delta=None``
    uses :data:`repro.core.analysis.DEFAULT_DELTA`; ``delta <= 0``
    recovers deterministic planning exactly.
    """
    if mode not in _EPS_MODES:
        raise ValueError(
            f"target_eps_mode must be one of {_EPS_MODES}, got {mode!r}")
    extra = (_TRUNC_EXTRA_BITS if split in _TRUNC_SPLITS
             else _SM_EXTRA_BITS if split in _SM_SPLITS else 0)
    guard = _GUARD_BITS + extra
    # probabilistic mode with delta <= 0 is the deterministic limit
    prob = mode == "probabilistic"
    if prob:
        delta = DEFAULT_DELTA if delta is None else delta
        if delta <= 0.0:
            prob = False
    # Plain oz2 fast mode (global anchor) gets NO probabilistic shave:
    # its dropped g > k+1 band is a systematic truncation of whole
    # slice-group products against the matrix-level anchor — not
    # mean-zero rounding noise, so the concentration argument does not
    # apply (and the deterministic fast-mode plan is already marginal on
    # wide-phi operands).  fast2's per-row equilibration re-anchors the
    # band at the row scale, restoring the concentration headroom.
    # ``fast`` may arrive as the raw config flag (bool or "fast2") or a
    # bool from a non-canonicalized config, so check both spellings.
    is_fast2 = fast == "fast2" or split.endswith("_fast2")
    if prob and bool(fast) and split in _OZ2_SPLITS and not is_fast2:
        prob = False
    lam = lambda_bits(delta) if prob else 0
    bias = _PROB_BIAS_BITS.get(split, 0) if prob else 0
    if gap_a is None or gap_b is None:
        needed = mantissa + _clog2(n) + guard
        if prob:
            # static: mantissa coverage + concentration margin (which
            # subsumes the base carry guard) + family extras + bias,
            # instead of worst-case n-growth
            needed = min(needed,
                         mantissa + max(lam, _GUARD_BITS) + extra + bias)
    else:
        if split in _OZ2_SPLITS:
            gaps = max(gap_a, gap_b) + int(bool(fast))
        else:
            gaps = gap_a + gap_b
        mp_term = _clog2(m * p)
        needed = (_bits_of(target_eps) + gaps + mp_term
                  + (_clog2(n) + 1) // 2 + guard)
        if prob:
            # probed: the min-|c| order-statistic charge concentrates
            mp_prob = (mp_term + 1) // 2 + lam + bias
            needed = min(needed,
                         _bits_of(target_eps) + gaps + mp_prob
                         + (_clog2(n) + 1) // 2 + guard)
    return _clamp_k(-(-needed // beta)), needed


@dataclasses.dataclass(frozen=True)
class Plan:
    """One contraction's resolved execution parameters + cost accounting."""

    k: int
    beta: int
    r: int
    bits_needed: int           # needed bits the chosen k covers (k * beta)
    probed: bool               # True: concrete-operand probe; False: static
    int8_gemms: int            # slice pairs (step iii): k(k+1)/2 for the
                               # ozimmu family and oz2 fast mode, k^2 for
                               # oz2 full mode
    highprec_adds: int         # step (iv): paper accounting for the ozimmu
                               # family; exponent-ladder windows for oz2
    blocks: Tuple[int, int, int]   # preferred (bm, bn, bp) kernel tiles

    def describe(self) -> str:
        return (f"k={self.k} (beta={self.beta}, "
                f"{'probed' if self.probed else 'static'}, "
                f"covers {self.bits_needed} bits), "
                f"{self.int8_gemms} int8 GEMMs, "
                f"{self.highprec_adds} high-precision adds, "
                f"blocks={self.blocks}")


# ---------------------------------------------------------------------------
# planner audit ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """One auto-k resolution, as the planner saw it (docs/observability.md).

    ``predicted_eps`` is the bit model's achieved bound at the resolved
    k: the target shifted by the slack bits ``k*beta - needed`` (negative
    slack — a :data:`K_MAX` clamp — predicts an eps *above* target, which
    is exactly the situation the ledger exists to surface)."""

    source: str                # "contraction" (plan_contraction) |
                               # "split_cache" (weight-freeze resolution)
    spec: str                  # split/accumulate[/fast][@mesh] summary
    mode: str                  # deterministic | probabilistic
    delta: Optional[float]     # :prob failure budget (None when det)
    target_eps: float
    probed: bool               # concrete-operand probe vs static plan
    m: int
    n: int
    p: int
    gap_a: Optional[int]       # probed exponent ranges (None when static)
    gap_b: Optional[int]
    k: int                     # the chosen slice count
    beta: int
    needed_bits: int           # modeled requirement the k covers
    predicted_eps: float
    int8_gemms: int            # cost row at the resolved k
    highprec_adds: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanLedger:
    """Bounded, thread-safe ring of :class:`PlanDecision` rows.

    Queryable (``entries()``, ``summary()``) and cheap to keep always-on:
    recording is one deque append under a lock, and happens only when the
    obs layer is enabled and only at plan-resolution time (eager calls
    and jit traces — never per jitted execution)."""

    def __init__(self, maxlen: int = 4096):
        import collections
        import threading
        self._lock = threading.Lock()
        self._ring = collections.deque(maxlen=maxlen)

    def record(self, d: PlanDecision):
        with self._lock:
            self._ring.append(d)

    def entries(self) -> list:
        with self._lock:
            return list(self._ring)

    def clear(self):
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def summary(self) -> dict:
        """Aggregate view: decision counts by spec/mode/k, probe split,
        worst predicted eps — the launch-time startup block."""
        rows = self.entries()
        by_spec: dict = {}
        k_hist: dict = {}
        for d in rows:
            by_spec[d.spec] = by_spec.get(d.spec, 0) + 1
            k_hist[d.k] = k_hist.get(d.k, 0) + 1
        return {
            "decisions": len(rows),
            "probed": sum(1 for d in rows if d.probed),
            "static": sum(1 for d in rows if not d.probed),
            "probabilistic": sum(1 for d in rows
                                 if d.mode == "probabilistic"),
            "by_spec": dict(sorted(by_spec.items())),
            "k_hist": {k: k_hist[k] for k in sorted(k_hist)},
            "worst_predicted_eps": max(
                (d.predicted_eps for d in rows), default=None),
        }

    def describe(self) -> str:
        """One-line human summary for launch logging."""
        s = self.summary()
        if not s["decisions"]:
            return "no auto-k decisions recorded"
        ks = "/".join(f"k={k}x{c}" for k, c in s["k_hist"].items())
        worst = s["worst_predicted_eps"]
        return (f"{s['decisions']} auto-k decisions "
                f"({s['probed']} probed, {s['static']} static"
                + (f", {s['probabilistic']} :prob" if s['probabilistic']
                   else "")
                + f"): {ks}, worst predicted eps {worst:.2e}")


_LEDGER = PlanLedger()


def get_ledger() -> PlanLedger:
    return _LEDGER


def _spec_str(cfg, prob: bool) -> str:
    fast = getattr(cfg, "fast", False)
    mode = "/fast2" if fast == "fast2" else "/fast" if fast else ""
    mesh = getattr(cfg, "mesh_axis", None)
    return (f"{cfg.split}/{cfg.accumulate}{mode}:{cfg.accum_dtype}"
            + (":prob" if prob else "")
            + (f"@{mesh}" if mesh else ""))


def record_decision(cfg, *, m: int, n: int, p: int, k: int, beta: int,
                    needed: int, probed: bool,
                    gap_a: Optional[int] = None,
                    gap_b: Optional[int] = None,
                    source: str = "contraction") -> None:
    """Append one auto-k resolution to the ledger (and mirror a counter
    into the metrics registry).  No-op when the obs layer is disabled."""
    from repro.obs import registry as _obs
    if not _obs.enabled():
        return
    eps = cfg.target_eps if cfg.target_eps is not None else DEFAULT_TARGET_EPS
    mode = getattr(cfg, "target_eps_mode", "deterministic")
    cost = _plan_static(n, m, p, k, beta, *_cfg_cost_key(cfg, beta))
    _LEDGER.record(PlanDecision(
        source=source, spec=_spec_str(cfg, mode == "probabilistic"),
        mode=mode, delta=getattr(cfg, "target_delta", None)
        if mode == "probabilistic" else None,
        target_eps=eps, probed=probed, m=m, n=n, p=p,
        gap_a=gap_a, gap_b=gap_b, k=k, beta=beta, needed_bits=needed,
        predicted_eps=math.ldexp(eps, needed - k * beta),
        int8_gemms=cost.int8_gemms, highprec_adds=cost.highprec_adds))
    _obs.get_registry().inc("plan.decisions", 1, source=source, mode=mode,
                            probed=int(probed), k=k)


@functools.lru_cache(maxsize=1024)
def _plan_static(n: int, m: int, p: int, k: int, beta: int, accumulate: str,
                 fast: bool, dbits: int, word_bits: int) -> Plan:
    if accumulate == "oz2":
        r = compute_r(n, beta, dbits)
        gemms = oz2_num_pairs(k, fast)
        adds = oz2_num_highprec_adds(k, r, beta, n, fast, dbits, word_bits)
    else:
        r = compute_r(n, beta)
        gemms = k * (k + 1) // 2
        adds = num_highprec_adds(k, r, accumulate == "group_ef")
    return Plan(k=k, beta=beta, r=r, bits_needed=k * beta, probed=False,
                int8_gemms=gemms, highprec_adds=adds,
                blocks=kernel_blocks(m, n, p))


def _word_bits(cfg) -> int:
    """Integer word budget of the oz2 exponent ladder under ``cfg``:
    52 bits (int64 word, exact f64 convert) for the f64 accumulator in x64
    mode, 31 (int32 word) otherwise — mirrors ``accumulate.matmul_oz2``."""
    if cfg.accum_dtype != "f64":
        return 31
    try:
        import jax
        return 52 if jax.config.jax_enable_x64 else 31
    except ImportError:
        return 52


def _cfg_cost_key(cfg, beta: int) -> Tuple[str, bool, int, int]:
    return (cfg.accumulate, bool(getattr(cfg, "fast", False)),
            digit_bits(cfg.split, beta), _word_bits(cfg))


def plan_contraction(cfg, m: int, n: int, p: int, *,
                     a=None, b=None, _record: bool = True) -> Plan:
    """Resolve the execution plan for ``(m, n) @ (n, p)`` under ``cfg``
    (an :class:`repro.core.ozimmu.OzimmuConfig`).

    With concrete operands ``a``/``b`` and ``cfg.auto_k``, the accuracy
    probe picks k; traced or absent operands fall back to the static
    mantissa-coverage plan.  Fixed-k configs just get the cost accounting
    and kernel blocks.  The oz2 variants are planned against the OS-II
    error model (max-of-gaps, see :func:`choose_k`) and costed with their
    own pair/ladder accounting.
    """
    beta = beta_for(cfg.split, n)
    if not getattr(cfg, "auto_k", False):
        return _plan_static(n, m, p, cfg.k, beta, *_cfg_cost_key(cfg, beta))
    eps = cfg.target_eps if cfg.target_eps is not None else DEFAULT_TARGET_EPS
    mantissa = 53 if _bits_of(eps) > 22 else 24
    if a is not None and hasattr(a, "dtype") \
            and np.dtype(a.dtype) in _MANTISSA:
        mantissa = _MANTISSA[np.dtype(a.dtype)]
    gap_a = gap_b = None
    probed = False
    if a is not None and b is not None and _is_concrete(a) \
            and _is_concrete(b):
        gap_a = operand_gap_bits(a, axis=0)
        gap_b = operand_gap_bits(b, axis=1)
        probed = True
    k, needed = choose_k_bits(
        n, beta, eps, split=cfg.split, mantissa=mantissa,
        m=m, p=p, gap_a=gap_a, gap_b=gap_b,
        fast=getattr(cfg, "fast", False),
        mode=getattr(cfg, "target_eps_mode", "deterministic"),
        delta=getattr(cfg, "target_delta", None))
    if _record:
        record_decision(cfg, m=m, n=n, p=p, k=k, beta=beta, needed=needed,
                        probed=probed, gap_a=gap_a, gap_b=gap_b)
    base = _plan_static(n, m, p, k, beta, *_cfg_cost_key(cfg, beta))
    return dataclasses.replace(base, probed=probed)


def auto_k(a, b, cfg) -> int:
    """The planner's k for canonical batched operands
    ``(*batch, m, n) @ (*batch, n, p)`` (the ``_bmm_impl`` entry shape)."""
    m, n, p = a.shape[-2], a.shape[-1], b.shape[-1]
    return plan_contraction(cfg, m, n, p, a=a, b=b).k


def _is_concrete(x) -> bool:
    """True when ``x`` holds actual values (not a jit/vmap tracer)."""
    try:
        import jax
        return not isinstance(x, jax.core.Tracer)
    except Exception:  # jax absent or jax.core layout drifted: duck-test
        # (no np.asarray here — that would materialize the operand)
        return not hasattr(x, "_trace")


# ---------------------------------------------------------------------------
# kernel block autotune table
# ---------------------------------------------------------------------------

# dim >= threshold -> preferred tile.  Sized for ~16 MB VMEM: an f32 input
# tile (bm*bn*4), k<=16 int8 output slices (k*bm*bn), and a pair of f32
# accumulator tiles (2*bm*bp*4) all fit at the largest entry.
_TILE_TABLE = (
    (4096, 512),
    (1024, 256),
    (0, 128),
)


def _preferred(dim: int) -> int:
    for threshold, tile_ in _TILE_TABLE:
        if dim >= threshold:
            return tile_
    return _TILE_TABLE[-1][1]


@functools.lru_cache(maxsize=4096)
def kernel_blocks(m: int, n: int, p: int = 1) -> Tuple[int, int, int]:
    """Preferred ``(bm, bn, bp)`` Pallas tiles for a ``(m, n) @ (n, p)``
    problem — the static-shape autotune table, cached per shape like the
    jitted sharded entry.  Each kernel aligns its dims to its own hardware
    multiple with :func:`tile` (8 sublanes for f32 rows, 128 lanes / MXU
    edges elsewhere)."""
    return (_preferred(m), _preferred(n), _preferred(p))


def tile(dim: int, pref: int, mult: int) -> int:
    """Align a preferred tile to a kernel's multiple, never exceeding the
    (rounded-up) dim — small problems get one mult-sized tile rather than
    a mostly-padding large one."""
    if dim <= mult:
        return mult
    if dim < pref:
        return min(pref, (dim + mult - 1) // mult * mult)
    return max(mult, pref // mult * mult)


def describe_config(cfg, m: int = 4096, n: int = 4096, p: int = 4096) -> str:
    """One-line human plan summary for an engine config (launch logging)."""
    # _record=False: the 4096^3 illustration shape is not a real decision
    pl = plan_contraction(cfg, m, n, p, _record=False)
    eps = cfg.target_eps if cfg.target_eps is not None else DEFAULT_TARGET_EPS
    prob = getattr(cfg, "target_eps_mode", "deterministic") \
        == "probabilistic"
    kpart = (f"k=auto({'prob ' if prob else ''}target_eps={eps:.1e}, "
             f"static {pl.k} @ n={n})"
             if getattr(cfg, "auto_k", False) else f"k={cfg.k}")
    fused = cfg.use_pallas == "fused"
    fast = getattr(cfg, "fast", False)
    mode = "/fast2" if fast == "fast2" else "/fast" if fast else ""
    return (f"{cfg.split}/{cfg.accumulate}{mode}:{cfg.accum_dtype} {kpart}, "
            f"{'fused split+epilogue Pallas pipeline' if fused else 'pallas group-GEMM' if cfg.use_pallas else 'XLA path'}, "
            f"{pl.int8_gemms} int8 GEMMs / {pl.highprec_adds} hp adds")
