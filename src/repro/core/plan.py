"""Per-contraction execution planner for the Ozaki-scheme emulation.

Two planning decisions are made here, both static per contraction:

**Accuracy-driven auto-k** (spec token ``auto``, e.g. ``ozimmu_h-auto``):
instead of a hand-picked slice count, the planner picks the smallest ``k``
whose modeled error stays under ``OzimmuConfig.target_eps`` (default
:data:`DEFAULT_TARGET_EPS`, ~f64-faithful).  The model follows the
exponent-distribution argument of *Improved Scaling for Fast Mode of Ozaki
Scheme II*: the splitting truncation after ``k`` slices is bounded by
``rowmax * 2^(1 - beta k)`` per element, so the bits the contraction needs
are the target bits plus every amplification the measured *elementwise
relative* error picks up on the way:

    needed = bits(target_eps)            # -log2 of the target bound
           + gap(A) + gap(B)             # probed operand exponent ranges:
                                         #   max row-max exponent minus the
                                         #   smallest per-row RMS exponent
                                         #   (output entries live at the
                                         #   row-RMS scale, the truncation
                                         #   at the row-max scale)
           + ceil(log2(m p))             # min |c_ij| over the output under
                                         #   random cancellation shrinks
                                         #   like 1/(m p)
           + ceil(log2(n)) / 2           # sqrt(n) CLT growth of |c| vs the
                                         #   n-term absolute error bound
           + guard                       # 2 bits; +5 for truncation
                                         #   splitting (bitmask digits round
                                         #   away-from-half a full ulp and
                                         #   waste the sign bit)
    k = ceil(needed / beta)

The probe runs on **concrete** operands (eager calls, benchmarks); under a
``jit`` trace there are no values to probe and the planner falls back to a
static, shape-only plan that covers the input mantissa
(``needed = t + ceil(log2 n) + guard``) — deterministic, and exactly the
paper's "emulate the input precision faithfully" posture.  Exponents come
from ``frexp`` as everywhere else in the repo (no float ``log2``).

**Kernel block autotuning**: a small static table mapping problem dims to
``(bm, bn, bp)`` Pallas tile sizes, ``lru_cache``-d like the jitted sharded
entry of ``core/ozimmu.py``, consumed by all three kernels through
``repro/kernels/ops.py``.  The table trades VMEM residency (input tile +
``k`` int8 slices + int32/df32 accumulator tiles must fit in ~16 MB)
against grid overhead; each kernel aligns the preferred tile to its own
sublane/lane multiple via :func:`tile`.

The planner's cost accounting reuses the paper's own accounting:
:func:`repro.core.accumulate.num_highprec_adds` for step (iv) and the
fast-mode pair count ``k(k+1)/2`` for step (iii) — see
``docs/algorithms.md#the-execution-planner-auto-k``.  The oz2 variants
get their own rows: ``k^2`` (full) / ``k(k+1)/2`` (fast) pairs, ladder-
window adds (``accumulate.oz2_num_highprec_adds``), and an eps model in
which the two probed operand gaps combine as ``max`` instead of sum (the
OS-II constant-scaling analysis — each truncation term carries only its
own operand's spread; the other operand enters via its RMS).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import numpy as np

from repro.core.accumulate import (num_highprec_adds, oz2_num_highprec_adds,
                                   oz2_num_pairs)
from repro.core.splitting import beta_for, compute_r, digit_bits

__all__ = ["DEFAULT_TARGET_EPS", "Plan", "plan_contraction", "auto_k",
           "operand_gap_bits", "kernel_blocks", "tile", "describe_config"]

# ~f64-faithful: at or below the elementwise relative error a plain FP64
# GEMM measures on the paper's phi-matrix grid (1e-11..7e-12 there), with
# headroom for harder operands.  2^-40 ~= 9.1e-13.
DEFAULT_TARGET_EPS = 2.0 ** -40

_MANTISSA = {np.dtype(np.float64): 53, np.dtype(np.float32): 24}

# Slice counts outside this window are either meaningless (k < 2 cannot
# carry a residual) or pure waste (k*beta beyond mantissa + probe-able
# spread extracts all-zero digits).
K_MIN, K_MAX = 2, 16

_GUARD_BITS = 2
_TRUNC_EXTRA_BITS = 5  # bitmask splitting: ~1 ulp truncation + no sign bit
_SM_EXTRA_BITS = 2     # sign-magnitude: k slices cover beta*k - 1 bits (the
                       # sign occupies one leading-slice bit) + full-ulp
                       # floor truncation vs RN's half ulp


def _clog2(x: int) -> int:
    """Exact integer ceil(log2 x) for x >= 1."""
    return max(0, (int(x) - 1).bit_length())


def _exponents(v: np.ndarray) -> np.ndarray:
    """ceil(log2 v_i) per positive entry via frexp (no log2)."""
    _, e = np.frexp(v)
    return e


def operand_gap_bits(x, axis: int) -> int:
    """Probed exponent range of one operand: bits between the largest
    row-max and the smallest per-row RMS (rows for ``axis=0``, columns for
    ``axis=1``; leading axes are batch).  This is the amplification the
    elementwise relative error of the product inherits from the operand's
    dynamic range; clipped to the operand's mantissa width (spread beyond
    the mantissa is unrepresentable in the input to begin with).

    The O(m*n) reductions run where the operand lives (on device for jax
    arrays); only the per-row vectors come back to the host.
    """
    m_axis = -1 if axis == 0 else -2
    xp = np
    try:
        import jax
        import jax.numpy as jnp
        if isinstance(x, jax.Array):
            xp = jnp
    except ImportError:
        pass
    a = xp.abs(x)
    rowmax = np.asarray(a.max(axis=m_axis))
    rowrms = np.asarray(xp.sqrt(xp.mean(xp.square(a), axis=m_axis)))
    live = rowmax > 0
    if not live.any():
        return 0
    gap = int(_exponents(rowmax[live]).max()) \
        - int(_exponents(rowrms[live]).min())
    t = _MANTISSA.get(np.dtype(x.dtype), 24)
    return int(min(max(gap, 0), t))


def _bits_of(eps: float) -> int:
    if not (0.0 < eps < 1.0):
        raise ValueError(f"target_eps must be in (0, 1), got {eps}")
    return int(math.ceil(-math.log2(eps)))


def _clamp_k(k: int) -> int:
    return max(K_MIN, min(K_MAX, k))


_TRUNC_SPLITS = ("bitmask", "oz2_bitmask", "oz2_bitmask_fast2")
_SM_SPLITS = ("sm",)
_OZ2_SPLITS = ("oz2_rn", "oz2_bitmask", "oz2_rn_fast2",
               "oz2_bitmask_fast2")


def choose_k(n: int, beta: int, target_eps: float, *, split: str,
             mantissa: int, m: int = 1, p: int = 1,
             gap_a: Optional[int] = None, gap_b: Optional[int] = None,
             fast: bool = False) -> int:
    """Smallest k meeting ``target_eps`` under the bit model above.

    ``gap_a``/``gap_b`` are the probed operand exponent ranges; ``None``
    means "no concrete operands" (traced call) and selects the static
    mantissa-coverage plan.

    The oz2 splits (constant scaling) follow the OS-II error analysis
    instead: each truncation term inherits only its OWN operand's spread —
    the other operand enters through its column/row RMS, bounded by
    Cauchy-Schwarz — so the two probed gaps combine as ``max``, not sum
    (docs/algorithms.md#ozaki-scheme-ii).  Fast mode charges one extra bit
    for the dropped g > k+1 groups (they sit at the truncation level).
    The fast2 splits charge the same bit (``fast`` arrives as the bool of
    the config's fast-mode flag): fast2's per-row-anchored error is
    elementwise <= the plain fast-mode error at equal k, so the resolved
    k is equal — never larger — and the ``target_eps`` guarantee carries
    over wherever plain fast mode met it.

    The sign-magnitude split charges :data:`_SM_EXTRA_BITS` (its k slices
    cover ``beta*k - 1`` mantissa bits, and its floor extraction truncates
    a full ulp where RN rounds half) — but its ``beta`` is 8, not 7, so
    at equal ``needed`` the resolved k is smaller: ``ceil((needed+2)/8)``
    vs ``ceil(needed/7)``, a strict win whenever needed >= ~50 (every f64
    target), the (k-1)-bit saving the family exists for.
    """
    guard = _GUARD_BITS + (_TRUNC_EXTRA_BITS if split in _TRUNC_SPLITS
                           else _SM_EXTRA_BITS if split in _SM_SPLITS
                           else 0)
    if gap_a is None or gap_b is None:
        needed = mantissa + _clog2(n) + guard
    elif split in _OZ2_SPLITS:
        needed = (_bits_of(target_eps) + max(gap_a, gap_b) + int(fast)
                  + _clog2(m * p) + (_clog2(n) + 1) // 2 + guard)
    else:
        needed = (_bits_of(target_eps) + gap_a + gap_b
                  + _clog2(m * p) + (_clog2(n) + 1) // 2 + guard)
    return _clamp_k(-(-needed // beta))


@dataclasses.dataclass(frozen=True)
class Plan:
    """One contraction's resolved execution parameters + cost accounting."""

    k: int
    beta: int
    r: int
    bits_needed: int           # needed bits the chosen k covers (k * beta)
    probed: bool               # True: concrete-operand probe; False: static
    int8_gemms: int            # slice pairs (step iii): k(k+1)/2 for the
                               # ozimmu family and oz2 fast mode, k^2 for
                               # oz2 full mode
    highprec_adds: int         # step (iv): paper accounting for the ozimmu
                               # family; exponent-ladder windows for oz2
    blocks: Tuple[int, int, int]   # preferred (bm, bn, bp) kernel tiles

    def describe(self) -> str:
        return (f"k={self.k} (beta={self.beta}, "
                f"{'probed' if self.probed else 'static'}, "
                f"covers {self.bits_needed} bits), "
                f"{self.int8_gemms} int8 GEMMs, "
                f"{self.highprec_adds} high-precision adds, "
                f"blocks={self.blocks}")


@functools.lru_cache(maxsize=1024)
def _plan_static(n: int, m: int, p: int, k: int, beta: int, accumulate: str,
                 fast: bool, dbits: int, word_bits: int) -> Plan:
    if accumulate == "oz2":
        r = compute_r(n, beta, dbits)
        gemms = oz2_num_pairs(k, fast)
        adds = oz2_num_highprec_adds(k, r, beta, n, fast, dbits, word_bits)
    else:
        r = compute_r(n, beta)
        gemms = k * (k + 1) // 2
        adds = num_highprec_adds(k, r, accumulate == "group_ef")
    return Plan(k=k, beta=beta, r=r, bits_needed=k * beta, probed=False,
                int8_gemms=gemms, highprec_adds=adds,
                blocks=kernel_blocks(m, n, p))


def _word_bits(cfg) -> int:
    """Integer word budget of the oz2 exponent ladder under ``cfg``:
    52 bits (int64 word, exact f64 convert) for the f64 accumulator in x64
    mode, 31 (int32 word) otherwise — mirrors ``accumulate.matmul_oz2``."""
    if cfg.accum_dtype != "f64":
        return 31
    try:
        import jax
        return 52 if jax.config.jax_enable_x64 else 31
    except ImportError:
        return 52


def _cfg_cost_key(cfg, beta: int) -> Tuple[str, bool, int, int]:
    return (cfg.accumulate, bool(getattr(cfg, "fast", False)),
            digit_bits(cfg.split, beta), _word_bits(cfg))


def plan_contraction(cfg, m: int, n: int, p: int, *,
                     a=None, b=None) -> Plan:
    """Resolve the execution plan for ``(m, n) @ (n, p)`` under ``cfg``
    (an :class:`repro.core.ozimmu.OzimmuConfig`).

    With concrete operands ``a``/``b`` and ``cfg.auto_k``, the accuracy
    probe picks k; traced or absent operands fall back to the static
    mantissa-coverage plan.  Fixed-k configs just get the cost accounting
    and kernel blocks.  The oz2 variants are planned against the OS-II
    error model (max-of-gaps, see :func:`choose_k`) and costed with their
    own pair/ladder accounting.
    """
    beta = beta_for(cfg.split, n)
    if not getattr(cfg, "auto_k", False):
        return _plan_static(n, m, p, cfg.k, beta, *_cfg_cost_key(cfg, beta))
    eps = cfg.target_eps if cfg.target_eps is not None else DEFAULT_TARGET_EPS
    mantissa = 53 if _bits_of(eps) > 22 else 24
    if a is not None and hasattr(a, "dtype") \
            and np.dtype(a.dtype) in _MANTISSA:
        mantissa = _MANTISSA[np.dtype(a.dtype)]
    gap_a = gap_b = None
    probed = False
    if a is not None and b is not None and _is_concrete(a) \
            and _is_concrete(b):
        gap_a = operand_gap_bits(a, axis=0)
        gap_b = operand_gap_bits(b, axis=1)
        probed = True
    k = choose_k(n, beta, eps, split=cfg.split, mantissa=mantissa,
                 m=m, p=p, gap_a=gap_a, gap_b=gap_b,
                 fast=bool(getattr(cfg, "fast", False)))
    base = _plan_static(n, m, p, k, beta, *_cfg_cost_key(cfg, beta))
    return dataclasses.replace(base, probed=probed)


def auto_k(a, b, cfg) -> int:
    """The planner's k for canonical batched operands
    ``(*batch, m, n) @ (*batch, n, p)`` (the ``_bmm_impl`` entry shape)."""
    m, n, p = a.shape[-2], a.shape[-1], b.shape[-1]
    return plan_contraction(cfg, m, n, p, a=a, b=b).k


def _is_concrete(x) -> bool:
    """True when ``x`` holds actual values (not a jit/vmap tracer)."""
    try:
        import jax
        return not isinstance(x, jax.core.Tracer)
    except Exception:  # jax absent or jax.core layout drifted: duck-test
        # (no np.asarray here — that would materialize the operand)
        return not hasattr(x, "_trace")


# ---------------------------------------------------------------------------
# kernel block autotune table
# ---------------------------------------------------------------------------

# dim >= threshold -> preferred tile.  Sized for ~16 MB VMEM: an f32 input
# tile (bm*bn*4), k<=16 int8 output slices (k*bm*bn), and a pair of f32
# accumulator tiles (2*bm*bp*4) all fit at the largest entry.
_TILE_TABLE = (
    (4096, 512),
    (1024, 256),
    (0, 128),
)


def _preferred(dim: int) -> int:
    for threshold, tile_ in _TILE_TABLE:
        if dim >= threshold:
            return tile_
    return _TILE_TABLE[-1][1]


@functools.lru_cache(maxsize=4096)
def kernel_blocks(m: int, n: int, p: int = 1) -> Tuple[int, int, int]:
    """Preferred ``(bm, bn, bp)`` Pallas tiles for a ``(m, n) @ (n, p)``
    problem — the static-shape autotune table, cached per shape like the
    jitted sharded entry.  Each kernel aligns its dims to its own hardware
    multiple with :func:`tile` (8 sublanes for f32 rows, 128 lanes / MXU
    edges elsewhere)."""
    return (_preferred(m), _preferred(n), _preferred(p))


def tile(dim: int, pref: int, mult: int) -> int:
    """Align a preferred tile to a kernel's multiple, never exceeding the
    (rounded-up) dim — small problems get one mult-sized tile rather than
    a mostly-padding large one."""
    if dim <= mult:
        return mult
    if dim < pref:
        return min(pref, (dim + mult - 1) // mult * mult)
    return max(mult, pref // mult * mult)


def describe_config(cfg, m: int = 4096, n: int = 4096, p: int = 4096) -> str:
    """One-line human plan summary for an engine config (launch logging)."""
    pl = plan_contraction(cfg, m, n, p)
    eps = cfg.target_eps if cfg.target_eps is not None else DEFAULT_TARGET_EPS
    kpart = (f"k=auto(target_eps={eps:.1e}, static {pl.k} @ n={n})"
             if getattr(cfg, "auto_k", False) else f"k={cfg.k}")
    fused = cfg.use_pallas == "fused"
    fast = getattr(cfg, "fast", False)
    mode = "/fast2" if fast == "fast2" else "/fast" if fast else ""
    return (f"{cfg.split}/{cfg.accumulate}{mode}:{cfg.accum_dtype} {kpart}, "
            f"{'fused split+epilogue Pallas pipeline' if fused else 'pallas group-GEMM' if cfg.use_pallas else 'XLA path'}, "
            f"{pl.int8_gemms} int8 GEMMs / {pl.highprec_adds} hp adds")
