"""Public API: high-precision GEMM emulation on integer matmul units.

The four named method variants of the paper:

  =============  ==============  =====================  ====================
  name           splitting       accumulation           paper
  =============  ==============  =====================  ====================
  ``ozimmu``     bitmask (Alg3)  naive (Alg4)           Ootomo et al. (base)
  ``ozimmu_rn``  RN adapt (Alg5) naive (Alg4)           proposed §3.1
  ``ozimmu_ef``  bitmask (Alg3)  group-EF (Alg6/7)      proposed §3.2
  ``ozimmu_h``   RN const (Alg8) group-EF (Alg6/7)      proposed §3.3
  =============  ==============  =====================  ====================

``ozimmu_matmul`` is differentiable (custom VJP: the cotangent GEMMs run
through the same emulation), jit/vmap/shard-compatible (everything is plain
lax), and supports f64 (paper-faithful DGEMM emulation) and f32 inputs with
``f64``/``f32``/``df32`` accumulators.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import accumulate, splitting

__all__ = ["OzimmuConfig", "VARIANTS", "ozimmu_matmul", "parse_spec"]


@dataclasses.dataclass(frozen=True)
class OzimmuConfig:
    k: int = 8                      # number of slices
    split: str = "rn_const"         # bitmask | rn | rn_const
    accumulate: str = "group_ef"    # naive | group_ef
    accum_dtype: str = "f64"        # f64 | f32 | df32
    use_pallas: bool = False        # route group GEMMs through the Pallas kernel

    def with_(self, **kw) -> "OzimmuConfig":
        return dataclasses.replace(self, **kw)


VARIANTS = {
    "ozimmu": OzimmuConfig(split="bitmask", accumulate="naive"),
    "ozimmu_rn": OzimmuConfig(split="rn", accumulate="naive"),
    "ozimmu_ef": OzimmuConfig(split="bitmask", accumulate="group_ef"),
    "ozimmu_h": OzimmuConfig(split="rn_const", accumulate="group_ef"),
}

_SPLITTERS = {
    "bitmask": splitting.split_bitmask,
    "rn": splitting.split_rn,
    "rn_const": splitting.split_rn_const,
}


def parse_spec(spec: str) -> OzimmuConfig:
    """Parse ``"ozimmu_h-8"`` / ``"ozimmu_ef-10:df32"`` style strings."""
    accum_dtype = "f64"
    if ":" in spec:
        spec, accum_dtype = spec.split(":")
    name, _, kstr = spec.partition("-")
    if name not in VARIANTS:
        raise ValueError(f"unknown ozimmu variant {name!r}; "
                         f"options: {sorted(VARIANTS)}")
    cfg = VARIANTS[name]
    return cfg.with_(k=int(kstr) if kstr else cfg.k, accum_dtype=accum_dtype)


def split_operands(a: jax.Array, b: jax.Array, cfg: OzimmuConfig):
    """Step (i)+(ii): slice A row-wise and B column-wise."""
    n = a.shape[1]
    beta = splitting.compute_beta(n)
    splitter = _SPLITTERS[cfg.split]
    sa = splitter(a, cfg.k, beta=beta, axis=0)
    sb = splitter(b, cfg.k, beta=beta, axis=1)
    return sa, sb


def _matmul_impl(a: jax.Array, b: jax.Array, cfg: OzimmuConfig) -> jax.Array:
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} @ {b.shape}")
    sa, sb = split_operands(a, b, cfg)
    group_gemm_fn = None
    if cfg.use_pallas:
        from repro.kernels import ops as kops  # lazy: kernels are optional
        group_gemm_fn = partial(kops.group_gemm, sa, sb)
    if cfg.accumulate == "naive":
        return accumulate.matmul_naive(
            sa, sb, accum=cfg.accum_dtype, out_dtype=a.dtype)
    return accumulate.matmul_group_ef(
        sa, sb, accum=cfg.accum_dtype, out_dtype=a.dtype,
        group_gemm_fn=group_gemm_fn)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def ozimmu_matmul(a: jax.Array, b: jax.Array,
                  cfg: OzimmuConfig = VARIANTS["ozimmu_h"]) -> jax.Array:
    """Emulated high-precision ``a @ b`` via k-slice INT8 GEMMs.

    a: (m, n), b: (n, p), both f32 or f64.  Returns (m, p) in a.dtype.
    """
    return _matmul_impl(a, b, cfg)


def _fwd(a, b, cfg):
    return _matmul_impl(a, b, cfg), (a, b)


def _bwd(cfg, res, g):
    a, b = res
    # Cotangents through the same emulated GEMM (transposes are free re-slices).
    da = _matmul_impl(g, b.T, cfg)
    db = _matmul_impl(a.T, g, cfg)
    return da, db


ozimmu_matmul.defvjp(_fwd, _bwd)
