"""Public API: high-precision GEMM emulation on integer matmul units.

The four named method variants of the paper:

  =============  ==============  =====================  ====================
  name           splitting       accumulation           paper
  =============  ==============  =====================  ====================
  ``ozimmu``     bitmask (Alg3)  naive (Alg4)           Ootomo et al. (base)
  ``ozimmu_rn``  RN adapt (Alg5) naive (Alg4)           proposed §3.1
  ``ozimmu_ef``  bitmask (Alg3)  group-EF (Alg6/7)      proposed §3.2
  ``ozimmu_h``   RN const (Alg8) group-EF (Alg6/7)      proposed §3.3
  =============  ==============  =====================  ====================

Two entry points:

  * ``ozimmu_matmul(a, b, cfg)`` — the paper's rank-2 ``(m,n)@(n,p)`` GEMM.
  * ``ozimmu_dot_general(a, b, dimension_numbers, cfg)`` — a drop-in
    emulated ``jax.lax.dot_general``: arbitrary batch dimensions and
    contraction axes.  Batch dims stay true batch dims all the way into the
    int8 slice GEMMs (no reshape-to-2D), which is what batched attention
    scores, MoE expert GEMMs and vmapped training steps need.

Both are differentiable (custom VJP written against general dimension
numbers: the cotangent contractions run through the same emulation),
jit/vmap/shard-compatible (everything is plain lax), and support f64
(paper-faithful DGEMM emulation) and f32 inputs with ``f64``/``f32``/``df32``
accumulators.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import accumulate, splitting

__all__ = ["OzimmuConfig", "VARIANTS", "ozimmu_matmul", "ozimmu_dot_general",
           "parse_spec"]

DimensionNumbers = Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]],
                         Tuple[Tuple[int, ...], Tuple[int, ...]]]


@dataclasses.dataclass(frozen=True)
class OzimmuConfig:
    k: int = 8                      # number of slices
    split: str = "rn_const"         # bitmask | rn | rn_const
    accumulate: str = "group_ef"    # naive | group_ef
    accum_dtype: str = "f64"        # f64 | f32 | df32
    use_pallas: bool = False        # route group GEMMs through the Pallas kernel

    def with_(self, **kw) -> "OzimmuConfig":
        return dataclasses.replace(self, **kw)


VARIANTS = {
    "ozimmu": OzimmuConfig(split="bitmask", accumulate="naive"),
    "ozimmu_rn": OzimmuConfig(split="rn", accumulate="naive"),
    "ozimmu_ef": OzimmuConfig(split="bitmask", accumulate="group_ef"),
    "ozimmu_h": OzimmuConfig(split="rn_const", accumulate="group_ef"),
}

_SPLITTERS = {
    "bitmask": splitting.split_bitmask,
    "rn": splitting.split_rn,
    "rn_const": splitting.split_rn_const,
}


def parse_spec(spec: str) -> OzimmuConfig:
    """Parse ``"ozimmu_h-8"`` / ``"ozimmu_ef-10:df32"`` style strings."""
    accum_dtype = "f64"
    if ":" in spec:
        spec, accum_dtype = spec.split(":")
    name, _, kstr = spec.partition("-")
    if name not in VARIANTS:
        raise ValueError(f"unknown ozimmu variant {name!r}; "
                         f"options: {sorted(VARIANTS)}")
    cfg = VARIANTS[name]
    return cfg.with_(k=int(kstr) if kstr else cfg.k, accum_dtype=accum_dtype)


def split_operands(a: jax.Array, b: jax.Array, cfg: OzimmuConfig):
    """Step (i)+(ii): slice A row-wise and B column-wise.

    a (*batch, m, n), b (*batch, n, p) — scales are per batch element.
    """
    n = a.shape[-1]
    beta = splitting.compute_beta(n)
    splitter = _SPLITTERS[cfg.split]
    sa = splitter(a, cfg.k, beta=beta, axis=0)
    sb = splitter(b, cfg.k, beta=beta, axis=1)
    return sa, sb


def _bmm_impl(a: jax.Array, b: jax.Array, cfg: OzimmuConfig) -> jax.Array:
    """Emulated batched matmul on canonical operands:
    (*batch, m, n) @ (*batch, n, p) -> (*batch, m, p)."""
    if a.ndim < 2 or b.ndim < 2 or a.shape[-1] != b.shape[-2] or \
            a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"bad batched GEMM shapes {a.shape} @ {b.shape}")
    if cfg.accum_dtype == "f64" and not jax.config.jax_enable_x64:
        # without x64 mode JAX truncates f64 to f32 anyway; downgrade
        # explicitly (the documented footgun — see docs/engine.md) instead
        # of emitting one truncation warning per accumulation step
        cfg = cfg.with_(accum_dtype="f32")
    sa, sb = split_operands(a, b, cfg)
    group_gemm_fn = None
    if cfg.use_pallas:
        from repro.kernels import ops as kops  # lazy: kernels are optional
        group_gemm_fn = partial(kops.group_gemm, sa, sb)
    if cfg.accumulate == "naive":
        return accumulate.matmul_naive(
            sa, sb, accum=cfg.accum_dtype, out_dtype=a.dtype)
    return accumulate.matmul_group_ef(
        sa, sb, accum=cfg.accum_dtype, out_dtype=a.dtype,
        group_gemm_fn=group_gemm_fn)


# ---------------------------------------------------------------------------
# general dot_general: canonicalization + implementation
# ---------------------------------------------------------------------------

def _canonicalize_dnums(dimension_numbers) -> DimensionNumbers:
    """Nested tuples (hashable: dimension_numbers is a nondiff VJP arg)."""
    (ac, bc), (ab, bb) = dimension_numbers
    return ((tuple(map(int, ac)), tuple(map(int, bc))),
            (tuple(map(int, ab)), tuple(map(int, bb))))


def _remaining(ndim: int, *exclude: Sequence[int]):
    ex = set()
    for e in exclude:
        ex.update(e)
    return [i for i in range(ndim) if i not in ex]


def _ranges_like(*seqs):
    start = 0
    out = []
    for s in seqs:
        out.append(list(range(start, start + len(s))))
        start += len(s)
    return out


def _argsort(seq):
    return sorted(range(len(seq)), key=seq.__getitem__)


def _dot_general_impl(a: jax.Array, b: jax.Array,
                      dnums: DimensionNumbers, cfg: OzimmuConfig) -> jax.Array:
    """Normalize to the canonical batched form and run the emulation.

    Layout convention matches ``jax.lax.dot_general``: output is
    (*batch [lhs order], *lhs free [ascending], *rhs free [ascending]).
    Multiple contraction axes are flattened into one inner dimension (beta /
    r are computed from the TOTAL contraction length, so the INT32
    no-overflow guarantees still hold); free axes flatten into m / p and are
    restored afterwards — batch axes are never flattened away.
    """
    (ac, bc), (ab, bb) = dnums
    if len(ac) != len(bc) or len(ab) != len(bb):
        raise ValueError(f"mismatched dimension numbers {dnums}")
    for i, j in zip(ac, bc):
        if a.shape[i] != b.shape[j]:
            raise ValueError(
                f"contraction size mismatch {a.shape} @ {b.shape}: {dnums}")
    for i, j in zip(ab, bb):
        if a.shape[i] != b.shape[j]:
            raise ValueError(
                f"batch size mismatch {a.shape} @ {b.shape}: {dnums}")
    a_free = _remaining(a.ndim, ac, ab)
    b_free = _remaining(b.ndim, bc, bb)
    batch_shape = tuple(a.shape[i] for i in ab)
    m_shape = tuple(a.shape[i] for i in a_free)
    p_shape = tuple(b.shape[i] for i in b_free)
    n = math.prod(a.shape[i] for i in ac)
    m = math.prod(m_shape)
    p = math.prod(p_shape)
    # (*batch, m, n) with contraction axes in pairing order (ac[i] <-> bc[i])
    a3 = jnp.transpose(a, list(ab) + a_free + list(ac)).reshape(
        batch_shape + (m, n))
    b3 = jnp.transpose(b, list(bb) + list(bc) + b_free).reshape(
        batch_shape + (n, p))
    out = _bmm_impl(a3, b3, cfg)
    return out.reshape(batch_shape + m_shape + p_shape)


# ---------------------------------------------------------------------------
# custom VJP against general dimension numbers
# ---------------------------------------------------------------------------

def _transpose_operand(g, other, target_ndim: int, dnums: DimensionNumbers,
                       cfg: OzimmuConfig, swap_ans: bool):
    """Cotangent of the lhs of ``dot_general(x, y, dnums)`` (mirror of
    jax._src.lax's ``_dot_general_transpose_lhs``, with the contraction
    itself emulated).  For the rhs cotangent, call with the roles of x and y
    swapped in ``dnums`` and ``swap_ans=True``."""
    (xc, yc), (xb, yb) = dnums
    x_kept = _remaining(target_ndim, xc, xb)
    y_kept = _remaining(other.ndim, yc, yb)
    if swap_ans:
        g_batch, g_y_kept, _ = _ranges_like(xb, y_kept, x_kept)
    else:
        g_batch, _, g_y_kept = _ranges_like(xb, x_kept, y_kept)
    dims = ((tuple(g_y_kept), tuple(y_kept)), (tuple(g_batch), tuple(yb)))
    dx = _dot_general_impl(g, other, _canonicalize_dnums(dims), cfg)
    xc_sorted_by_yc = [xc[i] for i in _argsort(yc)]
    out_axes = _argsort(list(xb) + x_kept + xc_sorted_by_yc)
    return jnp.transpose(dx, out_axes)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _oz_dot_general(a: jax.Array, b: jax.Array, dnums: DimensionNumbers,
                    cfg: OzimmuConfig) -> jax.Array:
    return _dot_general_impl(a, b, dnums, cfg)


def _fwd(a, b, dnums, cfg):
    return _dot_general_impl(a, b, dnums, cfg), (a, b)


def _bwd(dnums, cfg, res, g):
    a, b = res
    (ac, bc), (ab, bb) = dnums
    # Cotangents through the same emulated contraction (transposed dims are
    # free re-slices; no precision leaves the scheme).
    da = _transpose_operand(g, b, a.ndim, dnums, cfg, swap_ans=False)
    db = _transpose_operand(g, a, b.ndim, ((bc, ac), (bb, ab)), cfg,
                            swap_ans=True)
    return da, db


_oz_dot_general.defvjp(_fwd, _bwd)


def ozimmu_dot_general(a: jax.Array, b: jax.Array, dimension_numbers,
                       cfg: OzimmuConfig = VARIANTS["ozimmu_h"]) -> jax.Array:
    """Emulated ``jax.lax.dot_general`` via k-slice INT8 GEMMs.

    ``dimension_numbers`` is the standard lax contract,
    ``((lhs_contract, rhs_contract), (lhs_batch, rhs_batch))``; the output
    layout is lax's (batch dims, lhs free dims, rhs free dims).  Batch
    dimensions are carried natively through splitting (per-batch row/col
    scales) and the int8 ``dot_general``s.  Differentiable: the custom VJP
    evaluates both cotangents with the same emulation under the transposed
    dimension numbers.

    Example — batched attention-score-like contraction::

        out = ozimmu_dot_general(q, k, (((2,), (2,)), ((0,), (0,))), cfg)
        # q (B, Lq, D), k (B, Lk, D)  ->  out (B, Lq, Lk)
    """
    return _oz_dot_general(a, b, _canonicalize_dnums(dimension_numbers), cfg)


def ozimmu_matmul(a: jax.Array, b: jax.Array,
                  cfg: OzimmuConfig = VARIANTS["ozimmu_h"]) -> jax.Array:
    """Emulated high-precision ``a @ b`` via k-slice INT8 GEMMs.

    a: (m, n), b: (n, p), both f32 or f64.  Returns (m, p) in a.dtype.
    The rank-2 special case of :func:`ozimmu_dot_general`.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} @ {b.shape}")
    return ozimmu_dot_general(a, b, (((1,), (0,)), ((), ())), cfg)
