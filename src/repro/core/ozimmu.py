"""Public API: high-precision GEMM emulation on integer matmul units.

The four named method variants of the paper, plus the two Ozaki-II
constant-scaling variants (see docs/algorithms.md#ozaki-scheme-ii):

  ===============  ================  =====================  ====================
  name             splitting         accumulation           paper
  ===============  ================  =====================  ====================
  ``ozimmu``       bitmask (Alg3)    naive (Alg4)           Ootomo et al. (base)
  ``ozimmu_rn``    RN adapt (Alg5)   naive (Alg4)           proposed §3.1
  ``ozimmu_ef``    bitmask (Alg3)    group-EF (Alg6/7)      proposed §3.2
  ``ozimmu_h``     RN const (Alg8)   group-EF (Alg6/7)      proposed §3.3
  ``ozimmu_sm_b``  sign-magnitude    naive (Alg4)           cuBLASDx DGEMM-emu
  ``ozimmu_sm_h``  sign-magnitude    group-EF (Alg6/7)      cuBLASDx DGEMM-emu
  ``oz2_b``        oz2 trunc (const) exponent ladder        OS-II (Uchino et al.)
  ``oz2_h``        oz2 RN (const)    exponent ladder        OS-II fast-mode line
  ===============  ================  =====================  ====================

The sign-magnitude variants slice into UNSIGNED beta-bit magnitudes with
the sign carried only by the leading slice (``splitting.split_sm``):
no bit is reserved per digit for a sign, so beta reaches 8 and k slices
cover 8k-1 mantissa bits versus the signed splitters' 7k — the planner's
``auto`` picks a strictly smaller k (fewer int8 GEMMs, fewer
high-precision adds) at equal ``target_eps``.  Digit storage is int8 mod
2^8; accumulation widens through ``splitting.sm_decode`` (the
``accumulate.gemm_slice`` hook), and all scale folds stay pow2-exact, so
``:fused``, ``@mesh/int32`` and ``rhs_presplit`` remain bitwise
identical to the XLA path.

The oz2 variants share ONE power-of-two digit grid per matrix (constant
scaling), so all slice-pair scales collapse to a scalar exponent ladder:
full mode evaluates every k^2 slice pair, ``:fast`` mode only the
anti-diagonal band s + t <= k + 1, and consecutive groups fold into one
integer word before each high-precision add
(``accumulate.matmul_oz2``) — strictly fewer high-precision adds than the
group-EF path at equal k.  ``:fast2`` keeps the fast-mode band and cost
but runs it on the improved per-row equilibrated scaling (Kawakami &
Takahashi): each operand is exactly rescaled row/column-wise onto a
constant shared grid and the power-of-two factors are unscaled after the
ladder, anchoring the truncation error per row — near-full-mode accuracy
at fast-mode GEMM/add counts.

Two entry points:

  * ``ozimmu_matmul(a, b, cfg)`` — the paper's rank-2 ``(m,n)@(n,p)`` GEMM.
  * ``ozimmu_dot_general(a, b, dimension_numbers, cfg)`` — a drop-in
    emulated ``jax.lax.dot_general``: arbitrary batch dimensions and
    contraction axes.  Batch dims stay true batch dims all the way into the
    int8 slice GEMMs (no reshape-to-2D), which is what batched attention
    scores, MoE expert GEMMs and vmapped training steps need.

Both are differentiable (custom VJP written against general dimension
numbers: the cotangent contractions run through the same emulation),
jit/vmap/shard-compatible (everything is plain lax), and support f64
(paper-faithful DGEMM emulation) and f32 inputs with ``f64``/``f32``/``df32``
accumulators.

Mesh-native mode (``OzimmuConfig.mesh_axis`` / spec suffix ``@model``):
when a mesh is installed and the contraction length divides the named
axis, the contraction runs sharded under ``shard_map`` with the
cross-device accumulation kept inside the scheme — an exact INT32
product ``psum`` (bit-identical to the unsharded emulation) or, with
``mesh_reduce="df32"``, a TwoSum-compensated reduction of the partial
accumulators with one final rounding.  See docs/distributed.md.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from functools import partial
from functools import partial as partial_fn  # alias: `partial` is also a
                                             # keyword arg of _bmm_local
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accumulate, splitting
from repro.obs import registry as _obs
from repro.obs import tracing as _tracing

__all__ = ["OzimmuConfig", "VARIANTS", "ozimmu_matmul", "ozimmu_dot_general",
           "parse_spec", "canonical_rhs", "variant_name"]

DimensionNumbers = Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]],
                         Tuple[Tuple[int, ...], Tuple[int, ...]]]


@dataclasses.dataclass(frozen=True)
class OzimmuConfig:
    k: int = 8                      # number of slices (fixed-k configs)
    split: str = "rn_const"         # bitmask | rn | rn_const |
                                    # oz2_rn | oz2_bitmask (constant grid)
    accumulate: str = "group_ef"    # naive | group_ef | oz2 (exponent
                                    # ladder; needs an oz2_* split)
    fast: Union[bool, str] = False  # oz2 only: ``True`` (spec token
                                    # ``:fast``) evaluates the s+t <= k+1
                                    # band instead of all k^2 slice pairs;
                                    # ``"fast2"`` (token ``:fast2``) the
                                    # same band under the improved per-row
                                    # equilibrated scaling (the *_fast2
                                    # splits + exact two-sided unscale)
    accum_dtype: str = "f64"        # f64 | f32 | df32
    use_pallas: Union[bool, str] = False
                                    # False: XLA everywhere.  True: group
                                    # GEMMs through the Pallas kernel.
                                    # "fused" (spec token ``:fused``): the
                                    # whole one-HBM-pass pipeline — fused
                                    # k-slice extraction, Pallas group
                                    # GEMMs, fused convert+scale+add
                                    # epilogue (see core/plan.py docs).
    auto_k: bool = False            # spec token ``auto``: per-contraction
                                    # accuracy-driven k (core/plan.py)
    target_eps: Optional[float] = None
                                    # auto-k error target; None = the
                                    # planner default (~f64-faithful)
    target_eps_mode: str = "deterministic"
                                    # "deterministic" (worst-case eq.18
                                    # bit model) | "probabilistic" (spec
                                    # token ``:prob``: the 2506.11277
                                    # concentration model — smaller
                                    # auto-k at failure probability
                                    # target_delta; core/plan.py)
    target_delta: Optional[float] = None
                                    # probabilistic-mode per-entry failure
                                    # probability; None = the analysis
                                    # default (2^-20); <= 0 recovers the
                                    # deterministic planner exactly
    mesh_axis: Optional[str] = None  # mesh-native contraction sharding axis
    mesh_reduce: str = "int32"      # int32 (exact product psum) | df32
                                    # (compensated partial-accumulator psum)

    def with_(self, **kw) -> "OzimmuConfig":
        return dataclasses.replace(self, **kw)

    def local(self) -> "OzimmuConfig":
        """This config without the mesh-native reduction (single-device
        semantics; used inside shard_map bodies that already own the mesh
        axes — nested shard_maps are not a thing)."""
        return self.with_(mesh_axis=None) if self.mesh_axis else self


VARIANTS = {
    "ozimmu": OzimmuConfig(split="bitmask", accumulate="naive"),
    "ozimmu_rn": OzimmuConfig(split="rn", accumulate="naive"),
    "ozimmu_ef": OzimmuConfig(split="bitmask", accumulate="group_ef"),
    "ozimmu_h": OzimmuConfig(split="rn_const", accumulate="group_ef"),
    "ozimmu_sm_b": OzimmuConfig(split="sm", accumulate="naive"),
    "ozimmu_sm_h": OzimmuConfig(split="sm", accumulate="group_ef"),
    "oz2_b": OzimmuConfig(split="oz2_bitmask", accumulate="oz2"),
    "oz2_h": OzimmuConfig(split="oz2_rn", accumulate="oz2"),
}

_SPLITTERS = {
    "bitmask": splitting.split_bitmask,
    "rn": splitting.split_rn,
    "rn_const": splitting.split_rn_const,
    "sm": splitting.split_sm,
    "oz2_rn": splitting.split_oz2,
    "oz2_bitmask": splitting.split_oz2_bitmask,
    "oz2_rn_fast2": splitting.split_oz2_fast2,
    "oz2_bitmask_fast2": splitting.split_oz2_bitmask_fast2,
}


def canonical_fast2(cfg: "OzimmuConfig") -> "OzimmuConfig":
    """Tie ``cfg.fast == "fast2"`` and the ``*_fast2`` split names
    together (they are one mode; ``parse_spec`` emits them jointly, but a
    hand-built config may set only one half).  The split name is what
    keys the split cache and the presplit-compatibility check, so the
    normalization must happen before either looks at the config."""
    if cfg.fast == "fast2" and not cfg.split.endswith("_fast2"):
        return cfg.with_(split=cfg.split + "_fast2")
    if cfg.split.endswith("_fast2") and cfg.fast != "fast2":
        return cfg.with_(fast="fast2")
    return cfg

def digit_bits(cfg: "OzimmuConfig", beta: int) -> int:
    """Slice digit magnitude bits under ``cfg.split`` (sizes r / ladders);
    delegates to :func:`repro.core.splitting.digit_bits`."""
    return splitting.digit_bits(cfg.split, beta)


_VARIANT_NAMES = {(v.split, v.accumulate): name
                  for name, v in VARIANTS.items()}


def variant_name(cfg: "OzimmuConfig") -> str:
    """The ``VARIANTS`` name this config's (split, accumulate) pair maps
    back to (``*_fast2`` splits resolve to their base variant; unknown
    hand-built pairs fall back to ``split/accumulate``)."""
    split = cfg.split[:-len("_fast2")] if cfg.split.endswith("_fast2") \
        else cfg.split
    return _VARIANT_NAMES.get((split, cfg.accumulate),
                              f"{cfg.split}/{cfg.accumulate}")


def _record_emulation(cfg: "OzimmuConfig", a, p: int,
                      presplit: bool) -> None:
    """Mirror one resolved contraction into the metrics registry.

    Called from ``_bmm_impl`` after the config is fully canonical (fast2
    tied, accumulator downgraded, auto-k resolved to a concrete k), so
    the recorded counts are exactly what executes.  Host-side only: runs
    once per eager call or per jit *trace* — a compiled step that traced
    through here replays the same contraction on every execution, so
    trace-time counts are per-execution counts.  Costs come from the
    same ``Plan`` accounting the planner uses, which is what makes
    observed == planned a testable invariant (tests/test_obs.py).
    Shapes/dtypes only — never touches values, so tracers stay clean and
    outputs are bitwise-identical with obs on or off.
    """
    from repro.core import plan as _plan
    m, n = a.shape[-2], a.shape[-1]
    # canonical operands share batch dims; b is (*batch, n, p)
    batch = int(np.prod(a.shape[:-2], dtype=np.int64)) if a.ndim > 2 else 1
    pl = _plan.plan_contraction(cfg, m, n, p)
    labels = dict(
        variant=variant_name(cfg), k=cfg.k,
        path=("fused" if cfg.use_pallas == "fused"
              else "pallas" if cfg.use_pallas else "xla"),
        mesh=cfg.mesh_axis or "none", presplit=int(presplit))
    reg = _obs.get_registry()
    reg.inc("emulation.calls", 1, **labels)
    reg.inc("emulation.int8_gemms", batch * pl.int8_gemms, **labels)
    reg.inc("emulation.highprec_adds", batch * pl.highprec_adds, **labels)
    itemsize = np.dtype(a.dtype).itemsize
    split_elems = batch * m * n            # A is always split in-call;
    if not presplit:                       # B only when no frozen Split
        split_elems += batch * n * p
    reg.inc("emulation.split_bytes", split_elems * itemsize, **labels)


_MESH_REDUCES = ("int32", "df32")


def parse_spec(spec: str) -> OzimmuConfig:
    """Parse ``"ozimmu_h-8"`` / ``"oz2_h-auto:fast"`` style strings.

    Full grammar (docs/engine.md):
    ``variant["-"k][":"opt]*["@"mesh_axis["/"mesh_reduce]]`` where ``k`` is
    an integer or ``auto`` (per-contraction accuracy-driven slice count,
    core/plan.py) and each ``:opt`` is an accumulator dtype
    (``f64``/``f32``/``df32``), ``fused`` (the one-HBM-pass Pallas
    pipeline), ``prob`` (auto-k specs only, any variant: resolve k under
    the probabilistic eps model — ``target_eps_mode="probabilistic"``,
    core/plan.py), or — for the ``oz2_*`` variants only — ``fast``
    (evaluate the anti-diagonal band s + t <= k + 1 instead of all k^2
    slice pairs) or ``fast2`` (the same band under the improved per-row
    equilibrated scaling — near-full-mode accuracy at fast-mode cost;
    mutually exclusive with ``fast``).
    E.g. ``"ozimmu_h-auto:df32:fused@model"`` runs the fused pipeline,
    contraction-sharded over the ``model`` mesh axis with the exact int32
    cross-device reduction, with auto-planned k; ``"oz2_h-auto:fast"``
    runs the Ozaki-II fast mode with auto-planned k against the oz2 error
    model; ``"oz2_h-auto:fast2"`` the improved-scaling fast mode;
    ``"...@model/df32"`` selects the compensated
    partial-accumulator reduction instead (see docs/distributed.md).
    """
    mesh_axis, mesh_reduce = None, "int32"
    if "@" in spec:
        spec, mesh = spec.split("@", 1)
        mesh_axis, _, reduce_str = mesh.partition("/")
        if reduce_str:
            mesh_reduce = reduce_str
        if not mesh_axis or not mesh_axis.isidentifier():
            raise ValueError(f"bad mesh axis {mesh_axis!r} in engine spec")
        if mesh_reduce not in _MESH_REDUCES:
            raise ValueError(f"unknown mesh reduce {mesh_reduce!r}; "
                             f"options: {_MESH_REDUCES}")
    accum_dtype, use_pallas, fast, prob = "f64", False, False, False
    spec, *opts = spec.split(":")
    seen_accum = False
    for opt in opts:
        if opt in ("f64", "f32", "df32"):
            if seen_accum:
                raise ValueError(f"duplicate accumulator dtype {opt!r} "
                                 f"in engine spec")
            accum_dtype, seen_accum = opt, True
        elif opt == "fused":
            if use_pallas == "fused":
                raise ValueError("duplicate 'fused' token in engine spec")
            use_pallas = "fused"
        elif opt == "prob":
            if prob:
                raise ValueError("duplicate 'prob' token in engine spec")
            prob = True
        elif opt in ("fast", "fast2"):
            if fast == (opt if opt == "fast2" else True):
                raise ValueError(f"duplicate {opt!r} token in engine spec")
            if fast:
                raise ValueError(f"conflicting fast-mode tokens in engine "
                                 f"spec: {opt!r} after "
                                 f"{'fast2' if fast == 'fast2' else 'fast'!r}"
                                 f" (pick one)")
            fast = "fast2" if opt == "fast2" else True
        else:
            raise ValueError(f"unknown engine spec option {opt!r}; "
                             f"options: f64, f32, df32, fused, fast, "
                             f"fast2, prob")
    name, _, kstr = spec.partition("-")
    if name not in VARIANTS:
        raise ValueError(f"unknown ozimmu variant {name!r}; "
                         f"options: {sorted(VARIANTS)}")
    auto_k = kstr == "auto"
    if kstr and not auto_k and (not kstr.isdigit() or int(kstr) < 1):
        raise ValueError(f"bad slice count {kstr!r} in engine spec "
                         f"(an integer >= 1, or 'auto')")
    cfg = VARIANTS[name]
    if fast and cfg.accumulate != "oz2":
        token = "fast2" if fast == "fast2" else "fast"
        raise ValueError(f"the {token!r} token applies to the oz2_* "
                         f"variants only (the ozimmu family always "
                         f"evaluates the fast-mode band); got {name!r}")
    if prob and not auto_k:
        raise ValueError(f"the 'prob' token (probabilistic "
                         f"target_eps_mode) applies to auto-k specs only "
                         f"— a fixed slice count leaves the planner "
                         f"nothing to resolve; got {name!r} with "
                         f"k={kstr or cfg.k}, want e.g. {name}-auto:prob")
    return canonical_fast2(cfg.with_(
        k=cfg.k if (auto_k or not kstr) else int(kstr),
        auto_k=auto_k, accum_dtype=accum_dtype,
        use_pallas=use_pallas, fast=fast,
        target_eps_mode="probabilistic" if prob else "deterministic",
        mesh_axis=mesh_axis, mesh_reduce=mesh_reduce))


def split_operands(a: jax.Array, b: jax.Array, cfg: OzimmuConfig, *,
                   n_total: Optional[int] = None, rowmax_reduce=None,
                   rhs_presplit: Optional[splitting.Split] = None):
    """Step (i)+(ii): slice A row-wise and B column-wise.

    a (*batch, m, n), b (*batch, n, p) — scales are per batch element.
    ``n_total`` overrides the contraction length used for beta (eq. 4) when
    ``a``/``b`` are per-device shards of a longer contraction;
    ``rowmax_reduce`` (e.g. a mesh-axis ``pmax``) then makes the digit
    grids globally agreed — see docs/distributed.md.

    ``rhs_presplit`` short-circuits the B side entirely: a frozen
    column-scale :class:`~repro.core.splitting.Split` (from
    ``repro.core.split_cache``) is used as-is and only A is split — the
    serving-time path where B is a static weight matrix.  ``b`` may then
    be ``None``.

    With ``cfg.use_pallas == "fused"`` the extraction runs through the
    one-HBM-pass Pallas kernel (``kernels.ops.split_fused``) for the
    geometric strategies; the adaptive RN strategy needs a fresh row-max
    per slice and keeps the library splitter (its k re-reads are the
    point the paper's Alg. 8 removes).  Digits and scales are
    bit-identical either way.
    """
    n = n_total if n_total is not None else a.shape[-1]
    beta = splitting.beta_for(cfg.split, n)
    if cfg.use_pallas == "fused" and cfg.split != "rn":
        # every constant-ratio strategy fuses: per-row grids (bitmask,
        # rn_const) and the oz2 shared constant grids alike
        from repro.kernels import ops as kops  # lazy: kernels are optional
        sa = kops.split_fused(a, cfg.k, beta, mode=cfg.split, axis=0,
                              rowmax_reduce=rowmax_reduce)
        if rhs_presplit is not None:
            return sa, rhs_presplit
        sb = kops.split_fused(b, cfg.k, beta, mode=cfg.split, axis=1,
                              rowmax_reduce=rowmax_reduce)
        return sa, sb
    splitter = _SPLITTERS[cfg.split]
    sa = splitter(a, cfg.k, beta=beta, axis=0, rowmax_reduce=rowmax_reduce)
    if rhs_presplit is not None:
        return sa, rhs_presplit
    sb = splitter(b, cfg.k, beta=beta, axis=1, rowmax_reduce=rowmax_reduce)
    return sa, sb


def _bmm_local(a: jax.Array, b: jax.Array, cfg: OzimmuConfig, *,
               n_total: Optional[int] = None, rowmax_reduce=None,
               product_reduce=None, partial: bool = False,
               rhs_presplit: Optional[splitting.Split] = None):
    """Single-device emulated batched matmul (the shard-local body of the
    mesh-native path when the distributed hooks are given).

    ``cfg.use_pallas``: ``True`` routes the group GEMMs through the Pallas
    kernel; ``"fused"`` additionally replaces the per-slice splitter loop
    (``split_operands`` above) and the convert→scale→add epilogue with the
    one-HBM-pass kernels — every stage bit-identical to the XLA path, so
    the distributed hooks and ``partial`` compose unchanged.
    ``rhs_presplit`` (serving): B's frozen Split; the B-side splitter is
    skipped entirely and ``b`` may be ``None``.
    """
    with _tracing.phase_scope("split"):
        sa, sb = split_operands(a, b, cfg, n_total=n_total,
                                rowmax_reduce=rowmax_reduce,
                                rhs_presplit=rhs_presplit)
    group_gemm_fn = scale_accum_fn = pair_gemm_fn = unscale_fn = None
    if cfg.use_pallas:
        from repro.kernels import ops as kops  # lazy: kernels are optional
        if cfg.accumulate == "naive":
            # naive accumulation has no groups; each slice pair runs as a
            # G=1 Pallas GEMM (bit-identical to the XLA dot_general)
            pair_gemm_fn = lambda s, t: kops.group_gemm(sa, sb, [(s, t)])
        else:
            group_gemm_fn = partial_fn(kops.group_gemm, sa, sb)
        if cfg.use_pallas == "fused":
            scale_accum_fn = (kops.oz2_scale_accum_update
                              if cfg.accumulate == "oz2"
                              else kops.scale_accum_update)
            unscale_fn = kops.oz2_unscale_update
    if cfg.accumulate == "naive":
        return accumulate.matmul_naive(
            sa, sb, accum=cfg.accum_dtype, out_dtype=a.dtype,
            partial=partial, product_reduce=product_reduce,
            scale_accum_fn=scale_accum_fn, pair_gemm_fn=pair_gemm_fn)
    n = n_total if n_total is not None else a.shape[-1]
    if cfg.accumulate == "oz2":
        return accumulate.matmul_oz2(
            sa, sb, accum=cfg.accum_dtype, out_dtype=a.dtype,
            fast=cfg.fast, n_total=n, digit_bits=digit_bits(cfg, sa.beta),
            group_gemm_fn=group_gemm_fn, partial=partial,
            product_reduce=product_reduce, scale_accum_fn=scale_accum_fn,
            unscale_fn=unscale_fn)
    r = splitting.compute_r(n, sa.beta)
    return accumulate.matmul_group_ef(
        sa, sb, accum=cfg.accum_dtype, out_dtype=a.dtype, r=r,
        group_gemm_fn=group_gemm_fn, partial=partial,
        product_reduce=product_reduce, scale_accum_fn=scale_accum_fn)


@functools.lru_cache(maxsize=256)
def _sharded_fn(cfg: OzimmuConfig, mesh, nb: int, n_total: int,
                out_dtype, presplit_meta=None) -> "callable":
    """The jitted shard_map callable for one (config, mesh, rank) cell.

    Cached so repeated *eager* mesh-native contractions reuse one
    compiled entry instead of re-wrapping a fresh closure in ``jax.jit``
    per call (which would defeat jit's own cache); the jit is needed at
    all because eager shard_map is NotImplemented for some collective/dot
    patterns on older JAX.  Inside an outer jit it inlines for free.

    ``presplit_meta`` (serving): ``(beta, has_base, has_gbase, signmag)``
    of a frozen B-side Split — the callable then takes ``(a, (digits,
    scale, base, gbase))`` with the cached digit slices sharded along
    their contraction axis (they "live pre-sharded": splitting is
    elementwise given the grid, so the shard of the full-matrix digits
    equals the pmax-agreed shard-local split) and skips the B splitter
    entirely.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives, compat

    axis = cfg.mesh_axis
    a_spec = P(*((None,) * (nb + 1) + (axis,)))
    out_specs = P(*((None,) * (nb + 2)))
    local_cfg = cfg.local()

    if presplit_meta is None:
        in_specs = (a_spec, P(*((None,) * nb + (axis, None))))
        unpack = lambda operand: (operand, None)
    else:
        beta, has_base, has_gbase, signmag = presplit_meta
        # digits (k, *batch, n, p) shard on n; scales/bases replicated
        in_specs = (a_spec,
                    (P(*((None,) * (nb + 1) + (axis, None))), P(),
                     P() if has_base else None,
                     P() if has_gbase else None))

        def unpack(operand):
            digits, scale, base, gbase = operand
            return None, splitting.Split(digits, scale, base, beta, 1,
                                         gbase=gbase, signmag=signmag)

    if cfg.mesh_reduce == "int32":
        def body(al, operand):
            bl, sb = unpack(operand)
            return _bmm_local(
                al, bl, local_cfg, n_total=n_total,
                rowmax_reduce=lambda v: collectives.pmax_scales(v, axis),
                product_reduce=lambda p: collectives.psum_exact_int32(
                    p, axis),
                rhs_presplit=sb)
    else:
        def body(al, operand):
            bl, sb = unpack(operand)
            part = _bmm_local(al, bl, local_cfg, n_total=n_total,
                              partial=True, rhs_presplit=sb)
            if isinstance(part, accumulate.DF32):
                return collectives.psum_df32(part, axis).to_float(out_dtype)
            return collectives.psum_compensated(part, axis).astype(out_dtype)

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs, axis_names={axis},
                                    check_vma=False))


def _bmm_sharded(a: jax.Array, b: jax.Array, cfg: OzimmuConfig, mesh,
                 rhs_presplit: Optional[splitting.Split] = None) -> jax.Array:
    """Mesh-native emulated batched matmul: contraction axis sharded over
    ``cfg.mesh_axis``, cross-device accumulation inside the scheme.

    Strategy ``int32`` (default): row/col maxima are agreed across shards
    (one ``pmax``), every INT32 slice/group product is summed exactly over
    the axis (one stacked ``psum``), and the high-precision accumulation
    runs on the already-global products — bit-identical to the unsharded
    emulation.  Strategy ``df32``: each shard accumulates its local partial
    (local scales, no pmax pre-pass), and the partial accumulators are
    merged with a TwoSum-compensated reduction — one all-gather for the
    whole GEMM, error-free in the two-float representation, with the single
    final rounding after the merge.

    With ``rhs_presplit`` the cached B digits enter the shard_map sharded
    along their contraction axis; bit-identity with the unsharded presplit
    path is preserved for the int32 strategy (the cached full-matrix grid
    IS the pmax-agreed grid).  Under the df32 strategy the cached B grid
    is the globally-agreed one (computed from the full matrix) rather
    than each shard's local grid — a valid splitting either way; the
    compensated merge semantics are unchanged.
    """
    nb = a.ndim - 2
    if rhs_presplit is None:
        return _sharded_fn(cfg, mesh, nb, a.shape[-1], a.dtype)(a, b)
    sp = rhs_presplit
    meta = (int(sp.beta), sp.base is not None, sp.gbase is not None,
            bool(sp.signmag))
    fn = _sharded_fn(cfg, mesh, nb, a.shape[-1], a.dtype, meta)
    return fn(a, (sp.digits, sp.scale, sp.base, sp.gbase))


def _mesh_for(cfg: OzimmuConfig, n: int):
    """The installed mesh if the mesh-native path applies, else None
    (mesh absent, axis missing or trivial, or contraction indivisible —
    the caller falls back to the single-device emulation under GSPMD)."""
    if cfg.mesh_axis is None:
        return None
    from repro.distributed import compat
    mesh = compat.get_abstract_mesh()
    if mesh.empty or cfg.mesh_axis not in mesh.axis_names:
        return None
    size = dict(mesh.shape)[cfg.mesh_axis]
    if size <= 1 or n % size != 0:
        return None
    return mesh


def _check_presplit(a: jax.Array, b_shape, cfg: OzimmuConfig,
                    sp: splitting.Split) -> None:
    """Static consistency checks between a frozen B split and the call."""
    n = a.shape[-1]
    beta = splitting.beta_for(cfg.split, n)
    if sp.axis != 1:
        raise ValueError(f"rhs_presplit must carry column scales (axis=1), "
                         f"got axis={sp.axis}")
    # strategy mismatch first: a signed-vs-signmag disagreement also skews
    # beta, and the actionable diagnosis is the digit convention
    if bool(sp.signmag) != splitting.is_signmag(cfg.split):
        raise ValueError(
            f"rhs_presplit signmag={bool(sp.signmag)} does not match the "
            f"config's split {cfg.split!r}; sign-magnitude digits decode "
            f"differently from signed digits — re-freeze under the "
            f"current spec")
    if sp.beta != beta:
        raise ValueError(f"rhs_presplit beta={sp.beta} disagrees with the "
                         f"contraction's beta={beta} (n={n}); the split was "
                         f"frozen for a different contraction length")
    if tuple(sp.digits.shape[1:]) != tuple(b_shape):
        raise ValueError(f"rhs_presplit digits {sp.digits.shape} do not "
                         f"match the canonical rhs {tuple(b_shape)}")
    if sp.digits.shape[0] != cfg.k:
        raise ValueError(f"rhs_presplit has k={sp.digits.shape[0]} slices, "
                         f"config wants k={cfg.k}; re-freeze under the "
                         f"current spec")
    if cfg.accumulate == "oz2" and sp.gbase is None:
        raise ValueError("oz2 accumulation needs a constant-scaling "
                         "presplit (gbase); the cached split was frozen "
                         "under a per-row strategy")
    if cfg.accumulate == "group_ef" and sp.base is None:
        raise ValueError("group-EF accumulation needs geometric slice "
                         "scales; the cached split was frozen under the "
                         "adaptive RN strategy")
    if sp.scale.dtype != a.dtype:
        raise ValueError(f"rhs_presplit scales are {sp.scale.dtype}, the "
                         f"contraction computes in {a.dtype}; freeze the "
                         f"weight in the engine's compute dtype")


def _bmm_impl(a: jax.Array, b: jax.Array, cfg: OzimmuConfig,
              rhs_presplit: Optional[splitting.Split] = None) -> jax.Array:
    """Emulated batched matmul on canonical operands:
    (*batch, m, n) @ (*batch, n, p) -> (*batch, m, p)."""
    if a.ndim < 2 or b.ndim < 2 or a.shape[-1] != b.shape[-2] or \
            a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"bad batched GEMM shapes {a.shape} @ {b.shape}")
    cfg = canonical_fast2(cfg)
    if cfg.accum_dtype == "f64" and not jax.config.jax_enable_x64:
        # without x64 mode JAX truncates f64 to f32 anyway; downgrade
        # explicitly (the documented footgun — see docs/engine.md) instead
        # of emitting one truncation warning per accumulation step
        cfg = cfg.with_(accum_dtype="f32")
    if cfg.auto_k:
        if rhs_presplit is not None:
            # the cache resolved auto-k at freeze time with the static
            # mantissa-coverage plan (split_cache.resolved_k) — the same
            # plan a jitted call resolves to; adopt the frozen k so the
            # two paths agree bitwise.
            cfg = cfg.with_(k=int(rhs_presplit.digits.shape[0]),
                            auto_k=False)
        else:
            # accuracy-driven slice count (core/plan.py): probes concrete
            # operands eagerly; inside a jit trace it resolves to the
            # static mantissa-coverage plan.  Resolved BEFORE the mesh
            # dispatch so the jitted sharded entry is cached on the
            # concrete k.
            from repro.core import plan as _plan
            cfg = cfg.with_(k=_plan.auto_k(a, b, cfg), auto_k=False)
    if rhs_presplit is not None:
        _check_presplit(a, b.shape, cfg, rhs_presplit)
    if _obs.enabled():
        _record_emulation(cfg, a, b.shape[-1], rhs_presplit is not None)
    mesh = _mesh_for(cfg, a.shape[-1])
    if mesh is not None:
        return _bmm_sharded(a, b, cfg, mesh, rhs_presplit)
    return _bmm_local(a, b, cfg.local(), rhs_presplit=rhs_presplit)


# ---------------------------------------------------------------------------
# general dot_general: canonicalization + implementation
# ---------------------------------------------------------------------------

def _canonicalize_dnums(dimension_numbers) -> DimensionNumbers:
    """Nested tuples (hashable: dimension_numbers is a nondiff VJP arg)."""
    (ac, bc), (ab, bb) = dimension_numbers
    return ((tuple(map(int, ac)), tuple(map(int, bc))),
            (tuple(map(int, ab)), tuple(map(int, bb))))


def _remaining(ndim: int, *exclude: Sequence[int]):
    ex = set()
    for e in exclude:
        ex.update(e)
    return [i for i in range(ndim) if i not in ex]


def _ranges_like(*seqs):
    start = 0
    out = []
    for s in seqs:
        out.append(list(range(start, start + len(s))))
        start += len(s)
    return out


def _argsort(seq):
    return sorted(range(len(seq)), key=seq.__getitem__)


def canonical_rhs(b: jax.Array, dnums: DimensionNumbers):
    """The rhs of ``dot_general(a, b, dnums)`` in the canonical batched
    layout ``(*batch, n, p)`` the emulation contracts, plus the total
    contraction length n.  This is the exact transpose/reshape
    ``_dot_general_impl`` performs — the layout a frozen B-side Split
    (``repro.core.split_cache``) must be computed against."""
    (_, bc), (_, bb) = dnums
    b_free = _remaining(b.ndim, bc, bb)
    batch_shape = tuple(b.shape[i] for i in bb)
    n = math.prod(b.shape[i] for i in bc)
    p = math.prod(b.shape[i] for i in b_free)
    b3 = jnp.transpose(b, list(bb) + list(bc) + b_free).reshape(
        batch_shape + (n, p))
    return b3, n


def _dot_general_impl(a: jax.Array, b: jax.Array,
                      dnums: DimensionNumbers, cfg: OzimmuConfig,
                      rhs_presplit: Optional[splitting.Split] = None
                      ) -> jax.Array:
    """Normalize to the canonical batched form and run the emulation.

    Layout convention matches ``jax.lax.dot_general``: output is
    (*batch [lhs order], *lhs free [ascending], *rhs free [ascending]).
    Multiple contraction axes are flattened into one inner dimension (beta /
    r are computed from the TOTAL contraction length, so the INT32
    no-overflow guarantees still hold); free axes flatten into m / p and are
    restored afterwards — batch axes are never flattened away.

    With ``rhs_presplit`` the canonical ``b3`` is only used for static
    shape checks and the emulation consumes the frozen digits instead (the
    transpose/reshape of ``b`` is dead code XLA eliminates).
    """
    (ac, bc), (ab, bb) = dnums
    if len(ac) != len(bc) or len(ab) != len(bb):
        raise ValueError(f"mismatched dimension numbers {dnums}")
    for i, j in zip(ac, bc):
        if a.shape[i] != b.shape[j]:
            raise ValueError(
                f"contraction size mismatch {a.shape} @ {b.shape}: {dnums}")
    for i, j in zip(ab, bb):
        if a.shape[i] != b.shape[j]:
            raise ValueError(
                f"batch size mismatch {a.shape} @ {b.shape}: {dnums}")
    a_free = _remaining(a.ndim, ac, ab)
    batch_shape = tuple(a.shape[i] for i in ab)
    m_shape = tuple(a.shape[i] for i in a_free)
    p_shape = tuple(b.shape[i] for i in _remaining(b.ndim, bc, bb))
    m = math.prod(m_shape)
    n = math.prod(a.shape[i] for i in ac)
    # (*batch, m, n) with contraction axes in pairing order (ac[i] <-> bc[i])
    a3 = jnp.transpose(a, list(ab) + a_free + list(ac)).reshape(
        batch_shape + (m, n))
    b3, _ = canonical_rhs(b, dnums)
    out = _bmm_impl(a3, b3, cfg, rhs_presplit=rhs_presplit)
    return out.reshape(batch_shape + m_shape + p_shape)


# ---------------------------------------------------------------------------
# custom VJP against general dimension numbers
# ---------------------------------------------------------------------------

def _transpose_operand(g, other, target_ndim: int, dnums: DimensionNumbers,
                       cfg: OzimmuConfig, swap_ans: bool):
    """Cotangent of the lhs of ``dot_general(x, y, dnums)`` (mirror of
    jax._src.lax's ``_dot_general_transpose_lhs``, with the contraction
    itself emulated).  For the rhs cotangent, call with the roles of x and y
    swapped in ``dnums`` and ``swap_ans=True``."""
    (xc, yc), (xb, yb) = dnums
    x_kept = _remaining(target_ndim, xc, xb)
    y_kept = _remaining(other.ndim, yc, yb)
    if swap_ans:
        g_batch, g_y_kept, _ = _ranges_like(xb, y_kept, x_kept)
    else:
        g_batch, _, g_y_kept = _ranges_like(xb, x_kept, y_kept)
    dims = ((tuple(g_y_kept), tuple(y_kept)), (tuple(g_batch), tuple(yb)))
    dx = _dot_general_impl(g, other, _canonicalize_dnums(dims), cfg)
    xc_sorted_by_yc = [xc[i] for i in _argsort(yc)]
    out_axes = _argsort(list(xb) + x_kept + xc_sorted_by_yc)
    return jnp.transpose(dx, out_axes)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _oz_dot_general(a: jax.Array, b: jax.Array, dnums: DimensionNumbers,
                    cfg: OzimmuConfig) -> jax.Array:
    return _dot_general_impl(a, b, dnums, cfg)


def _fwd(a, b, dnums, cfg):
    return _dot_general_impl(a, b, dnums, cfg), (a, b)


def _bwd(dnums, cfg, res, g):
    a, b = res
    (ac, bc), (ab, bb) = dnums
    # Cotangents through the same emulated contraction (transposed dims are
    # free re-slices; no precision leaves the scheme).
    da = _transpose_operand(g, b, a.ndim, dnums, cfg, swap_ans=False)
    db = _transpose_operand(g, a, b.ndim, ((bc, ac), (bb, ab)), cfg,
                            swap_ans=True)
    return da, db


_oz_dot_general.defvjp(_fwd, _bwd)


# --- presplit variant: B's frozen Split rides along as a (nondifferentiable)
# pytree of arrays.  The cotangent contractions re-slice transposed operands
# under different dimension numbers, so the frozen B split never applies to
# the backward pass — both cotangents run the regular emulation, identical
# to `_bwd` above.

def _rebuild_split(arrays, beta: int, cfg: OzimmuConfig) -> splitting.Split:
    digits, scale, base, gbase = arrays
    return splitting.Split(digits, scale, base, beta, 1, gbase=gbase,
                           signmag=splitting.is_signmag(cfg.split))


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _oz_dot_general_presplit(a, b, presplit_arrays, dnums, cfg, beta):
    return _dot_general_impl(a, b, dnums, cfg,
                             rhs_presplit=_rebuild_split(presplit_arrays,
                                                         beta, cfg))


def _presplit_fwd(a, b, presplit_arrays, dnums, cfg, beta):
    out = _dot_general_impl(a, b, dnums, cfg,
                            rhs_presplit=_rebuild_split(presplit_arrays,
                                                        beta, cfg))
    return out, (a, b, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), presplit_arrays))


def _zero_cotangent(aval):
    import numpy as np
    if jnp.issubdtype(aval.dtype, jnp.floating):
        return jnp.zeros(aval.shape, aval.dtype)
    return np.zeros(aval.shape, jax.dtypes.float0)  # int digits


def _presplit_bwd(dnums, cfg, beta, res, g):
    a, b, presplit_avals = res
    (ac, bc), (ab, bb) = dnums
    da = _transpose_operand(g, b, a.ndim, dnums, cfg, swap_ans=False)
    db = _transpose_operand(g, a, b.ndim, ((bc, ac), (bb, ab)), cfg,
                            swap_ans=True)
    return da, db, jax.tree.map(_zero_cotangent, presplit_avals)


_oz_dot_general_presplit.defvjp(_presplit_fwd, _presplit_bwd)


def ozimmu_dot_general(a: jax.Array, b: jax.Array, dimension_numbers,
                       cfg: OzimmuConfig = VARIANTS["ozimmu_h"],
                       rhs_presplit: Optional[splitting.Split] = None
                       ) -> jax.Array:
    """Emulated ``jax.lax.dot_general`` via k-slice INT8 GEMMs.

    ``dimension_numbers`` is the standard lax contract,
    ``((lhs_contract, rhs_contract), (lhs_batch, rhs_batch))``; the output
    layout is lax's (batch dims, lhs free dims, rhs free dims).  Batch
    dimensions are carried natively through splitting (per-batch row/col
    scales) and the int8 ``dot_general``s.  Differentiable: the custom VJP
    evaluates both cotangents with the same emulation under the transposed
    dimension numbers.

    ``rhs_presplit`` (serving fast path): a frozen column-scale
    :class:`~repro.core.splitting.Split` of the canonical rhs — from
    :class:`repro.core.split_cache.SplitCache` — makes the call skip the
    B-side splitter entirely, bit-identical to the uncached path (the
    splitter is deterministic; freezing merely hoists it).  The split
    must have been frozen for these exact dimension numbers, contraction
    length, spec, and compute dtype (checked statically).  Gradients
    still flow to both operands through the regular emulated cotangent
    contractions (the frozen split only accelerates the forward).

    Example — batched attention-score-like contraction::

        out = ozimmu_dot_general(q, k, (((2,), (2,)), ((0,), (0,))), cfg)
        # q (B, Lq, D), k (B, Lk, D)  ->  out (B, Lq, Lk)
    """
    dnums = _canonicalize_dnums(dimension_numbers)
    if rhs_presplit is None:
        return _oz_dot_general(a, b, dnums, cfg)
    sp = rhs_presplit
    # beta is a static property of the TOTAL contraction length (eq. 4) —
    # recomputed here rather than read off the Split because a Split
    # passed through a jit boundary carries its int fields as tracers.
    # SplitCache freezes with exactly this beta; a concrete mismatch is
    # rejected, a traced one is unobservable (same construction).
    beta = splitting.beta_for(cfg.split,
                              math.prod(b.shape[i] for i in dnums[0][1]))
    if isinstance(sp.signmag, bool) and \
            sp.signmag != splitting.is_signmag(cfg.split):
        raise ValueError(
            f"rhs_presplit signmag={sp.signmag} does not match the "
            f"config's split {cfg.split!r}; sign-magnitude digits decode "
            f"differently from signed digits — re-freeze under the "
            f"current spec")
    if isinstance(sp.beta, int) and sp.beta != beta:
        raise ValueError(f"rhs_presplit beta={sp.beta} disagrees with the "
                         f"contraction's beta={beta}")
    return _oz_dot_general_presplit(
        a, b, (sp.digits, sp.scale, sp.base, sp.gbase), dnums, cfg, beta)


def ozimmu_matmul(a: jax.Array, b: jax.Array,
                  cfg: OzimmuConfig = VARIANTS["ozimmu_h"]) -> jax.Array:
    """Emulated high-precision ``a @ b`` via k-slice INT8 GEMMs.

    a: (m, n), b: (n, p), both f32 or f64.  Returns (m, p) in a.dtype.
    The rank-2 special case of :func:`ozimmu_dot_general`.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} @ {b.shape}")
    return ozimmu_dot_general(a, b, (((1,), (0,)), ((), ())), cfg)
