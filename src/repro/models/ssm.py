"""Mamba2 (SSD — state-space duality form): mamba2-780m.

The SSD form computes the selective-state-space recurrence as *chunked
matmuls* (intra-chunk quadratic term + inter-chunk state carry), which is
what makes it MXU-friendly — and GEMM-dominated, so the Ozaki engine applies
to its projections like any dense layer.

Layer i/o contract matches the dense transformer so the train/serve steps
are shared: ``forward(params, cfg, tokens)`` and
``decode_step(params, cfg, cache, tokens, cur_len)`` with a *constant-size*
cache (conv window + SSM state) — this is the sub-quadratic family that runs
the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ModelConfig, dense_param, init_stacked, stack_axes


def _dims(cfg: ModelConfig):
    d_inner = cfg.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return d_inner, n_heads, cfg.ssm_headdim, cfg.d_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mamba_layer(rng, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N          # x, B, C all pass through the conv
    ks = jax.random.split(rng, 6)
    params = {
        # order: [z (gate), x, B, C, dt]
        "w_in": dense_param(ks[0], (d, 2 * d_inner + 2 * N + H)),
        "conv_w": dense_param(ks[1], (cfg.d_conv, conv_dim), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),    # A = -exp(A_log) < 0
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,)) * 3.0 - 4.6))),  # ~[1e-3,1e-1]
        "D": jnp.ones((H,)),
        "norm_w": jnp.zeros((d_inner,)),
        "w_out": dense_param(ks[3], (d_inner, d), scale=d_inner ** -0.5),
        "ln": jnp.zeros((d,)),
    }
    axes = {
        "w_in": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "A_log": ("heads",),
        "dt_bias": ("heads",),
        "D": ("heads",),
        "norm_w": ("mlp",),
        "w_out": ("mlp", "embed"),
        "ln": ("embed",),
    }
    return params, axes


def init(rng, cfg: ModelConfig):
    k_emb, k_layers = jax.random.split(rng)
    _, layer_ax = init_mamba_layer(k_layers, cfg)
    stacked = init_stacked(k_layers, cfg.n_layers,
                           lambda r: init_mamba_layer(r, cfg)[0])
    params = {
        "embed": dense_param(k_emb, (cfg.padded_vocab, cfg.d_model), scale=1.0),
        "layers": stacked,
        "ln_f": jnp.zeros((cfg.d_model,)),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "layers": stack_axes(layer_ax),
        "ln_f": ("embed",),
    }
    return params, axes


# ---------------------------------------------------------------------------
# SSD core — chunked scan (training / prefill)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD: y[t] = C[t] . h[t];  h[t] = exp(dt_t A) h[t-1] + dt_t B[t] (x) x[t].

    x:  (Bb, L, H, P)   per-head inputs
    dt: (Bb, L, H)      discretization steps (post-softplus), > 0
    A:  (H,)            negative per-head decay rates
    B:  (Bb, L, N)      input projections  (single group, shared across heads)
    C:  (Bb, L, N)      output projections
    Returns y: (Bb, L, H, P), final_state: (Bb, H, P, N).
    """
    Bb, Lq, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, Lq)
    nc = -(-Lq // Q)
    pad = nc * Q - Lq
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = B.reshape(Bb, nc, Q, N)
    Cc = C.reshape(Bb, nc, Q, N)

    dA = dtc * A  # (Bb, nc, Q, H), negative
    cum = jnp.cumsum(dA, axis=2)                       # l_q = sum_{s<=q} dt_s A
    seg_total = cum[:, :, -1, :]                       # (Bb, nc, H)

    # intra-chunk (the "quadratic attention" term of SSD):
    #   scores[b,c,h,q,s] = (C_q . B_s) * exp(l_q - l_s) * dt_s,  s <= q
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc,
                    preferred_element_type=jnp.float32)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,c,q,s,h)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, -jnp.inf)
    w = jnp.exp(decay) * dtc[:, :, None, :, :]              # (b,c,q,s,h)
    scores = cb[..., None] * w                               # (b,c,q,s,h)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", scores.astype(x.dtype), xc,
                         preferred_element_type=jnp.float32)

    # chunk input states: S_c = sum_s exp(l_Q - l_s) dt_s B_s (x) x_s
    w_state = jnp.exp(seg_total[:, :, None, :] - cum) * dtc  # (b,c,s,h)
    S = jnp.einsum("bcsh,bcsn,bcshp->bchpn",
                   w_state.astype(x.dtype), Bc.astype(x.dtype), xc,
                   preferred_element_type=jnp.float32)       # (b,c,h,p,n)

    # inter-chunk recurrence over c:  h_c_in = exp(seg_total) h_{c-1}_in + S_{c-1}
    def carry_fn(h, inputs):
        S_c, g_c = inputs  # state contribution of chunk c, total decay of c
        h_out = h
        h = h * jnp.exp(g_c)[:, :, None, None] + S_c
        return h, h_out    # h_out = state at *entry* of chunk c

    S_sw = jnp.moveaxis(S, 1, 0)                # (nc, b, h, p, n)
    g_sw = jnp.moveaxis(seg_total, 1, 0)        # (nc, b, h)
    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    h_final, h_entry = lax.scan(carry_fn, h0, (S_sw, g_sw))
    h_entry = jnp.moveaxis(h_entry, 0, 1)       # (b, nc, h, p, n)

    # inter-chunk output: y_inter[q] = exp(l_q) * C_q . h_entry
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc.astype(x.dtype),
                         h_entry.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(Bb, nc * Q, H, P)[:, :Lq]
    return y.astype(x.dtype), h_final


def ssd_step(x, dt, A, B, C, h):
    """Single-token SSD update.  x (Bb,H,P); dt (Bb,H); B,C (Bb,N);
    h (Bb,H,P,N) -> (y (Bb,H,P), h_new)."""
    dA = jnp.exp(dt * A)                                     # (Bb, H)
    dBx = jnp.einsum("bn,bhp->bhpn", B, x * dt[..., None])
    h = h * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", h, C)
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# the Mamba2 block
# ---------------------------------------------------------------------------

def _split_proj(z, cfg):
    d_inner, H, P, N = _dims(cfg)
    zs = jnp.split(z, [d_inner, 2 * d_inner, 2 * d_inner + N,
                       2 * d_inner + 2 * N], axis=-1)
    return zs  # gate, x, B, C, dt_raw


def mamba_block(p, cfg: ModelConfig, u, *, conv_state=None, ssm_state=None):
    """u (Bb, L, d).  Full-sequence when states are None; single-step (L==1)
    decode otherwise.  Returns (out, new_conv_state, new_ssm_state)."""
    eng = cfg.engine
    d_inner, H, P, N = _dims(cfg)
    Bb, Lq, _ = u.shape
    un = L.rmsnorm(u, p["ln"], cfg.norm_eps)
    proj = eng(un, p["w_in"])
    gate, xbc_x, Bp, Cp, dt_raw = _split_proj(proj, cfg)
    xbc = jnp.concatenate([xbc_x, Bp, Cp], axis=-1)          # conv channels
    conv_w = p["conv_w"].astype(xbc.dtype)                   # (d_conv, conv_dim)

    new_conv = None
    if conv_state is None:
        # causal depthwise conv via shifted adds (d_conv is tiny, typ. 4)
        acc = xbc * conv_w[-1]
        for i in range(cfg.d_conv - 1):
            shift = cfg.d_conv - 1 - i
            acc = acc + jnp.pad(xbc, ((0, 0), (shift, 0), (0, 0))
                                )[:, :Lq] * conv_w[i]
        xbc = jax.nn.silu(acc + p["conv_b"].astype(acc.dtype))
    else:
        # conv_state: (Bb, d_conv-1, conv_dim) of past inputs
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (Bb, d_conv, C)
        acc = jnp.einsum("btc,tc->bc", window, conv_w)[:, None]
        xbc = jax.nn.silu(acc + p["conv_b"].astype(acc.dtype))
        new_conv = window[:, 1:]

    x, Bp, Cp = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(Bb, Lq, H, P)
    x = shard(x, "batch", "seq", "heads", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if ssm_state is None:
        y, h_final = ssd_chunked(x, dt, A, Bp.astype(x.dtype),
                                 Cp.astype(x.dtype), cfg.chunk)
    else:
        y1, h_final = ssd_step(x[:, 0], dt[:, 0], A,
                               Bp[:, 0].astype(x.dtype),
                               Cp[:, 0].astype(x.dtype), ssm_state)
        y = y1[:, None]
    y = y + x * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bb, Lq, d_inner)
    # gated RMSNorm (mamba2's norm-before-out with silu gate)
    y = L.rmsnorm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(gate)
    y = shard(y, "batch", "seq", "mlp")
    out = eng(y, p["w_out"])
    return u + out, new_conv, h_final


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: jax.Array,
            positions=None) -> jax.Array:
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)

    def body(lp, x, _):
        x, _, _ = mamba_block(lp, cfg, x)
        return x, None

    x, _ = T.scan_layers(body, params["layers"], x, n_layers=cfg.n_layers,
                         remat_block=cfg.remat_block)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    # tied embedding head
    return L.logits_head(x, params["embed"].T, cfg.engine)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Constant-size state: conv window + SSM state per layer."""
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    conv = jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, conv_dim),
                     jnp.bfloat16)
    ssm = jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32)
    conv = shard(conv, "layers", "cache_batch", None, "mlp")
    ssm = shard(ssm, "layers", "cache_batch", "heads", None, None)
    return {"conv": conv, "ssm": ssm}


def cache_axes(cfg: ModelConfig):
    return {"conv": ("layers", "cache_batch", None, "mlp"),
            "ssm": ("layers", "cache_batch", "heads", None, None)}


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                cur_len: jax.Array):
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)

    def body(x, inputs):
        lp, conv, ssm = inputs
        x, conv_n, ssm_n = mamba_block(lp, cfg, x, conv_state=conv.astype(x.dtype),
                                       ssm_state=ssm)
        return x, (conv_n.astype(conv.dtype), ssm_n)

    x, (conv_n, ssm_n) = lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]),
        length=cfg.n_layers)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = L.logits_head(x, params["embed"].T, cfg.engine)
    return logits, {"conv": conv_n, "ssm": ssm_n}
