"""Layer library: norms, RoPE, GQA/flash attention, MLPs, embeddings.

All contractions route through ``cfg.engine`` (MatmulEngine), so any layer
can run its GEMMs through the paper's INT8 Ozaki emulation via
``--matmul_engine ozimmu_h-8:df32`` etc.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard

NEG_INF = -1e30


def _edot(engine, lhs, rhs, dimension_numbers, out_dtype=None):
    """Batched contraction for the attention blocks.  With an ozimmu engine
    the score/output GEMMs (and their cotangents) run inside the INT8
    emulation as native batched ``dot_general``s.  For native specs — and
    for ``engine=None`` library use — this stays a plain lax.dot_general,
    bit-identical to the einsums it replaced: attention keeps its own
    mixed-precision discipline (f32 scores/probabilities feeding the online
    softmax and its backward), which an engine-dtype cast would truncate."""
    if engine is None or not engine.is_ozimmu:
        return lax.dot_general(lhs, rhs, dimension_numbers,
                               preferred_element_type=out_dtype)
    return engine.dot_general(lhs, rhs, dimension_numbers,
                              out_dtype=out_dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """positions (..., L) int32 -> cos/sin (..., L, dim//2) f32."""
    freqs = theta ** (-jnp.arange(0, dim // 2, dtype=jnp.float32) / (dim // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, L, H, D); cos/sin (B, L, D/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _scores_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(..., Lq, Lk) bool mask from absolute positions."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def attention_flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    q_offset: int = 0, engine=None) -> jax.Array:
    """Chunked online-softmax (flash-style) GQA attention, pure JAX.

    q: (B, Lq, H, D); k, v: (B, Lk, KV, D/Dv) with H % KV == 0 (Dv may
    differ from D, e.g. MLA).  Memory: O(q_chunk * kv_chunk) score blocks
    instead of O(Lq * Lk) — in BOTH directions: the backward is a custom
    VJP that recomputes score blocks (true flash backward).  Without it,
    autodiff of the forward scan stacks per-block probability matrices as
    scan residuals — the full O(L^2) attention matrix in f32 (measured:
    4.3 GB/device/remat-block for the internlm2 train_4k cell).

    ``engine`` (a MatmulEngine, hashable, nondiff) routes the score and
    output contractions — forward AND the recomputed backward blocks —
    through ``engine.dot_general`` as batched-over-(B, KV) contractions.
    """
    return _flash(q, k, v, engine, bool(causal), window, int(q_chunk),
                  int(kv_chunk), int(q_offset))


def _flash_dims(q, k, v, q_chunk, kv_chunk):
    B, Lq, H, D = q.shape
    _, Lk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    qc = min(q_chunk, Lq)
    kc = min(kv_chunk, Lk)
    nq, nk = -(-Lq // qc), -(-Lk // kc)
    return B, Lq, H, D, Lk, KV, Dv, G, qc, kc, nq, nk


def _flash_fwd_impl(q, k, v, engine, causal, window, q_chunk, kv_chunk,
                    q_offset):
    B, Lq, H, D, Lk, KV, Dv, G, qc, kc, nq, nk = _flash_dims(
        q, k, v, q_chunk, kv_chunk)
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    scale = D ** -0.5
    qg = q.reshape(B, nq, qc, KV, G, D)
    kg = k.reshape(B, nk, kc, KV, D)
    vg = v.reshape(B, nk, kc, KV, Dv)

    def q_body(_, qi):
        qblk = qg[:, qi] * scale  # (B, qc, KV, G, D)
        q_pos = qi * qc + jnp.arange(qc) + q_offset

        def kv_body(carry, ki):
            m_run, l_run, acc = carry
            kblk = kg[:, ki]
            vblk = vg[:, ki]
            k_pos = ki * kc + jnp.arange(kc)
            # scores: einsum "bqkgd,bskd->bkgqs" as a (B, KV)-batched
            # dot_general (contract d) so an ozimmu engine can emulate it
            s = _edot(engine, qblk, kblk, (((4,), (3,)), ((0, 2), (0, 2))),
                      out_dtype=jnp.float32).transpose(0, 1, 3, 2, 4)
            mask = _scores_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < Lk)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            # output: einsum "bkgqs,bskd->bkgqd" (contract s)
            pv = _edot(engine, p.astype(v.dtype), vblk,
                       (((4,), (1,)), ((0, 1), (0, 2))),
                       out_dtype=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        init = (jnp.full((B, KV, G, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, qc), jnp.float32),
                jnp.zeros((B, KV, G, qc, Dv), jnp.float32))
        (m_run, l_run, acc), _ = lax.scan(kv_body, init, jnp.arange(nk))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        # logsumexp per row; +inf on fully-masked (padding) rows so that
        # exp(s - lse) == 0 during backward recomputation
        lse = jnp.where(l_run > 0,
                        m_run + jnp.log(jnp.maximum(l_run, 1e-30)), jnp.inf)
        return None, (out, lse)  # (B, KV, G, qc, Dv), (B, KV, G, qc)

    _, (outs, lses) = lax.scan(q_body, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qc, H, Dv)
    return out[:, :Lq].astype(q.dtype), (outs, lses)


def _flash_bwd_impl(q, k, v, outs, lses, dout, engine, causal, window,
                    q_chunk, kv_chunk, q_offset):
    """True flash backward: recompute p blockwise; never materialize L^2."""
    B, Lq, H, D, Lk, KV, Dv, G, qc, kc, nq, nk = _flash_dims(
        q, k, v, q_chunk, kv_chunk)
    q_pad = jnp.pad(q, ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    k_pad = jnp.pad(k, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (0, nk * kc - Lk), (0, 0), (0, 0)))
    dout = jnp.pad(dout.astype(jnp.float32),
                   ((0, 0), (0, nq * qc - Lq), (0, 0), (0, 0)))
    scale = D ** -0.5
    qg = q_pad.reshape(B, nq, qc, KV, G, D)
    kg = k_pad.reshape(B, nk, kc, KV, D)
    vg = v_pad.reshape(B, nk, kc, KV, Dv)
    # dout in (nq, B, KV, G, qc, Dv) to match outs/lses block layout
    dg = dout.reshape(B, nq, qc, KV, G, Dv).transpose(1, 0, 3, 4, 2, 5)
    # delta_i = rowsum(dout_i * out_i): (nq, B, KV, G, qc)
    delta = jnp.einsum("nbkgqd,nbkgqd->nbkgq", dg, outs)

    def kv_outer(dq_acc, ki):
        kblk = kg[:, ki]                       # (B, kc, KV, D)
        vblk = vg[:, ki]                       # (B, kc, KV, Dv)
        k_pos = ki * kc + jnp.arange(kc)

        def q_inner(carry, qi):
            dq_acc, dk_blk, dv_blk = carry
            qblk = qg[:, qi] * scale           # (B, qc, KV, G, D)
            q_pos = qi * qc + jnp.arange(qc) + q_offset
            # recomputed scores (same contraction as forward)
            s = _edot(engine, qblk, kblk, (((4,), (3,)), ((0, 2), (0, 2))),
                      out_dtype=jnp.float32).transpose(0, 1, 3, 2, 4)
            mask = _scores_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < Lk)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lses[qi][..., None])            # (B,KV,G,qc,kc)
            do_blk = dg[qi]                                 # (B,KV,G,qc,Dv)
            # dv: einsum "bkgqs,bkgqd->bskd" (contract g, q)
            dv_blk = dv_blk + _edot(
                engine, p, do_blk, (((2, 3), (2, 3)), ((0, 1), (0, 1))),
                out_dtype=jnp.float32).transpose(0, 2, 1, 3)
            # dp: einsum "bkgqd,bskd->bkgqs" (contract d)
            dp = _edot(engine, do_blk, vblk.astype(jnp.float32),
                       (((4,), (3,)), ((0, 1), (0, 2))),
                       out_dtype=jnp.float32)
            ds = p * (dp - delta[qi][..., None])            # (B,KV,G,qc,kc)
            # dq: einsum "bkgqs,bskd->bqkgd" (contract s)
            dq_blk = _edot(engine, ds, kblk.astype(jnp.float32),
                           (((4,), (1,)), ((0, 1), (0, 2))),
                           out_dtype=jnp.float32
                           ).transpose(0, 3, 1, 2, 4) * scale
            dq_acc = dq_acc.at[:, qi].add(dq_blk)
            # dk: einsum "bkgqs,bqkgd->bskd" (contract g, q);
            # qblk already carries `scale`, so dk needs no extra factor
            dk_blk = dk_blk + _edot(
                engine, ds, qblk.astype(jnp.float32),
                (((2, 3), (3, 1)), ((0, 1), (0, 2))),
                out_dtype=jnp.float32).transpose(0, 2, 1, 3)
            return (dq_acc, dk_blk, dv_blk), None

        init = (dq_acc,
                jnp.zeros((B, kc, KV, D), jnp.float32),
                jnp.zeros((B, kc, KV, Dv), jnp.float32))
        (dq_acc, dk_blk, dv_blk), _ = lax.scan(q_inner, init, jnp.arange(nq))
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, nq, qc, KV, G, D), jnp.float32)
    dq_acc, (dks, dvs) = lax.scan(kv_outer, dq0, jnp.arange(nk))
    dq = dq_acc.reshape(B, nq * qc, H, D)[:, :Lq]
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, KV, D)[:, :Lk]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, KV, Dv)[:, :Lk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, engine, causal, window, q_chunk, kv_chunk, q_offset):
    return _flash_fwd_impl(q, k, v, engine, causal, window, q_chunk,
                           kv_chunk, q_offset)[0]


def _flash_fwd_rule(q, k, v, engine, causal, window, q_chunk, kv_chunk,
                    q_offset):
    out, (outs, lses) = _flash_fwd_impl(q, k, v, engine, causal, window,
                                        q_chunk, kv_chunk, q_offset)
    return out, (q, k, v, outs, lses)


def _flash_bwd_rule(engine, causal, window, q_chunk, kv_chunk, q_offset,
                    res, dout):
    q, k, v, outs, lses = res
    return _flash_bwd_impl(q, k, v, outs, lses, dout, engine, causal,
                           window, q_chunk, kv_chunk, q_offset)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def decode_positions(cur_len, batch: int) -> jax.Array:
    """(B, 1) absolute position ``cur_len - 1`` of the token being decoded.

    ``cur_len`` is ``()`` (all slots in lock-step — the pre-serving
    contract) or ``(B,)`` (continuous batching: every slot at its own
    sequence position)."""
    c = (jnp.asarray(cur_len) - 1).astype(jnp.int32)
    if c.ndim == 0:
        return jnp.broadcast_to(c, (batch, 1))
    return c[:, None]


def ring_row_index(cur_len, cache_len: int):
    """Cache row a decode step at sequence position ``cur_len`` writes:
    ``(cur_len - 1) mod cache_len`` (the ring wrap covers windowed
    caches whose buffer is shorter than the sequence).  The single
    source of truth shared by :func:`cache_update_row` and the paged
    pool's row scatter (``repro.serving.kvcache.PagedKV``) — the two
    must agree or a paged write lands in the wrong block."""
    return (jnp.asarray(cur_len) - 1) % cache_len


def cache_update_row(buf: jax.Array, new: jax.Array, cur_len) -> jax.Array:
    """Write the decode-step row at position ``(cur_len - 1) mod L`` of a
    per-slot cache buffer.

    ``buf`` (B, L, ...); ``new`` (B, 1, ...); ``cur_len`` ``()`` or
    ``(B,)``.  The scalar form keeps the original
    ``dynamic_update_slice`` (one shared index); the vector form scatters
    one row per slot — an identical single-row replace, so the two forms
    are bitwise-equal when every slot shares a position.

    Vector slots with ``cur_len == 0`` are NO-OPS (the old row value is
    written back).  The serving runtime uses 0 for slots that are idle or
    not yet started inside a right-aligned prefill scan; without the
    guard their garbage k/v would land in row L-1 — harmless for per-row
    split scales (the row stays masked) but fatal under the oz2 GLOBAL
    digit grid, where one garbage row can shift every entry's scale."""
    c = jnp.asarray(cur_len)
    idx = ring_row_index(c, buf.shape[1])
    new = new.astype(buf.dtype)
    if c.ndim == 0:
        return lax.dynamic_update_slice_in_dim(buf, new, idx, axis=1)
    b_idx = jnp.arange(buf.shape[0])
    old = buf[b_idx, idx]
    live = (c > 0).reshape((-1,) + (1,) * (new.ndim - 2))
    return buf.at[b_idx, idx].set(jnp.where(live, new[:, 0], old))


def attention_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, *, window: Optional[int] = None,
                     engine=None) -> jax.Array:
    """Single-position attention against a (B, Lmax, KV, D) cache.

    q: (B, 1, H, D); cur_len: () or (B,) — number of valid cache positions
    INCLUDING the current token (already written at cur_len - 1).  The
    score and output contractions are (B, KV)-batched dot_generals routed
    through ``engine`` when given (ozimmu emulation at decode time).
    """
    B, _, H, D = q.shape
    Lmax, KV = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // KV
    qg = (q * D ** -0.5).reshape(B, KV, G, D)
    # scores: einsum "bkgd,bskd->bkgs" (contract d)
    s = _edot(engine, qg, k_cache, (((3,), (3,)), ((0, 1), (0, 2))),
              out_dtype=jnp.float32)
    pos = jnp.arange(Lmax)
    cur = jnp.asarray(cur_len)
    cur = cur[:, None] if cur.ndim == 1 else cur[None, None]
    valid = pos[None, :] < cur                      # (B or 1, Lmax)
    if window is not None:
        valid &= pos[None, :] >= cur - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # output: einsum "bkgs,bskd->bkgd" (contract s)
    out = _edot(engine, p.astype(v_cache.dtype), v_cache,
                (((3,), (1,)), ((0, 1), (0, 2))), out_dtype=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# projections / MLPs / embeddings
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down, engine):
    # layout hints for GSPMD only: "embed"/"mlp" are unsharded in the
    # default rules, and the mesh-native engine path triggers on mesh
    # presence + contraction divisibility, never on these annotations —
    # they exist so per-arch rule overrides CAN place the activations
    # without resharding churn around the engine's shard_map boundary
    x = shard(x, "batch", "seq", "embed")
    h = jax.nn.silu(engine(x, w_gate)) * engine(x, w_up)
    h = shard(h, "batch", "seq", "mlp")
    return engine(h, w_down)


def gelu_mlp(x, w_up, w_down, engine):
    x = shard(x, "batch", "seq", "embed")
    h = jax.nn.gelu(engine(x, w_up))
    h = shard(h, "batch", "seq", "mlp")
    return engine(h, w_down)


def embed_tokens(tokens: jax.Array, emb: jax.Array, dtype) -> jax.Array:
    out = jnp.take(emb, tokens, axis=0).astype(dtype)
    return shard(out, "batch", "seq", "embed")


def logits_head(x: jax.Array, emb_or_w: jax.Array, engine) -> jax.Array:
    """x (B, L, d) @ W (d, vocab) -> f32 logits, vocab-sharded."""
    x = shard(x, "batch", "seq", "embed")
    out = engine(x, emb_or_w).astype(jnp.float32)
    return shard(out, "batch", "seq", "vocab")
