"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local (sliding
window) attention, pattern (R, R, A) — recurrentgemma-9b.

Layers come in two types, so the stack is scanned over homogeneous *pattern
blocks* (each holding 2 stacked recurrent layers + 1 attention layer); the
remainder layers (38 = 12*3 + 2) form an unrolled tail.  Like the SSM, the
recurrent state is constant-size, so this family runs ``long_500k``.

RG-LRU recurrence (Griffin eq. 4-6):
    r_t = sigmoid(W_a x_t + b_a)             # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)             # input gate
    a_t = exp(c * softplus(Lambda) * (-r_t)) # in (0, 1), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Computed with an associative scan over the diagonal linear recurrence
(log-space coefficients for stability at 500k steps).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ModelConfig, dense_param, init_stacked, stack_axes

_LRU_C = 8.0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_recurrent_layer(rng, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(rng, 6)
    # Lambda init so a^c in [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[4], (w,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _LRU_C)))  # softplus^-1
    params = {
        "w_x": dense_param(ks[0], (d, w)),           # conv branch in-proj
        "w_gate": dense_param(ks[1], (d, w)),        # gate branch (GeLU)
        "conv_w": dense_param(ks[2], (4, w), scale=0.5),
        "conv_b": jnp.zeros((w,)),
        "lru_a": dense_param(ks[3], (w, w), scale=w ** -0.5),  # W_a (diag-ish)
        "lru_a_b": jnp.zeros((w,)),
        "lru_x_b": jnp.zeros((w,)),
        "lambda": lam,
        "w_out": dense_param(ks[5], (w, d), scale=w ** -0.5),
        "ln": jnp.zeros((d,)),
    }
    axes = {
        "w_x": ("embed", "mlp"), "w_gate": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"), "conv_b": ("mlp",),
        "lru_a": ("mlp", None), "lru_a_b": ("mlp",), "lru_x_b": ("mlp",),
        "lambda": ("mlp",),
        "w_out": ("mlp", "embed"), "ln": ("embed",),
    }
    return params, axes


def init_block(rng, cfg: ModelConfig):
    """One pattern block: the R-layers (stacked) + one attention layer, each
    followed by its MLP."""
    n_r = sum(1 for c in cfg.pattern if c == "R")
    ks = jax.random.split(rng, 4)
    _, r_ax = init_recurrent_layer(ks[0], cfg)
    r_stack = init_stacked(ks[0], n_r, lambda r: init_recurrent_layer(r, cfg)[0])
    r_mlp_stack = init_stacked(ks[1], n_r, lambda r: _init_mlp_with_ln(r, cfg)[0])
    _, mlp_ax = _init_mlp_with_ln(ks[1], cfg)
    attn, attn_ax = T.init_dense_layer(ks[2], cfg)
    params = {"r_layers": r_stack, "r_mlps": r_mlp_stack, "attn_layer": attn}
    axes = {"r_layers": stack_axes(r_ax), "r_mlps": stack_axes(mlp_ax),
            "attn_layer": attn_ax}
    return params, axes


def _init_mlp_with_ln(rng, cfg):
    mlp, mlp_ax = T.init_mlp(rng, cfg)
    return ({"mlp": mlp, "ln2": jnp.zeros((cfg.d_model,))},
            {"mlp": mlp_ax, "ln2": ("embed",)})


def init(rng, cfg: ModelConfig):
    k_emb, k_blocks, k_tail = jax.random.split(rng, 3)
    _, block_ax = init_block(k_blocks, cfg)
    nb = cfg.n_pattern_blocks
    blocks = init_stacked(k_blocks, nb, lambda r: init_block(r, cfg)[0])
    # tail: remaining R layers (with MLPs)
    n_tail = cfg.n_tail_layers
    _, r_ax = init_recurrent_layer(k_tail, cfg)
    _, m_ax = _init_mlp_with_ln(k_tail, cfg)
    tail_r = init_stacked(k_tail, max(n_tail, 1),
                          lambda r: init_recurrent_layer(r, cfg)[0])
    tail_m = init_stacked(k_tail, max(n_tail, 1),
                          lambda r: _init_mlp_with_ln(r, cfg)[0])
    params = {
        "embed": dense_param(k_emb, (cfg.padded_vocab, cfg.d_model), scale=1.0),
        "blocks": blocks,
        "tail_r": tail_r, "tail_m": tail_m,
        "ln_f": jnp.zeros((cfg.d_model,)),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "blocks": stack_axes(block_ax),
        "tail_r": stack_axes(r_ax), "tail_m": stack_axes(m_ax),
        "ln_f": ("embed",),
    }
    return params, axes


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _lru_coeffs(p, x):
    """Per-step log-decay and input; x (Bb, L, w) -> (log_a, v) both f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["lru_a"].astype(jnp.float32) +
                       p["lru_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf + p["lru_x_b"].astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    v = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xf)
    return log_a, v


def rg_lru(p, x, h0: Optional[jax.Array] = None):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + v_t via associative scan.

    x (Bb, L, w); h0 (Bb, w) or None.  Returns (h (Bb, L, w), h_last)."""
    log_a, v = _lru_coeffs(p, x)
    if h0 is not None:
        # fold the initial state into the first input
        v = v.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0.astype(jnp.float32))

    def combine(c1, c2):
        la1, v1 = c1
        la2, v2 = c2
        return la1 + la2, v1 * jnp.exp(la2) + v2

    la_all, h = lax.associative_scan(combine, (log_a, v), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(p, x1, h):
    """Single-step: x1 (Bb, w), h (Bb, w) -> (y, h_new)."""
    log_a, v = _lru_coeffs(p, x1[:, None])
    h_new = jnp.exp(log_a[:, 0]) * h.astype(jnp.float32) + v[:, 0]
    return h_new.astype(x1.dtype), h_new


def recurrent_block(p, cfg: ModelConfig, x, *, conv_state=None, lru_state=None):
    """Griffin recurrent block. Returns (out, new_conv, new_lru)."""
    eng = cfg.engine
    Bb, Lq, _ = x.shape
    xn = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    branch = eng(xn, p["w_x"])
    gate = jax.nn.gelu(eng(xn, p["w_gate"]))
    conv_w = p["conv_w"].astype(branch.dtype)
    new_conv = None
    if conv_state is None:
        acc = branch * conv_w[-1]
        for i in range(3):
            shift = 3 - i
            acc = acc + jnp.pad(branch, ((0, 0), (shift, 0), (0, 0))
                                )[:, :Lq] * conv_w[i]
        conv_out = acc + p["conv_b"].astype(acc.dtype)
        y, h_last = rg_lru(p, conv_out, lru_state)
        new_lru = h_last
    else:
        window = jnp.concatenate([conv_state, branch], axis=1)
        acc = jnp.einsum("btc,tc->bc", window, conv_w)
        conv_out = acc + p["conv_b"].astype(acc.dtype)
        y1, new_lru = rg_lru_step(p, conv_out, lru_state)
        y = y1[:, None]
        new_conv = window[:, 1:]
    y = shard(y * gate, "batch", "seq", "mlp")
    return x + eng(y, p["w_out"]), new_conv, new_lru


def _mlp(p, cfg, x):
    xn = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + L.gelu_mlp(xn, p["mlp"]["w_up"], p["mlp"]["w_down"], cfg.engine)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _block_fwd(bp, cfg, x, cos, sin, caches=None, cur_len=None):
    """One (R, R, A) pattern block.  caches: dict with 'conv' (n_r, ...),
    'lru' (n_r, ...), 'k'/'v' attention cache — or None for training."""
    n_r = sum(1 for c in cfg.pattern if c == "R")
    new_caches = {}
    for i in range(n_r):
        rp = jax.tree.map(lambda a: a[i], bp["r_layers"])
        mp = jax.tree.map(lambda a: a[i], bp["r_mlps"])
        conv = caches["conv"][i] if caches else None
        lru = caches["lru"][i] if caches else None
        x, conv_n, lru_n = recurrent_block(rp, cfg, x, conv_state=conv,
                                           lru_state=lru)
        x = _mlp(mp, cfg, x)
        if caches:
            new_caches.setdefault("conv", []).append(conv_n)
            new_caches.setdefault("lru", []).append(lru_n)
    attn_cache = (caches["k"], caches["v"]) if caches else None
    x, attn_new = T.attn_block(bp["attn_layer"], cfg, x, cos, sin,
                               cache=attn_cache, cur_len=cur_len,
                               window=cfg.window)
    x = _mlp({"mlp": bp["attn_layer"]["mlp"], "ln2": bp["attn_layer"]["ln2"]},
             cfg, x)
    if caches:
        new_caches = {"conv": jnp.stack(new_caches["conv"]),
                      "lru": jnp.stack(new_caches["lru"]),
                      "k": attn_new[0], "v": attn_new[1]}
    return x, new_caches or None


def forward(params, cfg: ModelConfig, tokens: jax.Array, positions=None):
    B, Lq = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Lq, dtype=jnp.int32), (B, Lq))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def body(bp, x, _):
        x, _ = _block_fwd(bp, cfg, x, cos, sin)
        return x, None

    x, _ = T.scan_layers(body, params["blocks"], x,
                         n_layers=cfg.n_pattern_blocks,
                         remat_block=cfg.remat_block)
    for i in range(cfg.n_tail_layers):
        rp = jax.tree.map(lambda a: a[i], params["tail_r"])
        mp = jax.tree.map(lambda a: a[i], params["tail_m"])
        x, _, _ = recurrent_block(rp, cfg, x)
        x = _mlp(mp, cfg, x)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["embed"].T, cfg.engine)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    w = cfg.lru_width or cfg.d_model
    n_r = sum(1 for c in cfg.pattern if c == "R")
    nb = cfg.n_pattern_blocks
    KV, hd = cfg.n_kv_heads, cfg.hd
    attn_len = min(max_len, cfg.window) if cfg.window else max_len
    cache = {
        "conv": shard(jnp.zeros((nb, n_r, batch, 3, w), jnp.bfloat16),
                      "layers", None, "cache_batch", None, "mlp"),
        "lru": shard(jnp.zeros((nb, n_r, batch, w), jnp.float32),
                     "layers", None, "cache_batch", "mlp"),
        "k": shard(jnp.zeros((nb, batch, attn_len, KV, hd), jnp.bfloat16),
                   "layers", "cache_batch", None, "cache_heads", "cache_hd"),
        "v": shard(jnp.zeros((nb, batch, attn_len, KV, hd), jnp.bfloat16),
                   "layers", "cache_batch", None, "cache_heads", "cache_hd"),
        "tail_conv": shard(jnp.zeros((max(cfg.n_tail_layers, 1), batch, 3, w),
                                     jnp.bfloat16),
                           "layers", "cache_batch", None, "mlp"),
        "tail_lru": shard(jnp.zeros((max(cfg.n_tail_layers, 1), batch, w),
                                    jnp.float32),
                          "layers", "cache_batch", "mlp"),
    }
    return cache


def cache_axes(cfg: ModelConfig):
    return {
        "conv": ("layers", None, "cache_batch", None, "mlp"),
        "lru": ("layers", None, "cache_batch", "mlp"),
        "k": ("layers", "cache_batch", None, "cache_heads", "cache_hd"),
        "v": ("layers", "cache_batch", None, "cache_heads", "cache_hd"),
        "tail_conv": ("layers", "cache_batch", None, "mlp"),
        "tail_lru": ("layers", "cache_batch", "mlp"),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                cur_len: jax.Array):
    B = tokens.shape[0]
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = L.decode_positions(cur_len, B)
    cos, sin = L.rope_cos_sin(pos, cfg.hd, cfg.rope_theta)

    def body(x, inputs):
        bp, bc = inputs
        x, nc = _block_fwd(bp, cfg, x, cos, sin, caches=bc, cur_len=cur_len)
        return x, nc

    block_caches = {k: cache[k] for k in ("conv", "lru", "k", "v")}
    x, new_bc = lax.scan(body, x, (params["blocks"], block_caches),
                         length=cfg.n_pattern_blocks)
    tail_conv, tail_lru = [], []
    for i in range(cfg.n_tail_layers):
        rp = jax.tree.map(lambda a: a[i], params["tail_r"])
        mp = jax.tree.map(lambda a: a[i], params["tail_m"])
        x, conv_n, lru_n = recurrent_block(
            rp, cfg, x, conv_state=cache["tail_conv"][i].astype(x.dtype),
            lru_state=cache["tail_lru"][i])
        x = _mlp(mp, cfg, x)
        tail_conv.append(conv_n.astype(jnp.bfloat16))
        tail_lru.append(lru_n)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = L.logits_head(x, params["embed"].T, cfg.engine)
    new_cache = dict(new_bc)
    new_cache["tail_conv"] = (jnp.stack(tail_conv) if tail_conv
                              else cache["tail_conv"])
    new_cache["tail_lru"] = (jnp.stack(tail_lru) if tail_lru
                             else cache["tail_lru"])
    return logits, new_cache
