"""Llama-3.2-Vision-style VLM backbone: a dense GQA decoder with gated
cross-attention layers interleaved every ``cross_every`` self-attention
layers (40 = 8 x (4 self + 1 cross) for the 11B config).

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, vision_seq, d_model); this module
consumes them as the cross-attention memory.  The stack is scanned over
homogeneous (self x cross_every-1, cross) groups.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ModelConfig, dense_param, init_stacked, stack_axes


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_cross_layer(rng, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 5)
    attn, attn_ax = T.init_attn(ks[0], cfg)
    mlp, mlp_ax = T.init_mlp(ks[1], cfg)
    params = {"attn": attn, "mlp": mlp,
              "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
              "ln_kv": jnp.zeros((d,)),
              "gate_attn": jnp.zeros(()), "gate_mlp": jnp.zeros(())}
    axes = {"attn": attn_ax, "mlp": mlp_ax,
            "ln1": ("embed",), "ln2": ("embed",), "ln_kv": ("embed",),
            "gate_attn": (), "gate_mlp": ()}
    return params, axes


def init_group(rng, cfg: ModelConfig):
    """cross_every-1 self layers + 1 cross layer."""
    n_self = cfg.cross_every - 1
    k1, k2 = jax.random.split(rng)
    _, self_ax = T.init_dense_layer(k1, cfg)
    selfs = init_stacked(k1, n_self, lambda r: T.init_dense_layer(r, cfg)[0])
    cross, cross_ax = init_cross_layer(k2, cfg)
    return ({"selfs": selfs, "cross": cross},
            {"selfs": stack_axes(self_ax), "cross": cross_ax})


def init(rng, cfg: ModelConfig):
    assert cfg.n_layers % cfg.cross_every == 0
    ng = cfg.n_layers // cfg.cross_every
    k_emb, k_g, k_head = jax.random.split(rng, 3)
    _, group_ax = init_group(k_g, cfg)
    groups = init_stacked(k_g, ng, lambda r: init_group(r, cfg)[0])
    params = {
        "embed": dense_param(k_emb, (cfg.padded_vocab, cfg.d_model), scale=1.0),
        "groups": groups,
        "ln_f": jnp.zeros((cfg.d_model,)),
        "lm_head": dense_param(k_head, (cfg.d_model, cfg.padded_vocab)),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "groups": stack_axes(group_ax),
        "ln_f": ("embed",),
        "lm_head": ("embed", "vocab"),
    }
    return params, axes


# ---------------------------------------------------------------------------
# cross-attention block
# ---------------------------------------------------------------------------

def cross_block(p, cfg: ModelConfig, x, memory, *, kv_cache=None):
    """Gated cross-attention against vision memory (B, Lv, d).

    kv_cache: optional precomputed (k, v) from the memory — used in decode
    so the image K/V projection runs once per request, not per token."""
    eng = cfg.engine
    B, Lq, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = eng(xn, p["attn"]["wq"]).reshape(B, Lq, H, hd)
    if kv_cache is None:
        mn = L.rmsnorm(memory, p["ln_kv"], cfg.norm_eps)
        Lv = memory.shape[1]
        k = eng(mn, p["attn"]["wk"]).reshape(B, Lv, KV, hd)
        v = eng(mn, p["attn"]["wv"]).reshape(B, Lv, KV, hd)
    else:
        k, v = kv_cache
    q = shard(q, "batch", "seq", "heads", "head_dim")
    out = L.attention_flash(q, k, v, causal=False,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                            engine=eng)
    out = eng(out.reshape(B, Lq, H * hd), p["attn"]["wo"])
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * out
    xn2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    mlp_out = L.swiglu(xn2, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], eng)
    return x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * mlp_out


def cross_kv(p, cfg: ModelConfig, memory):
    """Precompute cross K/V for decode."""
    eng = cfg.engine
    B, Lv, _ = memory.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    mn = L.rmsnorm(memory, p["ln_kv"], cfg.norm_eps)
    k = eng(mn, p["attn"]["wk"]).reshape(B, Lv, KV, hd)
    v = eng(mn, p["attn"]["wv"]).reshape(B, Lv, KV, hd)
    return k, v


# ---------------------------------------------------------------------------
# forward / decode
# ---------------------------------------------------------------------------

def _group_fwd(gp, cfg, x, cos, sin, memory, *, self_cache=None,
               cross_kv_cache=None, cur_len=None):
    n_self = cfg.cross_every - 1
    new_kv = None
    if self_cache is None:
        def body(lp, xc, _):
            xc, _ = T.dense_layer(lp, cfg, xc, cos, sin)
            return xc, None
        x, _ = T.scan_layers(body, gp["selfs"], x, n_layers=n_self)
    else:
        def body(xc, inputs):
            lp, kc, vc = inputs
            xc, kv = T.dense_layer(lp, cfg, xc, cos, sin, cache=(kc, vc),
                                   cur_len=cur_len)
            return xc, kv
        x, new_kv = lax.scan(body, x,
                             (gp["selfs"], self_cache[0], self_cache[1]),
                             length=n_self)
    x = cross_block(gp["cross"], cfg, x, memory, kv_cache=cross_kv_cache)
    return x, new_kv


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            image_embeds: jax.Array, positions=None):
    """tokens (B, L); image_embeds (B, vision_seq, d_model) — stub frontend."""
    B, Lq = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    memory = shard(image_embeds.astype(cfg.compute_dtype),
                   "batch", "seq", "embed")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Lq, dtype=jnp.int32), (B, Lq))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    ng = cfg.n_layers // cfg.cross_every

    def body(gp, x, _):
        x, _ = _group_fwd(gp, cfg, x, cos, sin, memory)
        return x, None

    x, _ = T.scan_layers(body, params["groups"], x, n_layers=ng,
                         remat_block=cfg.remat_block)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["lm_head"], cfg.engine)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               image_embeds: Optional[jax.Array] = None, params=None):
    """Self-attn KV ring buffers per group + precomputed cross K/V."""
    ng = cfg.n_layers // cfg.cross_every
    n_self = cfg.cross_every - 1
    KV, hd = cfg.n_kv_heads, cfg.hd
    shp = (ng, n_self, batch, max_len, KV, hd)
    cache = {
        "k": shard(jnp.zeros(shp, jnp.bfloat16),
                   "layers", None, "cache_batch", None, "cache_heads", "cache_hd"),
        "v": shard(jnp.zeros(shp, jnp.bfloat16),
                   "layers", None, "cache_batch", None, "cache_heads", "cache_hd"),
    }
    if image_embeds is not None:
        memory = image_embeds.astype(cfg.compute_dtype)
        def kv_of_group(gp):
            return cross_kv(gp["cross"], cfg, memory)
        ck, cv = jax.vmap(kv_of_group)(
            jax.tree.map(lambda a: a, params["groups"]))
    else:
        Lv = cfg.vision_seq
        ck = jnp.zeros((ng, batch, Lv, KV, hd), cfg.compute_dtype)
        cv = jnp.zeros((ng, batch, Lv, KV, hd), cfg.compute_dtype)
    cache["cross_k"] = shard(ck.astype(jnp.bfloat16), "layers", "cache_batch",
                             None, "cache_heads", "cache_hd")
    cache["cross_v"] = shard(cv.astype(jnp.bfloat16), "layers", "cache_batch",
                             None, "cache_heads", "cache_hd")
    return cache


def cache_axes(cfg: ModelConfig):
    return {
        "k": ("layers", None, "cache_batch", None, "cache_heads", "cache_hd"),
        "v": ("layers", None, "cache_batch", None, "cache_heads", "cache_hd"),
        "cross_k": ("layers", "cache_batch", None, "cache_heads", None),
        "cross_v": ("layers", "cache_batch", None, "cache_heads", None),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                cur_len: jax.Array):
    B = tokens.shape[0]
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = L.decode_positions(cur_len, B)
    cos, sin = L.rope_cos_sin(pos, cfg.hd, cfg.rope_theta)

    def body(x, inputs):
        gp, kc, vc, ck, cv = inputs
        x, new_kv = _group_fwd(gp, cfg, x, cos, sin, None,
                               self_cache=(kc, vc),
                               cross_kv_cache=(ck.astype(x.dtype),
                                               cv.astype(x.dtype)),
                               cur_len=cur_len)
        return x, new_kv

    ng = cfg.n_layers // cfg.cross_every
    x, (k_n, v_n) = lax.scan(
        body, x, (params["groups"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]), length=ng)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = L.logits_head(x, params["lm_head"], cfg.engine)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_n, v_n
    return logits, new_cache
