"""Dense decoder-only transformer (GQA + RoPE): starcoder2-3b, phi4-mini,
internlm2-1.8b, deepseek-7b — and the base machinery reused by the MoE, VLM,
enc-dec and hybrid families."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.common import ModelConfig, dense_param, init_stacked, stack_axes


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attn(rng, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    params = {
        "wq": dense_param(ks[0], (d, H * hd)),
        "wk": dense_param(ks[1], (d, KV * hd)),
        "wv": dense_param(ks[2], (d, KV * hd)),
        "wo": dense_param(ks[3], (H * hd, d), scale=(H * hd) ** -0.5),
    }
    axes = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    return params, axes


def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if getattr(cfg, "mlp_type", "swiglu") == "gelu":
        params = {"w_up": dense_param(ks[0], (d, f)),
                  "w_down": dense_param(ks[1], (f, d), scale=f ** -0.5)}
        axes = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    else:
        params = {"w_gate": dense_param(ks[0], (d, f)),
                  "w_up": dense_param(ks[1], (d, f)),
                  "w_down": dense_param(ks[2], (f, d), scale=f ** -0.5)}
        axes = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                "w_down": ("mlp", "embed")}
    return params, axes


def init_dense_layer(rng, cfg: ModelConfig):
    k1, k2 = jax.random.split(rng)
    attn, attn_ax = init_attn(k1, cfg)
    mlp, mlp_ax = init_mlp(k2, cfg)
    params = {"attn": attn, "mlp": mlp,
              "ln1": jnp.zeros((cfg.d_model,)), "ln2": jnp.zeros((cfg.d_model,))}
    axes = {"attn": attn_ax, "mlp": mlp_ax,
            "ln1": ("embed",), "ln2": ("embed",)}
    return params, axes


def init(rng, cfg: ModelConfig):
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    layer_p, layer_ax = init_dense_layer(k_layers, cfg)  # axes template
    stacked = init_stacked(k_layers, cfg.n_layers,
                           lambda r: init_dense_layer(r, cfg)[0])
    params = {
        "embed": dense_param(k_emb, (cfg.padded_vocab, cfg.d_model), scale=1.0),
        "layers": stacked,
        "ln_f": jnp.zeros((cfg.d_model,)),
        "lm_head": dense_param(k_head, (cfg.d_model, cfg.padded_vocab)),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "layers": stack_axes(layer_ax),
        "ln_f": ("embed",),
        "lm_head": ("embed", "vocab"),
    }
    return params, axes


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def attn_block(p, cfg: ModelConfig, x, cos, sin, *, cache=None, cur_len=None,
               window=None):
    """Pre-norm GQA attention. cache=(k, v) (B, Lmax, KV, hd) -> decode."""
    eng = cfg.engine
    B, Lq, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = eng(xn, p["attn"]["wq"]).reshape(B, Lq, H, hd)
    k = eng(xn, p["attn"]["wk"]).reshape(B, Lq, KV, hd)
    v = eng(xn, p["attn"]["wv"]).reshape(B, Lq, KV, hd)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    new_cache = None
    if cache is None:
        if cfg.expand_kv and KV < H:
            # replicate KV heads across their G-groups so the score blocks
            # shard over all H q-heads (model axis) instead of only KV
            k = jnp.repeat(k, H // KV, axis=2)
            v = jnp.repeat(v, H // KV, axis=2)
            k = shard(k, "batch", "seq", "heads", "head_dim")
            v = shard(v, "batch", "seq", "heads", "head_dim")
        out = L.attention_flash(q, k, v, causal=True, window=window,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                engine=eng)
    else:
        # The cache is sized min(max_len, window): for windowed attention it
        # is a ring buffer (slot = (pos) mod window); otherwise a plain
        # append-at-position buffer.  Ring semantics: once full, every slot
        # is within the window, so no extra window mask is needed.
        kc, vc = cache
        cache_len = kc.shape[1]
        # cur_len is () or (B,) (per-slot continuous batching); the row
        # write and the validity mask are per slot either way
        valid_len = jnp.minimum(cur_len, cache_len)
        kc = L.cache_update_row(kc, k, cur_len)
        vc = L.cache_update_row(vc, v, cur_len)
        new_cache = (kc, vc)
        out = L.attention_decode(q, kc, vc, valid_len, window=None,
                                 engine=eng)
    out = eng(out.reshape(B, Lq, H * hd), p["attn"]["wo"])
    return x + out, new_cache


def mlp_block(p, cfg: ModelConfig, x):
    eng = cfg.engine
    xn = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if getattr(cfg, "mlp_type", "swiglu") == "gelu":
        out = L.gelu_mlp(xn, p["mlp"]["w_up"], p["mlp"]["w_down"], eng)
    else:
        out = L.swiglu(xn, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], eng)
    return x + out


def dense_layer(p, cfg, x, cos, sin, cache=None, cur_len=None):
    x, new_cache = attn_block(p, cfg, x, cos, sin, cache=cache,
                              cur_len=cur_len, window=cfg.window)
    x = mlp_block(p, cfg, x)
    return x, new_cache


# ---------------------------------------------------------------------------
# layer-stack scan with remat blocks
# ---------------------------------------------------------------------------

def scan_layers(body, stacked_params, x, xs=None, *, n_layers: int,
                remat_block: int = 1):
    """scan ``body(layer_params, x, layer_xs) -> (x, ys)`` over the stacked
    layer dim, rematerializing every ``remat_block`` layers."""
    rb = max(1, remat_block)
    assert n_layers % rb == 0, (n_layers, rb)

    def one(carry, inputs):
        lp, lxs = inputs
        return body(lp, carry, lxs)

    if rb == 1:
        step = jax.checkpoint(one)
        x, ys = lax.scan(step, x, (stacked_params, xs), length=n_layers)
        return x, ys

    nb = n_layers // rb
    blocked = jax.tree.map(
        lambda a: a.reshape(nb, rb, *a.shape[1:]), stacked_params)
    xs_b = None if xs is None else jax.tree.map(
        lambda a: a.reshape(nb, rb, *a.shape[1:]), xs)

    @jax.checkpoint
    def block(carry, inputs):
        bp, bxs = inputs
        return lax.scan(one, carry, (bp, bxs), length=rb)

    x, ys = lax.scan(block, x, (blocked, xs_b), length=nb)
    ys = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), ys)
    return x, ys


# ---------------------------------------------------------------------------
# full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: jax.Array,
            positions: Optional[jax.Array] = None) -> jax.Array:
    B, Lq = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Lq, dtype=jnp.int32), (B, Lq))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def body(lp, x, _):
        x, _ = dense_layer(lp, cfg, x, cos, sin)
        return x, None

    x, _ = scan_layers(body, params["layers"], x, n_layers=cfg.n_layers,
                       remat_block=cfg.remat_block)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["lm_head"], cfg.engine)


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    KV, hd = cfg.n_kv_heads, cfg.hd
    cache_len = min(max_len, cfg.window) if cfg.window else max_len
    shape = (cfg.n_layers, batch, cache_len, KV, hd)
    k = jnp.zeros(shape, jnp.bfloat16)
    v = jnp.zeros(shape, jnp.bfloat16)
    k = shard(k, "layers", "cache_batch", None, "cache_heads", "cache_hd")
    v = shard(v, "layers", "cache_batch", None, "cache_heads", "cache_hd")
    return {"k": k, "v": v}


def cache_axes(cfg: ModelConfig):
    ax = ("layers", "cache_batch", None, "cache_heads", "cache_hd")
    return {"k": ax, "v": ax}


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                cur_len: jax.Array):
    """One-token decode: tokens (B, 1) at absolute position cur_len-1.

    Returns (logits (B, 1, vocab), new_cache).  For windowed attention the
    cache is a rolling buffer of size window (index modulo window).
    ``cur_len`` is a scalar (all slots in lock-step) or a (B,) vector
    (continuous batching: each slot decodes at its own position).
    """
    B = tokens.shape[0]
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = L.decode_positions(cur_len, B)
    cos, sin = L.rope_cos_sin(pos, cfg.hd, cfg.rope_theta)

    def body(x, inputs):
        lp, kc, vc = inputs
        x, new_kv = dense_layer(lp, cfg, x, cos, sin, cache=(kc, vc),
                                cur_len=cur_len)
        return x, new_kv

    x, (k_new, v_new) = lax.scan(body, x,
                                 (params["layers"], cache["k"], cache["v"]),
                                 length=cfg.n_layers)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = L.logits_head(x, params["lm_head"], cfg.engine)
    return logits, {"k": k_new, "v": v_new}
