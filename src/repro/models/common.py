"""Shared model machinery: config dataclass, param builder, init helpers.

Models are functional: ``init(rng, cfg) -> (params, axes)`` where ``axes``
mirrors ``params`` with logical-axis tuples, and ``forward(params, cfg, ...)``
is a pure function.  No flax — params are nested dicts of jax arrays, which
keeps eval_shape/pjit/scan interop trivial.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import MatmulEngine, make_engine


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"   # dense | moe | mla_moe | vlm | encdec | ssm | hybrid
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 1024
    head_dim: Optional[int] = None
    rope_theta: float = 1e4
    mlp_type: str = "swiglu"          # swiglu | gelu
    window: Optional[int] = None            # sliding-window (local) attention
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "scatter"   # scatter (GSPMD) | a2a (shard_map)
    # MLA (deepseek-v2)
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0
    # SSM (mamba2)
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_headdim: int = 64
    chunk: int = 256
    # hybrid (recurrentgemma)
    pattern: Tuple[str, ...] = ()           # e.g. ("R", "R", "A")
    n_pattern_blocks: int = 0
    n_tail_layers: int = 0
    lru_width: int = 0
    # VLM (llama-3.2-vision)
    cross_every: int = 0                    # 1 cross-attn layer per N self
    vision_seq: int = 0
    # enc-dec (seamless)
    enc_layers: int = 0
    frames: int = 0
    # numerics / memory
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat_block: int = 1                    # layers per remat unit
    engine_spec: str = "bf16"               # MatmulEngine spec
    # attention chunking (flash-style)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # replicate KV heads to all Q heads before training attention: shards
    # the score computation over H (q-heads) instead of KV — wins whenever
    # KV < model-axis < H (uneven-KV GQA); costs 2x K/V activation bytes.
    expand_kv: bool = False
    # skip long-context cells (pure full-attention archs)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/LM-head
        shard evenly on the 16-way model axis (jit arg shardings require
        exact divisibility).  Standard practice; pad columns train to low
        logits and are never targets."""
        return -(-self.vocab // 256) * 256

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def engine(self) -> MatmulEngine:
        # make_engine, not the bare constructor: a bad spec (typo'd k,
        # "bf16@model", ...) must fail at config time with a ValueError,
        # not as a KeyError deep inside the first traced contraction
        return make_engine(self.engine_spec)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# param construction
# ---------------------------------------------------------------------------

def dense_param(rng, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else shape[0] ** -0.5
    return jax.random.normal(rng, shape, dtype) * scale


def init_stacked(rng, n: int, layer_init):
    """vmap a single-layer init over n layer seeds -> stacked params."""
    rngs = jax.random.split(rng, n)
    return jax.vmap(layer_init)(rngs)


def stack_axes(axes_tree):
    """Prepend the 'layers' axis to every logical-axes tuple in a tree."""
    return jax.tree.map(
        lambda t: ("layers",) + t, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x))


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
