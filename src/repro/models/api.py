"""Uniform model API across the six families.

Every family exposes, through :func:`get_model`:

    init(rng, cfg)                      -> (params, logical_axes)
    forward(params, cfg, batch)         -> logits (B, L, vocab) f32
    init_cache(cfg, batch_size, max_len, params=None, ctx=None) -> cache
    cache_axes(cfg)                     -> logical axes mirroring the cache
    decode_step(params, cfg, cache, tokens, cur_len) -> (logits, cache)

``batch`` is a dict: ``tokens`` (B, L) int32 always; ``image_embeds``
(B, vision_seq, d) for the vlm family; ``frames`` (B, F, d) for encdec.
The shared next-token loss lives here too.
"""
from __future__ import annotations

import types
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, moe, ssm, transformer, vlm
from repro.models.common import ModelConfig

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": moe,
    "mla_moe": moe,
    "vlm": vlm,
    "encdec": encdec,
    "ssm": ssm,
    "hybrid": hybrid,
}


class Model(types.SimpleNamespace):
    pass


def get_model(cfg: ModelConfig) -> Model:
    mod = _FAMILY_MODULES[cfg.family]

    def forward(params, cfg, batch: Dict[str, Any]):
        tokens = batch["tokens"]
        if cfg.family == "vlm":
            return mod.forward(params, cfg, tokens, batch["image_embeds"])
        if cfg.family == "encdec":
            return mod.forward(params, cfg, tokens, batch["frames"])
        return mod.forward(params, cfg, tokens)

    def init_cache(cfg, batch_size, max_len, params=None, ctx=None):
        if cfg.family == "vlm":
            return mod.init_cache(cfg, batch_size, max_len,
                                  image_embeds=ctx, params=params)
        if cfg.family == "encdec":
            return mod.init_cache(cfg, batch_size, max_len,
                                  memory=ctx, params=params)
        return mod.init_cache(cfg, batch_size, max_len)

    cache_axes = getattr(mod, "cache_axes", None)
    if cache_axes is None and cfg.family in ("moe", "mla_moe"):
        def cache_axes(cfg):
            if cfg.family == "mla_moe":
                return {"latent": ("layers", "cache_batch", None, "kv_lora"),
                        "k_rope": ("layers", "cache_batch", None, "cache_hd")}
            return transformer.cache_axes(cfg)

    return Model(init=mod.init, forward=forward, init_cache=init_cache,
                 cache_axes=cache_axes, decode_step=mod.decode_step,
                 module=mod)


def next_token_loss(logits: jax.Array, tokens: jax.Array,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy of logits[:, :-1] predicting tokens[:, 1:].

    The gold logit is extracted with a masked sum over the vocab axis (NOT
    ``take_along_axis``): under vocab-sharded logits a gather would make
    GSPMD all-gather the full (B, L, V) logits per device, while the masked
    sum stays sharded and reduces with one small all-reduce.
    """
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == targets[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
