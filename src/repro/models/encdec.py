"""Seamless-M4T-style encoder-decoder backbone (audio family).

Per the assignment, the modality frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, frames, d_model) from ``input_specs()``.
Encoder: bidirectional self-attention stack.  Decoder: causal self-attention
+ cross-attention to the encoder output.  Training is teacher-forced
seq2seq; serving decodes one token against (a) the decoder's KV ring buffer
and (b) cross K/V precomputed once from the encoder output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import ModelConfig, dense_param, init_stacked, stack_axes


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_dec_layer(rng, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    self_attn, sa_ax = T.init_attn(k1, cfg)
    cross_attn, ca_ax = T.init_attn(k2, cfg)
    mlp, mlp_ax = T.init_mlp(k3, cfg)
    d = cfg.d_model
    params = {"self": self_attn, "cross": cross_attn, "mlp": mlp,
              "ln1": jnp.zeros((d,)), "ln_x": jnp.zeros((d,)),
              "ln2": jnp.zeros((d,))}
    axes = {"self": sa_ax, "cross": ca_ax, "mlp": mlp_ax,
            "ln1": ("embed",), "ln_x": ("embed",), "ln2": ("embed",)}
    return params, axes


def init(rng, cfg: ModelConfig):
    k_emb, k_enc, k_dec, k_head = jax.random.split(rng, 4)
    _, enc_ax = T.init_dense_layer(k_enc, cfg)
    enc = init_stacked(k_enc, cfg.enc_layers,
                       lambda r: T.init_dense_layer(r, cfg)[0])
    _, dec_ax = init_dec_layer(k_dec, cfg)
    dec = init_stacked(k_dec, cfg.n_layers,
                       lambda r: init_dec_layer(r, cfg)[0])
    params = {
        "embed": dense_param(k_emb, (cfg.padded_vocab, cfg.d_model), scale=1.0),
        "enc_layers": enc,
        "dec_layers": dec,
        "ln_enc": jnp.zeros((cfg.d_model,)),
        "ln_f": jnp.zeros((cfg.d_model,)),
        "lm_head": dense_param(k_head, (cfg.d_model, cfg.padded_vocab)),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "enc_layers": stack_axes(enc_ax),
        "dec_layers": stack_axes(dec_ax),
        "ln_enc": ("embed",),
        "ln_f": ("embed",),
        "lm_head": ("embed", "vocab"),
    }
    return params, axes


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, F, d_model) — precomputed frame embeddings (stub frontend)."""
    B, F, _ = frames.shape
    x = shard(frames.astype(cfg.compute_dtype), "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def body(lp, x, _):
        # bidirectional: causal=False
        eng = cfg.engine
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        xn = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q = eng(xn, lp["attn"]["wq"]).reshape(B, F, H, hd)
        k = eng(xn, lp["attn"]["wk"]).reshape(B, F, KV, hd)
        v = eng(xn, lp["attn"]["wv"]).reshape(B, F, KV, hd)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        q = shard(q, "batch", "seq", "heads", "head_dim")
        out = L.attention_flash(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                engine=eng)
        x = x + eng(out.reshape(B, F, H * hd), lp["attn"]["wo"])
        x = T.mlp_block(lp, cfg, x)
        return x, None

    x, _ = T.scan_layers(body, params["enc_layers"], x,
                         n_layers=cfg.enc_layers, remat_block=cfg.remat_block)
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_layer(lp, cfg, x, cos, sin, memory=None, *, self_cache=None,
               cross_kv_cache=None, cur_len=None):
    x, new_kv = T.attn_block({"attn": lp["self"], "ln1": lp["ln1"]}, cfg, x,
                             cos, sin, cache=self_cache, cur_len=cur_len)
    # cross-attention
    eng = cfg.engine
    B, Lq, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = L.rmsnorm(x, lp["ln_x"], cfg.norm_eps)
    q = eng(xn, lp["cross"]["wq"]).reshape(B, Lq, H, hd)
    if cross_kv_cache is None:
        Lk = memory.shape[1]
        k = eng(memory, lp["cross"]["wk"]).reshape(B, Lk, KV, hd)
        v = eng(memory, lp["cross"]["wv"]).reshape(B, Lk, KV, hd)
    else:
        k, v = cross_kv_cache
    q = shard(q, "batch", "seq", "heads", "head_dim")
    out = L.attention_flash(q, k, v, causal=False,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                            engine=eng)
    x = x + eng(out.reshape(B, Lq, H * hd), lp["cross"]["wo"])
    x = T.mlp_block(lp, cfg, x)
    return x, new_kv


def forward(params, cfg: ModelConfig, tokens: jax.Array, frames: jax.Array,
            positions=None):
    """Teacher-forced decode over the full target: returns (B, L, vocab)."""
    memory = encode(params, cfg, frames)
    B, Lq = tokens.shape
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Lq, dtype=jnp.int32), (B, Lq))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def body(lp, x, _):
        x, _ = _dec_layer(lp, cfg, x, cos, sin, memory)
        return x, None

    x, _ = T.scan_layers(body, params["dec_layers"], x,
                         n_layers=cfg.n_layers, remat_block=cfg.remat_block)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return L.logits_head(x, params["lm_head"], cfg.engine)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               memory: Optional[jax.Array] = None, params=None):
    KV, hd = cfg.n_kv_heads, cfg.hd
    shp = (cfg.n_layers, batch, max_len, KV, hd)
    cache = {
        "k": shard(jnp.zeros(shp, jnp.bfloat16),
                   "layers", "cache_batch", None, "cache_heads", "cache_hd"),
        "v": shard(jnp.zeros(shp, jnp.bfloat16),
                   "layers", "cache_batch", None, "cache_heads", "cache_hd"),
    }
    if memory is not None and params is not None:
        eng = cfg.engine
        B, Lk, _ = memory.shape
        def kv_of(lp):
            k = eng(memory, lp["cross"]["wk"]).reshape(B, Lk, KV, hd)
            v = eng(memory, lp["cross"]["wv"]).reshape(B, Lk, KV, hd)
            return k, v
        ck, cv = jax.vmap(kv_of)(params["dec_layers"])
    else:
        Lk = max_len
        ck = jnp.zeros((cfg.n_layers, batch, Lk, KV, hd), jnp.bfloat16)
        cv = jnp.zeros((cfg.n_layers, batch, Lk, KV, hd), jnp.bfloat16)
    cache["cross_k"] = shard(ck.astype(jnp.bfloat16),
                             "layers", "cache_batch", None, "cache_heads", "cache_hd")
    cache["cross_v"] = shard(cv.astype(jnp.bfloat16),
                             "layers", "cache_batch", None, "cache_heads", "cache_hd")
    return cache


def cache_axes(cfg: ModelConfig):
    ax = ("layers", "cache_batch", None, "cache_heads", "cache_hd")
    return {"k": ax, "v": ax, "cross_k": ax, "cross_v": ax}


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                cur_len: jax.Array):
    B = tokens.shape[0]
    x = L.embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = L.decode_positions(cur_len, B)
    cos, sin = L.rope_cos_sin(pos, cfg.hd, cfg.rope_theta)

    def body(x, inputs):
        lp, kc, vc, ck, cv = inputs
        x, new_kv = _dec_layer(lp, cfg, x, cos, sin,
                               self_cache=(kc, vc),
                               cross_kv_cache=(ck.astype(x.dtype),
                                               cv.astype(x.dtype)),
                               cur_len=cur_len)
        return x, new_kv

    x, (k_n, v_n) = lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]), length=cfg.n_layers)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = L.logits_head(x, params["lm_head"], cfg.engine)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_n, v_n
    return logits, new_cache
