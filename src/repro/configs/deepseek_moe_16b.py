"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) per-expert
d_ff=1408 vocab=102400; 2 shared + 64 routed experts, top-6, fine-grained.
[arXiv:2401.06066; hf]

Expert sharding: experts map to the *data* axis (64/16 = 4 per slice) and
the expert-mlp dim to *model* (1408/16 = 88) — 256-way expert-parameter
sharding; GSPMD emits the token all-to-all from the sharding mismatch."""
from repro.models.common import ModelConfig

SKIP_SHAPES = (
    ("long_500k", "full O(L^2) attention; 524288-seq decode cell skipped"),
)

RULES_OVERRIDES = {"experts": ("data",), "expert_mlp": "model",
                   "cache_heads": "model"}  # kv=16


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek_moe_16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=2816,              # shared-expert ffn (2 x 1408)
        d_ff_expert=1408, n_experts=64, n_shared_experts=2, topk=6,
        vocab=102400, rope_theta=1e4,
        moe_dispatch="a2a",   # shard_map all-to-all (see EXPERIMENTS §Perf B)
        remat_block=4,
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=64, d_ff_expert=32, n_experts=8, topk=2,
                        n_shared_experts=1, vocab=256, remat_block=1,
                        q_chunk=64, kv_chunk=64)
