"""deepseek-7b [dense] — 30L d_model=4096 32H (GQA kv=32 = MHA) d_ff=11008
vocab=102400; llama-arch.  [arXiv:2401.02954; hf]"""
from repro.models.common import ModelConfig

RULES_OVERRIDES = {"cache_heads": "model"}  # kv divisible by 16

SKIP_SHAPES = (
    ("long_500k", "full O(L^2) attention; 524288-seq decode cell skipped"),
)


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek_7b", family="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab=102400, rope_theta=1e4,
        remat_block=5,
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=96, vocab=256, remat_block=1,
                        q_chunk=64, kv_chunk=64)
