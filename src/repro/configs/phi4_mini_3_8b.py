"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064; RoPE + SwiGLU + GQA.  [arXiv:2412.08905; hf]"""
from repro.models.common import ModelConfig

# kv heads not divisible by the 16-way model axis -> the
# decode cache shards its head_dim instead (always 16-divisible)
RULES_OVERRIDES = {"cache_hd": "model"}

SKIP_SHAPES = (
    ("long_500k", "full O(L^2) attention; 524288-seq decode cell skipped"),
)


def full() -> ModelConfig:
    return ModelConfig(
        name="phi4_mini_3_8b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=200064, rope_theta=1e4,
        remat_block=4,
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=96, vocab=256, remat_block=1,
                        q_chunk=64, kv_chunk=64)
