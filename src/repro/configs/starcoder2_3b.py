"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE.  [arXiv:2402.19173; hf]"""
from repro.models.common import ModelConfig

# kv heads not divisible by the 16-way model axis -> the
# decode cache shards its head_dim instead (always 16-divisible)
RULES_OVERRIDES = {"cache_hd": "model"}

SKIP_SHAPES = (
    ("long_500k", "full O(L^2) attention; 524288-seq decode cell skipped"),
)


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        d_ff=12288, vocab=49152, rope_theta=1e5,
        remat_block=5,
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=256, remat_block=1,
                        q_chunk=64, kv_chunk=64)
