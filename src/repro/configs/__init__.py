"""Architecture registry: one module per assigned arch, each exposing
``full()`` (the exact published config) and ``smoke()`` (a reduced
same-family config for CPU tests), plus optional per-arch sharding-rule
overrides and shape skips.

Shapes (assignment): every arch pairs with the four LM shapes below;
``decode_*``/``long_*`` lower ``serve_step``; ``long_500k`` only runs for
sub-quadratic families (ssm, hybrid) — full-attention archs record SKIP.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.models.common import ModelConfig

ARCH_IDS = (
    "starcoder2_3b",
    "phi4_mini_3_8b",
    "internlm2_1_8b",
    "deepseek_7b",
    "deepseek_moe_16b",
    "deepseek_v2_236b",
    "llama32_vision_11b",
    "seamless_m4t_medium",
    "mamba2_780m",
    "recurrentgemma_9b",
)

# accept hyphenated public names too
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def get_arch_module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise ValueError(f"unknown arch {name!r}; options: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str, *, smoke: bool = False, **overrides) -> ModelConfig:
    mod = get_arch_module(name)
    cfg = mod.smoke() if smoke else mod.full()
    return cfg.with_(**overrides) if overrides else cfg


def rules_overrides(name: str) -> dict:
    """Per-arch logical->mesh overrides merged over the base rule table."""
    return getattr(get_arch_module(name), "RULES_OVERRIDES", {})


def skipped_shapes(name: str):
    """dict shape -> reason for shapes this arch does not run."""
    return dict(getattr(get_arch_module(name), "SKIP_SHAPES", ()))


def arch_shape_cells(include_skipped: bool = False):
    """All (arch, shape) assignment cells — 40 total."""
    for arch in ARCH_IDS:
        skips = skipped_shapes(arch)
        for shape in SHAPES:
            if shape in skips and not include_skipped:
                continue
            yield arch, shape
