"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; gated cross-attn image layers every 5th layer (8 total).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, vision_seq=1600, d_model)."""
from repro.models.common import ModelConfig

# kv heads not divisible by the 16-way model axis -> the
# decode cache shards its head_dim instead (always 16-divisible)
RULES_OVERRIDES = {"cache_hd": "model"}

SKIP_SHAPES = (
    ("long_500k", "full O(L^2) attention; 524288-seq decode cell skipped"),
)


def full() -> ModelConfig:
    return ModelConfig(
        name="llama32_vision_11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, rope_theta=5e5,
        cross_every=5, vision_seq=1600,
        remat_block=2,          # blocks of pattern groups (8 groups total)
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=96, vocab=256, cross_every=2, vision_seq=16,
                        remat_block=1, q_chunk=64, kv_chunk=64)
