"""mamba2-780m [ssm] — 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128; SSD (state-space duality).  [arXiv:2405.21060; unverified]

expand=2 -> d_inner=3072, head_dim=64 -> 48 SSD heads.  Sub-quadratic:
runs the long_500k cell (constant-size conv + SSM state)."""
from repro.models.common import ModelConfig

SKIP_SHAPES = ()


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2_780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280,
        d_state=128, d_conv=4, expand=2, ssm_headdim=64, chunk=256,
        subquadratic=True,
        remat_block=4,
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=2, d_model=64, d_state=16, ssm_headdim=16,
                        chunk=32, vocab=256, remat_block=1)
