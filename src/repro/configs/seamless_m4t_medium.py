"""seamless-m4t-medium [audio] — enc-dec, 12L (each side) d_model=1024
16H d_ff=4096 vocab=256206; multimodal.  [arXiv:2308.11596; hf]

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, frames=seq_len, d_model) as encoder input.
GELU MLPs (transformer-standard for this family)."""
from repro.models.common import ModelConfig

RULES_OVERRIDES = {"cache_heads": "model"}  # kv divisible by 16

SKIP_SHAPES = (
    ("long_500k", "full O(L^2) attention (enc + cross); 524288 cell skipped"),
)


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_medium", family="encdec",
        n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=256206, rope_theta=1e4, mlp_type="gelu",
        remat_block=4,
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, d_ff=96, vocab=256, remat_block=1,
                        q_chunk=64, kv_chunk=64)
