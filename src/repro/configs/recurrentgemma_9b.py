"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1 = MQA)
d_ff=12288 vocab=256000; RG-LRU + local attention, pattern (R, R, A).
[arXiv:2402.19427; unverified]

38 layers = 12 x (R, R, A) pattern blocks + 2 tail R layers.  Local window
2048.  Sub-quadratic: runs long_500k (constant LRU state + 2048-window
attention ring buffers)."""
from repro.models.common import ModelConfig

# kv heads not divisible by the 16-way model axis -> the
# decode cache shards its head_dim instead (always 16-divisible)
RULES_OVERRIDES = {"cache_hd": "model"}

SKIP_SHAPES = ()


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        head_dim=256, d_ff=12288, vocab=256000, rope_theta=1e4,
        mlp_type="gelu", window=2048, lru_width=4096,
        pattern=("R", "R", "A"), n_pattern_blocks=12, n_tail_layers=2,
        subquadratic=True,
        remat_block=2,          # pattern blocks per remat unit (12 blocks)
    )


def smoke() -> ModelConfig:
    return full().with_(d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
                        d_ff=96, vocab=256, lru_width=64, window=32,
                        n_pattern_blocks=2, n_tail_layers=1, n_layers=7,
                        remat_block=1, q_chunk=64, kv_chunk=64)
