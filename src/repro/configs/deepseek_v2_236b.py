"""deepseek-v2-236b [moe] — 60L d_model=5120 128H per-expert d_ff=1536
vocab=102400; MLA kv_lora=512, 2 shared + 160 routed experts top-6.
[arXiv:2405.04434; hf]

MLA: per-head nope dim 128, shared rope key dim 64, v head dim 128; the
decode cache stores only the 512-dim latent + 64-dim rope key per position.
(The published config also low-ranks Q with q_lora=1536; we keep a full Q
projection — noted in DESIGN.md, it does not change cache or FFN shapes.)

Experts shard over (data: 160/16 = 10) x (expert_mlp over model: 1536/16 =
96) = 256-way; optimizer states inherit this (ZeRO over remaining axes)."""
from repro.models.common import ModelConfig

SKIP_SHAPES = (
    ("long_500k", "full O(L^2) attention; 524288-seq decode cell skipped"),
)

RULES_OVERRIDES = {"experts": ("data",), "expert_mlp": "model",
                   # MLA decode cache: shard the 512-dim latent and the
                   # 64-dim rope key over the model axis
                   "kv_lora": "model", "cache_hd": "model"}


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v2_236b", family="mla_moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        head_dim=128, kv_lora=512, rope_head_dim=64, v_head_dim=128,
        d_ff=3072,              # shared-expert ffn (2 x 1536)
        d_ff_expert=1536, n_experts=160, n_shared_experts=2, topk=6,
        vocab=102400, rope_theta=1e4,
        moe_dispatch="a2a",   # shard_map all-to-all (see EXPERIMENTS §Perf B)
        remat_block=6,
    )


def smoke() -> ModelConfig:
    return full().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        head_dim=16, kv_lora=32, rope_head_dim=8,
                        v_head_dim=16, d_ff=64, d_ff_expert=32, n_experts=8,
                        topk=2, n_shared_experts=1, vocab=256, remat_block=1,
                        q_chunk=64, kv_chunk=64)
