"""INT8 gradient compression with error feedback — beyond-paper reuse of the
paper's splitting machinery for the cross-pod all-reduce.

The Ozaki splitting (Alg. 8, rn_const) is exactly a *deterministic int8
quantizer with a power-of-two, row-wise scale*: slice 1 of a k=1 split is
the round-to-nearest int8 digit matrix.  We reuse it to compress gradients
before the pod-level all-reduce (4x fewer bytes on the slowest links), with
per-call error feedback (the residual — what the paper calls V_k — is
carried to the next step instead of dropped).

Because the scale is a power of two the quantization is unbiased-free
deterministic and the error-feedback state exactly absorbs the truncation:
this is the paper's "error-free transformation" idea applied to collectives.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import splitting


class CompressState(NamedTuple):
    """Per-parameter error-feedback residuals (same pytree as params)."""
    residual: jax.Array


def init_state(grads):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _as_2d(g: jax.Array) -> Tuple[jax.Array, tuple]:
    shape = g.shape
    if g.ndim == 1:
        return g.reshape(1, -1), shape
    return g.reshape(-1, shape[-1]), shape


def compress(g: jax.Array, err: jax.Array):
    """g + err -> (digits int8, scale f32 rows, new_err).  k=1 rn_const split."""
    x, shape = _as_2d(g.astype(jnp.float32) + err.astype(jnp.float32))
    sp = splitting.split_rn_const(x, 1, axis=0)
    recon = splitting.reconstruct(sp, jnp.float32)
    new_err = (x - recon).reshape(shape)
    return sp.digits[0], sp.scale[0], new_err


def decompress(digits: jax.Array, scale: jax.Array, shape) -> jax.Array:
    out = digits.astype(jnp.float32) * scale[:, None]
    return out.reshape(shape)


def compressed_psum(grads, err_tree, axis_name: str):
    """All-reduce ``grads`` over ``axis_name`` in int8 + f32 row scales.

    Inside shard_map: quantize (with error feedback), all-reduce the int8
    digits *as int32 sums* (exact — the paper's error-free integer
    accumulation applied to the collective), all-reduce the power-of-two
    scales by max, and rescale.  Returns (mean_grads, new_err_tree).
    """
    def one(g, err):
        x, shape = _as_2d(g.astype(jnp.float32) + err.astype(jnp.float32))
        # shared power-of-two scale across the axis: max of row maxima
        sp = splitting.split_rn_const(x, 1, axis=0)
        scale = jax.lax.pmax(sp.scale[0], axis_name)
        # re-quantize against the shared scale (digits stay int8-safe:
        # |x| <= rowmax <= scale * 2^(beta-1))
        d = jnp.round(x / scale[:, None]).astype(jnp.int32)
        total = jax.lax.psum(d, axis_name)                 # exact in int32
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = total.astype(jnp.float32) * scale[:, None] / n
        new_err = ((x - d.astype(jnp.float32) * scale[:, None])
                   .reshape(shape))
        return mean.reshape(shape), new_err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_tree)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
