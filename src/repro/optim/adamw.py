"""AdamW with ZeRO-1-style sharded optimizer state.

Functional (no optax dependency): ``init(params, axes) -> OptState``;
``step(grads, params, state, cfg, schedule_step) -> (params, state)``.

ZeRO-1: first/second moments (and the optional f32 master copy) carry an
*extended* sharding — each param's logical axes are augmented so that the
largest currently-unsharded axis maps to the ``zero`` rule (the pure-DP mesh
axes).  Params/grads keep the model sharding (so forward/backward are
untouched); only the state and the update computation are partitioned, which
is exactly ZeRO-1.  XLA inserts the reduce-scatter/all-gather pair around
the update from the sharding mismatch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import get_rules, logical_to_pspec, shard


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_f32: bool = False        # keep f32 master params (off for huge cfgs)
    state_dtype: str = "float32"


class OptState(NamedTuple):
    mu: Any
    nu: Any
    master: Optional[Any]
    count: jax.Array


# ---------------------------------------------------------------------------
# ZeRO axis augmentation
# ---------------------------------------------------------------------------

def zero_axes(axes_tree, params, zero_divisor: int):
    """Augment each param's logical axes: the largest axis that is unsharded
    (logical name None or mapping to None) and divisible by the zero-axis
    size gets the logical name 'zero'.

    The effective divisor is derived from the live mesh + the 'zero' rule
    when available (it may span several mesh axes, e.g. (pod, data));
    ``zero_divisor`` is the fallback when no mesh is installed."""
    from repro.distributed.compat import get_abstract_mesh
    rules = get_rules() or {}
    mesh = get_abstract_mesh()
    if rules.get("zero") and not mesh.empty:
        zr = rules["zero"]
        zr = (zr,) if isinstance(zr, str) else tuple(zr)
        prod = 1
        for a in zr:
            if a in mesh.axis_names:
                prod *= mesh.shape[a]
        if prod > 1:
            zero_divisor = prod

    def aug(axes, p):
        if not isinstance(axes, tuple):
            return axes
        mapped = [rules.get(a) if a else None for a in axes]
        best, best_dim = None, 0
        for i, (a, m) in enumerate(zip(axes, mapped)):
            if m is None and p.shape[i] % zero_divisor == 0 \
                    and p.shape[i] > best_dim:
                best, best_dim = i, p.shape[i]
        if best is None:
            return axes
        out = list(axes)
        out[best] = "zero"
        return tuple(out)

    return jax.tree.map(aug, axes_tree, params,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            e is None or isinstance(e, str) for e in x))


# ---------------------------------------------------------------------------
# init / step
# ---------------------------------------------------------------------------

def init(params, state_axes=None, cfg: OptConfig = OptConfig()) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    mu, nu = zeros, jax.tree.map(jnp.copy, zeros)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.master_f32 else None)
    if state_axes is not None:
        mu = _apply_axes(mu, state_axes)
        nu = _apply_axes(nu, state_axes)
        if master is not None:
            master = _apply_axes(master, state_axes)
    return OptState(mu, nu, master, jnp.zeros((), jnp.int32))


def _apply_axes(tree, axes_tree):
    return jax.tree.map(
        lambda x, a: shard(x, *a) if isinstance(a, tuple) else x,
        tree, axes_tree)


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def step(grads, params, state: OptState, cfg: OptConfig,
         state_axes=None):
    """One AdamW update.  Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.betas
    lr = lr_at(cfg, state.count)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, p, m, v, master):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g.astype(m.dtype)
        v = b2 * v + (1 - b2) * (g * g).astype(v.dtype)
        mhat = m.astype(jnp.float32) / c1
        vhat = v.astype(jnp.float32) / c2
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return new.astype(p.dtype), m, v, new

    masters = state.master if state.master is not None else jax.tree.map(
        lambda _: None, params, is_leaf=lambda x: x is None)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_ma = (treedef.flatten_up_to(state.master)
               if state.master is not None else [None] * len(flat_p))
    outs = [upd(g, p, m, v, ma)
            for g, p, m, v, ma in zip(flat_g, flat_p, flat_m, flat_v, flat_ma)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    new_master = (treedef.unflatten([o[3] for o in outs])
                  if state.master is not None else None)
    if state_axes is not None:
        new_m = _apply_axes(new_m, state_axes)
        new_v = _apply_axes(new_v, state_axes)
        if new_master is not None:
            new_master = _apply_axes(new_master, state_axes)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, new_master, count), metrics
