from repro.optim.adamw import (OptConfig, OptState, init, step, lr_at,
                               global_norm, zero_axes)
from repro.optim import compress
