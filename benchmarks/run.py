"""Benchmark harness entry point: one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only accuracy,...]
        [--out experiments/bench] [--summary BENCH_ozimmu.json]

Benches:
  accuracy    Figs. 1/5   — measured error vs k, phi (dd reference)
  breakdown   Figs. 2-3, 6-11 — phase-time shares (v5e model + CPU sanity)
  throughput  Figs. 12-13 — emulated TFLOPS vs n (v5e model)
  pareto      Fig. 14     — measured error vs modeled TFLOPS
  ozimmu_roofline          — roofline terms of the emulated GEMM (HLO)

Besides the per-bench JSON in ``--out``, the harness writes a top-level
``BENCH_ozimmu.json`` headline summary (schema documented in
docs/benchmarks.md) so the perf trajectory of the repo can be tracked
across PRs from one small committed artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# v2: added the `serving` bench (trace-replay tokens/s + TTFT +
# split-cache savings; docs/benchmarks.md#serving)
# v3: planner-economy headlines — `accuracy.prob_auto` (probed det/prob
# auto-k twins) and `breakdown.auto_cost` (static jit-path twins), both
# gated by check_against
# v4: serving `prefix` headline — shared-prompt-trace prefix-cache hit
# rate (gated) and TTFT ratio cached/uncached (recorded)
SUMMARY_SCHEMA_VERSION = 4


def _headline_accuracy(rows):
    """Max-phi errors at the paper's default k=8 per variant (+ fp64),
    plus the ``prob_auto`` planner-economy section: each ``<label>_prob``
    auto row paired with its deterministic twin's k / GEMM count."""
    fixed = [r for r in rows if not r.get("auto")]
    phis = sorted({r["phi"] for r in fixed if r["variant"] != "fp64"})
    ks = sorted({r["k"] for r in fixed if r["variant"] != "fp64"})
    if not phis or not ks:
        return {}
    phi = phis[-1]
    k = 8 if 8 in ks else ks[-1]
    err = {r["variant"]: r["err"] for r in fixed
           if r["phi"] == phi and r["k"] == k}
    fp64 = [r["err"] for r in fixed
            if r["phi"] == phi and r["variant"] == "fp64"]
    out = {"phi": phi, "k": k, "err": err,
           "err_fp64": fp64[0] if fp64 else None}
    auto = {r["variant"]: r for r in rows
            if r.get("auto") and r["phi"] == phi}
    prob = {}
    for label, r in sorted(auto.items()):
        if not label.endswith("_prob"):
            continue
        entry = {"k": r["k"], "err": r["err"],
                 "int8_gemms": r["int8_gemms"]}
        det = auto.get(label[: -len("_prob")])
        if det is not None:
            entry.update(k_det=det["k"], err_det=det["err"],
                         gemms_det=det["int8_gemms"])
        prob[label] = entry
    if prob:
        out["prob_auto"] = {"phi": phi, "rows": prob}
    return out


def _headline_breakdown(rows):
    """Accumulation-time shares, EF/H/oz2 modeled speedups, and the Plan
    cost accounting (int8 GEMMs / high-precision adds — where the oz2
    exponent ladder's reduction shows up) at one k.  Auto-planned rows
    (``"plan": "auto"``) stay out of the fixed-k section and feed the
    ``auto_cost`` section instead: the static det/prob k the jit path
    resolves, with the GEMM-count delta the :prob shave buys."""
    fixed = [r for r in rows if r.get("plan") != "auto"]
    ks = sorted({r["k"] for r in fixed})
    k = 8 if 8 in ks else ks[-1]
    at_k = [r for r in fixed if r["k"] == k]
    out = {
        "n": at_k[0]["n"], "k": k,
        "accum_share": {r["variant"]: r["share_accum"] for r in at_k},
        "speedup_vs_ozimmu": {
            r["variant"]: r["speedup_vs_ozimmu"] for r in at_k
            if "speedup_vs_ozimmu" in r},
        "cost": {r["variant"]: {"int8_gemms": r["int8_gemms"],
                                "hp_adds": r["hp_adds"]}
                 for r in at_k if "int8_gemms" in r},
    }
    auto = {r["variant"]: r for r in rows if r.get("plan") == "auto"}
    cost = {}
    for label, r in sorted(auto.items()):
        if not label.endswith("_prob"):
            continue
        entry = {"k": r["k"], "int8_gemms": r["int8_gemms"],
                 "hp_adds": r["hp_adds"]}
        det = auto.get(label[: -len("_prob")])
        if det is not None:
            entry.update(
                k_det=det["k"], gemms_det=det["int8_gemms"],
                gemms_saved=det["int8_gemms"] - r["int8_gemms"])
        cost[label] = entry
    if cost:
        out["auto_cost"] = {"n": auto[next(iter(auto))]["n"],
                            "rows": cost}
    return out


def _headline_throughput(rows):
    """Modeled TFLOPS per variant at the largest n, k=8."""
    ns = sorted({r["n"] for r in rows})
    ks = sorted({r["k"] for r in rows})
    n, k = ns[-1], (8 if 8 in ks else ks[-1])
    tf = {r["variant"]: r["tflops"] for r in rows
          if r["n"] == n and r["k"] == k}
    base = tf.get("ozimmu")
    return {"n": n, "k": k, "tflops": tf,
            "ef_over_base": (tf.get("ozimmu_ef", 0) / base) if base else None,
            "h_over_base": (tf.get("ozimmu_h", 0) / base) if base else None}


def _headline_pareto(rows):
    """Fraction of k cells where H Pareto-dominates base (Fig. 14 claim)."""
    idx = {(r["variant"], r["k"]): r for r in rows}
    ks = sorted({r["k"] for r in rows})
    claims = []
    for k in ks:
        h, b = idx.get(("ozimmu_h", k)), idx.get(("ozimmu", k))
        if h and b:
            claims.append(h["tflops"] >= 1.2 * b["tflops"]
                          and h["err"] <= 2.0 * b["err"])
    return {"ks": ks,
            "h_dominates_base_frac":
                (sum(claims) / len(claims)) if claims else None}


def _headline_roofline(rows):
    """Roofline-bound emulated TFLOPS per analyzed spec."""
    return {"n": rows[0]["n"] if rows else None,
            "emulated_tflops_bound": {
                r["spec"]: r["emulated_tflops_bound"] for r in rows},
            "bound": {r["spec"]: r["bound"] for r in rows}}


def _headline_serving(rows):
    """Runtime-vs-legacy tokens/s, split-cache effect, and the modeled
    decode-step splitter share under the weight split-cache, for the
    first ozimmu engine row (wall-clock ratios are recorded for the
    trajectory; the gate only checks the deterministic fields)."""
    oz = [r for r in rows if r.get("cached_over_uncached") is not None]
    if not oz:
        return {}
    r = oz[0]
    out = {
        "engine": r["engine"], "slots": r["slots"],
        "requests": r["requests"],
        "tokens_per_s": {m: round(v["tokens_per_s"], 3)
                         for m, v in r["modes"].items()},
        "runtime_over_legacy": r["runtime_over_legacy"],
        "cached_over_uncached": r["cached_over_uncached"],
        "weight_split_hit_rate": r["weight_split_hit_rate"],
        "modeled_decode": r.get("modeled_decode"),
    }
    pfx = r.get("prefix")
    if pfx is not None:
        out["prefix"] = {
            "hit_rate": pfx["hit_rate"],
            "hit_tokens": pfx["hit_tokens"],
            "prefix_ttft_ratio": round(pfx["prefix_ttft_ratio"], 4),
        }
    return out


_HEADLINES = {
    "accuracy": _headline_accuracy,
    "breakdown": _headline_breakdown,
    "throughput": _headline_throughput,
    "pareto": _headline_pareto,
    "ozimmu_roofline": _headline_roofline,
    "serving": _headline_serving,
}


def check_against(summary: dict, committed_path: str, tol: float = 2.0,
                  allow_new_rows: bool = False):
    """Regression gate: the run's accuracy headline must not be worse than
    the committed trajectory artifact (``BENCH_ozimmu.json``) by more than
    ``tol``x per variant.  One-sided — better-than-committed always passes
    (quick grids at smaller n measure smaller errors).  Returns a list of
    human-readable failures (empty = gate passes); raises on a summary
    that cannot be compared at all (missing/failed accuracy bench).

    Row sets must MATCH the committed artifact both ways: a committed row
    missing from this run fails (a variant silently dropped out), and a
    row in this run that the artifact has never seen fails too — an
    ungated row is a row whose regressions CI can't see.  Adding a
    variant legitimately means regenerating ``BENCH_ozimmu.json`` with a
    full ``python -m benchmarks.run`` in the same change;
    ``allow_new_rows`` (CLI ``--allow-new-rows``) is the escape hatch for
    runs that intentionally carry rows the artifact predates.

    The ``prob_auto`` planner-economy headline is gated the same way,
    plus its own invariants: measured err within ``tol``x, the resolved
    probabilistic k never above the committed one (quick grids run at
    n <= the full grid's, which needs no more slices), and within-run
    economy — k and GEMM count never above the deterministic twin's.
    """
    with open(committed_path) as f:
        committed = json.load(f)
    failures = []
    bench = summary.get("benches", {}).get("accuracy")
    if bench is None or bench.get("status") != "ok":
        raise SystemExit(f"[check] accuracy bench missing or failed in "
                         f"this run: {bench}")
    got = bench.get("headline", {}).get("err", {})
    want = committed["benches"]["accuracy"]["headline"]["err"]
    for variant, ref_err in sorted(want.items()):
        new_err = got.get(variant)
        if new_err is None:
            failures.append(f"accuracy: variant {variant!r} missing from "
                            f"this run's headline")
        elif new_err > tol * ref_err:
            failures.append(
                f"accuracy: {variant} err {new_err:.3e} exceeds "
                f"{tol}x committed {ref_err:.3e}")
    extra = sorted(set(got) - set(want))
    if extra and not allow_new_rows:
        failures.append(
            f"accuracy: headline row(s) {extra} absent from the committed "
            f"artifact — regenerate it with a full `python -m "
            f"benchmarks.run`, or pass --allow-new-rows")
    got_pa = (bench.get("headline", {}).get("prob_auto") or {}
              ).get("rows", {})
    want_pa = (committed["benches"]["accuracy"]["headline"]
               .get("prob_auto") or {}).get("rows", {})
    for label, ref in sorted(want_pa.items()):
        r = got_pa.get(label)
        if r is None:
            failures.append(f"prob_auto: row {label!r} missing from this "
                            f"run's headline")
            continue
        if r["err"] > tol * ref["err"]:
            failures.append(
                f"prob_auto: {label} err {r['err']:.3e} exceeds "
                f"{tol}x committed {ref['err']:.3e}")
        if r["k"] > ref["k"]:
            failures.append(
                f"prob_auto: {label} resolved k={r['k']} above committed "
                f"k={ref['k']} (planner regression)")
        if "k_det" in r and r["k"] > r["k_det"]:
            failures.append(
                f"prob_auto: {label} k={r['k']} exceeds its deterministic "
                f"twin's k={r['k_det']} — planner economy violated")
        if "gemms_det" in r and r["int8_gemms"] > r["gemms_det"]:
            failures.append(
                f"prob_auto: {label} int8_gemms={r['int8_gemms']} exceeds "
                f"its deterministic twin's {r['gemms_det']}")
    extra_pa = sorted(set(got_pa) - set(want_pa))
    if extra_pa and not allow_new_rows:
        failures.append(
            f"prob_auto: row(s) {extra_pa} absent from the committed "
            f"artifact — regenerate it with a full `python -m "
            f"benchmarks.run`, or pass --allow-new-rows")
    # serving gate (when both sides ran it): the weight split-cache must
    # stay fully effective — a deterministic property, unlike the
    # wall-clock ratios, which are recorded but not gated (CI noise).
    srv = summary.get("benches", {}).get("serving")
    srv_ref = committed.get("benches", {}).get("serving")
    if srv is not None and srv.get("status") == "ok" and srv_ref:
        got_rate = (srv.get("headline") or {}).get("weight_split_hit_rate")
        want_rate = (srv_ref.get("headline") or {}
                     ).get("weight_split_hit_rate")
        if want_rate is not None and (got_rate or 0.0) < want_rate:
            failures.append(
                f"serving: weight split-cache hit rate {got_rate} fell "
                f"below committed {want_rate}")
        # prefix-cache hit rate on the shared-prompt trace is likewise
        # deterministic (same trace, same keying); the TTFT ratio rides
        # along uncommitted-gated (wall clock).
        got_pfx = ((srv.get("headline") or {}).get("prefix")
                   or {}).get("hit_rate")
        want_pfx = ((srv_ref.get("headline") or {}).get("prefix")
                    or {}).get("hit_rate")
        if want_pfx is not None and (got_pfx or 0.0) < want_pfx:
            failures.append(
                f"serving: prefix-cache hit rate {got_pfx} fell below "
                f"committed {want_pfx}")
    for name, entry in summary["benches"].items():
        if entry.get("status") != "ok":
            failures.append(f"{name}: status {entry.get('status')!r} "
                            f"({entry.get('error')})")
    return failures


def _build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced problem sizes / grids (CI smoke)")
    ap.add_argument("--out", default="experiments/bench",
                    help="directory for the full per-bench JSON rows")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench names")
    ap.add_argument("--summary", default=None,
                    help="headline summary path (schema: docs/benchmarks.md)."
                         " Default: BENCH_ozimmu.json (the committed "
                         "trajectory artifact) for FULL runs; partial runs "
                         "(--quick/--only) default to bench_summary.json so "
                         "they never clobber the committed record. "
                         "'' disables")
    ap.add_argument("--check-against", default=None, metavar="BENCH_JSON",
                    help="regression gate: fail (exit 1) if this run's "
                         "accuracy headline errors exceed 2x the committed "
                         "summary's (e.g. BENCH_ozimmu.json), any headline "
                         "row is unknown to it, or any bench failed.  The "
                         "same gate CI runs — runnable locally.")
    ap.add_argument("--allow-new-rows", action="store_true",
                    help="with --check-against: tolerate headline rows the "
                         "committed artifact predates (default: unknown "
                         "rows are a hard failure — an ungated row is a "
                         "row whose regressions CI can't see)")
    return ap


def main(argv=None):
    ap = _build_parser()
    args = ap.parse_args(argv)
    if args.summary is None:
        args.summary = ("BENCH_ozimmu.json"
                        if not args.quick and not args.only
                        else "bench_summary.json")
    os.makedirs(args.out, exist_ok=True)

    from benchmarks import (bench_accuracy, bench_breakdown,
                            bench_ozimmu_roofline, bench_pareto,
                            bench_serving, bench_throughput)
    benches = {
        "accuracy": bench_accuracy.main,
        "breakdown": bench_breakdown.main,
        "throughput": bench_throughput.main,
        "pareto": bench_pareto.main,
        # roofline terms of the emulated GEMM itself, from compiled HLO
        # (n=2048 keeps the harness fast; §Perf Cell C uses 4096/8192)
        "ozimmu_roofline": lambda out_json=None, quick=False:
            bench_ozimmu_roofline.main(out_json=out_json, quick=True),
        # serving trace replay (continuous batching + weight split-cache)
        "serving": bench_serving.main,
    }
    unknown = (set(args.only.split(",")) - set(benches)) if args.only else ()
    if unknown:
        ap.error(f"unknown bench names {sorted(unknown)}; "
                 f"options: {sorted(benches)}")
    only = set(args.only.split(",")) if args.only else set(benches)
    failures = []
    summary = {
        "schema_version": SUMMARY_SCHEMA_VERSION,
        "generated_unix": int(time.time()),
        "quick": bool(args.quick),
        "only": sorted(only),
        "benches": {},
    }
    for name, fn in benches.items():
        if name not in only:
            continue
        print(f"\n===== bench: {name} =====")
        t0 = time.time()
        try:
            rows = fn(out_json=os.path.join(args.out, f"{name}.json"),
                      quick=args.quick)
            seconds = time.time() - t0
            try:
                headline = _HEADLINES[name](rows or [])
            except Exception as e:  # a bench reshape must not kill the run
                headline = {"error": f"headline extraction failed: {e!r}"}
            summary["benches"][name] = {
                "status": "ok", "seconds": round(seconds, 2),
                "headline": headline,
            }
            print(f"===== {name} done in {seconds:.1f}s =====")
        except Exception as e:
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
            summary["benches"][name] = {
                "status": "failed", "seconds": round(time.time() - t0, 2),
                "error": repr(e),
            }
    if args.summary:
        with open(args.summary, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"\nheadline summary -> {args.summary}")
    if failures:
        print("\nFAILED benches:", failures)
        sys.exit(1)
    if args.check_against:
        gate = check_against(summary, args.check_against,
                             allow_new_rows=args.allow_new_rows)
        if gate:
            print("\n[check] REGRESSION GATE FAILED vs", args.check_against)
            for line in gate:
                print("[check]  -", line)
            sys.exit(1)
        print(f"[check] regression gate vs {args.check_against}: OK")
    print("\nall benches complete; JSON in", args.out)


if __name__ == "__main__":
    main()
