"""Benchmark harness entry point: one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--out experiments/bench]

Benches:
  accuracy    Figs. 1/5   — measured error vs k, phi (dd reference)
  breakdown   Figs. 2-3, 6-11 — phase-time shares (v5e model + CPU sanity)
  throughput  Figs. 12-13 — emulated TFLOPS vs n (v5e model)
  pareto      Fig. 14     — measured error vs modeled TFLOPS
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/bench")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench names")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    from benchmarks import (bench_accuracy, bench_breakdown,
                            bench_ozimmu_roofline, bench_pareto,
                            bench_throughput)
    benches = {
        "accuracy": bench_accuracy.main,
        "breakdown": bench_breakdown.main,
        "throughput": bench_throughput.main,
        "pareto": bench_pareto.main,
        # roofline terms of the emulated GEMM itself, from compiled HLO
        # (n=2048 keeps the harness fast; §Perf Cell C uses 4096/8192)
        "ozimmu_roofline": lambda out_json=None, quick=False:
            bench_ozimmu_roofline.main(out_json=out_json, quick=True),
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    failures = []
    for name, fn in benches.items():
        if name not in only:
            continue
        print(f"\n===== bench: {name} =====")
        t0 = time.time()
        try:
            fn(out_json=os.path.join(args.out, f"{name}.json"),
               quick=args.quick)
            print(f"===== {name} done in {time.time() - t0:.1f}s =====")
        except Exception as e:
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("\nFAILED benches:", failures)
        sys.exit(1)
    print("\nall benches complete; JSON in", args.out)


if __name__ == "__main__":
    main()
