"""Paper-representative roofline: lower `ozimmu_matmul` itself and derive
the three terms from the compiled HLO (the §Perf "cell C").

Single-chip analysis (the emulated GEMM is the per-chip building block —
distribution shards the outer GEMM dims, not the scheme).  Compute time
prices int8 dots at the 394 TOP/s MXU int8 peak and float ops at 197
TFLOP/s; memory at 819 GB/s.

    PYTHONPATH=src python -m benchmarks.bench_ozimmu_roofline [--n 4096]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core import ozimmu
from repro.launch import hlo_cost

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9


def analyze_variant(spec: str, n: int, dtype=jnp.float32):
    cfg = ozimmu.parse_spec(spec)
    a = jax.ShapeDtypeStruct((n, n), dtype)
    b = jax.ShapeDtypeStruct((n, n), dtype)
    lowered = jax.jit(
        lambda a, b: ozimmu.ozimmu_matmul(a, b, cfg)).lower(a, b)
    compiled = lowered.compile()
    t = hlo_cost.analyze(compiled.as_text())
    int8 = t["int8_dot_flops"]
    other = t["flops"] - int8
    t_compute = int8 / PEAK_INT8 + other / PEAK_BF16
    t_memory = t["bytes"] / HBM_BW
    total = max(t_compute, t_memory)
    eff_tflops = 2.0 * n ** 3 / total / 1e12
    return {
        "spec": spec, "n": n,
        "int8_dot_flops": int8, "other_flops": other, "bytes": t["bytes"],
        "t_compute_ms": t_compute * 1e3, "t_memory_ms": t_memory * 1e3,
        "bound": "compute" if t_compute >= t_memory else "memory",
        "emulated_tflops_bound": eff_tflops,
    }


def main(out_json=None, quick=False, n=None):
    n = n or (1024 if quick else 4096)
    rows = []
    print(f"{'spec':22s} {'t_comp':>8s} {'t_mem':>8s} {'bound':>7s} "
          f"{'emulTFLOPS':>10s}  (n={n})")
    for spec in ("ozimmu-8", "ozimmu_rn-8", "ozimmu_ef-8", "ozimmu_h-8",
                 "ozimmu_h-8:df32", "ozimmu_h-8:f32"):
        r = analyze_variant(spec, n,
                            jnp.float64 if spec.endswith("-8") or
                            ":f64" in spec else jnp.float32)
        rows.append(r)
        print(f"{r['spec']:22s} {r['t_compute_ms']:7.2f}m "
              f"{r['t_memory_ms']:7.2f}m {r['bound']:>7s} "
              f"{r['emulated_tflops_bound']:10.1f}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(n=args.n, quick=args.quick)
