"""Exact-product reference: vectorized double-double (Dekker/TwoSum) matmul.

Used as the "truth" for accuracy experiments and tests: effective precision
~2^-106, far below both FP64 (2^-53) and every ozimmu configuration measured.
Pure numpy.  The contraction loop is BLOCKED: the two-products of a chunk
of ``block`` columns are evaluated in one vectorized (m, block, p) shot,
and only the (order-sensitive) TwoSum accumulation walks the chunk —
bit-identical to the original one-column-at-a-time loop, ~3x fewer numpy
dispatches, which is what lets the adversarial oracle harness
(tests/test_oracle.py) stay inside tier-1 time.
"""
from __future__ import annotations

import numpy as np

_SPLITTER = 134217729.0  # 2^27 + 1, Dekker split constant for f64


def _two_prod(a: np.ndarray, b: np.ndarray):
    """a*b = p + e exactly (Dekker two-product, no FMA needed)."""
    p = a * b
    a1 = a * _SPLITTER
    ah = a1 - (a1 - a)
    al = a - ah
    b1 = b * _SPLITTER
    bh = b1 - (b1 - b)
    bl = b - bh
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def _two_sum(a: np.ndarray, b: np.ndarray):
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def dd_matmul(a: np.ndarray, b: np.ndarray, block: int | None = None):
    """Double-double A @ B. Returns (hi, lo) with hi + lo accurate to ~2^-106.

    ``block`` trades the O(m*block*p) two-product workspace against numpy
    dispatch overhead; every block size produces bit-identical output (the
    TwoSum accumulation order is the column order regardless).  The
    default adapts to the output size: skinny/long contractions (small
    m*p, large n — the dispatch-bound regime, 2-3.5x measured) get large
    blocks, big outputs stay at block 1 where the (m, p) working set
    already fills the cache.
    """
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    m, n = a.shape
    n2, p = b.shape
    assert n == n2
    if block is None:
        block = max(1, min(64, (1 << 14) // max(m * p, 1)))
    hi = np.zeros((m, p))
    lo = np.zeros((m, p))
    for j0 in range(0, n, block):
        j1 = min(j0 + block, n)
        # all two-products of the chunk at once: (m, c, p)
        prod, perr = _two_prod(a[:, j0:j1, None], b[None, j0:j1, :])
        for i in range(j1 - j0):
            hi, e = _two_sum(hi, prod[:, i, :])
            lo += e + perr[:, i, :]
    # final renormalize
    hi2, e2 = _two_sum(hi, lo)
    return hi2, e2


def max_relative_error(approx: np.ndarray, exact_hi: np.ndarray,
                       exact_lo: np.ndarray) -> float:
    """max_ij |approx - exact| / |exact|  (dd-accurate difference)."""
    diff = (approx - exact_hi) - exact_lo
    denom = np.maximum(np.abs(exact_hi), np.finfo(np.float64).tiny)
    return float(np.max(np.abs(diff) / denom))
