"""Exact-product reference: vectorized double-double (Dekker/TwoSum) matmul.

Used as the "truth" for accuracy experiments and tests: effective precision
~2^-106, far below both FP64 (2^-53) and every ozimmu configuration measured.
Pure numpy; O(n) python-loop over the contraction axis with vectorized
(m, p) updates.
"""
from __future__ import annotations

import numpy as np

_SPLITTER = 134217729.0  # 2^27 + 1, Dekker split constant for f64


def _two_prod(a: np.ndarray, b: np.ndarray):
    """a*b = p + e exactly (Dekker two-product, no FMA needed)."""
    p = a * b
    a1 = a * _SPLITTER
    ah = a1 - (a1 - a)
    al = a - ah
    b1 = b * _SPLITTER
    bh = b1 - (b1 - b)
    bl = b - bh
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def _two_sum(a: np.ndarray, b: np.ndarray):
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def dd_matmul(a: np.ndarray, b: np.ndarray):
    """Double-double A @ B. Returns (hi, lo) with hi + lo accurate to ~2^-106."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    m, n = a.shape
    n2, p = b.shape
    assert n == n2
    hi = np.zeros((m, p))
    lo = np.zeros((m, p))
    for j in range(n):
        prod, perr = _two_prod(a[:, j:j + 1], b[j:j + 1, :])
        hi, e = _two_sum(hi, prod)
        lo += e + perr
    # final renormalize
    hi2, e2 = _two_sum(hi, lo)
    return hi2, e2


def max_relative_error(approx: np.ndarray, exact_hi: np.ndarray,
                       exact_lo: np.ndarray) -> float:
    """max_ij |approx - exact| / |exact|  (dd-accurate difference)."""
    diff = (approx - exact_hi) - exact_lo
    denom = np.maximum(np.abs(exact_hi), np.finfo(np.float64).tiny)
    return float(np.max(np.abs(diff) / denom))
