"""Paper Fig. 14: performance vs accuracy Pareto for n-fixed, phi=0.

Accuracy is MEASURED (CPU, real arithmetic, dd reference); throughput is
MODELED (v5e phase costs) at the paper's n=4096.  Paper claims reproduced:
H-k sits Pareto-left of base-(k+1) (same accuracy at ~one fewer slice with
group-EF speed), and EF tracks base accuracy at EF speed.
"""
from __future__ import annotations

import json

import numpy as np

import jax.numpy as jnp

from benchmarks.bench_accuracy import make_phi_matrix
from benchmarks.exact import dd_matmul, max_relative_error
from benchmarks.model_v5e import emulated_tflops
from repro.core import ozimmu

VARIANTS = ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h")


def run(n_acc: int = 256, n_perf: int = 4096, ks=range(3, 13), phi=0.0,
        seed=0):
    rng = np.random.default_rng(seed)
    a = make_phi_matrix(rng, n_acc, n_acc, phi)
    b = make_phi_matrix(rng, n_acc, n_acc, phi)
    hi, lo = dd_matmul(a, b)
    aj, bj = jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64)
    rows = []
    for k in ks:
        for variant in VARIANTS:
            cfg = ozimmu.VARIANTS[variant].with_(k=k)
            c = np.asarray(ozimmu.ozimmu_matmul(aj, bj, cfg))
            err = max_relative_error(c, hi, lo)
            tf = emulated_tflops(n_perf, n_perf, n_perf, k, variant=variant)
            rows.append({"variant": variant, "k": k, "err": err,
                         "tflops": tf})
    return rows


def main(out_json=None, quick=False):
    rows = run(n_acc=128 if quick else 256,
               ks=(6, 8) if quick else range(3, 13))
    print(f"{'variant':12s} {'k':>3s} {'err':>10s} {'tflops@4096':>12s}")
    for r in rows:
        print(f"{r['variant']:12s} {r['k']:3d} {r['err']:10.2e} "
              f"{r['tflops']:12.1f}")
    # paper's pareto claim: ozimmu_h at k matches ozimmu accuracy at k+1.
    # Only meaningful ABOVE the f64 error floor — once both variants hit
    # ~u = 2^-53 the one-slice relation is rounding noise (phi=0 matrices
    # reach the floor by k~8, exactly as in the paper's Fig. 14 where the
    # curves merge at the bottom).
    idx = {(r["variant"], r["k"]): r for r in rows}
    # Pareto-dominance at equal k (the figure's visible claim): H is both
    # faster (group-EF) and not less accurate (RN) than base.  At phi=0
    # accuracies tie to within 2x (paper Fig. 14: curves overlap); the
    # one-k-earlier fp64 crossing shows at phi=2 (bench_accuracy).
    claims = []
    for k in sorted({r["k"] for r in rows}):
        if ("ozimmu_h", k) in idx and ("ozimmu", k) in idx:
            h, b = idx[("ozimmu_h", k)], idx[("ozimmu", k)]
            claims.append(h["tflops"] >= 1.2 * b["tflops"] and
                          h["err"] <= 2.0 * b["err"])
    print(f"[pareto] H Pareto-dominates base at equal k "
          f"(>=1.2x speed, <=2x err): {sum(claims)}/{len(claims)}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
