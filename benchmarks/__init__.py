# The paper's scheme emulates FP64 GEMMs; x64 must be on before jax init.
import os
os.environ.setdefault("JAX_ENABLE_X64", "true")
