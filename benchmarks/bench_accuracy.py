"""Paper Figs. 1 & 5: accuracy of the four ozIMMU variants vs k and phi.

Matrices a_ij = (U_ij - 0.5) * exp(phi * N_ij) (the paper's generator);
reference product via double-double matmul (~2^-106).  Expected (paper):
RN/H beat bitmask (ozIMMU/EF) at equal k — roughly one slice's worth of
accuracy — and EF tracks ozIMMU / H tracks RN (grouping is error-free).
"""
from __future__ import annotations

import json

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.exact import dd_matmul, max_relative_error
from repro.core import ozimmu, plan

VARIANTS = ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h",
            "ozimmu_sm_b", "ozimmu_sm_h",
            "oz2_b", "oz2_h", "oz2_h_fast", "oz2_h_fast2")

# Planner-economy rows: det/prob auto-spec twins, probed on the same phi
# operands as the fixed-k grid.  Rows carry ``"auto": True`` so the
# fixed-k grid (and its headline err dict in benchmarks/run.py) stays
# untouched; run.py pairs ``<label>_prob`` with ``<label>`` into the
# ``prob_auto`` headline with the GEMM-count deltas.
AUTO_SPECS = (
    ("ozimmu_h_auto", "ozimmu_h-auto"),
    ("ozimmu_h_auto_prob", "ozimmu_h-auto:prob"),
    ("oz2_h_fast2_auto", "oz2_h-auto:fast2"),
    ("oz2_h_fast2_auto_prob", "oz2_h-auto:fast2:prob"),
    ("ozimmu_sm_h_auto", "ozimmu_sm_h-auto"),
    ("ozimmu_sm_h_auto_prob", "ozimmu_sm_h-auto:prob"),
)


def variant_cfg(variant: str, k: int):
    """Bench variant label -> config; the ``_fast`` suffix selects the
    oz2 diagonal-band mode, ``_fast2`` the improved-scaling band mode."""
    if variant.endswith("_fast2"):
        name, fast = variant[:-6], "fast2"
    elif variant.endswith("_fast"):
        name, fast = variant[:-5], True
    else:
        name, fast = variant, False
    return ozimmu.canonical_fast2(ozimmu.VARIANTS[name].with_(k=k, fast=fast))


def make_phi_matrix(rng, m, n, phi):
    u = rng.uniform(0.0, 1.0, (m, n))
    z = rng.standard_normal((m, n))
    return (u - 0.5) * np.exp(phi * z)


def run(n: int = 256, ks=(3, 4, 5, 6, 7, 8, 9, 10, 11, 12),
        phis=(0.5, 1.0, 2.0), seed: int = 0, verbose: bool = True):
    rng = np.random.default_rng(seed)
    rows = []
    for phi in phis:
        a = make_phi_matrix(rng, n, n, phi)
        b = make_phi_matrix(rng, n, n, phi)
        hi, lo = dd_matmul(a, b)
        aj = jnp.asarray(a, jnp.float64)
        bj = jnp.asarray(b, jnp.float64)
        # FP64 GEMM baseline error
        fp64 = np.asarray(aj @ bj)
        err64 = max_relative_error(fp64, hi, lo)
        rows.append({"phi": phi, "variant": "fp64", "k": 0, "err": err64})
        if verbose:
            print(f"phi={phi:4.1f}  fp64          err={err64:9.2e}")
        for k in ks:
            for variant in VARIANTS:
                cfg = variant_cfg(variant, k)
                c = np.asarray(ozimmu.ozimmu_matmul(aj, bj, cfg))
                err = max_relative_error(c, hi, lo)
                rows.append({"phi": phi, "variant": variant, "k": k,
                             "err": err})
                if verbose:
                    print(f"phi={phi:4.1f}  {variant:12s} k={k:2d} "
                          f"err={err:9.2e}")
        # auto-k twins: the probed planner resolves k per operand pair;
        # the eager ozimmu_matmul call below probes the same operands, so
        # measured err corresponds to exactly the planned k.
        for label, spec in AUTO_SPECS:
            cfg = ozimmu.parse_spec(spec)
            pl = plan.plan_contraction(cfg, n, n, n, a=aj, b=bj)
            c = np.asarray(ozimmu.ozimmu_matmul(aj, bj, cfg))
            err = max_relative_error(c, hi, lo)
            rows.append({"phi": phi, "variant": label, "k": pl.k,
                         "err": err, "auto": True, "spec": spec,
                         "int8_gemms": pl.int8_gemms,
                         "hp_adds": pl.highprec_adds})
            if verbose:
                print(f"phi={phi:4.1f}  {label:22s} k={pl.k:2d} "
                      f"gemms={pl.int8_gemms:3d} err={err:9.2e}")
    return rows


def main(out_json=None, quick=False):
    rows = run(n=128 if quick else 256,
               ks=(4, 6, 8) if quick else (3, 4, 5, 6, 7, 8, 9, 10, 11, 12),
               phis=(0.5, 2.0) if quick else (0.5, 1.0, 2.0))
    # paper claim check: RN/H at k at least as accurate as bitmask at k
    claims = []
    by = {(r["phi"], r["variant"], r["k"]): r["err"] for r in rows}
    for (phi, v, k), err in list(by.items()):
        if v == "ozimmu_rn" and (phi, "ozimmu", k) in by:
            claims.append(err <= by[(phi, "ozimmu", k)] * 4)
    ok = all(claims) if claims else False
    print(f"[accuracy] RN<=bitmask at equal k: {sum(claims)}/{len(claims)} "
          f"cells ({'OK' if ok else 'CHECK'})")
    # probabilistic planner economy: every :prob auto spec must resolve
    # k (and GEMMs) no larger than its deterministic twin on every cell
    auto = {(r["phi"], r["variant"]): r for r in rows if r.get("auto")}
    for (phi, label), r in sorted(auto.items()):
        if not label.endswith("_prob"):
            continue
        det = auto.get((phi, label[: -len("_prob")]))
        if det is None:
            continue
        econ = (r["k"] <= det["k"]
                and r["int8_gemms"] <= det["int8_gemms"])
        print(f"[accuracy] phi={phi}: {label} k={r['k']} "
              f"gemms={r['int8_gemms']} vs det k={det['k']} "
              f"gemms={det['int8_gemms']} "
              f"({'OK' if econ else 'CHECK'})")
    # paper §4.1, phi=2: RN/H crosses fp64 accuracy at a smaller k than
    # bitmask ("ozIMMU_RN-9 comparable to FP64; ozIMMU needs k=10")
    for phi in sorted({r["phi"] for r in rows if r["variant"] != "fp64"}):
        f64 = by.get((phi, "fp64", 0))
        if f64 is None:
            continue
        def crossing(variant):
            ks = sorted(k for (p, v, k) in by if p == phi and v == variant)
            for k in ks:
                if by[(phi, variant, k)] <= f64:
                    return k
            return None
        cb, ch = crossing("ozimmu"), crossing("ozimmu_h")
        if cb and ch:
            verdict = "OK" if ch <= cb else "CHECK"
            print(f"[accuracy] phi={phi}: fp64-crossing k: bitmask={cb} "
                  f"H={ch} ({verdict})")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
