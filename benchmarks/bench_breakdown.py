"""Paper Figs. 2-3 & 6-11: time breakdown of the Ozaki-scheme phases.

CPU container => the v5e phase-cost model prices exact per-phase op/byte
counts (benchmarks.model_v5e); the paper's qualitative claims to reproduce:

  * base ozIMMU: FP64 accumulation ~= 40-50 % of total time;
  * ozIMMU_EF / _H cut the accumulation share to ~10-20 %;
  * ozIMMU_RN does NOT cut it (same number of FP64 additions).

Also cross-checked: CPU wall-clock of the jitted phases (ordering only).
"""
from __future__ import annotations

import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.model_v5e import base_variant, phase_times, variant_split
from repro.core import ozimmu
from repro.core.accumulate import (num_highprec_adds, oz2_num_highprec_adds,
                                   oz2_num_pairs)
from repro.core.splitting import beta_for, compute_r, digit_bits

VARIANTS = ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h", "ozimmu_sm_h",
            "oz2_h", "oz2_h_fast", "oz2_h_fast2")

# det/prob auto-spec twins: the STATIC (jit-path) k the planner resolves
# with no operands to probe — the k every serving contraction pays —
# priced through the same phase model.  Rows carry ``"plan": "auto"`` so
# the fixed-k grid and its headline stay untouched.
AUTO_SPECS = (
    ("ozimmu_h_auto", "ozimmu_h-auto"),
    ("ozimmu_h_auto_prob", "ozimmu_h-auto:prob"),
    ("oz2_h_fast2_auto", "oz2_h-auto:fast2"),
    ("oz2_h_fast2_auto_prob", "oz2_h-auto:fast2:prob"),
)


def _counts(variant: str, n: int, k: int):
    """(int8_gemms, hp_adds) — the Plan cost accounting per variant, at
    the bench's paper-faithful f64 accumulator (52-bit ladder words)."""
    beta = beta_for(variant_split(variant), n)
    if variant.startswith("oz2"):
        fast = variant.endswith("_fast") or variant.endswith("_fast2")
        dbits = digit_bits(variant_split(variant), beta)
        r = compute_r(n, beta, dbits)
        return (oz2_num_pairs(k, fast),
                oz2_num_highprec_adds(k, r, beta, n, fast, dbits,
                                      word_bits=52))
    group_ef = variant in ("ozimmu_ef", "ozimmu_h", "ozimmu_sm_h")
    return (k * (k + 1) // 2,
            num_highprec_adds(k, compute_r(n, beta), group_ef))


def modeled(n: int = 4096, ks=(7, 8, 9, 10)):
    rows = []
    for k in ks:
        for variant in VARIANTS:
            pt = phase_times(n, n, n, k, variant=variant)
            unfused = phase_times(n, n, n, k, variant=variant,
                                  fused_split=False, fused_epilogue=False)
            gemms, adds = _counts(variant, n, k)
            rows.append({"n": n, "k": k, "variant": variant,
                         "total_ms": pt.total * 1e3,
                         "int8_gemms": gemms, "hp_adds": adds,
                         "fused_pipeline_speedup": unfused.total / pt.total,
                         **{f"share_{f}": s
                            for f, s in pt.shares().items()}})
    return rows


def auto_planned(n: int = 4096):
    """Static auto-k plan cost rows for the det/prob spec twins."""
    from repro.core import plan
    rows = []
    for label, spec in AUTO_SPECS:
        cfg = ozimmu.parse_spec(spec)
        pl = plan.plan_contraction(cfg, n, n, n)
        pt = phase_times(n, n, n, pl.k, variant=base_variant(label))
        rows.append({"n": n, "k": pl.k, "variant": label, "plan": "auto",
                     "spec": spec, "total_ms": pt.total * 1e3,
                     "int8_gemms": pl.int8_gemms,
                     "hp_adds": pl.highprec_adds,
                     **{f"share_{f}": s for f, s in pt.shares().items()}})
    return rows


def measured_cpu(n: int = 512, k: int = 8):
    """CPU wall-clock sanity check of the full emulation per variant."""
    from benchmarks.bench_accuracy import variant_cfg
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float64)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float64)
    out = {}
    for variant in VARIANTS:
        cfg = variant_cfg(variant, k)
        fn = jax.jit(lambda a, b: ozimmu.ozimmu_matmul(a, b, cfg))
        fn(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fn(a, b).block_until_ready()
        out[variant] = (time.perf_counter() - t0) / 3
    return out


def main(out_json=None, quick=False):
    rows = modeled(n=4096, ks=(8,) if quick else (7, 8, 9, 10))
    rows += auto_planned(n=4096)
    fixed = [r for r in rows if r.get("plan") != "auto"]
    auto = {r["variant"]: r for r in rows if r.get("plan") == "auto"}
    print(f"{'variant':22s} {'k':>2s} {'total_ms':>9s} "
          f"{'split':>6s} {'gemm':>6s} {'accum':>6s} {'copy':>6s}")
    for r in rows:
        print(f"{r['variant']:22s} {r['k']:2d} {r['total_ms']:9.3f} "
              f"{r['share_split']:6.1%} {r['share_gemm']:6.1%} "
              f"{r['share_accum']:6.1%} {r['share_copy']:6.1%}")
    base = {r["k"]: r for r in rows if r["variant"] == "ozimmu"}
    h = {r["k"]: r for r in rows if r["variant"] == "ozimmu_h"}
    for r in fixed:
        if r["variant"] in ("ozimmu_ef", "ozimmu_h", "ozimmu_sm_h", "oz2_h",
                            "oz2_h_fast", "oz2_h_fast2"):
            sp = base[r["k"]]["total_ms"] / r["total_ms"]
            r["speedup_vs_ozimmu"] = sp
    checks = {
        "base_accum_share_40_50pct": all(
            0.25 <= r["share_accum"] <= 0.60 for r in rows
            if r["variant"] == "ozimmu"),
        "ef_h_accum_share_le_20pct": all(
            r["share_accum"] <= 0.25 for r in rows
            if r["variant"] in ("ozimmu_ef", "ozimmu_h")),
        "ef_speedup_1.2_1.6": all(
            1.1 <= r.get("speedup_vs_ozimmu", 1.3) <= 2.0 for r in rows
            if r["variant"] == "ozimmu_ef"),
        # the one-HBM-pass pipeline (fused split + fused epilogue) must be
        # a genuine modeled win over per-slice/materializing passes for
        # every memory-bound paper variant (the oz2 ladder leaves so little
        # epilogue traffic that fusing it is a smaller, not-asserted win)
        "fused_pipeline_speedup_ge_1.2": all(
            r["fused_pipeline_speedup"] >= 1.2 for r in fixed
            if not r["variant"].startswith("oz2")),
        # the oz2 exponent ladder: strictly fewer high-precision adds than
        # group-EF at equal k, and a strictly faster modeled total
        "oz2_fast_fewer_hp_adds_than_h": all(
            r["hp_adds"] < h[r["k"]]["hp_adds"] for r in rows
            if r["variant"] == "oz2_h_fast"),
        "oz2_fast_total_faster_than_h": all(
            r["total_ms"] < h[r["k"]]["total_ms"] for r in rows
            if r["variant"] == "oz2_h_fast"),
        # fast2 (improved scaling) runs the same band + int8 GEMM count as
        # fast; its only extra cost is the exact diag-unscale pass, so the
        # modeled total stays within 5% of fast and still beats group-EF
        "oz2_fast2_same_gemms_as_fast": all(
            r["int8_gemms"] == next(
                s["int8_gemms"] for s in rows
                if s["variant"] == "oz2_h_fast" and s["k"] == r["k"])
            for r in rows if r["variant"] == "oz2_h_fast2"),
        "oz2_fast2_total_near_fast": all(
            r["total_ms"] <= 1.05 * next(
                s["total_ms"] for s in rows
                if s["variant"] == "oz2_h_fast" and s["k"] == r["k"])
            for r in rows if r["variant"] == "oz2_h_fast2"),
        "oz2_fast2_total_faster_than_h": all(
            r["total_ms"] < h[r["k"]]["total_ms"] for r in rows
            if r["variant"] == "oz2_h_fast2"),
        # the probabilistic planner's static shave (acceptance): each
        # :prob auto twin resolves strictly smaller k and strictly fewer
        # int8 GEMMs than its deterministic twin at the jit-path plan
        "prob_auto_strictly_fewer_gemms": all(
            auto[lbl]["k"] < auto[lbl[: -len("_prob")]]["k"]
            and auto[lbl]["int8_gemms"]
            < auto[lbl[: -len("_prob")]]["int8_gemms"]
            for lbl in auto if lbl.endswith("_prob")),
    }
    for lbl, r in sorted(auto.items()):
        if lbl.endswith("_prob"):
            det = auto[lbl[: -len("_prob")]]
            print(f"[breakdown] {lbl}: static k={r['k']} "
                  f"gemms={r['int8_gemms']} vs det k={det['k']} "
                  f"gemms={det['int8_gemms']} "
                  f"(saves {det['int8_gemms'] - r['int8_gemms']})")
    for name, ok in checks.items():
        print(f"[breakdown] {name}: {'OK' if ok else 'CHECK'}")
    cpu = measured_cpu(n=256 if quick else 512)
    print("[breakdown] cpu wall-clock (ordering sanity):",
          {k: f"{v * 1e3:.1f}ms" for k, v in cpu.items()})
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"modeled": rows, "cpu_measured": cpu,
                       "checks": checks}, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
