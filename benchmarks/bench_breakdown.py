"""Paper Figs. 2-3 & 6-11: time breakdown of the Ozaki-scheme phases.

CPU container => the v5e phase-cost model prices exact per-phase op/byte
counts (benchmarks.model_v5e); the paper's qualitative claims to reproduce:

  * base ozIMMU: FP64 accumulation ~= 40-50 % of total time;
  * ozIMMU_EF / _H cut the accumulation share to ~10-20 %;
  * ozIMMU_RN does NOT cut it (same number of FP64 additions).

Also cross-checked: CPU wall-clock of the jitted phases (ordering only).
"""
from __future__ import annotations

import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.model_v5e import phase_times
from repro.core import ozimmu

VARIANTS = ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h")


def modeled(n: int = 4096, ks=(7, 8, 9, 10)):
    rows = []
    for k in ks:
        for variant in VARIANTS:
            pt = phase_times(n, n, n, k, variant=variant)
            unfused = phase_times(n, n, n, k, variant=variant,
                                  fused_split=False, fused_epilogue=False)
            rows.append({"n": n, "k": k, "variant": variant,
                         "total_ms": pt.total * 1e3,
                         "fused_pipeline_speedup": unfused.total / pt.total,
                         **{f"share_{f}": s
                            for f, s in pt.shares().items()}})
    return rows


def measured_cpu(n: int = 512, k: int = 8):
    """CPU wall-clock sanity check of the full emulation per variant."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float64)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float64)
    out = {}
    for variant in VARIANTS:
        cfg = ozimmu.VARIANTS[variant].with_(k=k)
        fn = jax.jit(lambda a, b: ozimmu.ozimmu_matmul(a, b, cfg))
        fn(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fn(a, b).block_until_ready()
        out[variant] = (time.perf_counter() - t0) / 3
    return out


def main(out_json=None, quick=False):
    rows = modeled(n=4096, ks=(8,) if quick else (7, 8, 9, 10))
    print(f"{'variant':12s} {'k':>2s} {'total_ms':>9s} "
          f"{'split':>6s} {'gemm':>6s} {'accum':>6s} {'copy':>6s}")
    for r in rows:
        print(f"{r['variant']:12s} {r['k']:2d} {r['total_ms']:9.3f} "
              f"{r['share_split']:6.1%} {r['share_gemm']:6.1%} "
              f"{r['share_accum']:6.1%} {r['share_copy']:6.1%}")
    base = {r["k"]: r for r in rows if r["variant"] == "ozimmu"}
    for r in rows:
        if r["variant"] in ("ozimmu_ef", "ozimmu_h"):
            sp = base[r["k"]]["total_ms"] / r["total_ms"]
            r["speedup_vs_ozimmu"] = sp
    checks = {
        "base_accum_share_40_50pct": all(
            0.25 <= r["share_accum"] <= 0.60 for r in rows
            if r["variant"] == "ozimmu"),
        "ef_h_accum_share_le_20pct": all(
            r["share_accum"] <= 0.25 for r in rows
            if r["variant"] in ("ozimmu_ef", "ozimmu_h")),
        "ef_speedup_1.2_1.6": all(
            1.1 <= r.get("speedup_vs_ozimmu", 1.3) <= 2.0 for r in rows
            if r["variant"] == "ozimmu_ef"),
        # the one-HBM-pass pipeline (fused split + fused epilogue) must be
        # a genuine modeled win over per-slice/materializing passes for
        # every memory-bound variant
        "fused_pipeline_speedup_ge_1.2": all(
            r["fused_pipeline_speedup"] >= 1.2 for r in rows),
    }
    for name, ok in checks.items():
        print(f"[breakdown] {name}: {'OK' if ok else 'CHECK'}")
    cpu = measured_cpu(n=256 if quick else 512)
    print("[breakdown] cpu wall-clock (ordering sanity):",
          {k: f"{v * 1e3:.1f}ms" for k, v in cpu.items()})
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"modeled": rows, "cpu_measured": cpu,
                       "checks": checks}, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
