"""Paper Figs. 12-13: emulated-GEMM throughput (TFLOPS) vs n per variant/k.

Modeled on the v5e phase-cost model (CPU container).  Paper claims to
reproduce structurally: EF/H faster than base ozIMMU everywhere (1.2-1.6x),
RN slower than base (extra rowmax passes), throughput grows with n (GEMM
amortizes the memory-bound phases) and falls with k (quadratic pair count).
"""
from __future__ import annotations

import json

from benchmarks.model_v5e import emulated_tflops

VARIANTS = ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h", "ozimmu_sm_h",
            "oz2_h_fast", "oz2_h_fast2")


def run(ns=(1024, 2048, 4096, 8192, 16384), ks=(3, 7, 8, 12)):
    rows = []
    for n in ns:
        for k in ks:
            for variant in VARIANTS:
                tf = emulated_tflops(n, n, n, k, variant=variant)
                rows.append({"n": n, "k": k, "variant": variant,
                             "tflops": tf})
    return rows


def main(out_json=None, quick=False):
    rows = run(ns=(1024, 4096) if quick else (1024, 2048, 4096, 8192, 16384),
               ks=(3, 8) if quick else (3, 7, 8, 12))
    idx = {(r["n"], r["k"], r["variant"]): r["tflops"] for r in rows}
    print(f"{'n':>6s} {'k':>3s}  " + "  ".join(f"{v:>10s}" for v in VARIANTS)
          + "   EF/base  H/base")
    checks_ef = []
    for n in sorted({r["n"] for r in rows}):
        for k in sorted({r["k"] for r in rows}):
            vals = [idx[(n, k, v)] for v in VARIANTS]
            ef_ratio = vals[2] / vals[0]
            h_ratio = vals[3] / vals[0]
            checks_ef.append(ef_ratio > 1.05)
            print(f"{n:6d} {k:3d}  " + "  ".join(f"{v:10.1f}" for v in vals)
                  + f"   {ef_ratio:6.2f}  {h_ratio:6.2f}")
    ok = all(checks_ef)
    print(f"[throughput] EF > base everywhere: {'OK' if ok else 'CHECK'}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
