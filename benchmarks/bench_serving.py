"""Serving bench: offline request-trace replay through the runtime.

Replays a deterministic Poisson-arrival trace of mixed prompt/generation
lengths through three configurations per engine:

  legacy    the pre-runtime serve loop (fixed synchronized waves of
            ``slots`` requests: per-position prefill of the padded wave,
            then max-generation decode for everyone — useful tokens only
            are counted, exactly what that loop delivered)
  uncached  the continuous-batching runtime with the weight split-cache
            DISABLED (every decode step re-splits the weights)
  cached    the runtime with the split-cache on (the default)

and emits tokens/s + TTFT + split-cache savings rows, plus the
deterministic v5e decode-step phase model showing the weight-side
splitter cost going to ~0 under the cache
(``model_v5e.decode_phase_times``).  Arrivals are measured in scheduler
rounds (offline replay is CPU-speed independent; Poisson gaps stagger
admissions so the continuous refill path is exercised).

Headline + regression gate: ``benchmarks/run.py`` (``--only serving``;
the gate checks the split-cache hit rate and bench health — wall-clock
speedups are recorded, not gated, because CI machines are noisy).
"""
from __future__ import annotations

import json
import time
from typing import List, Optional

import numpy as np

ARCH = "internlm2_1_8b"
SLOTS = 4
MAX_LEN = 96


def make_trace(rng: np.random.Generator, n_requests: int, vocab: int,
               max_len: int, mean_gap_steps: float = 2.0) -> List[dict]:
    """Deterministic mixed-length request trace with Poisson arrivals.

    Prompt lengths are log-uniform-ish in [4, max_len // 3]; generation
    budgets uniform in [4, max_len // 3]; arrival_step is the scheduler
    round at which the request enters the queue (cumulative exponential
    gaps — Poisson arrivals in round-time).
    """
    hi = max(6, max_len // 3)
    out, t = [], 0.0
    for _ in range(n_requests):
        plen = int(np.exp(rng.uniform(np.log(4), np.log(hi))))
        gen = int(rng.integers(4, hi))
        t += rng.exponential(mean_gap_steps)
        out.append({
            "prompt": rng.integers(0, vocab, size=plen, dtype=np.int32),
            "max_new": gen,
            "arrival_step": int(t),
        })
    return out


def legacy_generate(cfg, model, params, prompts, gens, slots, max_len):
    """The pre-runtime serve loop (launch/serve.py before the serving
    subsystem): synchronized waves of ``slots`` requests — per-position
    prefill of the wave's padded prompts, then one decode step per token
    up to the wave's LONGEST generation budget.  Returns useful tokens."""
    import jax
    import jax.numpy as jnp

    decode = jax.jit(
        lambda c, t, n: model.decode_step(params, cfg, c, t, n))
    outs = []
    for w0 in range(0, len(prompts), slots):
        wave = prompts[w0:w0 + slots]
        wave_gens = gens[w0:w0 + slots]
        B = len(wave)
        wave = wave + [wave[-1]] * (slots - B)
        max_prompt = max(len(p) for p in wave)
        cache = model.init_cache(cfg, slots, max_len, params=params,
                                 ctx=None)
        toks = np.zeros((slots, max_prompt), np.int32)
        for i, p in enumerate(wave):
            toks[i, :len(p)] = p
        logits = None
        for t in range(max_prompt):
            logits, cache = decode(cache, jnp.asarray(toks[:, t:t + 1]),
                                   jnp.asarray(t + 1, jnp.int32))
        gen_out = [[] for _ in range(B)]
        cur = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(
            jnp.int32)
        for g in range(max(wave_gens)):
            for i in range(B):
                if g < wave_gens[i]:
                    gen_out[i].append(int(cur[i]))
            logits, cache = decode(cache, cur[:, None],
                                   jnp.asarray(max_prompt + g + 1,
                                               jnp.int32))
            cur = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(
                jnp.int32)
        outs.extend(gen_out)
    return outs


def replay(runtime, trace) -> dict:
    """Drive the runtime, submitting each request at its arrival round
    (Poisson-staggered admissions exercise the continuous slot refill)."""
    pending = sorted(trace, key=lambda r: r["arrival_step"])
    i, step = 0, 0
    while i < len(pending) or not runtime.sched.all_done:
        while i < len(pending) and pending[i]["arrival_step"] <= step:
            runtime.submit(pending[i]["prompt"], pending[i]["max_new"])
            i += 1
        runtime.step()
        step += 1
    return runtime.run()  # idle: finalizes and returns the summary


def steady_state(runtime, trace, warm_passes: int = 1) -> dict:
    """Measured steady-state replay: warm pass(es) first, THEN a metrics
    reset, THEN the timed pass — compiles never land in the headline.

    ``warm_passes`` must cover every compilation the measured pass will
    trigger.  One pass suffices for a plain runtime (it compiles every
    prefill bucket).  A prefix-cached runtime needs TWO: the first pass
    runs entirely cold (entries publish only as it prefills — when the
    requests all fit in the slots they admit in one wave before anything
    is published, so pass one gets zero hits) and therefore never
    compiles the hit path's suffix-length buckets; those would otherwise
    compile inside the measured window, which is exactly the
    first-pass-measurement bug this helper exists to prevent
    (tests/test_bench_gate.py pins the ordering)."""
    for _ in range(warm_passes):
        replay(runtime, trace)
    runtime.reset_metrics()
    return replay(runtime, trace)


def make_shared_prefix_trace(rng: np.random.Generator, n_requests: int,
                             vocab: int, prefix_len: int = 48,
                             suffix_len: int = 4, gen: int = 8) -> List[dict]:
    """The system-prompt regime: every request is one shared
    ``prefix_len``-token prefix plus a short private suffix."""
    prefix = rng.integers(0, vocab, size=prefix_len, dtype=np.int32)
    out, t = [], 0.0
    for _ in range(n_requests):
        sfx = rng.integers(0, vocab, size=suffix_len, dtype=np.int32)
        t += rng.exponential(1.0)
        out.append({"prompt": np.concatenate([prefix, sfx]),
                    "max_new": gen, "arrival_step": int(t)})
    return out


def main(out_json: Optional[str] = None, quick: bool = False):
    import jax

    from benchmarks import model_v5e
    from repro import configs
    from repro.core import plan
    from repro.models import api
    from repro.obs import registry as obs_registry
    from repro.serving import ServingRuntime

    engines = ["bf16", "ozimmu_h-4:df32"] if quick else \
        ["bf16", "ozimmu_h-4:df32", "oz2_h-4:df32:fast"]
    n_requests = 6 if quick else 10
    rows = []
    rng = np.random.default_rng(20260728)

    for spec in engines:
        cfg = configs.get_config(ARCH, smoke=True, engine_spec=spec)
        model = api.get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        trace = make_trace(rng, n_requests, cfg.vocab, MAX_LEN)
        prompts = [r["prompt"] for r in trace]
        gens = [r["max_new"] for r in trace]
        useful = sum(gens)

        # legacy baseline (pre-runtime loop).  All modes are timed in
        # steady state: one warm pass compiles every step (the runtime's
        # per-bucket prefill scans are the expensive traces), the second
        # pass is measured — serving throughput is an amortized quantity.
        legacy_generate(cfg, model, params, prompts, gens, SLOTS, MAX_LEN)
        t0 = time.time()
        legacy_out = legacy_generate(cfg, model, params, prompts, gens,
                                     SLOTS, MAX_LEN)
        legacy_dt = time.time() - t0
        assert sum(len(o) for o in legacy_out) == useful

        modes = [("uncached", False)] if cfg.engine.is_ozimmu else []
        modes += [("cached", None)]
        per_mode = {"legacy": {"tokens_per_s": useful / legacy_dt,
                               "seconds": legacy_dt}}
        reg0 = obs_registry.get_registry().snapshot()
        for mode, presplit in modes:
            runtime = ServingRuntime(cfg, params, slots=SLOTS,
                                     max_len=MAX_LEN, presplit=presplit)
            summary = steady_state(runtime, trace)
            per_mode[mode] = {
                "tokens_per_s": summary["tokens_per_s"],
                "seconds": summary["elapsed_s"],
                "ttft_mean_s": summary["ttft_s"]["mean"],
                "ttft_p95_s": summary["ttft_s"]["p95"],
                "split_cache": summary["split_cache"],
            }
            assert summary["tokens_generated"] == useful, \
                (summary["tokens_generated"], useful)

        # observed emulation counters (trace-time registry diff over this
        # engine's replays, plus the first decode step's capture): the
        # per-weight int8-GEMM count the runtime actually executed, next
        # to the Plan number it should execute.  Any divergence means the
        # emulation ran contractions the cost accounting doesn't know
        # about (or vice versa) — loud, not fatal: the bench still
        # reports, the gate diffs the row.
        observed = None
        oz = cfg.engine.ozimmu_config
        if oz is not None and runtime.decode_observed is not None:
            dobs = runtime.decode_observed
            n_frozen = (runtime.split_cache.stats.misses
                        if runtime.split_cache is not None else 0)
            per_weight_modeled = plan.plan_contraction(
                oz, SLOTS, cfg.d_model, cfg.d_model).int8_gemms
            modeled_step = n_frozen * per_weight_modeled
            observed = {
                "contractions_per_step": dobs["contractions"],
                "int8_gemms_per_step": dobs["int8_gemms"],
                "int8_gemms_presplit_per_step": dobs["int8_gemms_presplit"],
                "int8_gemms_per_token": dobs["int8_gemms"] / SLOTS,
                "presplit_weights": n_frozen,
                "per_weight_gemms_observed":
                    (dobs["int8_gemms_presplit"] / n_frozen)
                    if n_frozen else None,
                "per_weight_gemms_planned": per_weight_modeled,
                "modeled_presplit_gemms_per_step": modeled_step,
            }
            if dobs["int8_gemms_presplit"] != modeled_step:
                print(f"[serving] WARNING {spec}: observed presplit int8 "
                      f"GEMMs/step {dobs['int8_gemms_presplit']:.0f} != "
                      f"planned {modeled_step} "
                      f"({n_frozen} weights x {per_weight_modeled})")
            ediff = obs_registry.get_registry().snapshot().diff(reg0)
            observed["engine_totals"] = {
                name: ediff.total(name) for name in
                ("emulation.calls", "emulation.int8_gemms",
                 "emulation.highprec_adds", "emulation.split_bytes",
                 "split_cache.hits", "split_cache.misses")}

        # prefix-cache TTFT on the shared-prompt trace (the system-prompt
        # regime): paged runtimes with the prefix cache off vs on.  The
        # cold runtime warms in one pass; the prefix runtime needs two
        # (see steady_state) so the measured pass is hit-path steady
        # state — every request aliases the shared prefix and prefills
        # only its suffix.
        ptrace = make_shared_prefix_trace(rng, n_requests, cfg.vocab)
        cold_rt = ServingRuntime(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                                 page_block=8)
        s_cold = steady_state(cold_rt, ptrace, warm_passes=1)
        pfx_rt = ServingRuntime(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                                page_block=8, prefix_cache=True)
        s_pfx = steady_state(pfx_rt, ptrace, warm_passes=2)
        assert s_pfx["tokens_generated"] == s_cold["tokens_generated"]
        ttft_ratio = s_pfx["ttft_s"]["mean"] / s_cold["ttft_s"]["mean"]
        prefix_row = {
            "prefix_len": int(len(ptrace[0]["prompt"]) - 4),
            "hit_rate": s_pfx["prefix_cache"]["hit_rate"],
            "hit_tokens": s_pfx["prefix_cache"]["hit_tokens"],
            "ttft_uncached_s": s_cold["ttft_s"]["mean"],
            "ttft_cached_s": s_pfx["ttft_s"]["mean"],
            "prefix_ttft_ratio": ttft_ratio,
        }
        # the paper-level claim: aliasing the shared prefix must beat
        # re-running its prefill by a wide margin (asserted here at
        # regeneration; the CI gate checks the deterministic hit rate,
        # not wall-clock — bench-machine noise philosophy)
        assert ttft_ratio < 0.5, f"prefix TTFT ratio {ttft_ratio:.2f}"

        cached = per_mode["cached"]["tokens_per_s"]
        row = {
            "bench": "serving", "arch": ARCH, "engine": spec,
            "slots": SLOTS, "max_len": MAX_LEN, "requests": n_requests,
            "useful_tokens": useful,
            "modes": per_mode,
            "runtime_over_legacy":
                cached / per_mode["legacy"]["tokens_per_s"],
            "cached_over_uncached":
                (cached / per_mode["uncached"]["tokens_per_s"])
                if "uncached" in per_mode else None,
            "weight_split_hit_rate":
                (per_mode["cached"]["split_cache"] or
                 {}).get("weight_split_hit_rate"),
            "prefix": prefix_row,
            "observed_decode": observed,
        }
        # deterministic v5e decode-step phase model: weight-splitter
        # share with and without the split-cache
        if oz is not None:
            gemms = model_v5e.decode_weight_gemms(
                4096, 11008, 32000, 32)       # full-size arch shapes
            variant = spec.split("-")[0] + (
                "_fast" if ":fast" in spec else "")
            k = oz.k
            resplit = model_v5e.decode_phase_times(
                SLOTS, gemms, k, variant=variant,
                accum_dtype=oz.accum_dtype, presplit_weights=False)
            presplit_t = model_v5e.decode_phase_times(
                SLOTS, gemms, k, variant=variant,
                accum_dtype=oz.accum_dtype, presplit_weights=True)
            row["modeled_decode"] = {
                "split_share_resplit": resplit["split_share"],
                "split_share_presplit": presplit_t["split_share"],
                "step_speedup_presplit":
                    resplit["total"] / presplit_t["total"],
                # paper-scale GEMM-call count per token: every projection
                # of the full-size arch runs Plan-many int8 GEMMs
                "full_arch_weight_gemms": len(gemms),
                "full_arch_int8_gemms_per_token":
                    len(gemms) * (observed["per_weight_gemms_planned"]
                                  if observed else
                                  plan.plan_contraction(
                                      oz, SLOTS, 4096, 4096).int8_gemms),
            }
        rows.append(row)
        print(f"[serving] {spec}: legacy "
              f"{per_mode['legacy']['tokens_per_s']:.2f} tok/s, runtime "
              f"cached {cached:.2f} tok/s "
              f"(x{row['runtime_over_legacy']:.2f})"
              + (f", cached/uncached x{row['cached_over_uncached']:.2f}"
                 if row["cached_over_uncached"] else "")
              + f"; prefix hit rate {prefix_row['hit_rate']:.2f}, "
                f"TTFT x{ttft_ratio:.2f}")
        if observed is not None:
            print(f"[serving] {spec}: observed "
                  f"{observed['int8_gemms_per_token']:.0f} int8 GEMMs/token "
                  f"({observed['per_weight_gemms_observed']:.0f}/weight, "
                  f"planned {observed['per_weight_gemms_planned']}); "
                  f"full-size arch modeled "
                  f"{row['modeled_decode']['full_arch_int8_gemms_per_token']}"
                  f"/token")

    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1, default=float)
    return rows


if __name__ == "__main__":
    main(out_json="experiments/bench/serving.json")
