"""Serving bench: offline request-trace replay through the runtime.

Replays a deterministic Poisson-arrival trace of mixed prompt/generation
lengths through three configurations per engine:

  legacy    the pre-runtime serve loop (fixed synchronized waves of
            ``slots`` requests: per-position prefill of the padded wave,
            then max-generation decode for everyone — useful tokens only
            are counted, exactly what that loop delivered)
  uncached  the continuous-batching runtime with the weight split-cache
            DISABLED (every decode step re-splits the weights)
  cached    the runtime with the split-cache on (the default)

and emits tokens/s + TTFT + split-cache savings rows, plus the
deterministic v5e decode-step phase model showing the weight-side
splitter cost going to ~0 under the cache
(``model_v5e.decode_phase_times``).  Arrivals are measured in scheduler
rounds (offline replay is CPU-speed independent; Poisson gaps stagger
admissions so the continuous refill path is exercised).

Headline + regression gate: ``benchmarks/run.py`` (``--only serving``;
the gate checks the split-cache hit rate and bench health — wall-clock
speedups are recorded, not gated, because CI machines are noisy).
"""
from __future__ import annotations

import json
import time
from typing import List, Optional

import numpy as np

ARCH = "internlm2_1_8b"
SLOTS = 4
MAX_LEN = 96


def make_trace(rng: np.random.Generator, n_requests: int, vocab: int,
               max_len: int, mean_gap_steps: float = 2.0) -> List[dict]:
    """Deterministic mixed-length request trace with Poisson arrivals.

    Prompt lengths are log-uniform-ish in [4, max_len // 3]; generation
    budgets uniform in [4, max_len // 3]; arrival_step is the scheduler
    round at which the request enters the queue (cumulative exponential
    gaps — Poisson arrivals in round-time).
    """
    hi = max(6, max_len // 3)
    out, t = [], 0.0
    for _ in range(n_requests):
        plen = int(np.exp(rng.uniform(np.log(4), np.log(hi))))
        gen = int(rng.integers(4, hi))
        t += rng.exponential(mean_gap_steps)
        out.append({
            "prompt": rng.integers(0, vocab, size=plen, dtype=np.int32),
            "max_new": gen,
            "arrival_step": int(t),
        })
    return out


def legacy_generate(cfg, model, params, prompts, gens, slots, max_len):
    """The pre-runtime serve loop (launch/serve.py before the serving
    subsystem): synchronized waves of ``slots`` requests — per-position
    prefill of the wave's padded prompts, then one decode step per token
    up to the wave's LONGEST generation budget.  Returns useful tokens."""
    import jax
    import jax.numpy as jnp

    decode = jax.jit(
        lambda c, t, n: model.decode_step(params, cfg, c, t, n))
    outs = []
    for w0 in range(0, len(prompts), slots):
        wave = prompts[w0:w0 + slots]
        wave_gens = gens[w0:w0 + slots]
        B = len(wave)
        wave = wave + [wave[-1]] * (slots - B)
        max_prompt = max(len(p) for p in wave)
        cache = model.init_cache(cfg, slots, max_len, params=params,
                                 ctx=None)
        toks = np.zeros((slots, max_prompt), np.int32)
        for i, p in enumerate(wave):
            toks[i, :len(p)] = p
        logits = None
        for t in range(max_prompt):
            logits, cache = decode(cache, jnp.asarray(toks[:, t:t + 1]),
                                   jnp.asarray(t + 1, jnp.int32))
        gen_out = [[] for _ in range(B)]
        cur = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(
            jnp.int32)
        for g in range(max(wave_gens)):
            for i in range(B):
                if g < wave_gens[i]:
                    gen_out[i].append(int(cur[i]))
            logits, cache = decode(cache, cur[:, None],
                                   jnp.asarray(max_prompt + g + 1,
                                               jnp.int32))
            cur = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(
                jnp.int32)
        outs.extend(gen_out)
    return outs


def replay(runtime, trace) -> dict:
    """Drive the runtime, submitting each request at its arrival round
    (Poisson-staggered admissions exercise the continuous slot refill)."""
    pending = sorted(trace, key=lambda r: r["arrival_step"])
    i, step = 0, 0
    while i < len(pending) or not runtime.sched.all_done:
        while i < len(pending) and pending[i]["arrival_step"] <= step:
            runtime.submit(pending[i]["prompt"], pending[i]["max_new"])
            i += 1
        runtime.step()
        step += 1
    return runtime.run()  # idle: finalizes and returns the summary


def main(out_json: Optional[str] = None, quick: bool = False):
    import jax

    from benchmarks import model_v5e
    from repro import configs
    from repro.models import api
    from repro.serving import ServingRuntime

    engines = ["bf16", "ozimmu_h-4:df32"] if quick else \
        ["bf16", "ozimmu_h-4:df32", "oz2_h-4:df32:fast"]
    n_requests = 6 if quick else 10
    rows = []
    rng = np.random.default_rng(20260728)

    for spec in engines:
        cfg = configs.get_config(ARCH, smoke=True, engine_spec=spec)
        model = api.get_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0), cfg)
        trace = make_trace(rng, n_requests, cfg.vocab, MAX_LEN)
        prompts = [r["prompt"] for r in trace]
        gens = [r["max_new"] for r in trace]
        useful = sum(gens)

        # legacy baseline (pre-runtime loop).  All modes are timed in
        # steady state: one warm pass compiles every step (the runtime's
        # per-bucket prefill scans are the expensive traces), the second
        # pass is measured — serving throughput is an amortized quantity.
        legacy_generate(cfg, model, params, prompts, gens, SLOTS, MAX_LEN)
        t0 = time.time()
        legacy_out = legacy_generate(cfg, model, params, prompts, gens,
                                     SLOTS, MAX_LEN)
        legacy_dt = time.time() - t0
        assert sum(len(o) for o in legacy_out) == useful

        modes = [("uncached", False)] if cfg.engine.is_ozimmu else []
        modes += [("cached", None)]
        per_mode = {"legacy": {"tokens_per_s": useful / legacy_dt,
                               "seconds": legacy_dt}}
        for mode, presplit in modes:
            runtime = ServingRuntime(cfg, params, slots=SLOTS,
                                     max_len=MAX_LEN, presplit=presplit)
            replay(runtime, trace)          # warm-up: compile all buckets
            runtime.reset_metrics()
            summary = replay(runtime, trace)
            per_mode[mode] = {
                "tokens_per_s": summary["tokens_per_s"],
                "seconds": summary["elapsed_s"],
                "ttft_mean_s": summary["ttft_s"]["mean"],
                "ttft_p95_s": summary["ttft_s"]["p95"],
                "split_cache": summary["split_cache"],
            }
            assert summary["tokens_generated"] == useful, \
                (summary["tokens_generated"], useful)

        cached = per_mode["cached"]["tokens_per_s"]
        row = {
            "bench": "serving", "arch": ARCH, "engine": spec,
            "slots": SLOTS, "max_len": MAX_LEN, "requests": n_requests,
            "useful_tokens": useful,
            "modes": per_mode,
            "runtime_over_legacy":
                cached / per_mode["legacy"]["tokens_per_s"],
            "cached_over_uncached":
                (cached / per_mode["uncached"]["tokens_per_s"])
                if "uncached" in per_mode else None,
            "weight_split_hit_rate":
                (per_mode["cached"]["split_cache"] or
                 {}).get("weight_split_hit_rate"),
        }
        # deterministic v5e decode-step phase model: weight-splitter
        # share with and without the split-cache
        oz = cfg.engine.ozimmu_config
        if oz is not None:
            gemms = model_v5e.decode_weight_gemms(
                4096, 11008, 32000, 32)       # full-size arch shapes
            variant = spec.split("-")[0] + (
                "_fast" if ":fast" in spec else "")
            k = oz.k
            resplit = model_v5e.decode_phase_times(
                SLOTS, gemms, k, variant=variant,
                accum_dtype=oz.accum_dtype, presplit_weights=False)
            presplit_t = model_v5e.decode_phase_times(
                SLOTS, gemms, k, variant=variant,
                accum_dtype=oz.accum_dtype, presplit_weights=True)
            row["modeled_decode"] = {
                "split_share_resplit": resplit["split_share"],
                "split_share_presplit": presplit_t["split_share"],
                "step_speedup_presplit":
                    resplit["total"] / presplit_t["total"],
            }
        rows.append(row)
        print(f"[serving] {spec}: legacy "
              f"{per_mode['legacy']['tokens_per_s']:.2f} tok/s, runtime "
              f"cached {cached:.2f} tok/s "
              f"(x{row['runtime_over_legacy']:.2f})"
              + (f", cached/uncached x{row['cached_over_uncached']:.2f}"
                 if row["cached_over_uncached"] else ""))

    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1, default=float)
    return rows


if __name__ == "__main__":
    main(out_json="experiments/bench/serving.json")
