"""Analytic phase-cost model of the Ozaki scheme on TPU-v5e-like hardware.

The container is CPU-only, so the paper's wall-clock figures (Figs. 2-3,
6-13) are reproduced STRUCTURALLY: per-phase op/byte counts (exact, from the
algorithms) are priced with the v5e roofline constants.  The CPU runs
validate semantics; this model orders the variants the same way the paper's
GPU measurements do, because the phase ratios (int8 MACs vs high-precision
element passes) are hardware-agnostic up to the peak ratios.

Phases (paper steps):
  split   (i)+(ii)  memory-bound: extraction passes over A and B
  gemm    (iii)     compute-bound: int8 MACs on the MXU
  accum   (iv)      memory-bound: convert+scale+add passes over (m, p)
  copy    (v)       memory-bound: one pass over C
"""
from __future__ import annotations

import dataclasses

from repro.core.accumulate import (num_highprec_adds, oz2_num_chunks,
                                   oz2_num_highprec_adds, oz2_num_pairs)
from repro.core.splitting import beta_for, compute_r, digit_bits


def base_variant(label: str) -> str:
    """Bench row label with planner tags (``..._auto``, ``..._auto_prob``)
    -> the underlying phase-model variant name.  ``_prob`` changes which
    k the planner resolves, never the kernel pipeline, so tagged labels
    price through the untagged variant's phase formulas."""
    stripped = True
    while stripped:
        stripped = False
        for suf in ("_prob", "_auto"):
            if label.endswith(suf):
                label = label[: -len(suf)]
                stripped = True
    return label


def variant_split(variant: str) -> str:
    """Bench variant label (e.g. ``oz2_h_fast``, ``oz2_h_fast2``, or a
    planner-tagged ``ozimmu_h_auto_prob``) -> splitting strategy name,
    via the engine's own variant table and its fast2 canonicalization —
    single source of truth."""
    from repro.core.ozimmu import VARIANTS, canonical_fast2
    variant = base_variant(variant)
    if variant.endswith("_fast2"):
        base, fast = variant[:-6], "fast2"
    elif variant.endswith("_fast"):
        base, fast = variant[:-5], True
    else:
        base, fast = variant, False
    return canonical_fast2(VARIANTS[base].with_(fast=fast)).split

PEAK_INT8 = 394e12      # MACs*2 per second (ops/s)
HBM_BW = 819e9

_BYTES_HP = {"f64": 8, "f32": 4, "df32": 8}


@dataclasses.dataclass(frozen=True)
class PhaseTimes:
    split: float
    gemm: float
    accum: float
    copy: float

    @property
    def total(self) -> float:
        return self.split + self.gemm + self.accum + self.copy

    def shares(self) -> dict:
        t = self.total
        return {f: getattr(self, f) / t for f in
                ("split", "gemm", "accum", "copy")}


def phase_times(m: int, n: int, p: int, k: int, *, variant: str,
                accum_dtype: str = "f64", in_bytes: int = 8,
                fused_split: bool = True,
                fused_epilogue: bool = True) -> PhaseTimes:
    """Modeled seconds per phase on one v5e chip.

    variant: ozimmu | ozimmu_rn | ozimmu_ef | ozimmu_h | oz2_b | oz2_h,
    the oz2 names optionally suffixed ``_fast`` (the diagonal-band mode)
    or ``_fast2`` (same band with the improved per-row scaling; costs one
    extra diag-unscale RMW pass over the output).
    fused_split: single-HBM-read fused extraction (our Pallas kernel);
    False models Ootomo-style per-slice passes.
    fused_epilogue: one-pass convert+scale+add with the accumulator RMW'd
    in VMEM (kernels/scale_accum.py); False models a materialized scaled
    term per high-precision add (an extra write+read of the term).
    """
    split = variant_split(variant)
    beta = beta_for(split, n)     # sm slices are 8-bit, signed ones <= 7
    oz2 = variant.startswith("oz2")
    oz2_fast2 = variant.endswith("_fast2")
    oz2_fast = oz2_fast2 or variant.endswith("_fast")
    dbits = digit_bits(split, beta)
    r = compute_r(n, beta, dbits) if oz2 else compute_r(n, beta)
    group_ef = variant in ("ozimmu_ef", "ozimmu_h", "ozimmu_sm_h")
    hp_b = _BYTES_HP[accum_dtype]

    # --- split: read A (m*n) and B (n*p) in input precision, write k int8
    # slices (+ scale vectors, negligible).  RN-adaptive (ozimmu_rn)
    # recomputes the row max per slice -> k extra read passes.
    reads = 1 if fused_split else k
    if variant == "ozimmu_rn":
        reads += k - 1   # per-slice rowmax pass over the residual
    split_bytes = (m * n + n * p) * (reads * in_bytes + k * 1)
    t_split = split_bytes / HBM_BW

    # --- gemm: k(k+1)/2 int8 pair GEMMs (fast mode; oz2 full mode runs all
    # k^2).  Group-EF performs the same MACs but fewer kernel launches
    # (concatenated contraction) — MAC count identical, so same compute
    # time; the win is in `accum`.
    pairs = oz2_num_pairs(k, oz2_fast) if oz2 else k * (k + 1) // 2
    t_gemm = pairs * 2.0 * m * n * p / PEAK_INT8

    # --- accum: per high-precision term, read int32 product (4B) + RMW of
    # the hp accumulator (2*hp_b) over (m, p); the unfused epilogue also
    # materializes the converted+scaled term (one write + one read of hp_b).
    # oz2: one term per exponent-ladder window (the int64 shift-adds of the
    # fold ride along in the same pass over the window's products).
    # ladder word budget mirrors accumulate.matmul_oz2: int64 words (52
    # bits, exact f64 convert) for the f64 accumulator, int32 otherwise
    wbits = 52 if accum_dtype == "f64" else 31
    hp_terms = (oz2_num_highprec_adds(k, r, beta, n, oz2_fast, dbits, wbits)
                if oz2 else num_highprec_adds(k, r, group_ef))
    if oz2:
        # the ladder fold reads every chunk product once (int shift-adds),
        # but the hp accumulator is RMW'd only once per window
        reads_bytes = oz2_num_chunks(k, r, oz2_fast) * 4
        rmw_bytes = hp_terms * (2 * hp_b if fused_epilogue else 4 * hp_b)
        if oz2_fast2:
            # improved scaling: one exact diag-unscale RMW of the output
            rmw_bytes += 2 * hp_b
        accum_bytes = m * p * (reads_bytes + rmw_bytes)
    else:
        per_term = (4 + 2 * hp_b) if fused_epilogue else (4 + 4 * hp_b)
        accum_bytes = hp_terms * m * p * per_term
    t_accum = accum_bytes / HBM_BW

    # --- copy: C <- alpha D + beta C, one read+write of (m, p)
    t_copy = 2.0 * m * p * hp_b / HBM_BW

    return PhaseTimes(t_split, t_gemm, t_accum, t_copy)


def emulated_tflops(m: int, n: int, p: int, k: int, **kw) -> float:
    """Emulated-GEMM throughput: 2mnp / modeled time, in TFLOP/s."""
    t = phase_times(m, n, p, k, **kw).total
    return 2.0 * m * n * p / t / 1e12


# ---------------------------------------------------------------------------
# serving phase model: one decode step, with/without the weight split-cache
# ---------------------------------------------------------------------------

def decode_weight_gemms(d_model: int, d_ff: int, vocab: int,
                        n_layers: int) -> list:
    """(n, p) weight shapes of one decode step's projection GEMMs (GQA
    transformer shape family: qkvo + swiglu per layer, plus the LM head).
    The lhs of every one is the (slots, 1, d) activation sliver."""
    per_layer = [(d_model, d_model)] * 4 + \
        [(d_model, d_ff)] * 2 + [(d_ff, d_model)]
    return per_layer * n_layers + [(d_model, vocab)]


def decode_phase_times(slots: int, gemms: list, k: int, *, variant: str,
                       accum_dtype: str = "df32", in_bytes: int = 4,
                       presplit_weights: bool = False,
                       fused_split: bool = True,
                       fused_epilogue: bool = True) -> dict:
    """Modeled seconds per serving decode step, split by phase AND by
    operand side of the splitter.

    At decode the A operand of every projection is a ``(slots, n)``
    activation sliver while B is the full ``(n, p)`` weight — the B-side
    extraction dominates the split phase by a factor ~p/slots.  With
    ``presplit_weights`` (the serving split-cache) the B-side bytes drop
    out entirely: only ``split_a`` remains, which is what "decode-time
    splitter cost goes to ~0" means quantitatively
    (``bench_serving`` emits both columns; docs/serving.md).

    Delegates every phase formula to :func:`phase_times` (single source
    of truth for the cost model); the only serving-specific math is
    apportioning the split phase to its operand sides — both sides pay
    the same per-element cost, so bytes split as ``m*n : n*p``.
    """
    t = {"split_a": 0.0, "split_b": 0.0, "gemm": 0.0, "accum": 0.0,
         "copy": 0.0}
    m = slots
    for n, p in gemms:
        pt = phase_times(m, n, p, k, variant=variant,
                         accum_dtype=accum_dtype, in_bytes=in_bytes,
                         fused_split=fused_split,
                         fused_epilogue=fused_epilogue)
        frac_a = (m * n) / (m * n + n * p)
        t["split_a"] += pt.split * frac_a
        if not presplit_weights:
            t["split_b"] += pt.split * (1.0 - frac_a)
        t["gemm"] += pt.gemm
        t["accum"] += pt.accum
        t["copy"] += pt.copy
    t["total"] = sum(t.values())
    t["split_share"] = (t["split_a"] + t["split_b"]) / t["total"]
    return t
