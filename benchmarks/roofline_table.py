"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline
table (markdown to stdout).

    PYTHONPATH=src python -m benchmarks.roofline_table [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_t(sec: float) -> str:
    if sec >= 1.0:
        return f"{sec:.2f}s"
    return f"{sec * 1e3:.2f}ms"


def load(dirname: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16",
                    help="pod16x16 | pod2x16x16 | all")
    args = ap.parse_args(argv)
    rows = load(args.dir)
    if args.mesh != "all":
        rows = [r for r in rows if r["mesh"] == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("| arch | shape | mesh | t_comp | t_mem | t_coll | bound | "
          "useful/HLO | MFU-bound | HBM GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        rl = r["roofline"]
        mem = r.get("memory_analysis", {})
        dev_gb = (mem.get("argument_size_in_bytes", 0) +
                  mem.get("output_size_in_bytes", 0) -
                  mem.get("alias_size_in_bytes", 0) +
                  mem.get("temp_size_in_bytes", 0)) / 1e9
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt_t(rl['t_compute'])} | {fmt_t(rl['t_memory'])} "
              f"| {fmt_t(rl['t_collective'])} | {rl['bottleneck'][:4]} "
              f"| {rl['useful_flops_fraction']:.2f} "
              f"| {rl['mfu_bound']:.3f} | {dev_gb:.1f} |")

    # summary stats
    if rows:
        from collections import Counter
        c = Counter(r["roofline"]["bottleneck"] for r in rows)
        print(f"\nbottleneck distribution: {dict(c)}")
        worst = min((r for r in rows if r["shape"].startswith("train")),
                    key=lambda r: r["roofline"]["mfu_bound"], default=None)
        if worst:
            print(f"worst train-cell MFU-bound: {worst['arch']} x "
                  f"{worst['shape']} = {worst['roofline']['mfu_bound']:.4f}")


if __name__ == "__main__":
    main()
