"""Per-arch smoke tests on reduced configs (assignment requirement).

For every assigned architecture: instantiate the reduced same-family config,
run one forward and one train-grad step on CPU, assert output shapes and
no NaNs.  For decoder families additionally check decode-vs-forward parity:
teacher-forcing the same tokens through ``decode_step`` must reproduce the
full-sequence ``forward`` logits (the KV/state caches are exercised end to
end).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api


def make_batch(cfg, rng, batch=2, seq=16):
    tokens = jax.random.randint(rng, (batch, seq), 0, cfg.vocab,
                                dtype=jnp.int32)
    b = {"tokens": tokens}
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            rng, (batch, cfg.vision_seq, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            rng, (batch, seq, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, axes = model.init(rng, cfg)
    # axes tree mirrors params
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_a)

    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(lambda p, b: model.forward(p, cfg, b))(params, batch)
    B, L = batch["tokens"].shape
    assert logits.shape == (B, L, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    def loss_fn(p):
        return api.next_token_loss(model.forward(p, cfg, batch),
                                   batch["tokens"])

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


DECODE_TOL = {"dense": 2e-2, "moe": 5e-2, "mla_moe": 5e-2, "vlm": 2e-2,
              "encdec": 2e-2, "ssm": 5e-2, "hybrid": 5e-2}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode_step must reproduce forward() logits.

    MoE archs run with the f32 engine: the check targets KV/latent-cache
    correctness, and under a bf16 engine the legitimate flash-forward vs
    cached-decode numeric differences (~1e-2) flip discrete top-k expert
    choices on near-tied gates — an amplification no continuous tolerance
    can absorb (engine-noise robustness is covered by the dense archs).
    """
    moe_family = get_config(arch, smoke=True).family in ("moe", "mla_moe")
    cfg = get_config(arch, smoke=True,
                     **({"engine_spec": "f32"} if moe_family else {}))
    model = api.get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, _ = model.init(rng, cfg)
    B, L = 2, 8
    batch = make_batch(cfg, jax.random.PRNGKey(1), batch=B, seq=L)
    ref = model.forward(params, cfg, batch)  # (B, L, vocab)

    ctx = batch.get("image_embeds")
    if cfg.family == "encdec":
        from repro.models import encdec
        ctx = encdec.encode(params, cfg, batch["frames"])
    cache = model.init_cache(cfg, B, L, params=params, ctx=ctx)

    step = jax.jit(lambda c, t, n: model.decode_step(params, cfg, c, t, n))
    outs = []
    for t in range(L):
        logits, cache = step(cache, batch["tokens"][:, t:t + 1],
                             jnp.asarray(t + 1, jnp.int32))
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    tol = DECODE_TOL[cfg.family]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    if moe_family:
        # discrete routing: even at f32 a near-tied gate can flip one
        # token's expert set between the two attention paths, blowing up
        # that token's logits while every other position matches.  Cache
        # bugs look different — they corrupt runs of positions (all from
        # some step onward, or the tail for write-index off-by-ones) — so
        # require mismatches to be ISOLATED: at most one bad token per
        # sequence and never two consecutive bad positions.
        err_tok = np.asarray(jnp.max(jnp.abs(got - ref), axis=-1)) / scale
        bad = err_tok >= tol                                    # (B, L)
        per_seq = bad.sum(axis=1)
        consec = (bad[:, 1:] & bad[:, :-1]).any()
        assert per_seq.max(initial=0) <= 1 and not consec, \
            f"{arch}: decode mismatch beyond isolated routing flips " \
            f"(per-token err {err_tok.round(4)})"
    else:
        err = float(jnp.max(jnp.abs(got - ref)) / scale)
        assert err < tol, f"{arch}: decode mismatch {err}"


def test_mamba_ssd_chunked_vs_step():
    """SSD chunked scan must equal the step-by-step recurrence."""
    from repro.models.ssm import ssd_chunked, ssd_step
    rng = np.random.default_rng(0)
    Bb, Lq, H, P, N = 2, 12, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((Bb, Lq, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (Bb, Lq, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((Bb, Lq, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bb, Lq, N)), jnp.float32)
    y_chunk, h_chunk = ssd_chunked(x, dt, A, B, C, chunk=5)  # uneven chunks
    h = jnp.zeros((Bb, H, P, N), jnp.float32)
    ys = []
    for t in range(Lq):
        y, h = ssd_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], h)
        ys.append(y)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_rg_lru_scan_vs_step():
    from repro.models.hybrid import init_recurrent_layer, rg_lru, rg_lru_step
    from repro.configs import get_config
    cfg = get_config("recurrentgemma_9b", smoke=True)
    p, _ = init_recurrent_layer(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 9, cfg.lru_width)), jnp.float32)
    y_scan, h_last = rg_lru(p, x)
    h = jnp.zeros((2, cfg.lru_width))
    ys = []
    for t in range(9):
        y, h = rg_lru_step(p, x[:, t], h)
        ys.append(y)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-4, atol=1e-5)
