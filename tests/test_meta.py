"""Suite meta-invariants: the committed tier-1 collected-count floor.

``tests/tier1_floor.txt`` is the single source of the floor, consumed by
BOTH the CI workflow step and this test — so the floor bumps in the same
diff as the tests that moved it and can't silently drift from the
workflow (the failure mode of the old hand-maintained number in ci.yml:
a conftest/import error or refactor de-collecting part of the suite
still shows a green run).
"""
import os
import re
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FLOOR_FILE = os.path.join(REPO, "tests", "tier1_floor.txt")


def read_floor() -> int:
    with open(FLOOR_FILE) as f:
        return int(f.read().strip())


def test_floor_file_parses():
    floor = read_floor()
    # 407 was the last hand-maintained floor (sign-magnitude family PR);
    # the committed file must never regress below it
    assert floor >= 407


def test_ci_workflow_reads_floor_file():
    with open(os.path.join(REPO, ".github", "workflows", "ci.yml")) as f:
        text = f.read()
    assert "tests/tier1_floor.txt" in text, \
        "ci.yml must read the floor from tests/tier1_floor.txt"
    assert not re.search(r"-ge 407\b", text), \
        "ci.yml still hardcodes the old floor instead of the file"


def test_collected_count_meets_floor():
    """The floor check itself, same scope as the workflow step (the
    distributed suite runs in its own job and is excluded there too)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "--collect-only",
         "-p", "no:cacheprovider", "--ignore=tests/test_distributed.py"],
        cwd=REPO, env=env, capture_output=True, text=True)
    m = re.search(r"^(\d+) tests collected", out.stdout, flags=re.M)
    assert m, f"could not parse collected count from:\n{out.stdout[-2000:]}"
    collected, floor = int(m.group(1)), read_floor()
    assert collected >= floor, \
        (f"collected {collected} tier-1 tests, floor is {floor} — if "
         f"tests were removed on purpose, lower tests/tier1_floor.txt in "
         f"the same change")
