"""§5 rounding-error-analysis validation: computed results must satisfy the
paper's deterministic bounds, and the group-EF accounting (w, r) must match
the implementation's actual operation counts."""
import numpy as np
import pytest

import jax.numpy as jnp

from benchmarks.exact import dd_matmul
from repro.core import analysis, ozimmu
from repro.core.splitting import compute_beta, compute_r
from tests.conftest import make_phi_matrix


@pytest.mark.parametrize("n,k,phi", [
    (64, 4, 0.5), (64, 8, 0.5), (128, 6, 1.0), (128, 10, 2.0), (256, 8, 1.0),
])
@pytest.mark.parametrize("variant", ["ozimmu", "ozimmu_ef"])
def test_error_bound_holds(rng, n, k, phi, variant):
    """|AB - T_k| <= eq.(18) + accumulation term, elementwise."""
    a = make_phi_matrix(rng, n, n, phi)
    b = make_phi_matrix(rng, n, n, phi)
    cfg = ozimmu.VARIANTS[variant].with_(k=k)
    t = np.asarray(ozimmu.ozimmu_matmul(jnp.asarray(a), jnp.asarray(b), cfg))
    hi, lo = dd_matmul(a, b)
    err = np.abs((t - hi) - lo)
    bound = (analysis.error_bound_ozimmu(a, b, k) if variant == "ozimmu"
             else analysis.error_bound_group_ef(a, b, k))
    # dd reference itself contributes ~2^-106 — negligible
    assert np.all(err <= bound + 1e-300), \
        f"bound violated: max excess {(err - bound).max():.3e}"


@pytest.mark.parametrize("n,k,phi", [(128, 6, 2.0), (128, 8, 2.0),
                                     (256, 7, 1.5)])
def test_rn_splitting_more_accurate_end_to_end(rng, n, k, phi):
    """§3.1/Fig. 5: at equal k on hard (large-phi) matrices, the RN variants
    produce a more accurate PRODUCT than the bitmask variants.  (Raw
    residual magnitudes can tie — Alg. 8's grid is up to 2x coarser when
    ceil(log2 max) != floor — the paper's claim is about final accuracy,
    where centered RN errors cancel across the contraction.)"""
    a = make_phi_matrix(rng, n, n, phi)
    b = make_phi_matrix(rng, n, n, phi)
    hi, lo = dd_matmul(a, b)
    errs = {}
    for variant in ("ozimmu", "ozimmu_h"):
        cfg = ozimmu.VARIANTS[variant].with_(k=k)
        t = np.asarray(ozimmu.ozimmu_matmul(jnp.asarray(a), jnp.asarray(b),
                                            cfg))
        denom = np.maximum(np.abs(hi), 1e-300)
        errs[variant] = np.max(np.abs((t - hi) - lo) / denom)
    assert errs["ozimmu_h"] <= errs["ozimmu"] * 1.5, errs


def test_w_formula_matches_chunk_count():
    """w = ceil(k/r)(k - (r/2) floor((k-1)/r)) == sum_g ceil((g-1)/r)."""
    from repro.core.accumulate import num_highprec_adds
    for k in range(1, 16):
        for r in (1, 2, 3, 4, 8, 16):
            w_formula = analysis.accumulation_terms_w(k, r)
            w_impl = num_highprec_adds(k, r, True)
            assert abs(w_formula - w_impl) < 1e-9, (k, r, w_formula, w_impl)


def test_r_overflow_threshold():
    """r slice-pair products must fit INT32: (r-1) n (2^beta - 1)^2 < 2^31
    with equality-adjacent failure at r+something large."""
    for n in (64, 256, 1024, 4096, 16384):
        beta = compute_beta(n)
        r = compute_r(n, beta)
        assert (r - 1) * n * (2 ** beta - 1) ** 2 <= 2 ** 31 - 1


def test_group_ef_exactness_at_r(rng):
    """Summing exactly r slice-pair products in int32 is error-free: compare
    against int64 accumulation on adversarial full-scale digits."""
    n = 64
    beta = compute_beta(n)
    r = compute_r(n, beta)
    g = min(r, 6)
    lim = 2 ** beta - 1
    a8 = rng.integers(-lim, lim + 1, (g, 16, n)).astype(np.int8)
    b8 = rng.integers(-lim, lim + 1, (g, n, 16)).astype(np.int8)
    acc32 = np.zeros((16, 16), np.int32)
    for i in range(g):
        acc32 = acc32 + (a8[i].astype(np.int32) @ b8[i].astype(np.int32))
    acc64 = np.zeros((16, 16), np.int64)
    for i in range(g):
        acc64 = acc64 + (a8[i].astype(np.int64) @ b8[i].astype(np.int64))
    assert np.array_equal(acc32.astype(np.int64), acc64)


def test_fp64_crossing_rn_one_slice_earlier(rng):
    """Flagship §4.1 claim (φ=2): RN/H reaches FP64-grade accuracy at a k
    no LARGER than bitmask — the paper reports crossing at k=9 (RN) vs
    k=10 (bitmask)."""
    n, phi = 256, 2.0
    a = make_phi_matrix(rng, n, n, phi)
    b = make_phi_matrix(rng, n, n, phi)
    hi, lo = dd_matmul(a, b)
    denom = np.maximum(np.abs(hi), 1e-300)
    f64_err = np.max(np.abs((np.asarray(
        jnp.asarray(a) @ jnp.asarray(b)) - hi) - lo) / denom)

    def crossing(variant):
        for k in range(7, 13):
            cfg = ozimmu.VARIANTS[variant].with_(k=k)
            t = np.asarray(ozimmu.ozimmu_matmul(jnp.asarray(a),
                                                jnp.asarray(b), cfg))
            if np.max(np.abs((t - hi) - lo) / denom) <= f64_err:
                return k
        return 99

    k_bitmask = crossing("ozimmu")
    k_h = crossing("ozimmu_h")
    assert k_h <= k_bitmask, (k_h, k_bitmask)
