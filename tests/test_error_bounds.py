"""§5 rounding-error-analysis validation: computed results must satisfy the
paper's deterministic bounds, and the group-EF accounting (w, r) must match
the implementation's actual operation counts."""
import math

import numpy as np
import pytest

import jax.numpy as jnp

from benchmarks.exact import dd_matmul
from repro.core import analysis, ozimmu
from repro.core.splitting import compute_beta, compute_r
from tests.conftest import make_phi_matrix


@pytest.mark.parametrize("n,k,phi", [
    (64, 4, 0.5), (64, 8, 0.5), (128, 6, 1.0), (128, 10, 2.0), (256, 8, 1.0),
])
@pytest.mark.parametrize("variant", ["ozimmu", "ozimmu_ef"])
def test_error_bound_holds(rng, n, k, phi, variant):
    """|AB - T_k| <= eq.(18) + accumulation term, elementwise."""
    a = make_phi_matrix(rng, n, n, phi)
    b = make_phi_matrix(rng, n, n, phi)
    cfg = ozimmu.VARIANTS[variant].with_(k=k)
    t = np.asarray(ozimmu.ozimmu_matmul(jnp.asarray(a), jnp.asarray(b), cfg))
    hi, lo = dd_matmul(a, b)
    err = np.abs((t - hi) - lo)
    bound = (analysis.error_bound_ozimmu(a, b, k) if variant == "ozimmu"
             else analysis.error_bound_group_ef(a, b, k))
    # dd reference itself contributes ~2^-106 — negligible
    assert np.all(err <= bound + 1e-300), \
        f"bound violated: max excess {(err - bound).max():.3e}"


@pytest.mark.parametrize("n,k,phi", [(128, 6, 2.0), (128, 8, 2.0),
                                     (256, 7, 1.5)])
def test_rn_splitting_more_accurate_end_to_end(rng, n, k, phi):
    """§3.1/Fig. 5: at equal k on hard (large-phi) matrices, the RN variants
    produce a more accurate PRODUCT than the bitmask variants.  (Raw
    residual magnitudes can tie — Alg. 8's grid is up to 2x coarser when
    ceil(log2 max) != floor — the paper's claim is about final accuracy,
    where centered RN errors cancel across the contraction.)"""
    a = make_phi_matrix(rng, n, n, phi)
    b = make_phi_matrix(rng, n, n, phi)
    hi, lo = dd_matmul(a, b)
    errs = {}
    for variant in ("ozimmu", "ozimmu_h"):
        cfg = ozimmu.VARIANTS[variant].with_(k=k)
        t = np.asarray(ozimmu.ozimmu_matmul(jnp.asarray(a), jnp.asarray(b),
                                            cfg))
        denom = np.maximum(np.abs(hi), 1e-300)
        errs[variant] = np.max(np.abs((t - hi) - lo) / denom)
    assert errs["ozimmu_h"] <= errs["ozimmu"] * 1.5, errs


def test_w_formula_matches_chunk_count():
    """w = ceil(k/r)(k - (r/2) floor((k-1)/r)) == sum_g ceil((g-1)/r)."""
    from repro.core.accumulate import num_highprec_adds
    for k in range(1, 16):
        for r in (1, 2, 3, 4, 8, 16):
            w_formula = analysis.accumulation_terms_w(k, r)
            w_impl = num_highprec_adds(k, r, True)
            assert abs(w_formula - w_impl) < 1e-9, (k, r, w_formula, w_impl)


def test_r_overflow_threshold():
    """r slice-pair products must fit INT32: (r-1) n (2^beta - 1)^2 < 2^31
    with equality-adjacent failure at r+something large."""
    for n in (64, 256, 1024, 4096, 16384):
        beta = compute_beta(n)
        r = compute_r(n, beta)
        assert (r - 1) * n * (2 ** beta - 1) ** 2 <= 2 ** 31 - 1


def test_group_ef_exactness_at_r(rng):
    """Summing exactly r slice-pair products in int32 is error-free: compare
    against int64 accumulation on adversarial full-scale digits."""
    n = 64
    beta = compute_beta(n)
    r = compute_r(n, beta)
    g = min(r, 6)
    lim = 2 ** beta - 1
    a8 = rng.integers(-lim, lim + 1, (g, 16, n)).astype(np.int8)
    b8 = rng.integers(-lim, lim + 1, (g, n, 16)).astype(np.int8)
    acc32 = np.zeros((16, 16), np.int32)
    for i in range(g):
        acc32 = acc32 + (a8[i].astype(np.int32) @ b8[i].astype(np.int32))
    acc64 = np.zeros((16, 16), np.int64)
    for i in range(g):
        acc64 = acc64 + (a8[i].astype(np.int64) @ b8[i].astype(np.int64))
    assert np.array_equal(acc32.astype(np.int64), acc64)


def test_fp64_crossing_rn_one_slice_earlier(rng):
    """Flagship §4.1 claim (φ=2): RN/H reaches FP64-grade accuracy at a k
    no LARGER than bitmask — the paper reports crossing at k=9 (RN) vs
    k=10 (bitmask)."""
    n, phi = 256, 2.0
    a = make_phi_matrix(rng, n, n, phi)
    b = make_phi_matrix(rng, n, n, phi)
    hi, lo = dd_matmul(a, b)
    denom = np.maximum(np.abs(hi), 1e-300)
    f64_err = np.max(np.abs((np.asarray(
        jnp.asarray(a) @ jnp.asarray(b)) - hi) - lo) / denom)

    def crossing(variant):
        for k in range(7, 13):
            cfg = ozimmu.VARIANTS[variant].with_(k=k)
            t = np.asarray(ozimmu.ozimmu_matmul(jnp.asarray(a),
                                                jnp.asarray(b), cfg))
            if np.max(np.abs((t - hi) - lo) / denom) <= f64_err:
                return k
        return 99

    k_bitmask = crossing("ozimmu")
    k_h = crossing("ozimmu_h")
    assert k_h <= k_bitmask, (k_h, k_bitmask)


# ---------------------------------------------------------------------------
# probabilistic bounds (prob_error_bound_*) — property tests
# ---------------------------------------------------------------------------

from tests.conftest import hypothesis_or_stubs  # noqa: E402

given, settings, st = hypothesis_or_stubs()

_PROB_BOUNDS = {
    "ozimmu": lambda a, b, k, d: analysis.prob_error_bound_ozimmu(
        a, b, k, delta=d),
    "ozimmu_rn": lambda a, b, k, d: analysis.prob_error_bound_rn(
        a, b, k, delta=d),
    "ozimmu_ef": lambda a, b, k, d: analysis.prob_error_bound_group_ef(
        a, b, k, delta=d),
    "ozimmu_h": lambda a, b, k, d: analysis.prob_error_bound_rn(
        a, b, k, delta=d),
    "ozimmu_sm_b": lambda a, b, k, d: analysis.prob_error_bound_sm(
        a, b, k, delta=d),
    "ozimmu_sm_h": lambda a, b, k, d: analysis.prob_error_bound_sm(
        a, b, k, delta=d),
    "oz2_b": lambda a, b, k, d: analysis.prob_error_bound_oz2(
        a, b, k, fast=True, delta=d),
    "oz2_h": lambda a, b, k, d: analysis.prob_error_bound_oz2(
        a, b, k, fast=True, delta=d),
}

_DET_BOUNDS = {
    "ozimmu": lambda a, b, k: analysis.error_bound_ozimmu(a, b, k),
    "ozimmu_rn": lambda a, b, k: analysis.error_bound_rn(a, b, k),
    "ozimmu_ef": lambda a, b, k: analysis.error_bound_group_ef(a, b, k),
    "ozimmu_h": lambda a, b, k: analysis.error_bound_rn(a, b, k),
    "ozimmu_sm_b": lambda a, b, k: analysis.error_bound_sm(a, b, k),
    "ozimmu_sm_h": lambda a, b, k: analysis.error_bound_sm(a, b, k),
    "oz2_b": lambda a, b, k: analysis.error_bound_oz2(a, b, k, fast=True),
    "oz2_h": lambda a, b, k: analysis.error_bound_oz2(a, b, k, fast=True),
}


def _prob_case(rng, dtype, n=48, m=24, p=12, phi=1.0):
    a = make_phi_matrix(rng, m, n, phi).astype(dtype)
    b = make_phi_matrix(rng, n, p, phi).astype(dtype)
    return a, b


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("variant", sorted(_PROB_BOUNDS))
def test_prob_bound_delta_zero_is_deterministic_bitwise(rng, variant,
                                                        dtype):
    """For every variant x dtype, ``prob_error_bound(..., delta=0)``
    equals the deterministic bound BITWISE (the delta=0 limit evaluates
    the identical float expressions)."""
    a, b = _prob_case(rng, dtype)
    for k in (2, 5, 8, 12):
        d0 = _PROB_BOUNDS[variant](a, b, k, 0.0)
        det = _DET_BOUNDS[variant](a, b, k)
        assert d0.dtype == det.dtype
        assert np.array_equal(d0, det), (variant, k)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("variant", sorted(_PROB_BOUNDS))
def test_prob_bound_monotone_in_delta(rng, variant, dtype):
    """The bound is monotone non-increasing in delta: more admitted
    failure probability never widens the bound (and the default-delta
    bound never exceeds the deterministic one)."""
    a, b = _prob_case(rng, dtype)
    k = 8
    deltas = (0.0, 2.0 ** -200, 2.0 ** -60, 2.0 ** -20, 2.0 ** -5, 0.5)
    prev = None
    for d in deltas:
        cur = _PROB_BOUNDS[variant](a, b, k, d)
        if prev is not None:
            assert np.all(cur <= prev), (variant, d)
        prev = cur


@pytest.mark.parametrize("variant", sorted(_PROB_BOUNDS))
def test_prob_truncation_monotone_in_k(rng, variant):
    """The truncation component is non-decreasing in k-truncation:
    truncating MORE slices (smaller k) never shrinks the bound, at every
    delta — so the planner's smallest-k-meeting-eps search is
    well-posed against the probabilistic model too."""
    a, b = _prob_case(rng, np.float64)
    # evaluate with the accumulation term suppressed (u=0): what remains
    # is the truncation/dropped-band part, the k-truncation component
    prob = {
        "ozimmu": lambda k, d: analysis.prob_error_bound_ozimmu(
            a, b, k, delta=d, u=0.0),
        "ozimmu_rn": lambda k, d: analysis.prob_error_bound_rn(
            a, b, k, delta=d, u=0.0),
        "ozimmu_ef": lambda k, d: analysis.prob_error_bound_group_ef(
            a, b, k, delta=d, u=0.0),
        "ozimmu_h": lambda k, d: analysis.prob_error_bound_rn(
            a, b, k, delta=d, u=0.0),
        "ozimmu_sm_b": lambda k, d: analysis.prob_error_bound_sm(
            a, b, k, delta=d, u=0.0),
        "ozimmu_sm_h": lambda k, d: analysis.prob_error_bound_sm(
            a, b, k, delta=d, u=0.0),
        "oz2_b": lambda k, d: analysis.prob_error_bound_oz2(
            a, b, k, fast=True, delta=d, u=0.0),
        "oz2_h": lambda k, d: analysis.prob_error_bound_oz2(
            a, b, k, fast=True, delta=d, u=0.0),
    }[variant]
    for d in (0.0, 2.0 ** -20, 2.0 ** -5):
        for k in range(3, 12):
            assert np.all(prob(k - 1, d) >= prob(k, d)), (variant, k, d)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       k=st.integers(2, 14),
       log2_delta=st.integers(-300, -1))
def test_prob_effective_terms_properties(seed, k, log2_delta):
    """effective_terms drives every prob bound; property-check it
    directly: 0 <= eff <= count, eff(count, 0) == count exactly, eff is
    non-increasing in delta and non-decreasing in count."""
    gen = np.random.default_rng(seed)
    count = int(gen.integers(1, 10_000))
    delta = 2.0 ** log2_delta
    eff = analysis.effective_terms(count, delta)
    assert 0.0 < eff <= float(count)
    assert analysis.effective_terms(count, 0.0) == float(count)
    assert analysis.effective_terms(count, delta / 2.0) >= eff
    assert analysis.effective_terms(count + 1, delta) >= eff
    # lambda(delta) agreement: below the saturation point the ratio is
    # exactly sqrt(2 ln(2/delta) / count)
    lam = math.sqrt(2.0 * math.log(2.0 / delta))
    assert eff == pytest.approx(min(float(count),
                                    lam * math.sqrt(count)), rel=1e-12)
