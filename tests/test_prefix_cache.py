"""Prefix KV cache: block aliasing, copy-on-write, keying, eviction.

Covers the serving-level prefix cache (repro.serving.prefix_cache) and
the PagedKV refcount/CoW machinery it rides on:

* hit / miss / partial-overlap lookup semantics and the bitwise contract
  (a hit reproduces the cold prefill exactly — adopted blocks were
  written by the same jitted chunk calls over the same tokens);
* copy-on-write divergence at the pool level: two tables aliasing one
  physical block must never observe each other's writes;
* eviction under block pressure: LRU entries are dropped BEFORE live
  slots are preempted, blocks are conserved throughout;
* keyed-by-spec isolation: a deterministic engine and its ``:prob``
  twin are numerically different pipelines and must never alias;
* the full family matrix {prefix on, chunked on, paged} vs the
  monolithic un-chunked reference, per token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serving import PagedKV, PrefixCache, ServingRuntime
from repro.serving.prefix_cache import config_key

GEN = 3
PREFIX_LEN = 19        # wave-1 shared prefix (m_pub = 16 at block=8)
SUFFIX_LEN = 3


@pytest.fixture(scope="module")
def dense():
    """Smoke dense model on an ozimmu engine (presplit active) — the
    prefix cache must compose with the weight split-cache."""
    cfg = configs.get_config("internlm2_1_8b", smoke=True,
                             engine_spec="ozimmu_h-4:df32")
    model = api.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _prompts(rng, vocab, n, prefix):
    return [np.concatenate([prefix,
                            rng.integers(0, vocab, size=SUFFIX_LEN,
                                         dtype=np.int32)])
            for _ in range(n)]


def _cold(cfg, params, prompts, slots=3):
    """Monolithic, un-chunked, un-cached reference outputs."""
    rt = ServingRuntime(cfg, params, slots=slots, max_len=64)
    return rt.generate([p.copy() for p in prompts], GEN)


# ---------------------------------------------------------------------------
# PagedKV: refcounts + copy-on-write (direct unit tests)
# ---------------------------------------------------------------------------

def _set_block(paged, bid, value):
    for name in paged.paged_names:
        ax = paged._slot_ax[name]
        idx = (slice(None),) * ax + (bid,)
        paged.pool[name] = paged.pool[name].at[idx].set(value)


def _first_block_view(paged, slot):
    """The first ``block`` cache positions of ``slot``, gathered through
    its table — what the model would actually read."""
    g = paged.gather(paged.device_tables())
    name = paged.paged_names[0]
    ax = paged._slot_ax[name]
    view = np.take(np.asarray(g[name]), slot, axis=ax)
    return np.take(view, range(paged.block), axis=ax)


def test_paged_cow_divergence(dense):
    """Two slots aliasing one physical block: a write through one must
    copy first (CoW) so the other's view never changes."""
    cfg, model, params = dense
    paged = PagedKV(cfg, model, 2, 32, block=8, params=params)
    assert paged.ensure(0, 16)            # slot 0: two blocks
    shared = paged.share_blocks(0, 2)     # a prefix entry's references
    paged.adopt_blocks(1, shared)         # slot 1 aliases them
    b0 = int(paged.tables[0, 0])
    assert int(paged.tables[1, 0]) == b0
    assert paged.refcount[b0] == 3        # slot 0 + entry + slot 1
    assert paged.live_blocks + paged.free_block_count == paged.n_blocks

    _set_block(paged, b0, 1.0)            # aliased bytes, seen by both
    assert np.all(_first_block_view(paged, 0) == 1.0)
    assert np.all(_first_block_view(paged, 1) == 1.0)

    # privatize slot 1's first block before it diverges
    assert paged.cow_for_write(1, [0])
    b1 = int(paged.tables[1, 0])
    assert b1 != b0 and paged.cow_copies == 1
    assert paged.refcount[b0] == 2 and paged.refcount[b1] == 1
    # the copy carried the bytes ...
    assert np.all(_first_block_view(paged, 1) == 1.0)
    # ... and divergence stays private
    _set_block(paged, b1, 2.0)
    assert np.all(_first_block_view(paged, 0) == 1.0)
    assert np.all(_first_block_view(paged, 1) == 2.0)

    # already-private blocks are left alone (no copy churn)
    assert paged.cow_for_write(1, [0]) and paged.cow_copies == 1
    # the second table index is still shared three ways
    assert paged.refcount[int(paged.tables[0, 1])] == 3
    assert paged.live_blocks + paged.free_block_count == paged.n_blocks

    # teardown: every reference released -> every block back on the
    # free list (conservation, the property the soak asserts at scale)
    paged.free_slot(1)
    paged.free_slot(0)
    paged.release_blocks(shared)
    assert paged.free_block_count == paged.n_blocks
    assert paged.live_blocks == 0


def test_paged_cow_needs_free_block(dense):
    """CoW needs a free block for the copy: a full pool reports False
    (the runtime then evicts) instead of corrupting the shared block."""
    cfg, model, params = dense
    paged = PagedKV(cfg, model, 2, 32, block=8, n_blocks=2, params=params)
    assert paged.ensure(0, 16)
    paged.adopt_blocks(1, paged.share_blocks(0, 2))
    assert not paged.cow_for_write(1, [0])
    assert paged.cow_copies == 0


# ---------------------------------------------------------------------------
# hit / miss / partial overlap + bitwise-vs-cold (runtime level)
# ---------------------------------------------------------------------------

def test_prefix_hit_miss_partial_overlap(dense):
    cfg, model, params = dense
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab, size=PREFIX_LEN, dtype=np.int32)
    wave1 = _prompts(rng, cfg.vocab, 3, prefix)
    wave2 = _prompts(rng, cfg.vocab, 3, prefix)
    # partial overlap: diverges after 10 tokens -> only the length-8
    # aligned sub-prefix can hit
    part = wave2[0].copy()
    part[10] = (part[10] + 1) % cfg.vocab

    rt = ServingRuntime(cfg, params, slots=3, max_len=64, page_block=8,
                        prefix_cache=True)
    out1 = rt.generate([p.copy() for p in wave1], GEN)
    st = rt.prefix.stats
    # all three admitted cold (one wave), publication at m_pub=16 plus
    # the aligned sub-length 8 (stateless family), deduped across slots
    assert (st.hits, st.misses) == (0, 3)
    assert st.inserted == 2 and len(rt.prefix) == 2

    out2 = rt.generate([p.copy() for p in wave2], GEN)
    assert (st.hits, st.misses) == (3, 3)
    assert st.hit_tokens == 3 * 16        # 16 prefill tokens aliased each

    out3 = rt.generate([part.copy()], GEN)
    # longest-first lookup: 16 misses (bytes differ at index 10), 8 hits
    assert (st.hits, st.misses) == (4, 3)
    assert st.hit_tokens == 3 * 16 + 8
    # the diverged prompt publishes its OWN 16-token entry afterwards
    assert st.inserted == 3 and len(rt.prefix) == 3

    refs = _cold(cfg, params, wave1 + wave2 + [part])
    for o, r in zip(out1 + out2 + out3, refs):
        np.testing.assert_array_equal(o, r)
    pc = rt.metrics.summary()["prefix_cache"]
    assert pc["hit_rate"] == pytest.approx(4 / 7)
    assert pc["entries"] == 3


def test_prefix_chunked_hit_bitwise(dense):
    """Chunked prefill + prefix cache together: chunk boundaries land on
    the publication length, hits resume mid-prompt, outputs stay
    bitwise."""
    cfg, model, params = dense
    rng = np.random.default_rng(8)
    prefix = rng.integers(0, cfg.vocab, size=PREFIX_LEN, dtype=np.int32)
    waves = [_prompts(rng, cfg.vocab, 3, prefix) for _ in range(2)]
    rt = ServingRuntime(cfg, params, slots=3, max_len=64, page_block=8,
                        prefill_chunk=5, prefix_cache=True)
    outs = [rt.generate([p.copy() for p in w], GEN) for w in waves]
    assert rt.prefix.stats.hits == 3
    refs = _cold(cfg, params, waves[0] + waves[1])
    for o, r in zip(outs[0] + outs[1], refs):
        np.testing.assert_array_equal(o, r)


def test_steady_state_prefix_measured_window_all_hits(dense):
    """The bench's steady-state helper against a REAL prefix runtime:
    after two warm passes every request in the measured window is a
    prefix hit (the warm passes published the entries and compiled the
    hit path's suffix buckets — the first-pass-measurement bug fixed in
    benchmarks/bench_serving.py)."""
    from benchmarks.bench_serving import (make_shared_prefix_trace,
                                          steady_state)
    cfg, model, params = dense
    rng = np.random.default_rng(5)
    trace = make_shared_prefix_trace(rng, 4, cfg.vocab, prefix_len=19,
                                     suffix_len=3, gen=3)
    rt = ServingRuntime(cfg, params, slots=4, max_len=64, page_block=8,
                        prefix_cache=True)
    s = steady_state(rt, trace, warm_passes=2)
    assert s["requests"]["finished"] == len(trace)
    assert s["prefix_cache"]["hit_rate"] == 1.0
    assert s["prefix_cache"]["hit_tokens"] == 16 * len(trace)


# ---------------------------------------------------------------------------
# eviction under block pressure
# ---------------------------------------------------------------------------

def test_prefix_eviction_under_block_pressure(dense):
    """A pool too small for the working set drops LRU prefix entries
    first (cheaper than preempting live progress); requests still finish
    with bitwise-correct outputs and blocks are conserved."""
    cfg, model, params = dense
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab, size=PREFIX_LEN, dtype=np.int32)
    prompts = _prompts(rng, cfg.vocab, 4, prefix)
    rt = ServingRuntime(cfg, params, slots=2, max_len=64, page_block=8,
                        page_blocks=5, prefix_cache=True)
    outs = rt.generate([p.copy() for p in prompts], GEN)
    refs = _cold(cfg, params, prompts, slots=2)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    s = rt.metrics.summary()
    assert s["requests"]["finished"] == len(prompts)
    assert rt.prefix.stats.evicted > 0
    paged = rt.paged
    assert paged.live_blocks + paged.free_block_count == paged.n_blocks
    # at drain every live slot is freed: only entry references remain
    held = sum(len(e.blocks) for e in rt.prefix.entries.values())
    assert paged.live_blocks <= held


# ---------------------------------------------------------------------------
# keyed-by-spec isolation (det vs :prob must never alias)
# ---------------------------------------------------------------------------

def test_prefix_key_isolation_det_vs_prob(dense):
    cfg, model, params = dense
    det = configs.get_config("internlm2_1_8b", smoke=True,
                             engine_spec="ozimmu_h-auto:df32")
    prob = configs.get_config("internlm2_1_8b", smoke=True,
                              engine_spec="ozimmu_h-auto:df32:prob")
    assert config_key(det) != config_key(prob)

    # functional: entries published under the det key are invisible to a
    # lookup carrying the prob key — numerically distinct pipelines miss
    paged = PagedKV(det, model, 2, 32, block=8, params=params)
    cache = PrefixCache(paged, det)
    tokens = np.arange(17, dtype=np.int32)
    assert paged.ensure(0, 16)
    cache.publish(tokens, 16, 0)
    assert cache.lookup(tokens) is not None
    assert cache.lookup(tokens, key0=config_key(prob)) is None
    assert (cache.stats.hits, cache.stats.misses) == (1, 1)


def test_prefix_cache_rejects_foreign_pool(dense):
    """A PrefixCache instance is bound to ONE pool — handing it to a
    runtime with a different pool must fail closed."""
    cfg, model, params = dense
    foreign = PrefixCache(PagedKV(cfg, model, 2, 32, block=8,
                                  params=params), cfg)
    with pytest.raises(ValueError, match="another pool"):
        ServingRuntime(cfg, params, slots=2, max_len=64, page_block=8,
                       prefix_cache=foreign)


def test_prefix_cache_requires_paged(dense):
    cfg, model, params = dense
    with pytest.raises(ValueError, match="page_block"):
        ServingRuntime(cfg, params, slots=2, max_len=64,
                       prefix_cache=True)


# ---------------------------------------------------------------------------
# full family matrix: {prefix on, chunked on, paged} == monolithic
# ---------------------------------------------------------------------------

FAMILY_ARCHS = (
    "internlm2_1_8b",        # dense
    "deepseek_moe_16b",      # moe
    "deepseek_v2_236b",      # mla_moe (latent + k_rope paged)
    "llama32_vision_11b",    # vlm (cross-KV state leaves)
    "seamless_m4t_medium",   # encdec (cross-KV state leaves)
    "mamba2_780m",           # ssm (pure-state: nothing pages)
    "recurrentgemma_9b",     # hybrid (paged K/V + recurrent state)
)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_prefix_chunked_paged_matches_monolithic(arch):
    """Every serving family, served {paged, chunked, prefix-cached},
    reproduces the monolithic un-chunked un-cached runtime per token —
    across a cold wave AND a prefix-hit wave."""
    from repro.launch.serve import slot_context
    cfg = configs.get_config(arch, smoke=True)
    model = api.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    ctx = slot_context(cfg, params, 11)
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab, size=9, dtype=np.int32)
    waves = [[np.concatenate([prefix,
                              rng.integers(0, cfg.vocab, size=2,
                                           dtype=np.int32)])
              for _ in range(3)] for _ in range(2)]

    cold_rt = ServingRuntime(cfg, params, slots=2, max_len=32, ctx=ctx)
    refs = [cold_rt.generate([p.copy() for p in w], GEN) for w in waves]

    rt = ServingRuntime(cfg, params, slots=2, max_len=32, page_block=4,
                        prefill_chunk=3, prefix_cache=True, ctx=ctx)
    outs = [rt.generate([p.copy() for p in w], GEN) for w in waves]
    for o, r in zip(outs[0] + outs[1], refs[0] + refs[1]):
        np.testing.assert_array_equal(o, r)
    # the shared 9-token prefix publishes at m_pub=8; wave 2 must hit
    assert rt.prefix.stats.hits >= 3
    assert rt.metrics.summary()["requests"]["finished"] == 6
