"""Pallas flash-attention kernel vs naive oracle (interpret mode) —
shape/dtype sweep per the kernel-testing requirement."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


CASES = [
    # B, Lq, Lk, H, KV, D, Dv, causal, window, qc, kc
    (1, 32, 32, 2, 2, 8, 8, True, None, 16, 16),
    (2, 40, 40, 4, 2, 16, 16, True, None, 16, 32),   # GQA + uneven pad
    (1, 24, 24, 4, 1, 8, 8, True, 9, 8, 8),          # MQA + window
    (2, 16, 48, 2, 2, 8, 8, False, None, 8, 16),     # cross-attn Lk != Lq
]


@pytest.mark.parametrize("B,Lq,Lk,H,KV,D,Dv,causal,window,qc,kc", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_matches_oracle(B, Lq, Lk, H, KV, D, Dv, causal,
                                     window, qc, kc, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Lq, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Lk, KV, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Lk, KV, Dv)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              qc=qc, kc=kc)
    # oracle in the kernel layout
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Lq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, Lk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, Lk, Dv)
    want = ref.flash_attention_ref(qt, kt, vt, group=H // KV, causal=causal,
                                   window=window)
    want = want.reshape(B, H, Lq, Dv).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_matches_model_attention():
    """Kernel output == the model-layer flash implementation (which the
    train step uses) — ties the kernel to the production path."""
    from repro.models.layers import attention_flash
    rng = np.random.default_rng(1)
    B, L, H, KV, D = 2, 33, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, L, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, L, KV, D)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, qc=16, kc=16)
    want = attention_flash(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,Lq,Lk,H,KV,D,Dv,causal,window,qc,kc", CASES[:3])
def test_flash_bwd_kernel_matches_autodiff_oracle(B, Lq, Lk, H, KV, D, Dv,
                                                  causal, window, qc, kc):
    """dq/dk/dv from the Pallas backward kernels == autodiff of the naive
    oracle (in the kernel layout, GQA contributions summed into BKV)."""
    from repro.kernels.flash_attention import (flash_attention_fwd,
                                               flash_attention_bwd)
    rng = np.random.default_rng(2)
    group = H // KV
    Lq_p = -(-Lq // qc) * qc
    Lk_p = -(-Lk // kc) * kc
    q = jnp.asarray(rng.standard_normal((B * H, Lq_p, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B * KV, Lk_p, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B * KV, Lk_p, Dv)), jnp.float32)
    dout = jnp.asarray(rng.standard_normal((B * H, Lq_p, Dv)), jnp.float32)

    out, lse = flash_attention_fwd(q, k, v, group=group, causal=causal,
                                   window=window, qc=qc, kc=kc, lk=Lk)
    dq, dk, dv = flash_attention_bwd(q, k, v, out, lse, dout, group=group,
                                     causal=causal, window=window,
                                     qc=qc, kc=kc, lk=Lk)

    def loss(q, k, v):
        o = ref.flash_attention_ref(q, k, v, group=group, causal=causal,
                                    window=window, lk=Lk)
        return jnp.sum(o * dout)

    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(gq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(gk),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(gv),
                               rtol=2e-4, atol=2e-4)
