"""Observability layer (docs/observability.md).

Covers:

* MetricsRegistry semantics: labeled counters/gauges/histograms,
  snapshot / diff / merge / total, the injectable clock, and the
  disabled mode being a true no-op (nothing recorded, `enabled()` gates
  hot sites before any work);
* linear-interpolation percentiles (the `_pct` nearest-rank fix) and
  the new p99/p95 blocks in `ServingMetrics.summary`;
* observed emulation counters == `Plan` cost accounting, exactly, for
  every variant family (full/:fast/:fast2, fixed and auto k) — the
  acceptance invariant: what ran is what the planner priced;
* bitwise identity of instrumented runs: obs on vs off over XLA,
  :fused, rhs_presplit, and (subprocess, 8 forced host devices)
  @mesh/int32 — recording happens host-side at trace time, never in
  the graph;
* the planner audit ledger: one decision row per auto-k resolution
  with the spec, mode, chosen k, predicted eps and cost columns;
* split-cache hit/miss mirroring into the global registry;
* exporters: Prometheus text passes the format lint and round-trips
  through `parse_prometheus`; the JSON document exposes the `totals`
  surface the CI smoke asserts on.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ozimmu, plan, split_cache
from repro.obs import export, registry
from repro.obs.registry import MetricsRegistry, Snapshot

DN = (((1,), (0,)), ((), ()))


@pytest.fixture()
def fresh_registry():
    """Swap in a clean process-global registry (and restore after)."""
    reg = MetricsRegistry()
    old = registry.set_registry(reg)
    registry.set_enabled(True)
    try:
        yield reg
    finally:
        registry.set_registry(old)
        registry.set_enabled(True)


@pytest.fixture()
def operands():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((6, 96)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((96, 10)), jnp.float32)
    return a, b


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    reg.inc("gemms", 3, variant="ozimmu_h", k=4)
    reg.inc("gemms", 2, k=4, variant="ozimmu_h")   # kwarg order irrelevant
    reg.inc("gemms", 7, variant="oz2_h", k=4)
    reg.inc("gemms", 1, variant="oz2_h", k=6)
    assert reg.value("gemms", variant="ozimmu_h", k=4) == 5
    assert reg.total("gemms") == 13
    assert reg.total("gemms", variant="oz2_h") == 8
    assert reg.total("gemms", k=4) == 12
    assert reg.total("absent") == 0


def test_gauge_hist_and_virtual_clock_timer():
    t = [0.0]
    reg = MetricsRegistry(now=lambda: t[0])
    reg.gauge("bytes", 10)
    reg.gauge("bytes", 20)              # gauges overwrite
    assert reg.gauge_value("bytes") == 20
    with reg.timer("phase_s", stage="x"):
        t[0] += 2.5
    reg.observe("phase_s", 0.5, stage="x")
    assert reg.hist_values("phase_s", stage="x") == (2.5, 0.5)
    snap = reg.snapshot()
    assert snap.taken_at == 2.5         # snapshot stamps the clock


def test_snapshot_diff_and_merge():
    reg = MetricsRegistry()
    reg.inc("c", 5, tag="a")
    reg.observe("h", 1.0)
    before = reg.snapshot()
    reg.inc("c", 2, tag="a")
    reg.inc("c", 4, tag="b")
    reg.observe("h", 2.0)
    d = reg.snapshot().diff(before)
    assert d.value("c", tag="a") == 2
    assert d.value("c", tag="b") == 4
    assert d.hist_values("h") == (2.0,)   # histograms diff by suffix
    other = MetricsRegistry()
    other.inc("c", 10, tag="a")
    other.observe("h2", 9.0)
    m = reg.snapshot().merge(other.snapshot())
    assert m.value("c", tag="a") == 17
    assert m.hist_values("h2") == (9.0,)
    assert "h2" in m.names() and "c" in m.names()


def test_disabled_mode_records_nothing(fresh_registry):
    with registry.disabled():
        assert not registry.enabled()
        fresh_registry.inc("c", 5)
        fresh_registry.gauge("g", 1)
        fresh_registry.observe("h", 1.0)
        with fresh_registry.timer("t"):
            pass
        assert fresh_registry.is_empty()
    assert registry.enabled()
    fresh_registry.inc("c", 1)
    assert fresh_registry.value("c") == 1


def test_percentile_linear_interpolation():
    assert registry.percentile([1, 2, 3, 4], 0.5) == 2.5
    assert registry.percentile([1, 2, 3, 4], 0.0) == 1.0
    assert registry.percentile([1, 2, 3, 4], 1.0) == 4.0
    assert registry.percentile([7], 0.95) == 7.0
    assert registry.percentile([10, 20], 0.25) == 12.5
    with pytest.raises(ValueError):
        registry.percentile([], 0.5)


def test_serving_metrics_percentile_blocks():
    from repro.serving.metrics import ServingMetrics

    t = [0.0]
    m = ServingMetrics(now=lambda: t[0])
    m.start()
    t[0] = 10.0

    class R:
        arrival = 0.0
        first_token_at = None

    for i, (ttft, lat) in enumerate([(1, 2), (2, 4), (3, 6), (4, 8)]):
        r = R()
        r.arrival, r.first_token_at = 0.0, float(ttft)
        m.record_finish(r, float(lat))
    for d in (1, 2, 3, 4):
        m.sample_queue(d)
    s = m.summary()
    assert s["ttft_s"]["p50"] == 2.5          # linear, not nearest-rank
    assert "p99" in s["ttft_s"] and "p99" in s["latency_s"]
    assert s["queue_depth"]["p95"] == pytest.approx(3.85)
    m.observe_timing("decode_step", 0.25)
    tm = m.summary()["timings_s"]["decode_step"]
    assert tm["count"] == 1 and tm["p99"] == 0.25


# ---------------------------------------------------------------------------
# observed emulation counters == Plan accounting
# ---------------------------------------------------------------------------

# one spec per variant family cell: every split family, both accumulate
# paths, the oz2 full / :fast / :fast2 cost shapes
FIXED_SPECS = [
    "ozimmu-3:f32", "ozimmu_rn-3:f32", "ozimmu_ef-3:df32",
    "ozimmu_h-4:df32", "ozimmu_sm_b-3:f32", "ozimmu_sm_h-4:df32",
    "oz2_b-4:df32", "oz2_h-4:df32:fast", "oz2_b-4:df32:fast2",
]
AUTO_SPECS = ["ozimmu_h-auto:df32", "oz2_h-auto:df32:fast",
              "ozimmu_sm_h-auto:df32:prob"]


@pytest.mark.parametrize("spec", FIXED_SPECS + AUTO_SPECS)
def test_observed_counts_match_plan(spec, fresh_registry, operands):
    a, b = operands
    cfg = ozimmu.parse_spec(spec)
    ozimmu.ozimmu_dot_general(a, b, DN, cfg)
    # expected costs from the SAME accounting the planner prices with
    # (probing the concrete operands exactly like the eager auto-k path)
    pl = plan.plan_contraction(
        cfg if cfg.accum_dtype != "f64" else cfg.with_(accum_dtype="f32"),
        a.shape[0], a.shape[1], b.shape[1], a=a, b=b, _record=False)
    snap = fresh_registry.snapshot()
    assert snap.total("emulation.calls") == 1
    assert snap.total("emulation.int8_gemms") == pl.int8_gemms, spec
    assert snap.total("emulation.highprec_adds") == pl.highprec_adds, spec
    assert snap.total("emulation.int8_gemms", k=pl.k) == pl.int8_gemms
    assert snap.total("emulation.split_bytes") == \
        4 * (a.size + b.size)   # f32 operands, both sides split


def test_observed_counts_batched_and_presplit(fresh_registry):
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((3, 5, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((3, 64, 7)), jnp.float32)
    dn = (((2,), (1,)), ((0,), (0,)))
    cfg = ozimmu.parse_spec("ozimmu_h-4:df32")
    pl = plan.plan_contraction(cfg, 5, 64, 7, _record=False)
    ozimmu.ozimmu_dot_general(a, b, dn, cfg)
    snap = fresh_registry.snapshot()
    assert snap.total("emulation.int8_gemms") == 3 * pl.int8_gemms
    sp = split_cache.SplitCache().get(b, dn, cfg)
    before = fresh_registry.snapshot()
    ozimmu.ozimmu_dot_general(a, b, dn, cfg, rhs_presplit=sp)
    d = fresh_registry.snapshot().diff(before)
    assert d.total("emulation.int8_gemms", presplit=1) == \
        3 * pl.int8_gemms
    # the frozen rhs skips the B-side splitter: only A bytes recorded
    assert d.total("emulation.split_bytes") == 4 * a.size


def test_trace_time_recording_once_per_compile(fresh_registry, operands):
    """Counters record at trace time: a jitted call records once at
    compile, and compiled replays add nothing (each replay executes the
    same contractions — the per-execution count IS the traced count)."""
    a, b = operands
    cfg = ozimmu.parse_spec("ozimmu_h-4:df32")
    fn = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(a, b, DN, cfg))
    fn(a, b).block_until_ready()
    once = fresh_registry.total("emulation.int8_gemms")
    assert once == plan.plan_contraction(
        cfg, a.shape[0], a.shape[1], b.shape[1], _record=False).int8_gemms
    fn(a, b).block_until_ready()
    assert fresh_registry.total("emulation.int8_gemms") == once


# ---------------------------------------------------------------------------
# bitwise identity: obs on vs off
# ---------------------------------------------------------------------------

BITWISE_SPECS = ["ozimmu_h-4:df32", "ozimmu_sm_h-4:df32",
                 "oz2_h-4:df32:fast", "oz2_b-4:df32:fast2",
                 "ozimmu_h-4:df32:fused", "oz2_h-auto:df32:fast:fused"]


@pytest.mark.parametrize("spec", BITWISE_SPECS)
def test_bitwise_identity_obs_on_off(spec, fresh_registry, operands):
    a, b = operands
    cfg = ozimmu.parse_spec(spec)
    sp = split_cache.SplitCache().get(b, DN, cfg)
    on = ozimmu.ozimmu_dot_general(a, b, DN, cfg)
    on_jit = jax.jit(
        lambda a, b: ozimmu.ozimmu_dot_general(a, b, DN, cfg))(a, b)
    on_pre = ozimmu.ozimmu_dot_general(a, b, DN, cfg, rhs_presplit=sp)
    assert not fresh_registry.is_empty()
    with registry.disabled():
        off = ozimmu.ozimmu_dot_general(a, b, DN, cfg)
        off_jit = jax.jit(
            lambda a, b: ozimmu.ozimmu_dot_general(a, b, DN, cfg))(a, b)
        off_pre = ozimmu.ozimmu_dot_general(a, b, DN, cfg,
                                            rhs_presplit=sp)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(on_jit), np.asarray(off_jit))
    np.testing.assert_array_equal(np.asarray(on_pre), np.asarray(off_pre))


def test_bitwise_identity_mesh_int32_obs_on_off():
    """@mesh/int32 in a subprocess with 8 forced host devices: the
    sharded path's outputs are bitwise-identical with obs on vs off
    (named scopes are metadata; counters are host-side)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu
        from repro.obs import registry
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(11)
        a = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        cfg = ozimmu.parse_spec("ozimmu_h-4:df32@model/int32")
        mesh = make_test_mesh(data=1, model=8)
        with set_mesh(mesh):
            on = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                a, b, dn, cfg))(a, b)
            assert registry.get_registry().total(
                "emulation.int8_gemms", mesh="model") == 10
            with registry.disabled():
                off = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                    a, b, dn, cfg))(a, b)
        assert bool(jnp.all(on == off))
        print("OK")
    """)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    assert "OK" in p.stdout


# ---------------------------------------------------------------------------
# planner audit ledger
# ---------------------------------------------------------------------------

def test_plan_ledger_records_auto_k(fresh_registry, operands):
    a, b = operands
    led = plan.get_ledger()
    led.clear()
    ozimmu.ozimmu_dot_general(a, b, DN,
                              ozimmu.parse_spec("ozimmu_h-auto:df32"))
    ozimmu.ozimmu_dot_general(
        a, b, DN, ozimmu.parse_spec("oz2_h-auto:df32:fast:prob"))
    entries = led.entries()
    assert len(entries) == 2
    det, prob = entries
    assert det.mode == "deterministic" and det.probed
    assert prob.mode == "probabilistic" and ":prob" in prob.spec
    for e in entries:
        assert e.k >= 1 and e.int8_gemms > 0 and e.predicted_eps > 0
        assert e.m == a.shape[0] and e.n == a.shape[1]
        assert set(e.as_dict()) >= {"spec", "k", "predicted_eps",
                                    "int8_gemms", "highprec_adds"}
    summ = led.summary()
    assert summ["decisions"] == 2 and summ["probabilistic"] == 1
    assert summ["k_hist"] and summ["worst_predicted_eps"] > 0
    assert "auto-k decisions" in led.describe()
    # fixed-k contractions plan statically and leave no ledger rows
    led.clear()
    ozimmu.ozimmu_dot_general(a, b, DN,
                              ozimmu.parse_spec("ozimmu_h-4:df32"))
    assert len(led) == 0


def test_ledger_disabled_with_obs(fresh_registry, operands):
    a, b = operands
    led = plan.get_ledger()
    led.clear()
    with registry.disabled():
        ozimmu.ozimmu_dot_general(
            a, b, DN, ozimmu.parse_spec("ozimmu_h-auto:df32"))
    assert len(led) == 0


# ---------------------------------------------------------------------------
# split-cache mirroring
# ---------------------------------------------------------------------------

def test_split_cache_obs_counters(fresh_registry, operands):
    _, b = operands
    cfg = ozimmu.parse_spec("ozimmu_h-4:df32")
    cache = split_cache.SplitCache()
    cache.get(b, DN, cfg)
    cache.get(b, DN, cfg)
    snap = fresh_registry.snapshot()
    assert snap.total("split_cache.misses") == 1
    assert snap.total("split_cache.hits") == 1
    assert snap.total("split_cache.hit_bytes") == 4 * b.size
    assert snap.gauge("split_cache.cached_bytes") > 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_prometheus_roundtrip_and_lint():
    reg = MetricsRegistry()
    reg.inc("emulation.int8_gemms", 45, variant="oz2_h", k=9)
    reg.inc("emulation.int8_gemms", 10, variant="ozimmu_h", k=4)
    reg.gauge("split_cache.cached_bytes", 1024)
    for v in (0.1, 0.2, 0.4):
        reg.observe("serving.decode_step_s", v)
    text = export.to_prometheus(reg.snapshot(), prefix="repro")
    export.lint_prometheus(text)        # raises on any format violation
    parsed = export.parse_prometheus(text)
    assert parsed[
        'repro_emulation_int8_gemms_total{k="9",variant="oz2_h"}'] == 45
    assert parsed["repro_split_cache_cached_bytes"] == 1024
    assert parsed["repro_serving_decode_step_s_count"] == 3
    assert parsed['repro_serving_decode_step_s{quantile="0.5"}'] == 0.2
    # the lint rejects malformed text
    with pytest.raises(ValueError):
        export.lint_prometheus("no_type_line 1")
    with pytest.raises(ValueError):
        export.lint_prometheus("# TYPE x counter\nx{bad-label=\"1\"} 1")


def test_json_document_totals_and_ledger(fresh_registry, operands):
    a, b = operands
    plan.get_ledger().clear()
    ozimmu.ozimmu_dot_general(a, b, DN,
                              ozimmu.parse_spec("ozimmu_h-auto:df32"))
    extra_reg = MetricsRegistry()
    extra_reg.inc("serving.tokens_generated", 12)
    snap = export.unified_snapshot(extra_reg)
    doc = json.loads(export.to_json(snap, extra={"serving_summary": {}}))
    assert doc["totals"]["emulation.int8_gemms"] > 0
    assert doc["totals"]["serving.tokens_generated"] == 12
    assert doc["plan_ledger"]["decisions"] >= 1
    assert "serving_summary" in doc
