"""Distributed tests — run in a SUBPROCESS with 8 forced host devices (the
main test process keeps the single real CPU device; jax locks device count
at first init).

Covers: sharded train step on the (data, model) and (pod, data, model)
meshes, sharded-vs-single-device numerical parity, ZeRO-1 state sharding,
int8+error-feedback compressed all-reduce inside shard_map, and a
mini multi-pod dry-run (lower+compile) for one cell per family.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=900, x64=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_ENABLE_X64", None)
    if x64:  # must be set before jax initializes in the subprocess
        env["JAX_ENABLE_X64"] = "true"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_sharded_train_step_matches_single_device():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs, optim
        from repro.launch import steps as S
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules
        from repro.distributed.compat import set_mesh

        arch = "internlm2_1_8b"
        cfg = configs.get_config(arch, smoke=True)
        opt_cfg = optim.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                                  min_lr_frac=1.0)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab, dtype=jnp.int32)
        batch = {"tokens": tokens}

        def run(mesh):
            rules = mesh_rules(mesh, arch) if mesh else None
            import contextlib
            ctx = set_mesh(mesh) if mesh else contextlib.nullcontext()
            with ctx, use_rules(rules):
                state, axes, opt_axes = S.init_state(
                    jax.random.PRNGKey(0), cfg, opt_cfg)
                step = jax.jit(S.make_train_step(
                    cfg, opt_cfg, S.TrainConfig(microbatches=2),
                    opt_axes=opt_axes))
                losses = []
                for i in range(3):
                    state, m = step(state, batch)
                    losses.append(float(m["loss"]))
            return losses

        l_single = run(None)
        l_mesh = run(make_test_mesh(data=2, model=2))
        l_pod = run(make_test_mesh(data=2, model=2, pod=2))
        print("losses", l_single, l_mesh, l_pod)
        np.testing.assert_allclose(l_single, l_mesh, rtol=2e-2)
        np.testing.assert_allclose(l_single, l_pod, rtol=2e-2)
        assert l_single[2] < l_single[0]  # it learns
        print("OK")
    """)


def test_sharded_decode_matches_forward():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.models import api
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules
        from repro.distributed.compat import set_mesh

        arch = "recurrentgemma_9b"   # hybrid: ring buffers + LRU state
        cfg = configs.get_config(arch, smoke=True)
        model = api.get_model(cfg)
        mesh = make_test_mesh(data=2, model=2)
        with set_mesh(mesh), use_rules(mesh_rules(mesh, arch)):
            params, _ = model.init(jax.random.PRNGKey(0), cfg)
            B, L = 4, 8
            tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                        cfg.vocab, dtype=jnp.int32)
            ref = model.forward(params, cfg, {"tokens": tokens})
            cache = model.init_cache(cfg, B, L)
            step = jax.jit(lambda c, t, n: model.decode_step(
                params, cfg, c, t, n))
            outs = []
            for t in range(L):
                logits, cache = step(cache, tokens[:, t:t+1],
                                     jnp.asarray(t+1, jnp.int32))
                outs.append(logits[:, 0])
            got = jnp.stack(outs, axis=1)
            err = float(jnp.max(jnp.abs(got - ref)) /
                        (jnp.max(jnp.abs(ref)) + 1e-9))
            print("decode err", err)
            assert err < 5e-2
        print("OK")
    """)


def test_compressed_psum_shard_map():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim import compress
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(data=8, model=1)
        g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32))

        def body(g, err):
            g = g[0]; err = err[0]
            mean, new_err = compress.compressed_psum(
                {"w": g}, {"w": err}, "data")
            return mean["w"][None], new_err["w"][None]

        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"))))
        err0 = jnp.zeros_like(g_global)
        mean, err1 = fn(g_global, err0)
        true_mean = jnp.mean(g_global, axis=0)
        # every shard holds the same mean; int8 quantization error is
        # bounded by scale/2 <= rowmax * 2^-β (β=7 ⇒ <1% of rowmax)
        got = mean[0]
        tol = float(jnp.max(jnp.abs(g_global))) * 2.0 ** -6
        assert float(jnp.max(jnp.abs(got - true_mean))) < tol
        # error feedback: residual + transmitted == local contribution
        print("OK")
    """)


@pytest.mark.slow
def test_mini_dryrun_lower_compile_families():
    """Lower+compile a reduced train cell AND a decode cell on the 8-device
    multi-pod test mesh for one arch per distinct family."""
    run_sub("""
        import jax, jax.numpy as jnp
        from repro import configs, optim
        from repro.launch import steps as S
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules, spec_tree
        from repro.distributed.compat import set_mesh
        from repro.models import api
        from jax.sharding import NamedSharding, PartitionSpec as P

        for arch in ("phi4_mini_3_8b", "deepseek_moe_16b", "mamba2_780m",
                     "seamless_m4t_medium", "llama32_vision_11b"):
            cfg = configs.get_config(arch, smoke=True)
            model = api.get_model(cfg)
            mesh = make_test_mesh(data=2, model=2, pod=2)
            rules = mesh_rules(mesh, arch)
            with set_mesh(mesh), use_rules(rules):
                opt_cfg = optim.OptConfig()
                pshapes, axes = S.params_shapes(cfg)
                opt_axes = optim.zero_axes(axes, pshapes, 2)
                step = S.make_train_step(cfg, opt_cfg,
                                         S.TrainConfig(microbatches=2),
                                         opt_axes=opt_axes)
                state, _, _ = S.init_state(jax.random.PRNGKey(0), cfg,
                                           opt_cfg, zero_divisor=2)
                B, L = 8, 32
                batch = {"tokens": jnp.zeros((B, L), jnp.int32)}
                if cfg.family == "vlm":
                    batch["image_embeds"] = jnp.zeros(
                        (B, cfg.vision_seq, cfg.d_model), jnp.float32)
                if cfg.family == "encdec":
                    batch["frames"] = jnp.zeros((B, L, cfg.d_model),
                                                jnp.float32)
                lowered = jax.jit(step).lower(state, batch)
                compiled = lowered.compile()
                assert compiled.memory_analysis() is not None
                print(arch, "train lower+compile OK")
        print("OK")
    """, timeout=1200)


def test_moe_a2a_dispatch_matches_scatter():
    """The shard_map all-to-all MoE dispatch is bit-identical to the GSPMD
    scatter path (values and grads) when capacity is not binding."""
    run_sub("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.models import moe
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules
        from repro.distributed.compat import set_mesh

        cfg = configs.get_config("deepseek_moe_16b", smoke=True,
                                 capacity_factor=4.0)
        mesh = make_test_mesh(data=4, model=2)
        with set_mesh(mesh), use_rules(mesh_rules(mesh, "deepseek_moe_16b")):
            p, _ = moe.init_moe_ffn(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1),
                                  (8, 16, cfg.d_model), jnp.float32)
            y1 = jax.jit(lambda p, x: moe.moe_ffn(p, cfg, x))(p, x)
            y2 = jax.jit(lambda p, x: moe.moe_ffn_a2a(p, cfg, x))(p, x)
            assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
            g1 = jax.jit(jax.grad(
                lambda p: jnp.sum(moe.moe_ffn(p, cfg, x)**2)))(p)
            g2 = jax.jit(jax.grad(
                lambda p: jnp.sum(moe.moe_ffn_a2a(p, cfg, x)**2)))(p)
            for k in g1:
                if k == "shared":
                    continue
                e = float(jnp.max(jnp.abs(g1[k] - g2[k])))
                m = float(jnp.max(jnp.abs(g1[k]))) + 1e-9
                assert e < 5e-3 * m, (k, e)  # bf16 engine noise
        print("OK")
    """)


def test_elastic_restore_across_meshes():
    """A checkpoint saved from a (4,2) mesh restores onto a (2,2) mesh
    (elastic reshard-on-restore) with identical values."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs, optim
        from repro.checkpoint import Checkpointer
        from repro.launch import steps as S
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules, spec_tree
        from repro.distributed.compat import set_mesh
        import tempfile

        arch = "internlm2_1_8b"
        cfg = configs.get_config(arch, smoke=True)
        opt_cfg = optim.OptConfig()
        d = tempfile.mkdtemp()

        mesh_a = make_test_mesh(data=4, model=2)
        with set_mesh(mesh_a), use_rules(mesh_rules(mesh_a, arch)):
            state, axes, _ = S.init_state(jax.random.PRNGKey(0), cfg,
                                          opt_cfg, zero_divisor=4)
            Checkpointer(d).save(7, state, blocking=True)
            ref = np.asarray(state.params["embed"])

        mesh_b = make_test_mesh(data=2, model=2)
        with set_mesh(mesh_b), use_rules(mesh_rules(mesh_b, arch)):
            state_b, axes_b, _ = S.init_state(jax.random.PRNGKey(1), cfg,
                                              opt_cfg, zero_divisor=2)
            shardings = jax.tree.map(
                lambda s: jax.NamedSharding(mesh_b, s),
                spec_tree(axes_b), is_leaf=lambda x: hasattr(x, "index"))
            restored, step = Checkpointer(d).restore(state_b)
            assert step == 7
            got = np.asarray(restored.params["embed"])
            np.testing.assert_array_equal(got, ref)
            # restored params adopt mesh-B shardings when re-pinned
            p = jax.device_put(
                restored.params["embed"],
                jax.NamedSharding(mesh_b, jax.sharding.PartitionSpec(
                    "model", None)))
            assert p.sharding.mesh.shape["data"] == 2
        print("OK")
    """)


# ---------------------------------------------------------------------------
# mesh-native ozimmu: error-free cross-device accumulation
# ---------------------------------------------------------------------------

def test_ozimmu_sharded_bitwise_all_variants():
    """Contraction-axis sharding over 'model' (8 shards) is bit-identical
    to the single-device emulation for all four paper variants under the
    exact-int32 cross-device reduction — f32 and df32 accumulators here
    (no x64 in this subprocess); genuine f64 is the _x64 test below."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(0)
        def phi_mat(m, n, phi=1.0):
            u = rng.uniform(0, 1, (m, n)); z = rng.standard_normal((m, n))
            return (u - 0.5) * np.exp(phi * z)

        a = jnp.asarray(phi_mat(48, 256), jnp.float32)
        b = jnp.asarray(phi_mat(256, 64), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        mesh = make_test_mesh(data=1, model=8)
        accums = ("f32", "df32")
        for name in ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h",
                     "ozimmu_sm_b", "ozimmu_sm_h"):
            for accum in accums:
                cfg = ozimmu.VARIANTS[name].with_(k=6, accum_dtype=accum)
                ref = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
                sharded = cfg.with_(mesh_axis="model")
                with set_mesh(mesh):
                    got = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                        a, b, dn, sharded))(a, b)
                assert bool(jnp.all(ref == got)), (name, accum)
                print(name, accum, "bitwise OK")
        print("OK")
    """)


def test_ozimmu_sharded_bitwise_x64():
    """Same bitwise invariant with genuine f64 accumulation (x64 mode)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        assert jax.config.jax_enable_x64
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.standard_normal((32, 512)), jnp.float64)
        b = jnp.asarray(rng.standard_normal((512, 40)), jnp.float64)
        dn = (((1,), (0,)), ((), ()))
        mesh = make_test_mesh(data=1, model=8)
        for name in ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h",
                     "ozimmu_sm_b", "ozimmu_sm_h"):
            cfg = ozimmu.VARIANTS[name].with_(k=8, accum_dtype="f64")
            ref = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
            with set_mesh(mesh):
                got = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                    a, b, dn, cfg.with_(mesh_axis="model")))(a, b)
            assert bool(jnp.all(ref == got)), name
        print("OK")
    """, x64=True)


def test_ozimmu_batch_sharded_matches_single_device():
    """Batch-dim sharding over 'data' (GSPMD, no cross-device contraction)
    is bit-identical to single-device emulation — batch entries are
    independent, so no reduction crosses devices."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((8, 16, 64)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((8, 64, 24)), jnp.float32)
        dn = (((2,), (1,)), ((0,), (0,)))
        cfg = ozimmu.VARIANTS["ozimmu_h"].with_(k=6, accum_dtype="df32")
        ref = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
        mesh = make_test_mesh(data=8, model=1)
        with set_mesh(mesh):
            spec_a = NamedSharding(mesh, P("data", None, None))
            spec_b = NamedSharding(mesh, P("data", None, None))
            aa = jax.device_put(a, spec_a)
            bb = jax.device_put(b, spec_b)
            got = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                a, b, dn, cfg))(aa, bb)
        assert bool(jnp.all(ref == got))
        print("OK")
    """)


def test_ozimmu_sharded_vjp_bitwise():
    """Gradients through the mesh-native emulated contraction equal the
    single-device gradients bit for bit (the custom VJP's cotangent
    contractions run through the same sharded scheme)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((32, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        cfg = ozimmu.VARIANTS["ozimmu_h"].with_(k=6, accum_dtype="df32")
        loss0 = lambda a, b: jnp.sum(
            jnp.sin(ozimmu.ozimmu_dot_general(a, b, dn, cfg)))
        g_ref = jax.grad(loss0, argnums=(0, 1))(a, b)
        sharded = cfg.with_(mesh_axis="model")
        loss1 = lambda a, b: jnp.sum(
            jnp.sin(ozimmu.ozimmu_dot_general(a, b, dn, sharded)))
        mesh = make_test_mesh(data=1, model=8)
        with set_mesh(mesh):
            g_got = jax.jit(jax.grad(loss1, argnums=(0, 1)))(a, b)
        for r, g, nm in (*zip(g_ref, g_got, ("da", "db")),):
            assert bool(jnp.all(r == g)), nm
        print("OK")
    """)


def test_ozimmu_sharded_fused_pipeline_bitwise():
    """The fused Pallas pipeline (``:fused``) composes with the mesh-native
    path: under the exact-int32 reduction the sharded fused emulation is
    bit-identical to the single-device fused AND unfused paths, for all
    four variants (the acceptance invariant of the fused pipeline)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(0)
        def phi_mat(m, n, phi=1.0):
            u = rng.uniform(0, 1, (m, n)); z = rng.standard_normal((m, n))
            return (u - 0.5) * np.exp(phi * z)

        a = jnp.asarray(phi_mat(48, 256), jnp.float32)
        b = jnp.asarray(phi_mat(256, 64), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        mesh = make_test_mesh(data=1, model=8)
        for name in ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h",
                     "ozimmu_sm_b", "ozimmu_sm_h"):
            for accum in ("f32", "df32"):
                cfg = ozimmu.VARIANTS[name].with_(
                    k=6, accum_dtype=accum, use_pallas="fused")
                unfused = ozimmu.ozimmu_dot_general(
                    a, b, dn, cfg.with_(use_pallas=False))
                fused = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
                assert bool(jnp.all(unfused == fused)), (name, accum)
                sharded = cfg.with_(mesh_axis="model")
                with set_mesh(mesh):
                    got = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                        a, b, dn, sharded))(a, b)
                assert bool(jnp.all(fused == got)), (name, accum)
                print(name, accum, "fused sharded bitwise OK")
        print("OK")
    """)


def test_oz2_sharded_bitwise_both_modes():
    """Ozaki-II (constant scaling + exponent ladder): under the exact-int32
    reduction the sharded emulation — plain and fused — is bit-identical
    to the single-device path for both oz2 variants, full, fast AND fast2
    modes (the digit grid is agreed via one pmax — per-row for fast2 —
    and the int32 chunk products are psum'd BEFORE the ladder fold; the
    fast2 diag unscale is a pure-pow2 rescale of the reduced result, so
    it cannot break the bitwise invariant)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(5)
        def phi_mat(m, n, phi=1.0):
            u = rng.uniform(0, 1, (m, n)); z = rng.standard_normal((m, n))
            return (u - 0.5) * np.exp(phi * z)

        a = jnp.asarray(phi_mat(48, 256), jnp.float32)
        b = jnp.asarray(phi_mat(256, 64), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        mesh = make_test_mesh(data=1, model=8)
        for name in ("oz2_b", "oz2_h"):
            for fast in (False, True, "fast2"):
                for pallas in (False, "fused"):
                    cfg = ozimmu.VARIANTS[name].with_(
                        k=6, accum_dtype="df32", fast=fast,
                        use_pallas=pallas)
                    ref = ozimmu.ozimmu_dot_general(a, b, dn,
                                                    cfg.with_(use_pallas=False))
                    local = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
                    assert bool(jnp.all(ref == local)), (name, fast, pallas)
                    with set_mesh(mesh):
                        got = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                            a, b, dn, cfg.with_(mesh_axis="model")))(a, b)
                    assert bool(jnp.all(ref == got)), (name, fast, pallas)
                print(name, {False: "full", True: "fast"}.get(fast, "fast2"),
                      "sharded bitwise OK")
        print("OK")
    """)


def test_oz2_fast2_sharded_int32_bitwise():
    """:fast2 composed with @mesh/int32 specifically (the acceptance
    matrix cell): spec-driven configs, exact-int32 reduction, plain and
    fused — bit-identical to the single-device XLA path."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(11)
        def phi_mat(m, n, phi=2.0):
            u = rng.uniform(0, 1, (m, n)); z = rng.standard_normal((m, n))
            return (u - 0.5) * np.exp(phi * z)

        a = jnp.asarray(phi_mat(48, 256), jnp.float32)
        b = jnp.asarray(phi_mat(256, 64), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        mesh = make_test_mesh(data=1, model=8)
        for spec, sharded_spec in (
                ("oz2_h-6:df32:fast2", "oz2_h-6:df32:fast2@model/int32"),
                ("oz2_b-6:df32:fast2", "oz2_b-6:df32:fast2@model/int32"),
                ("oz2_h-6:df32:fast2:fused",
                 "oz2_h-6:df32:fast2:fused@model/int32")):
            cfg = ozimmu.parse_spec(spec)
            assert cfg.split.endswith("_fast2"), spec
            ref = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
            with set_mesh(mesh):
                got = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                    a, b, dn, ozimmu.parse_spec(sharded_spec)))(a, b)
            assert bool(jnp.all(ref == got)), spec
            print(spec, "sharded int32 bitwise OK")
        print("OK")
    """)


def test_sm_auto_sharded_int32_bitwise():
    """The sign-magnitude acceptance matrix cell: ``ozimmu_sm_h-auto`` is
    bit-identical across {XLA, :fused, @mesh/int32, rhs_presplit} — all
    jitted, so auto-k resolves the same static mantissa-coverage plan on
    every path, and the sm digit grid is pmax-agreed across shards with
    the signed products psum'd exactly in int32."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu, split_cache
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(17)
        def phi_mat(m, n, phi=2.0):
            u = rng.uniform(0, 1, (m, n)); z = rng.standard_normal((m, n))
            return (u - 0.5) * np.exp(phi * z)

        a = jnp.asarray(phi_mat(48, 256), jnp.float32)
        b = jnp.asarray(phi_mat(256, 64), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        mesh = make_test_mesh(data=1, model=8)
        for stem in ("ozimmu_sm_h-auto:df32", "ozimmu_sm_b-auto:df32"):
            cfg = ozimmu.parse_spec(stem)
            ref = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                a, b, dn, cfg))(a, b)
            fused = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                a, b, dn, ozimmu.parse_spec(stem + ":fused")))(a, b)
            assert bool(jnp.all(ref == fused)), (stem, "fused")
            sp = split_cache.SplitCache().get(b, dn, cfg)
            pre = jax.jit(lambda a, b, sp: ozimmu.ozimmu_dot_general(
                a, b, dn, cfg, rhs_presplit=sp))(a, b, sp)
            assert bool(jnp.all(ref == pre)), (stem, "presplit")
            with set_mesh(mesh):
                mcfg = ozimmu.parse_spec(stem + "@model/int32")
                got = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                    a, b, dn, mcfg))(a, b)
                gotf = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                    a, b, dn,
                    ozimmu.parse_spec(stem + ":fused@model/int32")))(a, b)
            assert bool(jnp.all(ref == got)), (stem, "@mesh/int32")
            assert bool(jnp.all(ref == gotf)), (stem, "fused@mesh/int32")
            print(stem, "4-way bitwise OK")
        print("OK")
    """)


def test_presplit_sharded_bitwise_all_variants():
    """Serving split-cache x @mesh: a frozen B-side split entering the
    shard_map pre-sharded along the contraction axis is bit-identical to
    the sharded uncached path (int32 reduction) for every variant incl.
    :fused, and to the single-device presplit path — the cached
    full-matrix digit grid IS the pmax-agreed grid (docs/serving.md)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu, split_cache
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(9)
        a = jnp.asarray(rng.standard_normal((24, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        mesh = make_test_mesh(data=1, model=8)
        cache = split_cache.SplitCache()
        FAST = {"oz2_h": True, "oz2_b": "fast2"}   # cover :fast AND :fast2
        for name in ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h",
                     "ozimmu_sm_b", "ozimmu_sm_h", "oz2_b", "oz2_h"):
            for pallas in (False, "fused"):
                if pallas == "fused" and name == "ozimmu_rn":
                    continue  # adaptive RN has no fused splitter
                cfg = ozimmu.canonical_fast2(ozimmu.VARIANTS[name].with_(
                    k=5, accum_dtype="df32", use_pallas=pallas,
                    fast=FAST.get(name, False)))
                ref = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
                with set_mesh(mesh):
                    mcfg = cfg.with_(mesh_axis="model")
                    sp = cache.get(b, dn, mcfg)
                    got = jax.jit(lambda a, b, sp: ozimmu.ozimmu_dot_general(
                        a, b, dn, mcfg, rhs_presplit=sp))(a, b, sp)
                    unc = jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                        a, b, dn, mcfg))(a, b)
                assert bool(jnp.all(got == unc)), (name, pallas)
                assert bool(jnp.all(got == ref)), (name, pallas)
            print(name, "presplit sharded bitwise OK")
        print("OK")
    """)


def test_serving_runtime_mesh_smoke():
    """The serving runtime end-to-end under a (data, model) mesh with an
    @model engine: generates finite tokens, split-cache active."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.distributed import compat
        from repro.distributed.sharding import use_rules
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.models import api
        from repro.serving import ServingRuntime

        arch = "internlm2_1_8b"
        mesh = make_test_mesh(data=2, model=4)
        cfg = configs.get_config(arch, smoke=True,
                                 engine_spec="ozimmu_h-4:df32@model")
        with compat.set_mesh(mesh), use_rules(mesh_rules(mesh, arch)):
            model = api.get_model(cfg)
            params, _ = model.init(jax.random.PRNGKey(0), cfg)
            rt = ServingRuntime(cfg, params, slots=2, max_len=32)
            rng = np.random.default_rng(0)
            prompts = [rng.integers(0, cfg.vocab, size=6, dtype=np.int32)
                       for _ in range(3)]
            outs = rt.generate(prompts, max_new=3)
        assert all(len(o) == 9 for o in outs)
        s = rt.metrics.summary()
        assert s["requests"]["finished"] == 3
        assert s["split_cache"]["weight_split_hit_rate"] == 1.0
        print("OK")
    """, x64=True)


def test_serving_prefix_chunked_paged_mesh_bitwise():
    """{paged, chunked prefill, prefix cache} under a (data, model) mesh
    with an @model engine reproduces the monolithic un-chunked mesh
    runtime per token — including across a prefix-hit second wave (the
    mesh key rides in the prefix keying, so entries published here can
    never alias a differently-sharded pipeline's)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.distributed import compat
        from repro.distributed.sharding import use_rules
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.models import api
        from repro.serving import ServingRuntime

        arch = "internlm2_1_8b"
        mesh = make_test_mesh(data=2, model=4)
        cfg = configs.get_config(arch, smoke=True,
                                 engine_spec="ozimmu_h-4:df32@model")
        with compat.set_mesh(mesh), use_rules(mesh_rules(mesh, arch)):
            model = api.get_model(cfg)
            params, _ = model.init(jax.random.PRNGKey(0), cfg)
            rng = np.random.default_rng(0)
            prefix = rng.integers(0, cfg.vocab, size=9, dtype=np.int32)
            waves = [[np.concatenate([prefix,
                                      rng.integers(0, cfg.vocab, size=2,
                                                   dtype=np.int32)])
                      for _ in range(3)] for _ in range(2)]
            cold = ServingRuntime(cfg, params, slots=2, max_len=32)
            refs = [cold.generate([p.copy() for p in w], 3)
                    for w in waves]
            rt = ServingRuntime(cfg, params, slots=2, max_len=32,
                                page_block=4, prefill_chunk=3,
                                prefix_cache=True)
            outs = [rt.generate([p.copy() for p in w], 3) for w in waves]
        for o, r in zip(outs[0] + outs[1], refs[0] + refs[1]):
            assert np.array_equal(o, r), (o, r)
        assert rt.prefix.stats.hits >= 3          # wave 2 hit the prefix
        s = rt.metrics.summary()
        assert s["requests"]["finished"] == 6
        assert s["split_cache"]["weight_split_hit_rate"] == 1.0
        print("OK")
    """, x64=True)


def test_psum_df32_error_free_vs_plain_f32():
    """The compensated DF32 reduction keeps what a plain f32 psum rounds
    away: partials engineered so small terms vanish under f32 summation."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.accumulate import DF32
        from repro.distributed import collectives
        from repro.distributed.compat import set_mesh, shard_map
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(data=8, model=1)
        # device i holds hi = (-1)^i * 2^24, lo = 0.5: the 2^24 terms cancel
        # pairwise, so the true sum is 4.0.  A plain f32 psum of (hi + lo)
        # collapses every partial to +-2^24 first (0.5 is under half an ulp
        # at 2^24, and -16777215.5 rounds half-to-even to -2^24 too) and
        # returns 0.0.
        his = jnp.asarray([(-1.0) ** i * 2.0 ** 24 for i in range(8)],
                          jnp.float32).reshape(8, 1)
        los = jnp.full((8, 1), 0.5, jnp.float32)

        def body(h, l):
            c = DF32(h[0], l[0])
            plain = jax.lax.psum(h[0] + l[0], "data")
            comp = collectives.psum_df32(c, "data")
            return plain[None], (comp.hi + comp.lo)[None]

        plain, comp = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data")), check_vma=False))(his, los)
        assert float(plain[0, 0]) == 0.0, plain     # f32 psum loses it
        assert float(comp[0, 0]) == 4.0, comp       # DF32 keeps it
        print("OK")
    """)


def test_ozimmu_sharded_df32_reduce_accuracy():
    """The @axis/df32 strategy (compensated partial-accumulator reduction)
    stays at the unsharded error level — no f32-psum accuracy cliff."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ozimmu
        from repro.distributed.compat import set_mesh
        from repro.launch.mesh import make_test_mesh

        rng = np.random.default_rng(4)
        a_np = rng.standard_normal((48, 512))
        b_np = rng.standard_normal((512, 32))
        exact = a_np @ b_np                      # numpy f64 reference
        a = jnp.asarray(a_np, jnp.float32)
        b = jnp.asarray(b_np, jnp.float32)
        dn = (((1,), (0,)), ((), ()))
        cfg = ozimmu.VARIANTS["ozimmu_h"].with_(k=6, accum_dtype="df32")
        ref = np.asarray(ozimmu.ozimmu_dot_general(a, b, dn, cfg),
                         np.float64)
        sharded = cfg.with_(mesh_axis="model", mesh_reduce="df32")
        mesh = make_test_mesh(data=1, model=8)
        with set_mesh(mesh):
            got = np.asarray(jax.jit(lambda a, b: ozimmu.ozimmu_dot_general(
                a, b, dn, sharded))(a, b), np.float64)
        scale = np.abs(exact).max()
        e_ref = np.abs(ref - exact).max() / scale
        e_got = np.abs(got - exact).max() / scale
        print("err unsharded", e_ref, "sharded/df32-reduce", e_got)
        # error-free reduction: sharded error within 2x of unsharded
        # (local per-shard scales can make it smaller, never psum-worse)
        assert e_got <= 2 * e_ref + 1e-7, (e_got, e_ref)
        print("OK")
    """)
