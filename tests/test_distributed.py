"""Distributed tests — run in a SUBPROCESS with 8 forced host devices (the
main test process keeps the single real CPU device; jax locks device count
at first init).

Covers: sharded train step on the (data, model) and (pod, data, model)
meshes, sharded-vs-single-device numerical parity, ZeRO-1 state sharding,
int8+error-feedback compressed all-reduce inside shard_map, and a
mini multi-pod dry-run (lower+compile) for one cell per family.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_ENABLE_X64", None)
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_sharded_train_step_matches_single_device():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs, optim
        from repro.launch import steps as S
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules

        arch = "internlm2_1_8b"
        cfg = configs.get_config(arch, smoke=True)
        opt_cfg = optim.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                                  min_lr_frac=1.0)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab, dtype=jnp.int32)
        batch = {"tokens": tokens}

        def run(mesh):
            rules = mesh_rules(mesh, arch) if mesh else None
            import contextlib
            ctx = jax.set_mesh(mesh) if mesh else contextlib.nullcontext()
            with ctx, use_rules(rules):
                state, axes, opt_axes = S.init_state(
                    jax.random.PRNGKey(0), cfg, opt_cfg)
                step = jax.jit(S.make_train_step(
                    cfg, opt_cfg, S.TrainConfig(microbatches=2),
                    opt_axes=opt_axes))
                losses = []
                for i in range(3):
                    state, m = step(state, batch)
                    losses.append(float(m["loss"]))
            return losses

        l_single = run(None)
        l_mesh = run(make_test_mesh(data=2, model=2))
        l_pod = run(make_test_mesh(data=2, model=2, pod=2))
        print("losses", l_single, l_mesh, l_pod)
        np.testing.assert_allclose(l_single, l_mesh, rtol=2e-2)
        np.testing.assert_allclose(l_single, l_pod, rtol=2e-2)
        assert l_single[2] < l_single[0]  # it learns
        print("OK")
    """)


def test_sharded_decode_matches_forward():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.models import api
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules

        arch = "recurrentgemma_9b"   # hybrid: ring buffers + LRU state
        cfg = configs.get_config(arch, smoke=True)
        model = api.get_model(cfg)
        mesh = make_test_mesh(data=2, model=2)
        with jax.set_mesh(mesh), use_rules(mesh_rules(mesh, arch)):
            params, _ = model.init(jax.random.PRNGKey(0), cfg)
            B, L = 4, 8
            tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                        cfg.vocab, dtype=jnp.int32)
            ref = model.forward(params, cfg, {"tokens": tokens})
            cache = model.init_cache(cfg, B, L)
            step = jax.jit(lambda c, t, n: model.decode_step(
                params, cfg, c, t, n))
            outs = []
            for t in range(L):
                logits, cache = step(cache, tokens[:, t:t+1],
                                     jnp.asarray(t+1, jnp.int32))
                outs.append(logits[:, 0])
            got = jnp.stack(outs, axis=1)
            err = float(jnp.max(jnp.abs(got - ref)) /
                        (jnp.max(jnp.abs(ref)) + 1e-9))
            print("decode err", err)
            assert err < 5e-2
        print("OK")
    """)


def test_compressed_psum_shard_map():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim import compress
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(data=8, model=1)
        g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32))

        def body(g, err):
            g = g[0]; err = err[0]
            mean, new_err = compress.compressed_psum(
                {"w": g}, {"w": err}, "data")
            return mean["w"][None], new_err["w"][None]

        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"))))
        err0 = jnp.zeros_like(g_global)
        mean, err1 = fn(g_global, err0)
        true_mean = jnp.mean(g_global, axis=0)
        # every shard holds the same mean; int8 quantization error is
        # bounded by scale/2 <= rowmax * 2^-β (β=7 ⇒ <1% of rowmax)
        got = mean[0]
        tol = float(jnp.max(jnp.abs(g_global))) * 2.0 ** -6
        assert float(jnp.max(jnp.abs(got - true_mean))) < tol
        # error feedback: residual + transmitted == local contribution
        print("OK")
    """)


@pytest.mark.slow
def test_mini_dryrun_lower_compile_families():
    """Lower+compile a reduced train cell AND a decode cell on the 8-device
    multi-pod test mesh for one arch per distinct family."""
    run_sub("""
        import jax, jax.numpy as jnp
        from repro import configs, optim
        from repro.launch import steps as S
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules, spec_tree
        from repro.models import api
        from jax.sharding import NamedSharding, PartitionSpec as P

        for arch in ("phi4_mini_3_8b", "deepseek_moe_16b", "mamba2_780m",
                     "seamless_m4t_medium", "llama32_vision_11b"):
            cfg = configs.get_config(arch, smoke=True)
            model = api.get_model(cfg)
            mesh = make_test_mesh(data=2, model=2, pod=2)
            rules = mesh_rules(mesh, arch)
            with jax.set_mesh(mesh), use_rules(rules):
                opt_cfg = optim.OptConfig()
                pshapes, axes = S.params_shapes(cfg)
                opt_axes = optim.zero_axes(axes, pshapes, 2)
                step = S.make_train_step(cfg, opt_cfg,
                                         S.TrainConfig(microbatches=2),
                                         opt_axes=opt_axes)
                state, _, _ = S.init_state(jax.random.PRNGKey(0), cfg,
                                           opt_cfg, zero_divisor=2)
                B, L = 8, 32
                batch = {"tokens": jnp.zeros((B, L), jnp.int32)}
                if cfg.family == "vlm":
                    batch["image_embeds"] = jnp.zeros(
                        (B, cfg.vision_seq, cfg.d_model), jnp.float32)
                if cfg.family == "encdec":
                    batch["frames"] = jnp.zeros((B, L, cfg.d_model),
                                                jnp.float32)
                lowered = jax.jit(step).lower(state, batch)
                compiled = lowered.compile()
                assert compiled.memory_analysis() is not None
                print(arch, "train lower+compile OK")
        print("OK")
    """, timeout=1200)


def test_moe_a2a_dispatch_matches_scatter():
    """The shard_map all-to-all MoE dispatch is bit-identical to the GSPMD
    scatter path (values and grads) when capacity is not binding."""
    run_sub("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.models import moe
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules

        cfg = configs.get_config("deepseek_moe_16b", smoke=True,
                                 capacity_factor=4.0)
        mesh = make_test_mesh(data=4, model=2)
        with jax.set_mesh(mesh), use_rules(mesh_rules(mesh, "deepseek_moe_16b")):
            p, _ = moe.init_moe_ffn(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1),
                                  (8, 16, cfg.d_model), jnp.float32)
            y1 = jax.jit(lambda p, x: moe.moe_ffn(p, cfg, x))(p, x)
            y2 = jax.jit(lambda p, x: moe.moe_ffn_a2a(p, cfg, x))(p, x)
            assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
            g1 = jax.jit(jax.grad(
                lambda p: jnp.sum(moe.moe_ffn(p, cfg, x)**2)))(p)
            g2 = jax.jit(jax.grad(
                lambda p: jnp.sum(moe.moe_ffn_a2a(p, cfg, x)**2)))(p)
            for k in g1:
                if k == "shared":
                    continue
                e = float(jnp.max(jnp.abs(g1[k] - g2[k])))
                m = float(jnp.max(jnp.abs(g1[k]))) + 1e-9
                assert e < 5e-3 * m, (k, e)  # bf16 engine noise
        print("OK")
    """)


def test_elastic_restore_across_meshes():
    """A checkpoint saved from a (4,2) mesh restores onto a (2,2) mesh
    (elastic reshard-on-restore) with identical values."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs, optim
        from repro.checkpoint import Checkpointer
        from repro.launch import steps as S
        from repro.launch.mesh import make_test_mesh, mesh_rules
        from repro.distributed.sharding import use_rules, spec_tree
        import tempfile

        arch = "internlm2_1_8b"
        cfg = configs.get_config(arch, smoke=True)
        opt_cfg = optim.OptConfig()
        d = tempfile.mkdtemp()

        mesh_a = make_test_mesh(data=4, model=2)
        with jax.set_mesh(mesh_a), use_rules(mesh_rules(mesh_a, arch)):
            state, axes, _ = S.init_state(jax.random.PRNGKey(0), cfg,
                                          opt_cfg, zero_divisor=4)
            Checkpointer(d).save(7, state, blocking=True)
            ref = np.asarray(state.params["embed"])

        mesh_b = make_test_mesh(data=2, model=2)
        with jax.set_mesh(mesh_b), use_rules(mesh_rules(mesh_b, arch)):
            state_b, axes_b, _ = S.init_state(jax.random.PRNGKey(1), cfg,
                                              opt_cfg, zero_divisor=2)
            shardings = jax.tree.map(
                lambda s: jax.NamedSharding(mesh_b, s),
                spec_tree(axes_b), is_leaf=lambda x: hasattr(x, "index"))
            restored, step = Checkpointer(d).restore(state_b)
            assert step == 7
            got = np.asarray(restored.params["embed"])
            np.testing.assert_array_equal(got, ref)
            # restored params adopt mesh-B shardings when re-pinned
            p = jax.device_put(
                restored.params["embed"],
                jax.NamedSharding(mesh_b, jax.sharding.PartitionSpec(
                    "model", None)))
            assert p.sharding.mesh.shape["data"] == 2
        print("OK")
    """)
