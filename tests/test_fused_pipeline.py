"""Fused Pallas emulation pipeline (``use_pallas="fused"``) and the
accuracy-driven execution planner (``core/plan.py``, spec token ``auto``).

The fused pipeline's contract is BIT-identity with the unfused XLA path:
every stage (fused split, Pallas group GEMM, fused convert+scale+add
epilogue) performs the same exact/compensated operation sequence, so the
whole emulation — forward, VJP, batched, sharded — must produce the same
bits.  The planner's contract is that ``auto`` never picks a k whose
measured error (vs the double-double oracle) exceeds ``target_eps`` on the
bench accuracy grid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.exact import dd_matmul, max_relative_error
from repro.core import (VARIANTS, make_engine, ozimmu_dot_general,
                        ozimmu_matmul, parse_spec)
from repro.core import plan
from repro.core.splitting import compute_beta
from tests.conftest import make_phi_matrix


# ---------------------------------------------------------------------------
# fused-vs-unfused bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", list(VARIANTS))
@pytest.mark.parametrize("accum", ["f64", "f32", "df32"])
def test_fused_bit_identical_all_variants(rng, variant, accum):
    """All four paper variants, every accumulator, odd (non-multiple-of-
    block) shapes: the fused pipeline returns the same bits."""
    a = jnp.asarray(make_phi_matrix(rng, 33, 130, phi=1.0))
    b = jnp.asarray(make_phi_matrix(rng, 130, 17, phi=1.0))
    cfg = VARIANTS[variant].with_(k=6, accum_dtype=accum)
    c_ref = np.asarray(ozimmu_matmul(a, b, cfg))
    c_fused = np.asarray(ozimmu_matmul(a, b, cfg.with_(use_pallas="fused")))
    np.testing.assert_array_equal(c_fused, c_ref)


@pytest.mark.parametrize("variant", ["oz2_b", "oz2_h"])
@pytest.mark.parametrize("fast", [True, "fast2"])
@pytest.mark.parametrize("accum", ["f64", "f32", "df32"])
def test_fused_bit_identical_oz2_fast_modes(rng, variant, fast, accum):
    """The oz2 fast-mode band selections — :fast and the improved-scaling
    :fast2 (whose post-ladder diag unscale runs as a Pallas epilogue when
    fused) — stay bit-identical to the XLA path on odd shapes."""
    a = jnp.asarray(make_phi_matrix(rng, 33, 130, phi=2.0))
    b = jnp.asarray(make_phi_matrix(rng, 130, 17, phi=2.0))
    cfg = VARIANTS[variant].with_(k=6, accum_dtype=accum, fast=fast)
    c_ref = np.asarray(ozimmu_matmul(a, b, cfg))
    c_fused = np.asarray(ozimmu_matmul(a, b, cfg.with_(use_pallas="fused")))
    np.testing.assert_array_equal(c_fused, c_ref)


def test_fused_bit_identical_f32_inputs(rng):
    a = jnp.asarray(make_phi_matrix(rng, 48, 160, dtype=np.float32))
    b = jnp.asarray(make_phi_matrix(rng, 160, 40, dtype=np.float32))
    for variant in VARIANTS:
        cfg = VARIANTS[variant].with_(k=5, accum_dtype="df32")
        c_ref = np.asarray(ozimmu_matmul(a, b, cfg))
        c_fused = np.asarray(ozimmu_matmul(a, b,
                                           cfg.with_(use_pallas="fused")))
        np.testing.assert_array_equal(c_fused, c_ref, err_msg=variant)


def test_fused_bit_identical_batched_dot_general(rng):
    """Batch dims ride the kernels' batch grid axes: an attention-score-like
    contraction is bit-identical fused vs unfused."""
    q = jnp.asarray(make_phi_matrix(rng, 4 * 12, 64,
                                    dtype=np.float32).reshape(4, 12, 64))
    k = jnp.asarray(make_phi_matrix(rng, 4 * 10, 64,
                                    dtype=np.float32).reshape(4, 10, 64))
    dn = (((2,), (2,)), ((0,), (0,)))
    for variant in ("ozimmu_h", "ozimmu_sm_h"):
        for accum in ("f32", "df32"):
            cfg = VARIANTS[variant].with_(k=5, accum_dtype=accum)
            ref = np.asarray(ozimmu_dot_general(q, k, dn, cfg))
            fused = np.asarray(ozimmu_dot_general(
                q, k, dn, cfg.with_(use_pallas="fused")))
            np.testing.assert_array_equal(fused, ref, err_msg=variant)


@pytest.mark.parametrize("variant", ["ozimmu_h", "ozimmu_sm_h"])
def test_fused_vjp_bit_identical(rng, variant):
    """Gradients flow through the same emulated cotangent contractions:
    fused and unfused backward passes agree bit for bit — including the
    sign-magnitude family, whose cotangent contractions re-split under
    the same sm digit convention."""
    a = jnp.asarray(make_phi_matrix(rng, 24, 96))
    b = jnp.asarray(make_phi_matrix(rng, 96, 16))
    cfg = VARIANTS[variant].with_(k=6)

    def loss(cfg):
        return lambda a, b: jnp.sum(jnp.sin(ozimmu_matmul(a, b, cfg)))

    ga, gb = jax.grad(loss(cfg), argnums=(0, 1))(a, b)
    fa, fb = jax.grad(loss(cfg.with_(use_pallas="fused")),
                      argnums=(0, 1))(a, b)
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(ga))
    np.testing.assert_array_equal(np.asarray(fb), np.asarray(gb))


def test_fused_under_jit(rng):
    a = jnp.asarray(make_phi_matrix(rng, 16, 64, dtype=np.float32))
    b = jnp.asarray(make_phi_matrix(rng, 64, 24, dtype=np.float32))
    cfg = VARIANTS["ozimmu_ef"].with_(k=5, accum_dtype="df32",
                                      use_pallas="fused")
    eager = np.asarray(ozimmu_matmul(a, b, cfg))
    jitted = np.asarray(jax.jit(
        lambda a, b: ozimmu_matmul(a, b, cfg))(a, b))
    np.testing.assert_array_equal(jitted, eager)


# ---------------------------------------------------------------------------
# spec grammar: `auto` k token, `:fused`
# ---------------------------------------------------------------------------

def test_parse_spec_new_tokens():
    cfg = parse_spec("ozimmu_h-auto:df32:fused@model")
    assert cfg.auto_k and cfg.use_pallas == "fused"
    assert cfg.accum_dtype == "df32" and cfg.mesh_axis == "model"
    assert parse_spec("ozimmu_h-auto").auto_k
    assert parse_spec("ozimmu_ef-8:fused").use_pallas == "fused"
    assert parse_spec("ozimmu_ef-8:fused").accum_dtype == "f64"
    assert parse_spec("ozimmu_h-8:fused:df32").accum_dtype == "df32"
    assert not parse_spec("ozimmu_h-8:df32").auto_k
    for bad in ("ozimmu_h-auto:fused:bogus", "ozimmu_h-8:f32:df32",
                "ozimmu_h-8:fused:fused", "ozimmu_h-au", "bf16:fused"):
        with pytest.raises(ValueError):
            make_engine(bad)


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

def test_auto_k_meets_target_eps_on_bench_grid(rng):
    """Acceptance: `auto` never selects a k whose measured error (dd
    oracle) exceeds target_eps, across the bench accuracy grid."""
    n = 128
    eps = plan.DEFAULT_TARGET_EPS
    for phi in (0.5, 2.0):
        a = make_phi_matrix(rng, n, n, phi=phi)
        b = make_phi_matrix(rng, n, n, phi=phi)
        hi, lo = dd_matmul(a, b)
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        for variant in VARIANTS:
            cfg = VARIANTS[variant].with_(auto_k=True)
            k = plan.auto_k(aj, bj, cfg)
            c = np.asarray(ozimmu_matmul(aj, bj, cfg))
            err = max_relative_error(c, hi, lo)
            assert err <= eps, (variant, phi, k, err)
            assert plan.K_MIN <= k <= plan.K_MAX


def test_auto_k_respects_custom_target_eps(rng):
    """A looser target picks a smaller (or equal) k; the measured error
    still meets the loosened target."""
    n = 96
    a = make_phi_matrix(rng, n, n, phi=1.0)
    b = make_phi_matrix(rng, n, n, phi=1.0)
    hi, lo = dd_matmul(a, b)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    cfg_tight = VARIANTS["ozimmu_h"].with_(auto_k=True)
    cfg_loose = cfg_tight.with_(target_eps=1e-6)
    k_tight = plan.auto_k(aj, bj, cfg_tight)
    k_loose = plan.auto_k(aj, bj, cfg_loose)
    assert k_loose <= k_tight
    c = np.asarray(ozimmu_matmul(aj, bj, cfg_loose))
    assert max_relative_error(c, hi, lo) <= 1e-6


def test_auto_k_static_fallback_inside_jit(rng):
    """Traced operands cannot be probed: the planner resolves to the
    deterministic mantissa-coverage plan and the contraction still runs."""
    a = jnp.asarray(make_phi_matrix(rng, 32, 128))
    b = jnp.asarray(make_phi_matrix(rng, 128, 16))
    cfg = VARIANTS["ozimmu_h"].with_(auto_k=True, use_pallas="fused")
    out = jax.jit(lambda a, b: ozimmu_matmul(a, b, cfg))(a, b)
    beta = compute_beta(128)
    k_static = plan.choose_k(128, beta, plan.DEFAULT_TARGET_EPS,
                             split="rn_const", mantissa=53)
    # the static plan covers the f64 mantissa + carry guard
    assert k_static * beta >= 53
    ref = np.asarray(a.astype(jnp.float64) @ b.astype(jnp.float64))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-13)


def test_plan_cost_accounting_reuses_paper_formulas():
    """Plan.int8_gemms / highprec_adds are the paper's own accounting
    (k(k+1)/2 fast-mode pairs; num_highprec_adds for step iv)."""
    cfg = VARIANTS["ozimmu_h"].with_(k=8)
    pl = plan.plan_contraction(cfg, 256, 256, 256)
    assert pl.int8_gemms == 8 * 9 // 2
    assert pl.highprec_adds == 8          # group-EF: one add per group
    cfg_naive = VARIANTS["ozimmu"].with_(k=8, accumulate="naive")
    pl_naive = plan.plan_contraction(cfg_naive, 256, 256, 256)
    assert pl_naive.highprec_adds == 36   # k(k+1)/2
    assert pl.describe()


def test_kernel_blocks_table():
    """The autotune table: aligned, monotone with problem size, cached."""
    small = plan.kernel_blocks(64, 128, 64)
    large = plan.kernel_blocks(8192, 8192, 8192)
    assert all(b % 128 == 0 for b in small + large)
    assert all(s <= l for s, l in zip(small, large))
    assert plan.kernel_blocks(64, 128, 64) is small  # lru-cached
    # tile alignment: never exceeds the rounded-up dim, honors multiples
    assert plan.tile(8, 256, 8) == 8
    assert plan.tile(100, 256, 8) == 104
    assert plan.tile(1000, 256, 128) == 256


def test_engine_auto_fused_spec_end_to_end(rng):
    """`ozimmu_h-auto:df32:fused` through MatmulEngine — the full
    spec-to-contraction path models use."""
    eng = make_engine("ozimmu_h-auto:df32:fused")
    x = jnp.asarray(make_phi_matrix(rng, 6 * 8, 64,
                                    dtype=np.float32).reshape(6, 8, 64))
    w = jnp.asarray(make_phi_matrix(rng, 64, 32, dtype=np.float32))
    out = eng(x, w)
    ref = np.asarray(jnp.einsum("abi,ij->abj", x.astype(jnp.float64),
                                w.astype(jnp.float64)))
    rel = np.abs(np.asarray(out, np.float64) - ref) / (np.abs(ref) + 1e-6)
    assert rel.max() < 5e-5
