"""Tests for `ozimmu_dot_general`: batched / multi-batch / transposed
contractions vs `jnp.einsum` references, gradient correctness through the
general-dimension-numbers custom VJP, batch-vs-loop bit-equality, the
batched Pallas path, and the engine routing (no reshape-to-2D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (VARIANTS, make_engine, ozimmu_dot_general,
                        ozimmu_matmul)
from tests.conftest import make_phi_matrix


def phi_tensor(rng, shape, phi=0.5, dtype=np.float64):
    flat = make_phi_matrix(rng, int(np.prod(shape[:-1])), shape[-1], phi,
                           dtype)
    return jnp.asarray(flat.reshape(shape))


TOL = dict(rtol=1e-12, atol=1e-12)


def test_batched_bmn_bnp(rng):
    """bmn,bnp->bmp to emulation accuracy, every variant."""
    a = phi_tensor(rng, (3, 24, 40))
    b = phi_tensor(rng, (3, 40, 12))
    dn = (((2,), (1,)), ((0,), (0,)))
    ref = jnp.einsum("bmn,bnp->bmp", a, b)
    for variant in VARIANTS:
        c = ozimmu_dot_general(a, b, dn, VARIANTS[variant].with_(k=10))
        assert c.shape == ref.shape
        np.testing.assert_allclose(np.asarray(c), np.asarray(ref), **TOL)


def test_multi_batch_and_multi_free(rng):
    """Two batch dims + a free dim on each side (attention-score shape)."""
    q = phi_tensor(rng, (2, 3, 10, 32))
    k = phi_tensor(rng, (2, 3, 14, 32))
    dn = (((3,), (3,)), ((0, 1), (0, 1)))
    ref = jnp.einsum("xyld,xysd->xyls", q, k)
    c = ozimmu_dot_general(q, k, dn, VARIANTS["ozimmu_h"].with_(k=10))
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref), **TOL)


def test_transposed_contraction(rng):
    """Contract over lhs axis 0 / rhs axis 1: nm,pn->mp (both transposed)."""
    a = phi_tensor(rng, (40, 24))      # (n, m)
    b = phi_tensor(rng, (12, 40))      # (p, n)
    dn = (((0,), (1,)), ((), ()))
    ref = jnp.einsum("nm,pn->mp", a, b)
    c = ozimmu_dot_general(a, b, dn, VARIANTS["ozimmu_h"].with_(k=10))
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref), **TOL)


def test_multiple_contraction_axes(rng):
    """Two contraction axes flatten into one inner dim (beta from total n)."""
    x = phi_tensor(rng, (2, 6, 5, 8))
    y = phi_tensor(rng, (2, 6, 8, 7))
    dn = (((1, 3), (1, 2)), ((0,), (0,)))
    ref = jax.lax.dot_general(x, y, dn)
    c = ozimmu_dot_general(x, y, dn, VARIANTS["ozimmu_h"].with_(k=10))
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref), **TOL)


def test_batched_equals_per_batch_loop(rng):
    """Batch dims must be carried natively: the batched emulation is
    BIT-IDENTICAL to looping ozimmu_matmul over the batch (per-batch
    row/col scales, same int8 digits, same accumulation order)."""
    a = phi_tensor(rng, (4, 16, 48))
    b = phi_tensor(rng, (4, 48, 8))
    dn = (((2,), (1,)), ((0,), (0,)))
    for variant in ("ozimmu", "ozimmu_rn", "ozimmu_h",
                    "ozimmu_sm_b", "ozimmu_sm_h"):
        cfg = VARIANTS[variant].with_(k=8)
        got = np.asarray(ozimmu_dot_general(a, b, dn, cfg))
        want = np.stack([np.asarray(ozimmu_matmul(a[i], b[i], cfg))
                         for i in range(a.shape[0])])
        np.testing.assert_array_equal(got, want, err_msg=variant)


def test_oz2_fast_modes_batched_equals_loop_and_grads(rng):
    """oz2 :fast and :fast2 under general dnums: batched == per-batch loop
    bitwise (per-batch gbase and, for fast2, per-batch diag unscale), and
    cotangents match the f64 reference through the custom VJP."""
    a = phi_tensor(rng, (3, 16, 48), phi=1.5)
    b = phi_tensor(rng, (3, 48, 8), phi=1.5)
    dn = (((2,), (1,)), ((0,), (0,)))
    for variant in ("oz2_b", "oz2_h"):
        for fast in (True, "fast2"):
            cfg = VARIANTS[variant].with_(k=10, fast=fast)
            got = np.asarray(ozimmu_dot_general(a, b, dn, cfg))
            want = np.stack([np.asarray(ozimmu_matmul(a[i], b[i], cfg))
                             for i in range(a.shape[0])])
            np.testing.assert_array_equal(got, want)
    cfg = VARIANTS["oz2_h"].with_(k=10, fast="fast2")
    ga, gb = jax.grad(lambda a, b: jnp.sum(
        jnp.sin(ozimmu_dot_general(a, b, dn, cfg))), (0, 1))(a, b)
    ra, rb = jax.grad(lambda a, b: jnp.sum(
        jnp.sin(jax.lax.dot_general(a, b, dn))), (0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb),
                               rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("variant", ["ozimmu_h", "ozimmu_sm_h"])
def test_grads_of_batched_contraction(rng, variant):
    """Cotangents flow through the emulation under general dnums — the
    sign-magnitude family included (its cotangent contractions re-split
    both operands under the sm convention)."""
    a = phi_tensor(rng, (3, 9, 20))
    b = phi_tensor(rng, (3, 20, 7))
    dn = (((2,), (1,)), ((0,), (0,)))
    cfg = VARIANTS[variant].with_(k=10)

    def loss_oz(a, b):
        return jnp.sum(jnp.sin(ozimmu_dot_general(a, b, dn, cfg)))

    def loss_ref(a, b):
        return jnp.sum(jnp.sin(jax.lax.dot_general(a, b, dn)))

    ga, gb = jax.grad(loss_oz, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb),
                               rtol=1e-9, atol=1e-12)


def test_grads_transposed_and_multi_batch(rng):
    """VJP transpose bookkeeping for non-trivial axis layouts."""
    x = phi_tensor(rng, (2, 4, 5, 3))
    y = phi_tensor(rng, (2, 4, 3, 6))
    dn = (((1, 3), (1, 2)), ((0,), (0,)))
    cfg = VARIANTS["ozimmu_h"].with_(k=10)
    g1 = jax.grad(lambda x, y: jnp.sum(
        jnp.sin(ozimmu_dot_general(x, y, dn, cfg))), (0, 1))(x, y)
    g2 = jax.grad(lambda x, y: jnp.sum(
        jnp.sin(jax.lax.dot_general(x, y, dn))), (0, 1))(x, y)
    for got, want in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-12)


def test_jit_vmap_compose(rng):
    """vmap over an already-batched emulated contraction, under jit."""
    a = phi_tensor(rng, (2, 3, 8, 16))
    b = phi_tensor(rng, (3, 16, 5))
    cfg = VARIANTS["ozimmu_h"].with_(k=6)
    dn = (((2,), (1,)), ((0,), (0,)))
    f = jax.jit(jax.vmap(lambda x: ozimmu_dot_general(x, b, dn, cfg)))
    out = f(a)
    ref = jnp.einsum("vbmn,bnp->vbmp", a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-8)


def test_pallas_batched_path_matches_jnp(rng):
    """The Pallas group-GEMM kernel's batch grid axis is bit-identical to
    the pure-jnp batched path."""
    a = phi_tensor(rng, (2, 40, 64), dtype=np.float32)
    b = phi_tensor(rng, (2, 64, 24), dtype=np.float32)
    dn = (((2,), (1,)), ((0,), (0,)))
    for variant in ("ozimmu_ef", "ozimmu_h"):
        cfg = VARIANTS[variant].with_(k=5, accum_dtype="f32")
        c_jnp = np.asarray(ozimmu_dot_general(a, b, dn, cfg))
        c_pl = np.asarray(ozimmu_dot_general(
            a, b, dn, cfg.with_(use_pallas=True)))
        np.testing.assert_array_equal(c_pl, c_jnp)


def test_engine_batched_no_reshape(rng):
    """MatmulEngine handles leading dims as dot_general free dims and true
    batched contractions via .dot_general — no flatten-to-2D on either."""
    x = jnp.asarray(make_phi_matrix(rng, 4 * 6, 32, dtype=np.float32)
                    .reshape(4, 6, 32))
    w = jnp.asarray(make_phi_matrix(rng, 32, 16, dtype=np.float32))
    ref = np.asarray(jnp.einsum("abi,ij->abj", x.astype(jnp.float64),
                                w.astype(jnp.float64)))
    for spec in ("f32", "ozimmu_h-6:f32", "ozimmu_h-6:df32"):
        out = np.asarray(make_engine(spec)(x, w), np.float64)
        rel = np.abs(out - ref) / (np.abs(ref) + 1e-6)
        assert rel.max() < 5e-5, (spec, rel.max())

    # true batched rhs — impossible for the old reshape-to-2D engine
    wb = jnp.asarray(make_phi_matrix(rng, 4 * 32, 16, dtype=np.float32)
                     .reshape(4, 32, 16))
    dn = (((2,), (1,)), ((0,), (0,)))
    refb = np.asarray(jnp.einsum("bli,bij->blj", x.astype(jnp.float64),
                                 wb.astype(jnp.float64)))
    for spec in ("f32", "ozimmu_h-6:df32", "ozimmu_ef-6:f32"):
        out = np.asarray(make_engine(spec).dot_general(x, wb, dn), np.float64)
        rel = np.abs(out - refb) / (np.abs(refb) + 1e-6)
        assert rel.max() < 5e-5, (spec, rel.max())


def test_dnum_validation():
    a = jnp.zeros((3, 4, 5))
    b = jnp.zeros((3, 6, 7))
    with pytest.raises(ValueError):
        ozimmu_dot_general(a, b, (((2,), (1,)), ((0,), (0,))))
    with pytest.raises(ValueError):
        ozimmu_dot_general(a, b, (((2,), (1,), (0,)), ((0,), (0,))))
    with pytest.raises(ValueError):
        ozimmu_matmul(a, b)
