import os

# Tests run on the single real CPU device (the dry-run sets its own 512-device
# flag in a subprocess).  x64 must be enabled before jax initializes: the core
# library emulates FP64 GEMMs.
os.environ.setdefault("JAX_ENABLE_X64", "true")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(12345)


def make_phi_matrix(rng, m, n, phi=0.5, dtype=np.float64):
    """Paper's test matrices: a_ij = (U_ij - 0.5) * exp(phi * N_ij)."""
    u = rng.uniform(0.0, 1.0, (m, n))
    z = rng.standard_normal((m, n))
    return ((u - 0.5) * np.exp(phi * z)).astype(dtype)
