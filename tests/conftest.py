import os

# Tests run on the single real CPU device (the dry-run sets its own 512-device
# flag in a subprocess).  x64 must be enabled before jax initializes: the core
# library emulates FP64 GEMMs.
os.environ.setdefault("JAX_ENABLE_X64", "true")

import numpy as np
import pytest


def hypothesis_or_stubs():
    """``(given, settings, st)`` — real hypothesis when installed, else
    stand-in decorators that turn each property test into a runtime
    ``pytest.importorskip("hypothesis")`` skip.  Importing test modules
    therefore never errors when the optional dev dependency is missing
    (``pip install -r requirements-dev.txt`` restores the property tests);
    the example-based tests in the same files keep running either way.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        pass

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            def skipper(*a, **k):
                pytest.importorskip("hypothesis")
            skipper.__name__ = f.__name__
            skipper.__doc__ = f.__doc__
            return skipper
        return deco

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    return given, settings, _Strategies()


@pytest.fixture
def rng():
    return np.random.default_rng(12345)


@pytest.fixture(autouse=True)
def _deterministic_global_seed():
    """Seeding audit backstop: every random test input must come from the
    seeded ``rng`` fixture, an explicit ``np.random.default_rng(<int>)``,
    or a fixed ``jax.random.PRNGKey`` (audited; oracle error measurements
    must reproduce bit-for-bit across the CI matrix).  Any stray call
    into numpy's LEGACY global generator would be order-dependent — pin
    it per test so even that cannot wobble."""
    np.random.seed(0)


def make_phi_matrix(rng, m, n, phi=0.5, dtype=np.float64):
    """Paper's test matrices: a_ij = (U_ij - 0.5) * exp(phi * N_ij)."""
    u = rng.uniform(0.0, 1.0, (m, n))
    z = rng.standard_normal((m, n))
    return ((u - 0.5) * np.exp(phi * z)).astype(dtype)
