"""Failure paths of the benchmark regression gate
(``benchmarks/run.py --check-against``).

The gate is CI's only eye on the committed trajectory artifact, so its
*failure* behavior is what matters: a headline row unknown to the
artifact must be a hard failure (an ungated row is a row whose
regressions CI can't see), with ``--allow-new-rows`` as the explicit
escape hatch, the ``prob_auto`` planner-economy rows must be gated
on error, resolved k, and det-twin economy, and the serving gate must
catch split-cache / prefix-cache hit-rate drops.  Pure dict plumbing —
no benches run here, plus the ``steady_state`` measurement-ordering
regression (a fake runtime; the first-pass-measurement bug).
"""
import copy
import json

import pytest

from benchmarks import run as bench_run


SERVING_HEADLINE = {
    "engine": "ozimmu_h-4:df32",
    "runtime_tokens_per_s": 100.0,
    "runtime_over_legacy": 1.5,
    "cached_over_uncached": 1.2,
    "weight_split_hit_rate": 1.0,
    "modeled_decode": None,
    "prefix": {"hit_rate": 0.8, "hit_tokens": 384,
               "prefix_ttft_ratio": 0.31},
}


def _summary(err=None, prob_rows=None, extra_benches=(), serving=None):
    headline = {"phi": 2.0, "k": 8,
                "err": dict(err or {"ozimmu": 1e-10, "ozimmu_h": 1e-11}),
                "err_fp64": 7e-12}
    if prob_rows is not None:
        headline["prob_auto"] = {"phi": 2.0, "rows": prob_rows}
    benches = {"accuracy": {"status": "ok", "seconds": 1.0,
                            "headline": headline}}
    if serving is not None:
        benches["serving"] = {"status": "ok", "seconds": 1.0,
                              "headline": serving}
    for name in extra_benches:
        benches[name] = {"status": "ok", "seconds": 1.0, "headline": {}}
    return {"schema_version": 4, "quick": True, "only": sorted(benches),
            "benches": benches}


PROB_ROW = {"k": 9, "err": 3e-15, "int8_gemms": 45,
            "k_det": 10, "err_det": 2e-16, "gemms_det": 55}


@pytest.fixture
def committed(tmp_path):
    """A committed artifact with one prob_auto row; returns (path, dict)."""
    art = _summary(prob_rows={"ozimmu_h_auto_prob": dict(PROB_ROW)})
    path = tmp_path / "BENCH_ref.json"
    path.write_text(json.dumps(art))
    return str(path), art


def _gate(summary, committed_path, **kw):
    return bench_run.check_against(summary, committed_path, **kw)


def test_gate_passes_on_identical_summary(committed):
    path, art = committed
    assert _gate(copy.deepcopy(art), path) == []


def test_unknown_err_row_is_hard_failure(committed):
    path, art = committed
    got = copy.deepcopy(art)
    got["benches"]["accuracy"]["headline"]["err"]["brand_new"] = 1e-12
    failures = _gate(got, path)
    assert any("brand_new" in f and "absent from the committed" in f
               for f in failures), failures
    # the escape hatch tolerates the new row
    assert _gate(got, path, allow_new_rows=True) == []


def test_unknown_prob_auto_row_is_hard_failure(committed):
    path, art = committed
    got = copy.deepcopy(art)
    got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "oz2_h_fast2_auto_prob"] = dict(PROB_ROW)
    failures = _gate(got, path)
    assert any("oz2_h_fast2_auto_prob" in f for f in failures), failures
    assert _gate(got, path, allow_new_rows=True) == []


def test_missing_committed_rows_still_fail(committed):
    """The pre-existing direction: committed rows absent from the run."""
    path, art = committed
    got = copy.deepcopy(art)
    del got["benches"]["accuracy"]["headline"]["err"]["ozimmu_h"]
    del got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    failures = _gate(got, path)
    assert any("'ozimmu_h' missing" in f for f in failures), failures
    assert any("'ozimmu_h_auto_prob' missing" in f
               for f in failures), failures
    # allow_new_rows must NOT excuse missing rows — it is one-directional
    assert _gate(got, path, allow_new_rows=True) == failures


def test_prob_auto_err_regression_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    row = got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    row["err"] = PROB_ROW["err"] * 10  # > 2x tol
    failures = _gate(got, path)
    assert any("exceeds 2.0x committed" in f and "prob_auto" in f
               for f in failures), failures


def test_prob_auto_k_regression_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    row = got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    row["k"] = PROB_ROW["k"] + 1  # above committed -> planner regression
    failures = _gate(got, path)
    assert any("above committed" in f for f in failures), failures


def test_prob_auto_economy_violation_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    row = got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    # k at the det twin's +1 and more GEMMs than det: both economy checks
    row["k"] = row["k_det"] + 1
    row["int8_gemms"] = row["gemms_det"] + 1
    failures = _gate(got, path)
    assert any("planner economy violated" in f for f in failures), failures
    assert any("int8_gemms" in f and "deterministic twin" in f
               for f in failures), failures


def test_failed_bench_status_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    got["benches"]["breakdown"] = {"status": "failed",
                                   "error": "RuntimeError('boom')"}
    failures = _gate(got, path)
    assert any("breakdown" in f and "failed" in f for f in failures)


def test_cli_wires_allow_new_rows():
    ap = bench_run._build_parser()
    assert ap.parse_args([]).allow_new_rows is False
    assert ap.parse_args(["--allow-new-rows"]).allow_new_rows is True


# ---------------------------------------------------------------------------
# serving gate: split-cache + prefix-cache hit rates
# ---------------------------------------------------------------------------

@pytest.fixture
def committed_serving(tmp_path):
    art = _summary(serving=copy.deepcopy(SERVING_HEADLINE))
    path = tmp_path / "BENCH_ref.json"
    path.write_text(json.dumps(art))
    return str(path), art


def test_serving_gate_passes_on_identical(committed_serving):
    path, art = committed_serving
    assert _gate(copy.deepcopy(art), path) == []


def test_prefix_hit_rate_drop_fails(committed_serving):
    """The shared-prompt trace is deterministic, so a hit-rate drop means
    the keying or publication logic regressed — a hard failure."""
    path, art = committed_serving
    got = copy.deepcopy(art)
    got["benches"]["serving"]["headline"]["prefix"]["hit_rate"] = 0.5
    failures = _gate(got, path)
    assert any("prefix-cache hit rate" in f and "0.5" in f
               for f in failures), failures


def test_prefix_headline_vanishing_fails(committed_serving):
    """A run that silently stops producing the prefix headline (bench
    drift) must not pass the gate while the artifact still has one."""
    path, art = committed_serving
    got = copy.deepcopy(art)
    del got["benches"]["serving"]["headline"]["prefix"]
    failures = _gate(got, path)
    assert any("prefix-cache hit rate" in f for f in failures), failures


def test_weight_split_hit_rate_drop_fails(committed_serving):
    path, art = committed_serving
    got = copy.deepcopy(art)
    got["benches"]["serving"]["headline"]["weight_split_hit_rate"] = 0.9
    failures = _gate(got, path)
    assert any("weight split-cache hit rate" in f for f in failures), \
        failures


def test_prefix_ttft_ratio_not_gated(committed_serving):
    """Wall-clock TTFT ratios are recorded for the trajectory but NOT
    gated — CI machines are noisy."""
    path, art = committed_serving
    got = copy.deepcopy(art)
    got["benches"]["serving"]["headline"]["prefix"][
        "prefix_ttft_ratio"] = 5.0
    assert _gate(got, path) == []


# ---------------------------------------------------------------------------
# steady_state measurement ordering (the first-pass-measurement bug)
# ---------------------------------------------------------------------------

class _FakeRuntime:
    """Minimal runtime double: counts replay passes and which pass the
    metrics window covers, so the test can pin warm -> reset -> measure
    ordering without running a model."""

    class _Sched:
        all_done = True

    def __init__(self):
        self.sched = self._Sched()
        self.events = []
        self.passes = 0
        self.window_passes = 0      # passes since the last metrics reset

    def submit(self, prompt, max_new):
        self.events.append("submit")

    def step(self):
        self.events.append("step")

    def run(self):
        self.passes += 1
        self.window_passes += 1
        self.events.append("run")
        return {"pass": self.passes, "window_passes": self.window_passes}

    def reset_metrics(self):
        self.window_passes = 0
        self.events.append("reset")


def test_steady_state_orders_warm_reset_measure():
    """steady_state must run EVERY warm pass, then reset the metrics
    window, then measure — the measured summary covers exactly one pass.
    (The original bench measured pass one: with a prefix cache and
    requests <= slots, pass one runs fully cold and compiles the
    hit-path buckets inside the timed window.)"""
    from benchmarks.bench_serving import steady_state
    trace = [{"prompt": [1, 2], "max_new": 1, "arrival_step": 0}]
    rt = _FakeRuntime()
    out = steady_state(rt, trace, warm_passes=2)
    assert rt.passes == 3                    # 2 warm + 1 measured
    assert out == {"pass": 3, "window_passes": 1}
    runs = [i for i, e in enumerate(rt.events) if e == "run"]
    reset = rt.events.index("reset")
    assert runs[0] < runs[1] < reset < runs[2]


def test_steady_state_default_single_warm_pass():
    from benchmarks.bench_serving import steady_state
    rt = _FakeRuntime()
    out = steady_state(rt, [{"prompt": [1], "max_new": 1,
                             "arrival_step": 0}])
    assert rt.passes == 2 and out["window_passes"] == 1
