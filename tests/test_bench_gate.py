"""Failure paths of the benchmark regression gate
(``benchmarks/run.py --check-against``).

The gate is CI's only eye on the committed trajectory artifact, so its
*failure* behavior is what matters: a headline row unknown to the
artifact must be a hard failure (an ungated row is a row whose
regressions CI can't see), with ``--allow-new-rows`` as the explicit
escape hatch, and the ``prob_auto`` planner-economy rows must be gated
on error, resolved k, and det-twin economy.  Pure dict plumbing — no
benches run here.
"""
import copy
import json

import pytest

from benchmarks import run as bench_run


def _summary(err=None, prob_rows=None, extra_benches=()):
    headline = {"phi": 2.0, "k": 8,
                "err": dict(err or {"ozimmu": 1e-10, "ozimmu_h": 1e-11}),
                "err_fp64": 7e-12}
    if prob_rows is not None:
        headline["prob_auto"] = {"phi": 2.0, "rows": prob_rows}
    benches = {"accuracy": {"status": "ok", "seconds": 1.0,
                            "headline": headline}}
    for name in extra_benches:
        benches[name] = {"status": "ok", "seconds": 1.0, "headline": {}}
    return {"schema_version": 2, "quick": True, "only": sorted(benches),
            "benches": benches}


PROB_ROW = {"k": 9, "err": 3e-15, "int8_gemms": 45,
            "k_det": 10, "err_det": 2e-16, "gemms_det": 55}


@pytest.fixture
def committed(tmp_path):
    """A committed artifact with one prob_auto row; returns (path, dict)."""
    art = _summary(prob_rows={"ozimmu_h_auto_prob": dict(PROB_ROW)})
    path = tmp_path / "BENCH_ref.json"
    path.write_text(json.dumps(art))
    return str(path), art


def _gate(summary, committed_path, **kw):
    return bench_run.check_against(summary, committed_path, **kw)


def test_gate_passes_on_identical_summary(committed):
    path, art = committed
    assert _gate(copy.deepcopy(art), path) == []


def test_unknown_err_row_is_hard_failure(committed):
    path, art = committed
    got = copy.deepcopy(art)
    got["benches"]["accuracy"]["headline"]["err"]["brand_new"] = 1e-12
    failures = _gate(got, path)
    assert any("brand_new" in f and "absent from the committed" in f
               for f in failures), failures
    # the escape hatch tolerates the new row
    assert _gate(got, path, allow_new_rows=True) == []


def test_unknown_prob_auto_row_is_hard_failure(committed):
    path, art = committed
    got = copy.deepcopy(art)
    got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "oz2_h_fast2_auto_prob"] = dict(PROB_ROW)
    failures = _gate(got, path)
    assert any("oz2_h_fast2_auto_prob" in f for f in failures), failures
    assert _gate(got, path, allow_new_rows=True) == []


def test_missing_committed_rows_still_fail(committed):
    """The pre-existing direction: committed rows absent from the run."""
    path, art = committed
    got = copy.deepcopy(art)
    del got["benches"]["accuracy"]["headline"]["err"]["ozimmu_h"]
    del got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    failures = _gate(got, path)
    assert any("'ozimmu_h' missing" in f for f in failures), failures
    assert any("'ozimmu_h_auto_prob' missing" in f
               for f in failures), failures
    # allow_new_rows must NOT excuse missing rows — it is one-directional
    assert _gate(got, path, allow_new_rows=True) == failures


def test_prob_auto_err_regression_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    row = got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    row["err"] = PROB_ROW["err"] * 10  # > 2x tol
    failures = _gate(got, path)
    assert any("exceeds 2.0x committed" in f and "prob_auto" in f
               for f in failures), failures


def test_prob_auto_k_regression_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    row = got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    row["k"] = PROB_ROW["k"] + 1  # above committed -> planner regression
    failures = _gate(got, path)
    assert any("above committed" in f for f in failures), failures


def test_prob_auto_economy_violation_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    row = got["benches"]["accuracy"]["headline"]["prob_auto"]["rows"][
        "ozimmu_h_auto_prob"]
    # k at the det twin's +1 and more GEMMs than det: both economy checks
    row["k"] = row["k_det"] + 1
    row["int8_gemms"] = row["gemms_det"] + 1
    failures = _gate(got, path)
    assert any("planner economy violated" in f for f in failures), failures
    assert any("int8_gemms" in f and "deterministic twin" in f
               for f in failures), failures


def test_failed_bench_status_fails(committed):
    path, art = committed
    got = copy.deepcopy(art)
    got["benches"]["breakdown"] = {"status": "failed",
                                   "error": "RuntimeError('boom')"}
    failures = _gate(got, path)
    assert any("breakdown" in f and "failed" in f for f in failures)


def test_cli_wires_allow_new_rows():
    ap = bench_run._build_parser()
    assert ap.parse_args([]).allow_new_rows is False
    assert ap.parse_args(["--allow-new-rows"]).allow_new_rows is True
