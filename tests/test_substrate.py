"""Substrate tests: data pipeline determinism, checkpoint save/restore,
optimizer semantics, gradient compression with error feedback."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, Pipeline
from repro import optim
from repro.optim import compress


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(seq_len=64, global_batch=8, vocab=128, seed=7)
    p1 = Pipeline(cfg)
    p2 = Pipeline(cfg)
    b1 = p1.batch_at(5)
    b2 = p2.batch_at(5)  # fresh instance, same step -> identical
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 64)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128
    # different steps differ
    assert not np.array_equal(b1["tokens"], p1.batch_at(6)["tokens"])


def test_pipeline_host_sharding_partitions_global_batch():
    cfg = DataConfig(seq_len=32, global_batch=8, vocab=64, seed=3)
    full = Pipeline(cfg, host_id=0, num_hosts=1).batch_at(2)["tokens"]
    parts = [Pipeline(cfg, host_id=h, num_hosts=4).batch_at(2)["tokens"]
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_learnable_structure():
    """Planted copied spans -> bigram statistics beat chance."""
    cfg = DataConfig(seq_len=512, global_batch=4, vocab=512, seed=0)
    toks = Pipeline(cfg).batch_at(0)["tokens"]
    # repeated-span structure => some exact 8-gram appears twice per row
    found = 0
    for row in toks:
        s = row.tobytes()
        for i in range(0, len(row) - 8):
            pat = row[i:i + 8].tobytes()
            if s.count(pat) > 1:
                found += 1
                break
    assert found >= toks.shape[0] // 2


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.asarray(3, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(10, t, blocking=True)
    restored, step = ck.restore(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    ck.wait()
    steps = ck.list_steps()
    assert steps[-1] == 4 and len(steps) <= 2  # retention kept newest
    _, step = ck.restore(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree()))
    assert step == 4


def test_checkpoint_restore_with_shardings(tmp_path):
    """Elastic-restore path: restore with explicit (single-device) shardings."""
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(1, t, blocking=True)
    dev = jax.devices()[0]
    sh = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    restored, _ = ck.restore(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t),
        shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference():
    cfg = optim.OptConfig(lr=1e-2, betas=(0.9, 0.99), eps=1e-8,
                          weight_decay=0.01, grad_clip=1e9,
                          warmup_steps=0, total_steps=100, min_lr_frac=1.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = optim.init(params, cfg=cfg)
    new_p, state, _ = optim.step(grads, params, state, cfg)
    # manual AdamW reference (bias-corrected, decoupled decay)
    g = np.asarray(grads["w"])
    m = 0.1 * g
    v = 0.01 * g * g
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    ref = np.asarray(params["w"]) - 1e-2 * (
        mh / (np.sqrt(vh) + 1e-8) + 0.01 * np.asarray(params["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-6)


def test_grad_clip_applies():
    cfg = optim.OptConfig(lr=1.0, grad_clip=0.5, warmup_steps=0,
                          total_steps=10, weight_decay=0.0, min_lr_frac=1.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 10.0)}  # norm 20 >> clip 0.5
    state = optim.init(params, cfg=cfg)
    _, _, metrics = optim.step(grads, params, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(20.0)


def test_lr_schedule_warmup_cosine():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    assert float(optim.lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(optim.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(optim.lr_at(cfg, jnp.asarray(110))) == pytest.approx(0.1)


def test_zero_axes_augmentation():
    params = {"w": jnp.zeros((8, 6)), "b": jnp.zeros((7,))}
    axes = {"w": (None, "mlp"), "b": (None,)}
    from repro.distributed.sharding import use_rules
    with use_rules({"mlp": "model", "zero": ("data",)}):
        out = optim.zero_axes(axes, params, zero_divisor=4)
    assert out["w"] == ("zero", "mlp")   # dim0=8 divisible by 4
    assert out["b"] == (None,)           # 7 not divisible -> untouched


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------

def test_compress_error_feedback_exact_recovery():
    """quantized + residual == original, exactly (power-of-two scales)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((16, 32)) * 3.0, jnp.float32)
    err = jnp.zeros_like(g)
    digits, scale, new_err = compress.compress(g, err)
    recon = compress.decompress(digits, scale, g.shape)
    np.testing.assert_allclose(np.asarray(recon + new_err), np.asarray(g),
                               rtol=0, atol=0)  # exact
    assert digits.dtype == jnp.int8


def test_compress_error_feedback_converges():
    """Repeated compression of a constant gradient: error stays bounded and
    the long-run mean of transmitted values approaches the gradient."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(50):
        digits, scale, err = compress.compress(g, err)
        sent = sent + compress.decompress(digits, scale, g.shape)
    np.testing.assert_allclose(np.asarray(sent / 50), np.asarray(g),
                               atol=np.abs(np.asarray(g)).max() * 0.02)


def test_train_restart_equivalence(tmp_path):
    """Fault-tolerance: train N steps straight == train N/2, 'crash',
    restore from checkpoint, train to N (bitwise-equal losses thereafter)."""
    from repro.launch.train import train
    losses_ref = train("internlm2_1_8b", smoke=True, n_steps=4,
                       global_batch=2, seq_len=32, log_every=0,
                       seed=3)[1]
    ck = str(tmp_path / "ck")
    train("internlm2_1_8b", smoke=True, n_steps=2, global_batch=2,
          seq_len=32, ckpt_dir=ck, ckpt_every=2, log_every=0, seed=3)
    losses_resumed = train("internlm2_1_8b", smoke=True, n_steps=4,
                           global_batch=2, seq_len=32, ckpt_dir=ck,
                           ckpt_every=10, log_every=0, seed=3)[1]
    np.testing.assert_allclose(losses_resumed, losses_ref[2:], rtol=1e-5)
