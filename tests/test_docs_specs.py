"""Docs ↔ code consistency: every engine spec string quoted in the docs
and README must parse through ``make_engine``.

Guards against grammar drift: when parse_spec grows or changes a token
(as with the ``@mesh_axis`` suffix), stale examples in the prose fail
here instead of silently rotting.  Scope: backtick-quoted tokens in
*.md that look like ozimmu engine specs (start with ``ozimmu`` and
contain only spec characters), minus known non-spec identifiers.
"""
import os
import re

import pytest

from repro.core.engine import make_engine

REPO = os.path.join(os.path.dirname(__file__), "..")
DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md"))

# module/function names and grammar templates that legitimately start with
# "ozimmu"/"oz2" but are not engine specs
IGNORE = {
    "ozimmu_matmul", "ozimmu_dot_general", "ozimmu_config", "ozimmu.py",
    "ozimmu_roofline", "ozimmu_h_k8",
    "oz2_num_pairs", "oz2_num_highprec_adds", "oz2_num_chunks",
    "matmul_oz2", "split_oz2", "split_oz2_bitmask", "oz2_rn", "oz2_bitmask",
    "oz2_scale_accum_update",
    "split_oz2_fast2", "split_oz2_bitmask_fast2", "oz2_rn_fast2",
    "oz2_bitmask_fast2", "oz2_unscale", "oz2_unscale_update", "oz2_h_fast2",
    "oz2_h_fast",
}
# a candidate spec: spec charset only, no brackets/dots/parens (those mark
# grammar templates like `ozimmu[-k]` or code references).  k is digits or
# `auto`; `:opt` repeats (accumulator dtype, `fused`, and/or `fast`).
CANDIDATE = re.compile(r"^(ozimmu|oz2)[a-z0-9_]*(-([0-9]+|auto))?"
                       r"(:[a-z0-9_]+)*(@[a-z0-9_]+(/[a-z0-9_]+)?)?$")
BACKTICKED = re.compile(r"`([^`\n]+)`")


def doc_specs():
    found = []
    for rel in DOC_FILES:
        with open(os.path.join(REPO, rel)) as f:
            text = f.read()
        # code fences can hold several specs per line (spec grammar blocks
        # are skipped: they contain metacharacters the CANDIDATE rejects)
        tokens = set(BACKTICKED.findall(text))
        for block in re.findall(r"```.*?```", text, flags=re.S):
            tokens.update(block.replace("```", " ").split())
        for tok in tokens:
            for part in tok.replace(",", " ").split():
                if part.lower() in IGNORE:
                    continue
                if CANDIDATE.match(part):
                    found.append((rel, part))
    return sorted(set(found))


SPECS = doc_specs()


def test_docs_quote_enough_specs():
    """The extractor still sees the documented examples (guards against a
    silent regex/doc-layout change gutting this check)."""
    specs = {s for _, s in SPECS}
    assert {"ozimmu_h-8", "ozimmu_h-8:df32@model",
            "ozimmu_h-auto:df32:fused", "oz2_h-auto:fast",
            "oz2_h-auto:fast2", "oz2_b-8:df32@model",
            "ozimmu_sm_h-auto:df32", "ozimmu_sm_b-8",
            "ozimmu_sm_h-8:df32:fused@model/int32",
            "ozimmu_h-auto:prob", "oz2_h-auto:fast2:prob"} <= specs, specs
    assert len(specs) >= 13, specs


@pytest.mark.parametrize("rel,spec", SPECS,
                         ids=[f"{r}:{s}" for r, s in SPECS])
def test_doc_spec_parses(rel, spec):
    make_engine(spec)  # raises ValueError on drift


def test_native_specs_parse():
    for spec in ("bf16", "f32", "f64"):
        make_engine(spec)


# ---------------------------------------------------------------------------
# grammar regressions: the fast-mode tokens
# ---------------------------------------------------------------------------

def test_fast_tokens_rejected_outside_oz2():
    """`:fast`/`:fast2` are oz2-family tokens; elsewhere parse_spec names
    the offending token in the ValueError (not a generic parse failure)."""
    for tok, spec in (("fast", "ozimmu_h-8:fast"),
                      ("fast2", "ozimmu_h-8:fast2"),
                      ("fast", "ozimmu_ef-8:df32:fast"),
                      ("fast2", "ozimmu-8:fast2:fused"),
                      ("fast", "ozimmu_sm_h-8:fast"),
                      ("fast2", "ozimmu_sm_b-auto:df32:fast2")):
        with pytest.raises(ValueError, match=f"'{tok}'"):
            make_engine(spec)


def test_conflicting_fast_tokens_rejected():
    """`:fast` and `:fast2` are mutually exclusive; duplicates and
    conflicts are rejected with the token named either way round."""
    with pytest.raises(ValueError, match="conflicting fast-mode"):
        make_engine("oz2_h-8:fast:fast2")
    with pytest.raises(ValueError, match="conflicting fast-mode"):
        make_engine("oz2_h-8:fast2:fast")
    with pytest.raises(ValueError, match="duplicate 'fast2'"):
        make_engine("oz2_h-8:fast2:fast2")
    with pytest.raises(ValueError, match="duplicate 'fast'"):
        make_engine("oz2_h-8:fast:fast")


def test_fast2_spec_round_trips():
    """The canonical :fast2 specs build engines whose configs carry the
    fast2 split strategy (the grammar row documented in docs/engine.md)."""
    from repro.core.ozimmu import parse_spec
    assert parse_spec("oz2_h-8:fast2").split == "oz2_rn_fast2"
    assert parse_spec("oz2_b-auto:fast2:df32").split == "oz2_bitmask_fast2"
    make_engine("oz2_h-auto:fast2")
    make_engine("oz2_h-8:fast2:fused@model/int32")


def test_prob_token_rejected_without_auto():
    """`:prob` applies to auto-k specs only; on a fixed-k spec the
    ValueError names the token (the grammar note in docs/engine.md)."""
    for spec in ("ozimmu_h-8:prob", "oz2_h-4:fast2:prob",
                 "ozimmu_sm_h:prob"):
        with pytest.raises(ValueError, match="'prob'"):
            make_engine(spec)


def test_prob_specs_round_trip():
    """The documented :prob specs build engines whose configs carry the
    probabilistic eps mode (the when-to-use rows in docs/engine.md)."""
    from repro.core.ozimmu import parse_spec
    for spec in ("ozimmu_h-auto:prob", "oz2_h-auto:fast2:prob"):
        cfg = parse_spec(spec)
        assert cfg.auto_k and cfg.target_eps_mode == "probabilistic", spec
        make_engine(spec)


def test_sm_specs_round_trip():
    """The canonical sign-magnitude specs build engines whose configs
    carry the ``sm`` split strategy with the documented accumulators
    (the grammar rows documented in docs/engine.md)."""
    from repro.core.ozimmu import parse_spec
    cfg = parse_spec("ozimmu_sm_h-8")
    assert cfg.split == "sm" and cfg.accumulate == "group_ef"
    cfg = parse_spec("ozimmu_sm_b-auto:df32")
    assert cfg.split == "sm" and cfg.accumulate == "naive" and cfg.auto_k
    make_engine("ozimmu_sm_h-auto:df32")
    make_engine("ozimmu_sm_h-8:df32:fused@model/int32")
