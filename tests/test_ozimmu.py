"""Integration tests for the full ozimmu GEMM emulation (all 4 variants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from benchmarks.exact import dd_matmul, max_relative_error
from repro.core import (VARIANTS, OzimmuConfig, ozimmu_matmul, compute_beta,
                        compute_r, num_highprec_adds, make_engine)
from repro.core.accumulate import matmul_naive, matmul_group_ef, int8_gemm
from repro.core.ozimmu import split_operands
from repro.core import analysis
from tests.conftest import make_phi_matrix


@pytest.mark.parametrize("variant", list(VARIANTS))
def test_beats_fp64_at_high_k(rng, variant):
    """Paper Fig. 5: with enough slices every variant out-accuracies DGEMM."""
    n = 128
    a = make_phi_matrix(rng, n, n, phi=0.5)
    b = make_phi_matrix(rng, n, n, phi=0.5)
    hi, lo = dd_matmul(a, b)
    cfg = VARIANTS[variant].with_(k=11)
    c = np.asarray(ozimmu_matmul(jnp.asarray(a), jnp.asarray(b), cfg))
    err = max_relative_error(c, hi, lo)
    err64 = max_relative_error(np.asarray(jnp.asarray(a) @ jnp.asarray(b)), hi, lo)
    assert err < err64, (err, err64)
    assert err < 1e-13


def test_group_ef_is_error_free_vs_naive(rng):
    """Alg. 6's claim: grouping changes NOTHING numerically (bit-identical)
    while r >= group size — the int32 sums are exact."""
    a = jnp.asarray(make_phi_matrix(rng, 48, 64, phi=1.0))
    b = jnp.asarray(make_phi_matrix(rng, 64, 32, phi=1.0))
    for split in ("bitmask", "rn_const"):
        base = OzimmuConfig(k=8, split=split)
        c_naive = np.asarray(ozimmu_matmul(a, b, base.with_(accumulate="naive")))
        c_ef = np.asarray(ozimmu_matmul(a, b, base.with_(accumulate="group_ef")))
        # identical up to FP64 summation *order*; group sums themselves exact.
        np.testing.assert_allclose(c_ef, c_naive, rtol=1e-15)


def test_group_sum_exactness_int32(rng):
    """The heart of §3.2: sum of <= r slice-pair products fits INT32 exactly."""
    m = n = p = 64
    a = jnp.asarray(make_phi_matrix(rng, m, n, phi=2.0))
    b = jnp.asarray(make_phi_matrix(rng, n, p, phi=2.0))
    cfg = VARIANTS["ozimmu_h"].with_(k=8)
    sa, sb = split_operands(a, b, cfg)
    g = 9  # largest fast-mode group for k=8: pairs (1,8)..(8,1)
    pairs = [(s, g - s) for s in range(1, g)]
    acc = np.zeros((m, p), np.int64)
    for s, t in pairs:
        acc += np.asarray(int8_gemm(sa.digits[s - 1], sb.digits[t - 1]), np.int64)
    assert np.abs(acc).max() < 2**31  # the r-bound held
    a_cat = jnp.concatenate([sa.digits[s - 1] for s, _ in pairs], axis=1)
    b_cat = jnp.concatenate([sb.digits[t - 1] for _, t in pairs], axis=0)
    fused = np.asarray(int8_gemm(a_cat, b_cat), np.int64)
    np.testing.assert_array_equal(fused, acc)


def test_rn_needs_fewer_slices_than_bitmask(rng):
    """Paper §4.1: ozIMMU_RN-k comparable to ozIMMU-(k+1)."""
    n = 128
    a = make_phi_matrix(rng, n, n, phi=2.0)
    b = make_phi_matrix(rng, n, n, phi=2.0)
    hi, lo = dd_matmul(a, b)
    aj, bj = jnp.asarray(a), jnp.asarray(b)

    def err(variant, k):
        c = np.asarray(ozimmu_matmul(aj, bj, VARIANTS[variant].with_(k=k)))
        return max_relative_error(c, hi, lo)

    for k in (5, 6, 7):
        assert err("ozimmu_rn", k) <= err("ozimmu", k) * 4.0
        assert err("ozimmu_rn", k) <= err("ozimmu", k + 1) * 64.0


def test_high_precision_add_counts():
    """Paper's accounting: naive k(k+1)/2 vs EF ~k (w with chunking)."""
    assert num_highprec_adds(8, 512, group_ef=False) == 36
    assert num_highprec_adds(8, 512, group_ef=True) == 8
    # chunked case r < k: group g needs ceil((g-1)/r) flushes (Alg. 6, q==r)
    assert num_highprec_adds(4, 2, group_ef=True) == 1 + 1 + 2 + 2


def test_error_bound_holds(rng):
    """§5 deterministic bounds hold for the computed results."""
    n = 96
    a = make_phi_matrix(rng, n, n, phi=1.0)
    b = make_phi_matrix(rng, n, n, phi=1.0)
    hi, lo = dd_matmul(a, b)
    for k in (4, 6, 8):
        for variant, bound_fn in [("ozimmu", analysis.error_bound_ozimmu),
                                  ("ozimmu_ef", analysis.error_bound_group_ef)]:
            c = np.asarray(ozimmu_matmul(jnp.asarray(a), jnp.asarray(b),
                                         VARIANTS[variant].with_(k=k)))
            err = np.abs((c - hi) - lo)
            bound = bound_fn(a, b, k)
            assert np.all(err <= bound), (variant, k, float((err - bound).max()))


def test_rectangular_shapes(rng):
    a = jnp.asarray(make_phi_matrix(rng, 17, 130))
    b = jnp.asarray(make_phi_matrix(rng, 130, 9))
    hi, lo = dd_matmul(np.asarray(a), np.asarray(b))
    for variant in VARIANTS:
        c = np.asarray(ozimmu_matmul(a, b, VARIANTS[variant].with_(k=10)))
        assert max_relative_error(c, hi, lo) < 1e-12


def test_custom_vjp_grads_close_to_exact(rng):
    a = jnp.asarray(make_phi_matrix(rng, 12, 24))
    b = jnp.asarray(make_phi_matrix(rng, 24, 8))
    cfg = VARIANTS["ozimmu_h"].with_(k=10)

    def loss_oz(a, b):
        return jnp.sum(jnp.sin(ozimmu_matmul(a, b, cfg)))

    def loss_ref(a, b):
        return jnp.sum(jnp.sin(a @ b))

    ga, gb = jax.grad(loss_oz, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra), rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb), rtol=1e-9, atol=1e-12)


def test_jit_and_vmap_compatible(rng):
    a = jnp.asarray(make_phi_matrix(rng, 4 * 8, 16).reshape(4, 8, 16))
    b = jnp.asarray(make_phi_matrix(rng, 16, 8))
    cfg = VARIANTS["ozimmu_h"].with_(k=6)
    f = jax.jit(jax.vmap(lambda x: ozimmu_matmul(x, b, cfg)))
    out = f(a)
    ref = jnp.einsum("bij,jk->bik", a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-8)


def test_engine_specs(rng):
    x = jnp.asarray(make_phi_matrix(rng, 4 * 6, 32).reshape(4, 6, 32), jnp.float32)
    w = jnp.asarray(make_phi_matrix(rng, 32, 16), jnp.float32)
    ref = np.asarray(jnp.einsum("abi,ij->abj", x.astype(jnp.float64),
                                w.astype(jnp.float64)))
    for spec in ("f32", "ozimmu_h-6:f32", "ozimmu_h-6:df32", "ozimmu-6:f32",
                 "ozimmu_rn-6:f32", "ozimmu_ef-6:df32"):
        eng = make_engine(spec)
        out = np.asarray(eng(x, w), np.float64)
        rel = np.abs(out - ref) / (np.abs(ref) + 1e-6)
        assert rel.max() < 5e-5, (spec, rel.max())
    bf = make_engine("bf16")(x, w)
    assert bf.dtype == x.dtype


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 10), n=st.integers(2, 48), p=st.integers(1, 10),
    k=st.integers(3, 11), phi=st.floats(0, 2), seed=st.integers(0, 2**31),
    variant=st.sampled_from(sorted(VARIANTS)),
)
def test_property_error_within_paper_bound(m, n, p, k, phi, seed, variant):
    """Property: |AB - T_k| <= truncation + accumulation bound (§5) for random
    shapes, slice counts, difficulty, and variant."""
    rng = np.random.default_rng(seed)
    a = make_phi_matrix(rng, m, n, phi)
    b = make_phi_matrix(rng, n, p, phi)
    hi, lo = dd_matmul(a, b)
    c = np.asarray(ozimmu_matmul(jnp.asarray(a), jnp.asarray(b),
                                 VARIANTS[variant].with_(k=k)))
    err = np.abs((c - hi) - lo)
    bound = analysis.error_bound_ozimmu(a, b, k)  # RN strictly sharper (§5 intro)
    assert np.all(err <= bound + 1e-300)
