"""Adversarial accuracy oracle: every spec family vs the double-double
reference on a hostile input grid.

The grid goes after the places emulation schemes break: wide per-element
exponent spread (digit grids far from most elements), signed cancellation
(output magnitudes far below the operand scale), rows/columns hundreds of
orders of magnitude below the matrix maximum (incl. subnormal entries),
and exact zero rows/columns.  Three families of assertions:

  * **documented bounds** — every variant's measured elementwise error
    stays under its documented deterministic bound
    (``repro.core.analysis``): eq. (18)-based for the ozimmu family,
    the global-anchor OS-II bound for oz2 (``error_bound_oz2``).
  * **planner guarantee** — ``auto`` k never yields a measured relative
    error above ``OzimmuConfig.target_eps`` on the oracle grid, for every
    variant including both oz2 modes.
  * **oz2 plan economy (acceptance)** — ``oz2_h-auto:fast`` meets
    ``target_eps`` while its :class:`repro.core.plan.Plan` charges
    strictly fewer int8 GEMMs and high-precision adds than the
    equal-accuracy ``ozimmu_h-auto`` plan; and ``oz2_h-auto:fast2``
    (improved scaling) charges no more int8 GEMMs than ``:fast`` while
    its measured headline error on the hostile grid stays within 4x the
    oz2 FULL mode's.

The fast-mode axis makes this a 9-variant matrix: the four signed ozimmu
variants, the two sign-magnitude ones (ozimmu_sm_b / ozimmu_sm_h, bound
``error_bound_sm``), plus oz2_{b,h} x {full, :fast, :fast2}, each against
the {f64, df32, f32} accumulators.

Domain note (documented in docs/engine.md): the ``df32``/``f32``
accumulators hold scales in f32, so their bounds apply on operands whose
row/column maxima stay within the f32 exponent range; the hostile grid
therefore scopes its extreme-magnitude cases (2^-300 rows, subnormals) to
the ``f64`` accumulator and uses a 2^-40 version for the f32-based ones.

Everything random is drawn from explicitly seeded generators so the
measured errors — and hence these assertions — are reproducible across
the CI matrix.
"""
import functools
import math

import numpy as np
import pytest

import jax.numpy as jnp

from benchmarks.exact import _two_prod, dd_matmul, max_relative_error
from repro.core import (VARIANTS, analysis, ozimmu_matmul, parse_spec, plan)
from tests.conftest import make_phi_matrix

U = {"f64": 2.0 ** -53, "df32": 2.0 ** -48, "f32": 2.0 ** -24}

BOUNDS = {
    "ozimmu": lambda a, b, k, u, fast: analysis.error_bound_ozimmu(a, b, k, u),
    "ozimmu_rn": lambda a, b, k, u, fast: analysis.error_bound_rn(a, b, k, u),
    "ozimmu_ef": lambda a, b, k, u, fast:
        analysis.error_bound_group_ef(a, b, k, u),
    "ozimmu_h": lambda a, b, k, u, fast: analysis.error_bound_rn(a, b, k, u),
    "ozimmu_sm_b": lambda a, b, k, u, fast:
        analysis.error_bound_sm(a, b, k, u),
    "ozimmu_sm_h": lambda a, b, k, u, fast:
        analysis.error_bound_sm(a, b, k, u),
    "oz2_b": lambda a, b, k, u, fast:
        analysis.error_bound_oz2(a, b, k, fast, u),
    "oz2_h": lambda a, b, k, u, fast:
        analysis.error_bound_oz2(a, b, k, fast, u),
}


# ---------------------------------------------------------------------------
# hostile input generators
# ---------------------------------------------------------------------------

def _wide_spread(rng, m, n, bits):
    """|a_ij| spanning ``bits`` binary orders of magnitude, signed."""
    e = rng.integers(-bits, 1, (m, n)).astype(np.float64)
    sign = np.where(rng.uniform(size=(m, n)) < 0.5, -1.0, 1.0)
    return sign * rng.uniform(0.5, 1.0, (m, n)) * 2.0 ** e


def _cancelling_pair(rng, m, n, p):
    """C = A @ B with catastrophic cancellation: the left operand's column
    halves nearly negate each other against a duplicated right operand."""
    v = rng.standard_normal((m, n // 2))
    a = np.concatenate([v, -v + 1e-9 * rng.standard_normal(v.shape)], axis=1)
    w = rng.standard_normal((n // 2, p))
    return a, np.concatenate([w, w], axis=0)


def _row_spread_cancel(rng, m, n, p, lo):
    """Wide PER-ROW exponent spread combined with cancellation: the
    cancelling pair with A's rows scattered down to 2^lo and B's columns
    likewise.  This is the fast2 showcase — the global-anchor fast mode
    loses the small rows entirely (its dropped-band term anchors at
    EA*EB), while the per-row equilibrated grid keeps resolving them."""
    a, b = _cancelling_pair(rng, m, n, p)
    a = a * 2.0 ** rng.integers(lo, 1, (m, 1)).astype(np.float64)
    b = b * 2.0 ** rng.integers(lo, 1, (1, p)).astype(np.float64)
    return a, b


def _alt_sign_rows(rng, m, n, bits):
    """Whole rows alternate sign under a wide per-row magnitude spread —
    the sign-magnitude splitters' hostile shape: every element of every
    other row extracts a NEGATIVE leading digit, and the tiniest negative
    entries sit exactly where the two's-complement lead residual rounds
    to 1.0 (the all-(2^beta - 1) digit-cascade clamp of
    ``splitting._sm_extract``).  Used on the contraction axis of B it
    also drives heavy output cancellation."""
    a = np.abs(_wide_spread(rng, m, n, bits))
    a = a * 2.0 ** rng.integers(-bits, 1, (m, 1)).astype(np.float64)
    return a * (-1.0) ** np.arange(m)[:, None]


def _scaled_rows(rng, m, n, lo):
    """Rows scattered down to 2^lo below the matrix maximum."""
    a = rng.standard_normal((m, n))
    return a * 2.0 ** rng.integers(lo, 1, (m, 1)).astype(np.float64)


def _zeros_mixed(rng, m, n):
    a = rng.standard_normal((m, n))
    a[0] = 0.0
    a[:, 3] = 0.0
    a[-1, ::2] = 0.0
    return a


@functools.lru_cache(maxsize=4)
def _hostile_cases(f32_domain: bool):
    """[(name, A, B, dd_hi, dd_lo)] — cached: every parametrized case
    reuses one deterministic grid and its double-double reference."""
    rng = np.random.default_rng(20260728)
    m, n, p = 40, 160, 24
    lo = -40 if f32_domain else -300
    cases = [
        ("spread", _wide_spread(rng, m, n, 30), _wide_spread(rng, n, p, 30)),
        ("cancel", *_cancelling_pair(rng, m, n, p)),
        ("tiny_rows_cols", _scaled_rows(rng, m, n, lo),
         np.ascontiguousarray(_scaled_rows(rng, p, n, lo).T)),
        ("zeros", _zeros_mixed(rng, m, n),
         np.ascontiguousarray(_zeros_mixed(rng, p, n).T)),
        ("phi2", make_phi_matrix(rng, m, n, phi=2.0),
         make_phi_matrix(rng, n, p, phi=2.0)),
        ("row_spread_cancel", *_row_spread_cancel(rng, m, n, p, lo)),
        ("sign_flip", _alt_sign_rows(rng, m, n, 30),
         _alt_sign_rows(rng, n, p, 30)),
    ]
    return [(name, a, b, *dd_matmul(a, b)) for name, a, b in cases]


def _modes(variant):
    """Fast-mode axis of the oracle matrix: the oz2 variants run full,
    fast AND fast2 (the 7-variant grid of docs/algorithms.md)."""
    return (False, True, "fast2") if variant.startswith("oz2") else (False,)


# ---------------------------------------------------------------------------
# documented bounds on the hostile grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("accum", ["f64", "df32", "f32"])
@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_documented_bound_on_hostile_grid(variant, accum):
    """measured elementwise |err| <= the variant's documented bound, for
    every hostile case, both oz2 modes, k = 8."""
    k = 8
    for name, a, b, hi, lo in _hostile_cases(accum != "f64"):
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        for fast in _modes(variant):
            cfg = VARIANTS[variant].with_(k=k, accum_dtype=accum, fast=fast)
            t = np.asarray(ozimmu_matmul(aj, bj, cfg))
            err = np.abs((t - hi) - lo)
            bound = BOUNDS[variant](a, b, k, U[accum], fast)
            excess = (err - bound).max()
            assert np.all(err <= bound + 1e-300), \
                (variant, accum, name, fast, f"excess {excess:.3e}")


def test_subnormal_entries_f64_bound(rng):
    """Entries down in the subnormal range (via rows at 2^-1040): digits
    below the grid extract as exact zeros, the documented f64 bounds
    hold."""
    gen = np.random.default_rng(20260729)
    a = _scaled_rows(gen, 24, 96, -300)
    a[1] = np.ldexp(gen.standard_normal(96), -1040)
    b = np.ascontiguousarray(_scaled_rows(gen, 16, 96, -300).T)
    hi, lo = dd_matmul(a, b)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    for variant in sorted(VARIANTS):
        for fast in _modes(variant):
            cfg = VARIANTS[variant].with_(k=8, fast=fast)
            t = np.asarray(ozimmu_matmul(aj, bj, cfg))
            err = np.abs((t - hi) - lo)
            bound = BOUNDS[variant](a, b, 8, U["f64"], fast)
            assert np.all(err <= bound + 1e-300), (variant, fast)


# ---------------------------------------------------------------------------
# planner guarantee (auto-k) on the oracle grid
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _planner_grid():
    rng = np.random.default_rng(20260730)
    n = 128
    mats = [make_phi_matrix(rng, n, n, phi) for phi in (0.5, 2.0)
            for _ in (0, 1)]
    mats += [_wide_spread(rng, n, n, 12), _wide_spread(rng, n, n, 12)]
    out = []
    for i in range(0, len(mats), 2):
        a, b = mats[i], mats[i + 1]
        out.append((a, b, *dd_matmul(a, b)))
    return out


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_planner_target_eps_guarantee(variant):
    """`auto` never picks a k whose measured relative error (dd oracle)
    exceeds target_eps — phi matrices AND moderate-spread operands, both
    oz2 modes included."""
    eps = plan.DEFAULT_TARGET_EPS
    for a, b, hi, lo in _planner_grid():
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        for fast in _modes(variant):
            cfg = VARIANTS[variant].with_(auto_k=True, fast=fast)
            k = plan.auto_k(aj, bj, cfg)
            err = max_relative_error(
                np.asarray(ozimmu_matmul(aj, bj, cfg)), hi, lo)
            assert err <= eps, (variant, fast, k, err)


# ---------------------------------------------------------------------------
# oz2 plan economy — the acceptance criterion
# ---------------------------------------------------------------------------

def test_oz2_fast_auto_cheaper_than_equal_accuracy_ozimmu_h():
    """`oz2_h-auto:fast` meets target_eps on the oracle grid while its
    Plan charges strictly fewer int8 GEMMs and strictly fewer
    high-precision adds than the equal-accuracy `ozimmu_h-auto` plan
    (phi >= 1 cells; at phi=0.5 the two models converge to the same k and
    oz2 still wins strictly on adds, never losing on GEMMs)."""
    rng = np.random.default_rng(20260731)
    n = 256
    cfg_oz2 = parse_spec("oz2_h-auto:fast")
    cfg_h = parse_spec("ozimmu_h-auto")
    eps = plan.DEFAULT_TARGET_EPS
    for phi in (0.5, 1.0, 2.0):
        a = make_phi_matrix(rng, n, n, phi)
        b = make_phi_matrix(rng, n, n, phi)
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        pl_oz2 = plan.plan_contraction(cfg_oz2, n, n, n, a=aj, b=bj)
        pl_h = plan.plan_contraction(cfg_h, n, n, n, a=aj, b=bj)
        assert pl_oz2.highprec_adds < pl_h.highprec_adds, phi
        assert pl_oz2.int8_gemms <= pl_h.int8_gemms, phi
        if phi >= 1.0:
            assert pl_oz2.int8_gemms < pl_h.int8_gemms, phi
        hi, lo = dd_matmul(a, b)
        err = max_relative_error(
            np.asarray(ozimmu_matmul(aj, bj, cfg_oz2)), hi, lo)
        assert err <= eps, (phi, pl_oz2.k, err)


def test_oz2_fast2_economy_vs_fast():
    """Acceptance for the improved scaling: on the oracle grids,
    ``oz2_h-auto:fast2``

      * meets ``target_eps`` (measured, dd reference) wherever ``:fast``
        does,
      * resolves a k no larger than ``:fast`` at equal target_eps — so
        its Plan charges int8 GEMMs <= the fast plan's (same band shape),
      * and its measured HEADLINE error on the hostile grid (k=8, f64)
        stays within 4x the oz2_h FULL mode's headline — the dropped
        band costs at most a small constant once the grid is per-row
        equilibrated, where plain :fast loses the small rows entirely.
    """
    cfg_fast = parse_spec("oz2_h-auto:fast")
    cfg_fast2 = parse_spec("oz2_h-auto:fast2")
    eps = plan.DEFAULT_TARGET_EPS
    for a, b, hi, lo in _planner_grid():
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        n = a.shape[0]
        p_fast = plan.plan_contraction(cfg_fast, n, n, n, a=aj, b=bj)
        p_fast2 = plan.plan_contraction(cfg_fast2, n, n, n, a=aj, b=bj)
        assert p_fast2.k <= p_fast.k
        assert p_fast2.int8_gemms <= p_fast.int8_gemms
        err = max_relative_error(
            np.asarray(ozimmu_matmul(aj, bj, cfg_fast2)), hi, lo)
        assert err <= eps, (p_fast2.k, err)
    # headline error on the hostile grid: fast2 <= 4x FULL mode (and far
    # below plain fast, whose global anchor abandons the scattered rows)
    k = 8
    cfg_full = VARIANTS["oz2_h"].with_(k=k)
    cfg_f2 = VARIANTS["oz2_h"].with_(k=k, fast="fast2")
    cfg_f1 = VARIANTS["oz2_h"].with_(k=k, fast=True)
    head_full = head_f1 = head_f2 = 0.0
    for name, a, b, hi, lo in _hostile_cases(False):
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        head_full = max(head_full, max_relative_error(
            np.asarray(ozimmu_matmul(aj, bj, cfg_full)), hi, lo))
        head_f1 = max(head_f1, max_relative_error(
            np.asarray(ozimmu_matmul(aj, bj, cfg_f1)), hi, lo))
        head_f2 = max(head_f2, max_relative_error(
            np.asarray(ozimmu_matmul(aj, bj, cfg_f2)), hi, lo))
    assert head_f2 <= 4.0 * head_full, (head_f2, head_full)
    assert head_f2 < head_f1, (head_f2, head_f1)


def test_sm_auto_economy_vs_ozimmu_h():
    """Acceptance for the sign-magnitude family: at the default
    ``target_eps``, ``ozimmu_sm_h-auto`` resolves a STRICTLY smaller k
    than ``ozimmu_h-auto`` — beta_sm = 8 covers ``8k - 1`` bits where the
    RN splitters cover ``7k``, so ``ceil((needed + 2) / 8) <
    ceil(needed / 7)`` at every f64-grade needed — hence strictly fewer
    int8 GEMMs, while its measured relative error (dd reference) still
    meets ``target_eps`` on every planner-grid cell.  Holds for the
    probed (eager) plan on each cell AND for the static (traced-shape)
    plan."""
    cfg_sm = parse_spec("ozimmu_sm_h-auto")
    cfg_h = parse_spec("ozimmu_h-auto")
    eps = plan.DEFAULT_TARGET_EPS
    # static mantissa-coverage plan (what a jitted call resolves)
    n = 128
    p_sm = plan.plan_contraction(cfg_sm, n, n, n)
    p_h = plan.plan_contraction(cfg_h, n, n, n)
    assert p_sm.k < p_h.k, (p_sm.k, p_h.k)
    assert p_sm.int8_gemms < p_h.int8_gemms
    for a, b, hi, lo in _planner_grid():
        n = a.shape[0]
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        p_sm = plan.plan_contraction(cfg_sm, n, n, n, a=aj, b=bj)
        p_h = plan.plan_contraction(cfg_h, n, n, n, a=aj, b=bj)
        assert p_sm.probed and p_h.probed
        assert p_sm.k < p_h.k, (p_sm.k, p_h.k)
        assert p_sm.int8_gemms < p_h.int8_gemms
        err = max_relative_error(
            np.asarray(ozimmu_matmul(aj, bj, cfg_sm)), hi, lo)
        assert err <= eps, (p_sm.k, err)


def test_oz2_ladder_adds_strictly_fewer_at_equal_k():
    """At any fixed k >= 3, the oz2 exponent ladder performs strictly
    fewer high-precision adds than ozimmu_h's group-EF accounting — the
    structural consequence of folding the shared grid."""
    for n in (128, 1024, 4096):
        for k in range(3, 13):
            p_oz2 = plan.plan_contraction(
                VARIANTS["oz2_h"].with_(k=k, fast=True), n, n, n)
            p_h = plan.plan_contraction(
                VARIANTS["ozimmu_h"].with_(k=k), n, n, n)
            assert p_oz2.highprec_adds < p_h.highprec_adds, (n, k)
            assert p_oz2.int8_gemms == p_h.int8_gemms  # same band at same k


def test_oz2_rn_endpoint_digits_no_int32_wrap():
    """Regression: RN digits ATTAIN ±2^(beta-1), so eq. (12)'s power-of-two
    r would let a constant-sign chunk sum reach exactly +2^31 and wrap.
    ``compute_r`` with explicit digit_bits shaves one pair; on the
    adversarial all-endpoint operand the error must stay at the
    truncation level (it was ~2^32 * scale above it with the wrap)."""
    from repro.core.splitting import compute_beta, compute_r
    n = 65536
    assert compute_beta(n) == 7
    assert compute_r(n, 7, 6) * n * 64 * 64 < 2 ** 31  # the shaved r
    x = sum(63.5 * 2.0 ** (-14 * j) for j in range(4))  # ±64 digits
    a = np.full((2, n), x)
    for sign in (1.0, -1.0):
        b = np.full((n, 2), sign * x)
        hi, lo = dd_matmul(a, b)
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        for variant in ("oz2_h", "oz2_b"):
            for fast in (False, True, "fast2"):
                cfg = VARIANTS[variant].with_(k=8, fast=fast)
                t = np.asarray(ozimmu_matmul(aj, bj, cfg))
                err = np.abs((t - hi) - lo)
                bound = BOUNDS[variant](a, b, 8, U["f64"], fast)
                assert np.all(err <= bound), (sign, variant, fast,
                                              err.max(), bound.max())


# ---------------------------------------------------------------------------
# oz2 spec grammar
# ---------------------------------------------------------------------------

def test_oz2_spec_grammar():
    cfg = parse_spec("oz2_h-auto:fast:fused@model/df32")
    assert cfg.split == "oz2_rn" and cfg.accumulate == "oz2"
    assert cfg.fast and cfg.auto_k and cfg.use_pallas == "fused"
    assert cfg.mesh_axis == "model" and cfg.mesh_reduce == "df32"
    assert parse_spec("oz2_b-8").split == "oz2_bitmask"
    assert not parse_spec("oz2_h-8").fast
    assert parse_spec("oz2_h-8:df32:fast").accum_dtype == "df32"
    # fast2 (improved scaling): canonicalizes to the *_fast2 splits
    cfg2 = parse_spec("oz2_h-auto:fast2:fused@model/int32")
    assert cfg2.split == "oz2_rn_fast2" and cfg2.fast == "fast2"
    assert cfg2.use_pallas == "fused" and cfg2.mesh_reduce == "int32"
    assert parse_spec("oz2_b-8:fast2").split == "oz2_bitmask_fast2"
    from repro.core import make_engine
    for bad in ("ozimmu_h-8:fast", "ozimmu_h-8:fast2", "oz2_h-8:fast:fast",
                "oz2_h-8:fast2:fast2", "oz2_h-8:fast:fast2",
                "oz2_h-8:fast2:fast", "oz2_x-8", "oz2_h-8:slow"):
        with pytest.raises(ValueError):
            make_engine(bad)


# ---------------------------------------------------------------------------
# probabilistic planner (:prob) — grammar, economy, oracle calibration
# ---------------------------------------------------------------------------

# Specs the probabilistic calibration ensembles measure.  Plain :fast is
# deliberately absent: the prob planner gives its global-anchor dropped
# band no shave (choose_k), so :fast:prob plans are identical to :fast —
# covered by test_prob_plain_fast_resolves_deterministic_k instead.
_PROB_SPECS = ("ozimmu-auto:prob", "ozimmu_h-auto:prob",
               "ozimmu_sm_h-auto:prob", "oz2_h-auto:prob",
               "oz2_h-auto:fast2:prob")

_PROB_DELTA = 2.0 ** -20  # analysis.DEFAULT_DELTA, pinned


def _det_twin(spec):
    return parse_spec(spec.replace(":prob", ""))


def test_prob_spec_grammar():
    cfg = parse_spec("ozimmu_h-auto:prob")
    assert cfg.auto_k and cfg.target_eps_mode == "probabilistic"
    assert cfg.target_delta is None  # None -> analysis.DEFAULT_DELTA
    cfg2 = parse_spec("oz2_h-auto:fast2:prob:df32:fused@model")
    assert cfg2.target_eps_mode == "probabilistic"
    assert cfg2.split == "oz2_rn_fast2" and cfg2.fast == "fast2"
    assert cfg2.accum_dtype == "df32" and cfg2.use_pallas == "fused"
    assert cfg2.mesh_axis == "model"
    # every variant family accepts :prob on auto-k specs
    for name in sorted(VARIANTS):
        assert parse_spec(f"{name}-auto:prob").target_eps_mode \
            == "probabilistic"
    assert parse_spec("ozimmu_h-auto").target_eps_mode == "deterministic"
    from repro.core import make_engine
    for bad in ("ozimmu_h-8:prob",       # fixed k leaves nothing to plan
                "ozimmu_h:prob",         # default k is fixed k
                "oz2_h-4:fast2:prob",
                "ozimmu_h-auto:prob:prob"):
        with pytest.raises(ValueError, match="'prob'|prob"):
            make_engine(bad)


def test_prob_auto_strictly_smaller_k_static():
    """Acceptance: on the static n=96/128 bench-grid plans (what a jitted
    serving call resolves), ``ozimmu_h-auto:prob`` and
    ``oz2_h-auto:fast2:prob`` resolve STRICTLY smaller k — hence strictly
    fewer int8 GEMMs per Plan accounting — than their deterministic twins
    at the default target_eps; and no variant ever resolves a LARGER k
    under the probabilistic model (the min-clamp in choose_k)."""
    for spec in ("ozimmu_h-auto:prob", "oz2_h-auto:fast2:prob"):
        cfg, cfg_det = parse_spec(spec), _det_twin(spec)
        for n in (96, 128):
            pp = plan.plan_contraction(cfg, n, n, n)
            pd = plan.plan_contraction(cfg_det, n, n, n)
            assert pp.k < pd.k, (spec, n, pp.k, pd.k)
            assert pp.int8_gemms < pd.int8_gemms, (spec, n)
            assert pp.highprec_adds <= pd.highprec_adds, (spec, n)
    for name in sorted(VARIANTS):
        for fast in _modes(name):
            cfg_det = VARIANTS[name].with_(auto_k=True, fast=fast)
            cfg = cfg_det.with_(target_eps_mode="probabilistic")
            for n in (96, 128, 4096):
                kp = plan.plan_contraction(cfg, n, n, n).k
                kd = plan.plan_contraction(cfg_det, n, n, n).k
                assert kp <= kd, (name, fast, n, kp, kd)


def test_prob_planner_grid_guarantee():
    """Probed path on the planner grid: every :prob spec resolves
    ``k <= k_det`` (strictly smaller on the low-spread cells for
    ozimmu_h), and the measured relative error (dd oracle) still meets
    ``target_eps`` on every cell."""
    eps = plan.DEFAULT_TARGET_EPS
    strict_shaves = 0
    for a, b, hi, lo in _planner_grid():
        n = a.shape[0]
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        for spec in _PROB_SPECS:
            cfg, cfg_det = parse_spec(spec), _det_twin(spec)
            pp = plan.plan_contraction(cfg, n, n, n, a=aj, b=bj)
            pd = plan.plan_contraction(cfg_det, n, n, n, a=aj, b=bj)
            assert pp.probed and pd.probed
            assert pp.k <= pd.k, (spec, pp.k, pd.k)
            if pp.k < pd.k:
                strict_shaves += 1
                assert pp.int8_gemms < pd.int8_gemms, spec
            err = max_relative_error(
                np.asarray(ozimmu_matmul(aj, bj, cfg)), hi, lo)
            assert err <= eps, (spec, pp.k, err)
    assert strict_shaves >= 3, strict_shaves


def test_prob_plain_fast_resolves_deterministic_k():
    """``oz2_h-auto:fast:prob`` plans exactly like ``oz2_h-auto:fast``:
    the dropped-band term of the global-anchor fast mode is a systematic
    truncation the concentration model must not shave."""
    cfg = parse_spec("oz2_h-auto:fast:prob")
    cfg_det = parse_spec("oz2_h-auto:fast")
    for a, b, hi, lo in _planner_grid():
        n = a.shape[0]
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        assert plan.plan_contraction(cfg, n, n, n, a=aj, b=bj).k \
            == plan.plan_contraction(cfg_det, n, n, n, a=aj, b=bj).k
    for n in (96, 128, 4096):
        assert plan.plan_contraction(cfg, n, n, n).k \
            == plan.plan_contraction(cfg_det, n, n, n).k


def test_prob_delta_semantics():
    """``target_delta`` wiring: delta <= 0 recovers the deterministic
    plan exactly; shrinking delta never shrinks k (more confidence costs
    bits); lambda_bits is the pinned concentration constant."""
    assert plan.lambda_bits(_PROB_DELTA) == 3
    assert plan.lambda_bits(0.5) >= 1
    with pytest.raises(ValueError):
        plan.lambda_bits(0.0)
    cfg_det = parse_spec("ozimmu_h-auto")
    cfg0 = parse_spec("ozimmu_h-auto:prob").with_(target_delta=0.0)
    for n in (96, 128, 4096):
        assert plan.plan_contraction(cfg0, n, n, n).k \
            == plan.plan_contraction(cfg_det, n, n, n).k
    ks = []
    for delta in (2.0 ** -5, 2.0 ** -20, 2.0 ** -60, 2.0 ** -200):
        cfg = parse_spec("ozimmu_h-auto:prob").with_(target_delta=delta)
        ks.append(plan.plan_contraction(cfg, 128, 128, 128).k)
    assert ks == sorted(ks), ks                  # smaller delta -> k up
    assert ks[-1] <= plan.plan_contraction(cfg_det, 128, 128, 128).k


def test_prob_split_cache_distinct_entries():
    """A :prob config resolves a smaller static k than its deterministic
    twin, and the two NEVER share a split-cache entry (k is part of the
    cache key); the frozen k matches the jitted static plan on both."""
    from repro.core.split_cache import SplitCache, resolved_k
    rng = np.random.default_rng(20260806)
    n, p = 128, 16
    w = jnp.asarray(rng.standard_normal((n, p)))
    cfg_det = parse_spec("ozimmu_h-auto")
    cfg_prob = parse_spec("ozimmu_h-auto:prob")
    kd = resolved_k(cfg_det, n, w.dtype)
    kp = resolved_k(cfg_prob, n, w.dtype)
    assert kp < kd, (kp, kd)
    assert kp == plan.plan_contraction(cfg_prob, 1, n, p).k
    assert kd == plan.plan_contraction(cfg_det, 1, n, p).k
    cache = SplitCache()
    dnums = (((1,), (0,)), ((), ()))
    sp_det = cache.get(w, dnums, cfg_det)
    sp_prob = cache.get(w, dnums, cfg_prob)
    assert len(cache) == 2 and cache.stats.misses == 2
    assert sp_det.digits.shape[0] == kd
    assert sp_prob.digits.shape[0] == kp
    # repeat lookups hit their own entries
    assert cache.get(w, dnums, cfg_prob) is sp_prob
    assert cache.get(w, dnums, cfg_det) is sp_det
    assert cache.stats.hits == 2


@pytest.mark.slow
@pytest.mark.prob_calibration
def test_prob_calibration_probed_ensemble():
    """Oracle calibration of the probed probabilistic planner: over a
    seeded 120-trial ensemble (n in {96, 128}; phi 0.5/1/2, wide-spread
    8/12 and Gaussian operands; the five :prob calibration specs) the
    measured relative error (dd reference) meets target_eps on >= the
    claimed 1 - delta fraction of trials — with delta = 2^-20 and 120
    trials, that is EVERY trial — and k_prob <= k_det on each."""
    rng = np.random.default_rng(20260808)
    eps = plan.DEFAULT_TARGET_EPS
    trials, failures = 0, []
    for n in (96, 128):
        gens = [lambda: make_phi_matrix(rng, n, n, 0.5),
                lambda: make_phi_matrix(rng, n, n, 1.0),
                lambda: make_phi_matrix(rng, n, n, 2.0),
                lambda: _wide_spread(rng, n, n, 8),
                lambda: _wide_spread(rng, n, n, 12),
                lambda: rng.standard_normal((n, n))]
        for rep in range(2):
            for gi, gen in enumerate(gens):
                a, b = gen(), gen()
                hi, lo = dd_matmul(a, b)
                aj, bj = jnp.asarray(a), jnp.asarray(b)
                for spec in _PROB_SPECS:
                    cfg = parse_spec(spec)
                    kp = plan.auto_k(aj, bj, cfg)
                    kd = plan.auto_k(aj, bj, _det_twin(spec))
                    assert kp <= kd, (spec, n, gi, kp, kd)
                    err = max_relative_error(
                        np.asarray(ozimmu_matmul(aj, bj, cfg)), hi, lo)
                    trials += 1
                    if err > eps:
                        failures.append((spec, n, gi, rep, kp, err))
    allowed = int(math.floor(trials * _PROB_DELTA))
    assert len(failures) <= allowed, (trials, failures)


@pytest.mark.slow
@pytest.mark.prob_calibration
def test_prob_calibration_static_bound_ensemble():
    """Oracle calibration of the STATIC probabilistic plan (what jitted
    serving calls resolve — k=8 at n=96/128 for the headline specs,
    strictly below the deterministic k=9): the measured ELEMENTWISE
    error stays under ``prob_error_bound_*(..., delta)`` on >= 1 - delta
    of seeded trials (all of them here).  The absolute-relative
    ``target_eps`` contract intentionally under-delivers on this path —
    bounded by the beta * (k_det - k_prob) shaved bits on non-cancelling
    outputs but unbounded where outputs cancel (the min-|c| term only
    the probed path can charge for) — which is exactly the documented
    trade (docs/algorithms.md#the-probabilistic-planner-prob)."""
    import jax
    rng = np.random.default_rng(20260809)
    cases = [
        ("ozimmu_h-auto:prob",
         lambda a, b, k: analysis.prob_error_bound_rn(a, b, k)),
        ("oz2_h-auto:fast2:prob",
         lambda a, b, k: analysis.prob_error_bound_oz2(a, b, k,
                                                       fast2=True)),
        ("ozimmu_sm_h-auto:prob",
         lambda a, b, k: analysis.prob_error_bound_sm(a, b, k)),
    ]
    trials, failures = 0, []
    for spec, bound in cases:
        cfg = parse_spec(spec)
        fn = jax.jit(functools.partial(ozimmu_matmul, cfg=cfg))
        for n in (96, 128):
            pp = plan.plan_contraction(cfg, n, n, n)
            pd = plan.plan_contraction(_det_twin(spec), n, n, n)
            assert pp.k <= pd.k and not pp.probed
            for gen in [lambda: make_phi_matrix(rng, n, n, 0.5),
                        lambda: rng.standard_normal((n, n)),
                        lambda: rng.uniform(-1.0, 1.0, (n, n))]:
                for rep in range(2):
                    a, b = gen(), gen()
                    hi, lo = dd_matmul(a, b)
                    t = np.asarray(fn(jnp.asarray(a), jnp.asarray(b)))
                    err = np.abs((t - hi) - lo)
                    bd = bound(a, b, pp.k)
                    trials += 1
                    if not np.all(err <= bd + 1e-300):
                        failures.append((spec, n, rep,
                                         float((err - bd).max())))
    allowed = int(math.floor(trials * _PROB_DELTA))
    assert len(failures) <= allowed, (trials, failures)


# ---------------------------------------------------------------------------
# the oracle itself: dd_matmul micro-pins
# ---------------------------------------------------------------------------

def test_dd_matmul_integer_fsum_pin(rng):
    """Integer-valued inputs: products are exact, so dd hi must equal the
    correctly-rounded math.fsum exactly and lo must vanish."""
    a = rng.integers(-50, 50, (5, 24)).astype(np.float64)
    b = rng.integers(-50, 50, (24, 3)).astype(np.float64)
    hi, lo = dd_matmul(a, b)
    for i in range(5):
        for j in range(3):
            fs = math.fsum(a[i, k] * b[k, j] for k in range(24))
            assert hi[i, j] == fs and lo[i, j] == 0.0, (i, j)


def test_dd_matmul_float_fsum_pin(rng):
    """Float inputs: expand each product into its exact (p, e) Dekker pair
    and fsum the 2n floats — the correctly-rounded true sum.  dd (hi, lo)
    must agree with it up to fsum's own final rounding (half an ulp of
    fs) plus dd's ~2^-106 effective precision on the term magnitude —
    i.e. dd is at least as accurate as the correctly-rounded f64 sum."""
    a = rng.standard_normal((4, 20)) * np.exp(2 * rng.standard_normal((4, 20)))
    b = rng.standard_normal((20, 3)) * np.exp(2 * rng.standard_normal((20, 3)))
    hi, lo = dd_matmul(a, b)
    for i in range(4):
        for j in range(3):
            terms = []
            for k in range(20):
                pr, er = _two_prod(np.float64(a[i, k]), np.float64(b[k, j]))
                terms += [float(pr), float(er)]
            fs = math.fsum(terms)
            scale = sum(abs(t) for t in terms) or 1.0
            assert abs((hi[i, j] - fs) + lo[i, j]) <= \
                2.0 ** -53 * abs(fs) + 2.0 ** -100 * scale


def test_dd_matmul_block_invariant(rng):
    """Blocking is pure dispatch batching: every block size returns the
    same bits (the TwoSum order is the column order regardless)."""
    a = rng.standard_normal((17, 130))
    b = rng.standard_normal((130, 9))
    hi1, lo1 = dd_matmul(a, b, block=1)
    for blk in (7, 32, 130, 999):
        hi, lo = dd_matmul(a, b, block=blk)
        assert np.array_equal(hi, hi1) and np.array_equal(lo, lo1), blk
