"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles, with
shape/dtype sweeps, plus end-to-end equivalence against the core library."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import (OzimmuConfig, VARIANTS, ozimmu_matmul, compute_beta,
                        split_bitmask, split_rn_const)
from repro.core.ozimmu import split_operands
from repro.kernels import ops, ref
from repro.kernels.split_fused import split_fused as raw_split
from repro.kernels.group_gemm import group_gemm as raw_group_gemm
from repro.kernels.scale_accum import scale_accum as raw_scale_accum
from tests.conftest import make_phi_matrix


# ---------------------------------------------------------------------------
# split_fused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["bitmask", "rn_const"])
@pytest.mark.parametrize("m,n", [(8, 128), (16, 256), (256, 512), (264, 640)])
def test_split_fused_matches_ref(rng, mode, m, n):
    k, beta = 5, 7
    a = jnp.asarray(make_phi_matrix(rng, m, n, phi=1.0, dtype=np.float32))
    rowmax = jnp.max(jnp.abs(a), axis=1, keepdims=True)
    from repro.core.splitting import _pow2_ceil, _pow2_floor
    if mode == "bitmask":
        inv = (2.0 ** beta) / (2.0 * _pow2_floor(rowmax))
    else:
        inv = 1.0 / (_pow2_ceil(rowmax) * 2.0 ** (1 - beta))
    bm = 8 if m <= 8 else 16
    bn = 128
    a_p = ops._pad_to(a, (bm, bn))
    inv_p = ops._pad_to(inv, (bm, 1))
    got = raw_split(a_p, inv_p, k=k, beta=beta, mode=mode, bm=bm, bn=bn,
                    interpret=True)
    want = ref.split_fused_ref(a_p, inv_p, k=k, beta=beta, mode=mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode,lib", [("bitmask", split_bitmask),
                                      ("rn_const", split_rn_const)])
def test_split_fused_matches_library(rng, mode, lib):
    """The kernel path must produce the SAME digits as the core splitters
    (both axes), since they implement the same algorithm."""
    a = jnp.asarray(make_phi_matrix(rng, 48, 160, dtype=np.float32))
    k = 4
    beta = compute_beta(160)
    for axis in (0, 1):
        sp_k = ops.split_fused(a, k, beta, mode=mode, axis=axis)
        sp_l = lib(a, k, beta=beta, axis=axis)
        np.testing.assert_array_equal(np.asarray(sp_k.digits),
                                      np.asarray(sp_l.digits))
        np.testing.assert_allclose(np.asarray(sp_k.scale),
                                   np.asarray(sp_l.scale), rtol=0)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 40), n=st.integers(1, 300), k=st.integers(1, 6),
       seed=st.integers(0, 2**31), mode=st.sampled_from(["bitmask", "rn_const"]))
def test_split_fused_property_padding(m, n, k, seed, mode):
    """Arbitrary (unaligned) shapes: ops.split_fused == library splitter."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(make_phi_matrix(rng, m, n, dtype=np.float32))
    beta = 7
    lib = split_bitmask if mode == "bitmask" else split_rn_const
    sp_k = ops.split_fused(a, k, beta, mode=mode)
    sp_l = lib(a, k, beta=beta)
    np.testing.assert_array_equal(np.asarray(sp_k.digits),
                                  np.asarray(sp_l.digits))


# ---------------------------------------------------------------------------
# group_gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G,m,n,p", [(1, 128, 128, 128), (3, 128, 256, 128),
                                     (7, 256, 128, 384)])
def test_group_gemm_matches_ref(rng, G, m, n, p):
    a8 = jnp.asarray(rng.integers(-127, 128, (G, m, n)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-127, 128, (G, n, p)), jnp.int8)
    got = raw_group_gemm(a8, b8, bm=128, bp=128, bn=128, interpret=True)
    want = ref.group_gemm_ref(a8, b8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=8, deadline=None)
@given(G=st.integers(1, 5), m=st.integers(1, 150), n=st.integers(1, 200),
       p=st.integers(1, 150), seed=st.integers(0, 2**31))
def test_group_gemm_property_unaligned(G, m, n, p, seed):
    """ops.group_gemm pads arbitrary shapes and matches the int64 oracle."""
    rng = np.random.default_rng(seed)
    from repro.core.splitting import Split
    a8 = jnp.asarray(rng.integers(-64, 65, (3, m, n)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-64, 65, (3, n, p)), jnp.int8)
    sa = Split(a8, None, None, 7, 0)
    sb = Split(b8, None, None, 7, 1)
    pairs = [(s + 1, 3 - s) for s in range(min(G, 2) + 1)][:G] or [(1, 1)]
    pairs = [(s, t) for s, t in pairs if s <= 3 and t <= 3]
    got = np.asarray(ops.group_gemm(sa, sb, pairs), np.int64)
    want = np.zeros((m, p), np.int64)
    for s, t in pairs:
        want += np.asarray(a8[s - 1], np.int64) @ np.asarray(b8[t - 1], np.int64)
    np.testing.assert_array_equal(got, want)


def test_group_gemm_no_int32_overflow_at_r_limit(rng):
    """Adversarial: G = r pairs of max-magnitude digits must NOT overflow."""
    n = 128
    beta = compute_beta(n)  # 7
    from repro.core import compute_r
    r = compute_r(n, beta)
    G = min(r, 8)
    a8 = jnp.full((G, 8, n), 127, jnp.int8)
    b8 = jnp.full((G, n, 8), 127, jnp.int8)
    got = np.asarray(raw_group_gemm(
        ops._pad_to(a8, (1, 128, 128)), ops._pad_to(b8, (1, 128, 128)),
        bm=128, bp=128, bn=128, interpret=True), np.int64)[:8, :8]
    want = G * n * 127 * 127
    assert want < 2**31
    np.testing.assert_array_equal(got, np.full((8, 8), want))


# ---------------------------------------------------------------------------
# scale_accum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,p", [(8, 128), (256, 512), (100, 300)])
def test_scale_accum_matches_ref(rng, m, p):
    p32 = jnp.asarray(rng.integers(-2**30, 2**30, (m, p)), jnp.int32)
    srow = jnp.asarray(2.0 ** rng.integers(-20, 20, (m,)), jnp.float32)
    scol = jnp.asarray(2.0 ** rng.integers(-20, 20, (p,)), jnp.float32)
    c_hi = jnp.asarray(rng.standard_normal((m, p)), jnp.float32)
    c_lo = jnp.asarray(rng.standard_normal((m, p)) * 1e-7, jnp.float32)
    hi, lo = ops.scale_accum(p32, srow, scol, c_hi, c_lo)
    whi, wlo = ref.scale_accum_ref(p32, srow[:, None], scol[None, :], c_hi, c_lo)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(whi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(wlo))


@pytest.mark.parametrize("batch", [(3,), (2, 2)])
def test_scale_accum_batched_matches_ref(rng, batch):
    """Leading batch dims map onto the kernel's batch grid axis with
    per-batch scale vectors."""
    m, p = 24, 140
    p32 = jnp.asarray(rng.integers(-2**30, 2**30, batch + (m, p)), jnp.int32)
    srow = jnp.asarray(2.0 ** rng.integers(-10, 10, batch + (m,)), jnp.float32)
    scol = jnp.asarray(2.0 ** rng.integers(-10, 10, batch + (p,)), jnp.float32)
    c_hi = jnp.asarray(rng.standard_normal(batch + (m, p)), jnp.float32)
    c_lo = jnp.asarray(rng.standard_normal(batch + (m, p)) * 1e-7, jnp.float32)
    hi, lo = ops.scale_accum(p32, srow, scol, c_hi, c_lo)
    whi, wlo = ref.scale_accum_ref(p32, srow[..., :, None],
                                   scol[..., None, :], c_hi, c_lo)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(whi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(wlo))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("m,p", [(8, 128), (100, 300)])
def test_scale_accum_plain_matches_ref(rng, dtype, m, p):
    """The plain-accumulator kernel mode (f64 interpret / f32) equals the
    inline epilogue in the accumulator's own dtype."""
    p32 = jnp.asarray(rng.integers(-2**30, 2**30, (m, p)), jnp.int32)
    srow = jnp.asarray(2.0 ** rng.integers(-20, 20, (m,)), dtype)
    scol = jnp.asarray(2.0 ** rng.integers(-20, 20, (p,)), dtype)
    c = jnp.asarray(rng.standard_normal((m, p)), dtype)
    got = ops.scale_accum_plain(p32, srow, scol, c)
    want = ref.scale_accum_plain_ref(p32, srow[:, None], scol[None, :], c)
    assert got.dtype == dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("batch", [(), (3,)])
def test_scale_accum_const_matches_jnp_epilogue(rng, batch):
    """The constant-grid (oz2 ladder) df32 kernel is bit-identical to the
    inline `accumulate._oz2_accum_df32` epilogue, rank-2 and batched."""
    from repro.core.accumulate import DF32, _oz2_accum_df32
    m, p = 24, 140
    word = jnp.asarray(rng.integers(-2**30, 2**30, batch + (m, p)), jnp.int32)
    s = jnp.asarray(2.0 ** rng.integers(-10, 10, batch), jnp.float32)
    c_hi = jnp.asarray(rng.standard_normal(batch + (m, p)), jnp.float32)
    c_lo = jnp.asarray(rng.standard_normal(batch + (m, p)) * 1e-7, jnp.float32)
    hi, lo = ops.oz2_scale_accum(word, s, c_hi, c_lo)
    want = _oz2_accum_df32(word, s, DF32(c_hi, c_lo))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))


@pytest.mark.parametrize("word_dtype", [jnp.int32, jnp.int64])
@pytest.mark.parametrize("acc_dtype", [jnp.float32, jnp.float64])
def test_scale_accum_const_plain_matches_jnp(rng, word_dtype, acc_dtype):
    """The plain const kernel accepts int32 AND int64 ladder words (the
    f64/x64 exponent ladder) and equals the inline epilogue bitwise."""
    from repro.core.accumulate import _oz2_accum_plain
    m, p = 16, 130
    word = jnp.asarray(rng.integers(-2**50, 2**50, (m, p)), word_dtype)
    s = jnp.asarray(2.0 ** rng.integers(-10, 10, ()), acc_dtype)
    c = jnp.asarray(rng.standard_normal((m, p)), acc_dtype)
    got = ops.oz2_scale_accum_plain(word, s, c)
    want = _oz2_accum_plain(word, s, c)
    assert got.dtype == acc_dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_split_fused_const_grid_matches_library(rng):
    """The const-grid kernel mode (one (1,1) scalar operand) produces the
    same digits/scales as the library oz2 splitters, both axes, f32/f64,
    batched (where the scalar broadcasts onto the row grid)."""
    from repro.core.splitting import split_oz2, split_oz2_bitmask
    k, n = 4, 160
    beta = compute_beta(n)
    for lib, mode in ((split_oz2, "oz2_rn"),
                      (split_oz2_bitmask, "oz2_bitmask")):
        for shape, dtype in (((48, n), np.float32), ((48, n), np.float64),
                             ((2, 24, n), np.float32)):
            a = jnp.asarray(make_phi_matrix(
                rng, int(np.prod(shape[:-1])), n,
                dtype=dtype).reshape(shape))
            for axis in (0, 1):
                x = a if axis == 0 else jnp.swapaxes(a, -1, -2)
                sp_k = ops.split_fused(x, k, beta, mode=mode, axis=axis)
                sp_l = lib(x, k, beta=beta, axis=axis)
                np.testing.assert_array_equal(np.asarray(sp_k.digits),
                                              np.asarray(sp_l.digits))
                np.testing.assert_array_equal(np.asarray(sp_k.gbase),
                                              np.asarray(sp_l.gbase))
                np.testing.assert_array_equal(np.asarray(sp_k.scale),
                                              np.asarray(sp_l.scale))


@pytest.mark.parametrize("mode,lib", [("bitmask", split_bitmask),
                                      ("rn_const", split_rn_const)])
def test_split_fused_f64_and_batched_matches_library(rng, mode, lib):
    """The fused splitter preserves f64 through the interpret path (the
    paper-faithful DGEMM emulation needs digits beyond f32's 24 bits) and
    flattens batch dims without changing any digit."""
    a64 = jnp.asarray(make_phi_matrix(rng, 40, 96, dtype=np.float64))
    k, beta = 9, 7  # k*beta = 63 bits > f32 mantissa: catches an f32 cast
    for axis in (0, 1):
        sp_k = ops.split_fused(a64, k, beta, mode=mode, axis=axis)
        sp_l = lib(a64, k, beta=beta, axis=axis)
        assert sp_k.digits.dtype == jnp.int8 and sp_k.scale.dtype == a64.dtype
        np.testing.assert_array_equal(np.asarray(sp_k.digits),
                                      np.asarray(sp_l.digits))
        np.testing.assert_array_equal(np.asarray(sp_k.scale),
                                      np.asarray(sp_l.scale))
    ab = jnp.asarray(make_phi_matrix(rng, 6 * 20, 64,
                                     dtype=np.float32).reshape(2, 3, 20, 64))
    for axis in (0, 1):
        sp_k = ops.split_fused(ab, 5, beta, mode=mode, axis=axis)
        sp_l = lib(ab, 5, beta=beta, axis=axis)
        np.testing.assert_array_equal(np.asarray(sp_k.digits),
                                      np.asarray(sp_l.digits))
        np.testing.assert_array_equal(np.asarray(sp_k.scale),
                                      np.asarray(sp_l.scale))


def test_scale_accum_compensation_beats_naive(rng):
    """df32 accumulation keeps bits a plain f32 accumulator loses."""
    m = p = 8
    c_hi = jnp.full((m, p), 1e8, jnp.float32)
    c_lo = jnp.zeros((m, p), jnp.float32)
    p32 = jnp.full((m, p), 3, jnp.int32)
    one_r = jnp.ones((m,), jnp.float32)
    one_c = jnp.ones((p,), jnp.float32)
    hi, lo = ops.scale_accum(p32, one_r, one_c, c_hi, c_lo)
    total = np.asarray(hi, np.float64) + np.asarray(lo, np.float64)
    np.testing.assert_array_equal(total, np.full((m, p), 1e8 + 3.0))
    naive = np.asarray(c_hi) + np.float32(3.0)
    assert not np.array_equal(naive, np.full((m, p), 1e8 + 3.0))  # f32 lost it


# ---------------------------------------------------------------------------
# end-to-end: full ozimmu through the Pallas path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["ozimmu_ef", "ozimmu_h"])
def test_pallas_path_matches_jnp_path(rng, variant):
    a = jnp.asarray(make_phi_matrix(rng, 96, 160, dtype=np.float32))
    b = jnp.asarray(make_phi_matrix(rng, 160, 64, dtype=np.float32))
    cfg = VARIANTS[variant].with_(k=5, accum_dtype="f32")
    c_jnp = np.asarray(ozimmu_matmul(a, b, cfg))
    c_pl = np.asarray(ozimmu_matmul(a, b, cfg.with_(use_pallas=True)))
    np.testing.assert_array_equal(c_pl, c_jnp)
