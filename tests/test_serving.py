"""Serving runtime + persistent weight split-cache.

Covers the PR-5 subsystem (docs/serving.md):

* cached-vs-uncached bitwise identity of the presplit path for all six
  variants x accumulator dtypes x the fused pipeline, eager and jitted,
  including auto-k (frozen static plan == traced static plan);
* the engine-level `PresplitWeight` wrapper (use + safe fallback);
* SplitCache keying (spec miss, update miss, weakref invalidation);
* scheduler invariants (no slot leak, FIFO fairness under eviction,
  bucketed prefill grouping);
* runtime end-to-end vs a per-request reference decode (continuous
  batching with mixed prompt lengths is bitwise-faithful), presplit on
  and off;
* paged-KV equivalence to the monolithic cache per token, including
  under pool pressure (evictions);
* chunked prefill: bitwise identity to monolithic prefill across chunk
  sizes (1, a non-divisor, larger-than-any-prompt), decode interleaving
  during a long chunked prefill, and a chunked+paged soak;
* property-based scheduler/pool invariants (hypothesis when installed;
  skipped gracefully otherwise — tests/conftest.hypothesis_or_stubs);
* `slow`-marked soak replays (random trace, tight pool, chunking).

The `@mesh` composition of the presplit path is asserted in
tests/test_distributed.py (needs forced host devices).
"""
import gc

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ozimmu, split_cache
from repro.core.engine import PresplitWeight, make_engine

DN = (((1,), (0,)), ((), ()))


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((6, 96)))
    b = jnp.asarray(rng.standard_normal((96, 10)))
    return a, b


# ---------------------------------------------------------------------------
# presplit bitwise identity
# ---------------------------------------------------------------------------

SPECS = [
    f"{variant}-4{dt}{fused}"
    for variant in ("ozimmu", "ozimmu_rn", "ozimmu_ef", "ozimmu_h",
                    "ozimmu_sm_b", "ozimmu_sm_h", "oz2_b", "oz2_h")
    for dt in ("", ":df32", ":f32")
    for fused in ("", ":fused")
] + ["oz2_h-4:fast", "oz2_b-4:df32:fast", "oz2_h-4:fast:fused",
     "oz2_h-4:fast2", "oz2_b-4:df32:fast2", "oz2_h-4:fast2:fused"]


@pytest.mark.parametrize("spec", SPECS)
def test_presplit_bitwise(spec, operands):
    """Frozen-B path == splitter-in-the-loop path, bit for bit, eager and
    under jit (the serving steps are jitted)."""
    a, b = operands
    cfg = ozimmu.parse_spec(spec)
    cache = split_cache.SplitCache()
    sp = cache.get(b, DN, cfg)
    ref = ozimmu.ozimmu_dot_general(a, b, DN, cfg)
    out = ozimmu.ozimmu_dot_general(a, b, DN, cfg, rhs_presplit=sp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    jit_ref = jax.jit(
        lambda a, b: ozimmu.ozimmu_dot_general(a, b, DN, cfg))(a, b)
    jit_out = jax.jit(
        lambda a, b, sp: ozimmu.ozimmu_dot_general(a, b, DN, cfg,
                                                   rhs_presplit=sp)
    )(a, b, sp)
    np.testing.assert_array_equal(np.asarray(jit_out), np.asarray(jit_ref))


def test_presplit_bitwise_batched_dnums():
    """Expert-style stacked rhs: batch dims ride through the frozen split."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((3, 5, 64)))
    b = jnp.asarray(rng.standard_normal((3, 64, 7)))
    dn = (((2,), (1,)), ((0,), (0,)))
    for spec in ("ozimmu_h-5:df32", "oz2_h-5:fast", "oz2_h-5:fast2"):
        cfg = ozimmu.parse_spec(spec)
        sp = split_cache.SplitCache().get(b, dn, cfg)
        ref = ozimmu.ozimmu_dot_general(a, b, dn, cfg)
        out = ozimmu.ozimmu_dot_general(a, b, dn, cfg, rhs_presplit=sp)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_presplit_auto_k_matches_jitted_plan(operands):
    """Auto-k freezes the static mantissa-coverage k — the same k a
    jitted (traced) call resolves — so cached and uncached jitted paths
    agree bitwise."""
    a, b = operands
    for spec in ("ozimmu_h-auto:df32", "oz2_h-auto:fast",
                 "oz2_h-auto:fast2"):
        cfg = ozimmu.parse_spec(spec)
        sp = split_cache.SplitCache().get(b, DN, cfg)
        assert sp.digits.shape[0] == split_cache.resolved_k(
            cfg, b.shape[0], b.dtype)
        ref = jax.jit(
            lambda a, b: ozimmu.ozimmu_dot_general(a, b, DN, cfg))(a, b)
        out = jax.jit(
            lambda a, b, sp: ozimmu.ozimmu_dot_general(
                a, b, DN, cfg, rhs_presplit=sp))(a, b, sp)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("spec", ["ozimmu_h-4:df32", "oz2_h-4:fast2",
                                  "oz2_b-4:df32:fast2"])
def test_presplit_grad_matches(spec, operands):
    """Gradients flow through the presplit forward unchanged (cotangent
    contractions never use the frozen split) — including the fast2
    splits, whose base/gbase ride the VJP residual pytree."""
    a, b = operands
    cfg = ozimmu.parse_spec(spec)
    sp = split_cache.SplitCache().get(b, DN, cfg)
    g_ref = jax.grad(
        lambda a, b: ozimmu.ozimmu_dot_general(a, b, DN, cfg).sum(),
        argnums=(0, 1))(a, b)
    g_out = jax.grad(
        lambda a, b: ozimmu.ozimmu_dot_general(
            a, b, DN, cfg, rhs_presplit=sp).sum(), argnums=(0, 1))(a, b)
    for r, o in zip(g_ref, g_out):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r))


def test_presplit_mismatch_rejected(operands):
    a, b = operands
    cfg = ozimmu.parse_spec("ozimmu_h-4:df32")
    sp = split_cache.SplitCache().get(b, DN, cfg)
    with pytest.raises(ValueError, match="k="):
        ozimmu.ozimmu_dot_general(a, b, DN, cfg.with_(k=6),
                                  rhs_presplit=sp)
    with pytest.raises(ValueError, match="constant-scaling"):
        ozimmu.ozimmu_dot_general(a, b, DN,
                                  ozimmu.parse_spec("oz2_h-4"),
                                  rhs_presplit=sp)
    # a split frozen under a SIGNED spec cannot serve a sign-magnitude
    # config (its stored digits decode differently) — and vice versa
    with pytest.raises(ValueError, match="signmag"):
        ozimmu.ozimmu_dot_general(a, b, DN,
                                  ozimmu.parse_spec("ozimmu_sm_h-4:df32"),
                                  rhs_presplit=sp)
    sp_sm = split_cache.SplitCache().get(
        b, DN, ozimmu.parse_spec("ozimmu_sm_h-4:df32"))
    with pytest.raises(ValueError, match="signmag"):
        ozimmu.ozimmu_dot_general(a, b, DN, cfg, rhs_presplit=sp_sm)


# ---------------------------------------------------------------------------
# engine wrapper
# ---------------------------------------------------------------------------

def _wrap(w, engine):
    from repro.serving.presplit import freeze_weight
    return freeze_weight(w, engine, split_cache.SplitCache())


def test_engine_wrapper_bitwise(operands):
    a, b = operands
    eng = make_engine("ozimmu_h-4:df32")
    pw = _wrap(b, eng)
    ref = eng(a, b)
    np.testing.assert_array_equal(np.asarray(eng(a, pw)), np.asarray(ref))
    out = jax.jit(lambda x, w: eng(x, w))(a, pw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_engine_wrapper_fallback(operands):
    """A wrapper consumed under an unexpected contraction silently uses
    the raw array (wrapping is always safe)."""
    a, b = operands
    eng = make_engine("ozimmu_h-4:df32")
    pw = _wrap(b, eng)
    # transposed-contraction dnums: not the frozen pattern
    dn = (((1,), (1,)), ((), ()))
    bt = jnp.asarray(np.asarray(b).T)
    pw_t = PresplitWeight(bt, pw.digits, pw.scale, pw.base, pw.gbase,
                          pw.beta, pw.split, pw.k)
    ref = eng.dot_general(a, bt, dn)
    np.testing.assert_array_equal(np.asarray(eng.dot_general(a, pw_t, dn)),
                                  np.asarray(ref))


def test_presplit_consumption_is_measured(operands):
    """The engine records trace-time presplit use vs fallback — the
    serving hit-rate metric is measured, not assumed (a silent
    usable_split fallback must show up in the gated number)."""
    from repro.core.engine import presplit_trace_counts
    a, b = operands
    eng = make_engine("ozimmu_h-4:df32")
    pw = _wrap(b, eng)
    c0 = presplit_trace_counts()
    eng(a, pw)                                    # applies
    other = make_engine("oz2_h-4:df32")           # wrong split strategy
    other(a, pw)                                  # silently falls back
    c1 = presplit_trace_counts()
    assert c1["used"] - c0["used"] == 1
    assert c1["fallback"] - c0["fallback"] == 1


def test_engine_wrapper_stacked_scan(operands):
    """A layer-stacked wrapper sliced by lax.scan equals per-layer calls."""
    a, _ = operands
    rng = np.random.default_rng(11)
    ws = jnp.asarray(rng.standard_normal((3, 96, 10)))
    eng = make_engine("ozimmu_h-4:df32")
    pw = _wrap(ws, eng)
    assert pw.digits.shape[:2] == (3, 4)

    def body(x, w):
        return x, eng(x, w)

    _, outs = jax.lax.scan(body, a, pw)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(outs[i]),
                                      np.asarray(eng(a, ws[i])))


# ---------------------------------------------------------------------------
# cache keying / invalidation
# ---------------------------------------------------------------------------

def test_cache_keying(operands):
    _, b = operands
    cache = split_cache.SplitCache()
    h = ozimmu.parse_spec("ozimmu_h-4")
    cache.get(b, DN, h)
    assert (cache.stats.hits, cache.stats.misses) == (0, 1)
    cache.get(b, DN, h)
    assert (cache.stats.hits, cache.stats.misses) == (1, 1)
    # same weights + different spec => miss (k, then strategy)
    cache.get(b, DN, h.with_(k=6))
    assert cache.stats.misses == 2
    cache.get(b, DN, ozimmu.parse_spec("oz2_h-4"))
    assert cache.stats.misses == 3
    # fast2 is a DIFFERENT split strategy (oz2_rn_fast2): its own entry,
    # and hitting it again is a hit
    cache.get(b, DN, ozimmu.parse_spec("oz2_h-4:fast2"))
    assert cache.stats.misses == 4
    cache.get(b, DN, ozimmu.parse_spec("oz2_h-4:fast2"))
    assert (cache.stats.hits, cache.stats.misses) == (2, 4)
    # sign-magnitude is its own split strategy ("sm"): a distinct entry
    # from every signed spec at the same k/dtype...
    cache.get(b, DN, ozimmu.parse_spec("ozimmu_sm_h-4"))
    assert cache.stats.misses == 5
    # ...while sm_b / sm_h (same splitter, different accumulation) share
    # one frozen split — the digits are identical by construction
    cache.get(b, DN, ozimmu.parse_spec("ozimmu_sm_b-4"))
    assert (cache.stats.hits, cache.stats.misses) == (3, 5)
    # "updated" weights (a new array) => miss
    b2 = b + 0.0
    cache.get(b2, DN, h)
    assert cache.stats.misses == 6
    assert len(cache) == 6


def test_cache_weakref_invalidation(operands):
    _, b = operands
    cache = split_cache.SplitCache()
    tmp = b * 2.0
    cache.get(tmp, DN, ozimmu.parse_spec("ozimmu_h-4"))
    assert len(cache) == 1
    del tmp
    gc.collect()
    assert len(cache) == 0
    assert cache.stats.invalidations == 1


def test_cache_rejects_tracers(operands):
    _, b = operands
    cache = split_cache.SplitCache()
    cfg = ozimmu.parse_spec("ozimmu_h-4")
    with pytest.raises(TypeError, match="concrete"):
        jax.jit(lambda b: cache.get(b, DN, cfg))(b)


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def test_scheduler_fifo_and_no_slot_leak():
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(2)
    reqs = [sched.submit([1, 2, 3], max_new=2) for _ in range(5)]
    adm = sched.admit()
    assert [r.rid for _, r in adm] == [reqs[0].rid, reqs[1].rid]
    # finish slot 0's request -> next queued request takes the slot
    sched.on_prefilled(0, first_token=9)
    sched.on_token(0, 9)                     # max_new=2 -> finished
    assert sched.slots[0].free
    adm2 = sched.admit()
    assert [r.rid for _, r in adm2] == [reqs[2].rid]
    # invariant: active + free == n_slots (checked internally every op)
    assert len(sched.active_slots()) + sum(
        s.free for s in sched.slots) == 2


def test_scheduler_eviction_fifo_fair():
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(3)
    reqs = [sched.submit([1] * 4, max_new=8) for _ in range(3)]
    sched.admit()
    for i in range(3):
        sched.on_prefilled(i, first_token=5)
    # victim is the LATEST-admitted slot, never the earliest request
    victim = sched.pick_victim()
    assert sched.slots[victim].request is reqs[2]
    evicted = sched.evict(victim)
    assert evicted is reqs[2]
    # evicted request resumes from the FRONT of the queue with its
    # generated tokens carried (re-prefill = prompt + generated)
    assert sched.queue[0] is reqs[2]
    assert list(evicted.prefill_tokens()) == [1, 1, 1, 1, 5]
    adm = sched.admit()
    assert adm[0][1] is reqs[2]


def test_scheduler_random_soak_invariants():
    from repro.serving.scheduler import Scheduler
    rng = np.random.default_rng(0)
    sched = Scheduler(3)
    for _ in range(200):
        op = rng.integers(0, 4)
        if op == 0:
            sched.submit([1] * int(rng.integers(1, 6)),
                         max_new=int(rng.integers(1, 4)))
        elif op == 1:
            for slot, _ in sched.admit():
                sched.on_prefilled(slot, int(rng.integers(0, 9)))
        elif op == 2:
            for slot in list(sched.active_slots()):
                sched.on_token(slot, int(rng.integers(0, 9)))
        else:
            v = sched.pick_victim()
            if v is not None:
                sched.evict(v)
    # every op ran the internal _check() leak assertions; drain cleanly
    while not sched.all_done:
        for slot, _ in sched.admit():
            sched.on_prefilled(slot, 1)
        for slot in list(sched.active_slots()):
            sched.on_token(slot, 1)


def test_prefill_bucketing():
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(4, bucket="pow2")
    rs = [sched.submit([1] * n, max_new=1) for n in (3, 8, 9, 5)]
    groups = dict(sched.prefill_groups(sched.admit()))
    assert set(groups) == {8, 16}
    assert sorted(r.rid for _, r in groups[8]) == [rs[0].rid, rs[1].rid,
                                                   rs[3].rid]


# ---------------------------------------------------------------------------
# runtime end-to-end
# ---------------------------------------------------------------------------

GEN = 4
PROMPT_LENS = (5, 9, 3, 11, 7)


@pytest.fixture(scope="module")
def served():
    """One smoke model + reference outputs, shared by the e2e tests."""
    from repro import configs
    from repro.models import api
    cfg = configs.get_config("internlm2_1_8b", smoke=True,
                             engine_spec="ozimmu_h-4:df32")
    model = api.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n, dtype=np.int32)
               for n in PROMPT_LENS]

    step = jax.jit(lambda c, t, n: model.decode_step(params, cfg, c, t, n))

    def reference(prompt):
        cache = model.init_cache(cfg, 1, 64)
        logits = None
        for t, tok in enumerate(prompt):
            logits, cache = step(cache, jnp.asarray([[tok]], jnp.int32),
                                 jnp.asarray(t + 1, jnp.int32))
        out = list(prompt)
        cur = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        for g in range(GEN):
            out.append(cur)
            logits, cache = step(cache, jnp.asarray([[cur]], jnp.int32),
                                 jnp.asarray(len(prompt) + g + 1,
                                             jnp.int32))
            cur = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        return np.asarray(out)

    refs = [reference(p) for p in prompts]
    return cfg, params, prompts, refs


def _run(cfg, params, prompts, **kw):
    from repro.serving import ServingRuntime
    rt = ServingRuntime(cfg, params, slots=3, max_len=64, **kw)
    outs = rt.generate([p.copy() for p in prompts], GEN)
    return rt, outs


def test_runtime_matches_reference_presplit(served):
    """Continuous batching with mixed prompt lengths + the weight
    split-cache reproduces the per-request reference decode bitwise."""
    cfg, params, prompts, refs = served
    rt, outs = _run(cfg, params, prompts)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    s = rt.metrics.summary()
    assert s["requests"]["finished"] == len(prompts)
    assert s["tokens_generated"] == GEN * len(prompts)
    from repro.serving.presplit import wrappable_paths
    sc = s["split_cache"]
    assert sc["weight_split_hit_rate"] == 1.0
    assert sc["avoided_split_bytes"] > 0
    assert sc["misses"] == len(wrappable_paths(params))


def test_runtime_matches_reference_no_presplit(served):
    cfg, params, prompts, refs = served
    _, outs = _run(cfg, params, prompts, presplit=False)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_paged_equals_monolithic_per_token(served):
    """Block-paged KV pool: same tokens as the monolithic cache."""
    cfg, params, prompts, refs = served
    rt, outs = _run(cfg, params, prompts, page_block=8)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    assert rt.metrics.summary()["evictions"] == 0


def test_paged_eviction_pressure(served):
    """A pool too small for all slots forces eviction; outputs stay
    correct (recompute-resume) and the earliest request is never the
    victim (FIFO fairness)."""
    cfg, params, prompts, refs = served
    # 3 blocks of 8 positions: the admission wave alone wants 4 (1+2+1),
    # so the latest-admitted slot is preempted at prefill time
    rt, outs = _run(cfg, params, prompts, page_block=8, page_blocks=3)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    s = rt.metrics.summary()
    assert s["evictions"] > 0
    assert s["requests"]["finished"] == len(prompts)


def test_runtime_matches_reference_oz2(served):
    """oz2 engines are the sensitive case for slot hygiene: one garbage
    cache row would shift the GLOBAL digit grid of the whole per-slot
    operand (per-row ozimmu scales only ever confine damage to a masked
    row/column).  The right-aligned prefill warm-up and idle decode slots
    must therefore write NOTHING (cache_update_row's cur==0 no-op)."""
    from repro import configs
    from repro.models import api
    from repro.serving import ServingRuntime
    cfg = configs.get_config("internlm2_1_8b", smoke=True,
                             engine_spec="oz2_h-4:df32:fast")
    model = api.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    _, _, prompts, _ = served
    prompts = prompts[:3]
    step = jax.jit(lambda c, t, n: model.decode_step(params, cfg, c, t, n))

    def reference(prompt):
        cache = model.init_cache(cfg, 1, 64)
        logits = None
        for t, tok in enumerate(prompt):
            logits, cache = step(cache, jnp.asarray([[tok]], jnp.int32),
                                 jnp.asarray(t + 1, jnp.int32))
        out = list(prompt)
        cur = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        for g in range(3):
            out.append(cur)
            logits, cache = step(cache, jnp.asarray([[cur]], jnp.int32),
                                 jnp.asarray(len(prompt) + g + 1,
                                             jnp.int32))
            cur = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        return np.asarray(out)

    refs = [reference(p) for p in prompts]
    rt = ServingRuntime(cfg, params, slots=2, max_len=64)
    outs = rt.generate([p.copy() for p in prompts], 3)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_runtime_ssm_family(served):
    """State-family (exact-length prefill buckets) end-to-end smoke."""
    from repro import configs
    from repro.models import api
    from repro.serving import ServingRuntime
    cfg = configs.get_config("mamba2_780m", smoke=True)
    model = api.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=n, dtype=np.int32)
               for n in (4, 6, 4)]
    step = jax.jit(lambda c, t, n: model.decode_step(params, cfg, c, t, n))

    def reference(prompt):
        cache = model.init_cache(cfg, 1, 32)
        logits = None
        for t, tok in enumerate(prompt):
            logits, cache = step(cache, jnp.asarray([[tok]], jnp.int32),
                                 jnp.asarray(t + 1, jnp.int32))
        out = list(prompt)
        cur = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        for g in range(3):
            out.append(cur)
            logits, cache = step(cache, jnp.asarray([[cur]], jnp.int32),
                                 jnp.asarray(len(prompt) + g + 1,
                                             jnp.int32))
            cur = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        return np.asarray(out)

    refs = [reference(p) for p in prompts]
    rt = ServingRuntime(cfg, params, slots=2, max_len=32)
    outs = rt.generate([p.copy() for p in prompts], 3)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


@pytest.mark.slow
def test_serving_soak_random_trace(served):
    """Soak: a longer random mixed trace under tight pool pressure —
    every request completes with the reference continuation."""
    cfg, params, prompts, refs = served
    from benchmarks.bench_serving import make_trace, replay
    from repro.serving import ServingRuntime
    rng = np.random.default_rng(42)
    trace = make_trace(rng, n_requests=9, vocab=cfg.vocab, max_len=48)
    rt = ServingRuntime(cfg, params, slots=3, max_len=48, page_block=8,
                        page_blocks=10)
    summary = replay(rt, trace)
    assert summary["requests"]["finished"] == len(trace)
    assert summary["tokens_generated"] == sum(r["max_new"] for r in trace)
    assert summary["split_cache"]["weight_split_hit_rate"] == 1.0


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", (1, 5, 16))
def test_chunked_prefill_equals_monolithic(served, chunk):
    """Splitting the prefill scan is bitwise-exact: the scan body is the
    same per-token function, each chunk resumes from the cache the
    previous one wrote.  chunk=1 is the extreme (every prompt token its
    own round), 5 divides none of the prompt lengths, 16 exceeds them
    all (degenerates to monolithic prefill)."""
    cfg, params, prompts, refs = served
    rt, outs = _run(cfg, params, prompts, prefill_chunk=chunk)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    s = rt.metrics.summary()
    if chunk < max(PROMPT_LENS):
        assert s["prefill_chunks"] > 0      # actually chunked
    else:
        assert s["prefill_chunks"] == 0     # one call per prompt


@pytest.mark.parametrize("chunk", (1, 5))
def test_chunked_prefill_paged_equals_monolithic(served, chunk):
    """Chunked prefill over the paged pool (span write-back per chunk)
    is bitwise too."""
    cfg, params, prompts, refs = served
    _, outs = _run(cfg, params, prompts, prefill_chunk=chunk,
                   page_block=8)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_chunked_prefill_ssm_family():
    """State families freeze mid-prefill recurrent states through the
    decode-side slot select (`_decode_select`) — a neighbour's decode
    step must not integrate into a half-prefilled SSM state."""
    from repro import configs
    from repro.models import api
    from repro.serving import ServingRuntime
    cfg = configs.get_config("mamba2_780m", smoke=True)
    model = api.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n, dtype=np.int32)
               for n in (4, 7, 5)]
    ref_rt = ServingRuntime(cfg, params, slots=2, max_len=32)
    refs = ref_rt.generate([p.copy() for p in prompts], 3)
    rt = ServingRuntime(cfg, params, slots=2, max_len=32,
                        prefill_chunk=2)
    outs = rt.generate([p.copy() for p in prompts], 3)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)


def test_chunked_prefill_interleaves_decode(served):
    """Ordering invariant: while a long prompt trickles in chunk by
    chunk, already-resident slots keep producing one token per round —
    chunking exists so a long prefill cannot stall TTFT/ITL for
    everyone else."""
    from repro.serving import ServingRuntime
    cfg, params, prompts, _ = served
    rt = ServingRuntime(cfg, params, slots=2, max_len=64,
                        prefill_chunk=2)
    short = rt.submit(prompts[2], max_new=12)       # 3 tokens
    for _ in range(3):
        rt.step()
    n0 = len(short.generated)
    assert n0 > 0                                   # already decoding
    long_req = rt.submit(prompts[3], max_new=2)     # 11 tokens: 6 chunks
    for _ in range(4):
        rt.step()
    # the long prompt is still mid-prefill: no token produced yet ...
    assert len(long_req.generated) == 0
    assert rt.metrics.summary()["prefill_chunks"] >= 3
    # ... while the short request advanced one token EVERY round
    assert len(short.generated) == n0 + 4
    rt.run()
    assert len(short.generated) == 12
    assert len(long_req.generated) == 2


@pytest.mark.slow
def test_serving_soak_chunked_eviction(served):
    """Soak: random trace under tight pool pressure WITH chunked prefill
    and the prefix cache — every scheduler op runs the internal
    slot-leak `_check`, every request completes, blocks conserve."""
    cfg, params, prompts, refs = served
    from benchmarks.bench_serving import make_trace, replay
    from repro.serving import ServingRuntime
    rng = np.random.default_rng(43)
    trace = make_trace(rng, n_requests=9, vocab=cfg.vocab, max_len=48)
    rt = ServingRuntime(cfg, params, slots=3, max_len=48, page_block=8,
                        page_blocks=10, prefill_chunk=3,
                        prefix_cache=True)
    summary = replay(rt, trace)
    assert summary["requests"]["finished"] == len(trace)
    assert summary["tokens_generated"] == sum(r["max_new"] for r in trace)
    paged = rt.paged
    assert paged.live_blocks + paged.free_block_count == paged.n_blocks


# ---------------------------------------------------------------------------
# property-based invariants (hypothesis when installed)
# ---------------------------------------------------------------------------

from tests.conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_prop_scheduler_fifo_and_no_dropped_tokens(seed):
    """Random op soup, then drain.  Properties: (1) FIRST admissions
    follow submission order exactly (FIFO; front-requeued evictees are
    RE-admissions and exempt); (2) no generated token is ever dropped on
    requeue — we feed each request the sequence 0,1,2,... and every
    finished request must hold exactly range(max_new)."""
    from repro.serving.scheduler import Scheduler
    rng = np.random.default_rng(seed)
    sched = Scheduler(int(rng.integers(1, 4)))
    submitted, first_admits = [], []

    def admit():
        for _, r in sched.admit():
            if r.prefills == 1:
                first_admits.append(r.rid)

    def prefill_round(chunked):
        for slot, r in sched.pending_prefill():
            rem = len(r.prefill_tokens()) - sched.slots[slot].prefilled
            c = int(rng.integers(1, rem + 1)) if chunked else rem
            if c < rem:
                sched.on_chunk(slot, c)
            else:
                sched.on_prefilled(slot, len(r.generated))

    def decode_round():
        for slot in list(sched.decode_slots()):
            r = sched.slots[slot].request
            sched.on_token(slot, len(r.generated))

    for _ in range(60):
        op = rng.integers(0, 5)
        if op == 0 and len(submitted) < 12:
            submitted.append(sched.submit(
                [1] * int(rng.integers(1, 6)),
                max_new=int(rng.integers(1, 4))))
        elif op == 1:
            admit()
        elif op == 2:
            prefill_round(chunked=True)
        elif op == 3:
            decode_round()
        else:
            v = sched.pick_victim()
            if v is not None:
                sched.evict(v)
    while not sched.all_done:
        admit()
        prefill_round(chunked=False)
        decode_round()
    assert first_admits == [r.rid for r in submitted]
    assert len(sched.finished) == len(submitted)
    for r in sched.finished:
        assert r.generated == list(range(r.max_new))


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=10, deadline=None)
def test_prop_paged_block_conservation(seed):
    """Random alloc/free/share/adopt/CoW ops on a real pool: after every
    op `live + free == n_blocks`, and releasing every reference at drain
    returns every block to the free list (alloc == free)."""
    from repro import configs
    from repro.models import api
    from repro.serving import PagedKV
    cfg = configs.get_config("internlm2_1_8b", smoke=True)
    model = api.get_model(cfg)
    paged = PagedKV(cfg, model, 3, 32, block=8)
    rng = np.random.default_rng(seed)
    entries = []
    for _ in range(40):
        op = rng.integers(0, 6)
        slot = int(rng.integers(0, 3))
        if op == 0:
            paged.ensure(slot, int(rng.integers(1, 33)))
        elif op == 1:
            paged.free_slot(slot)
        elif op == 2 and int(paged.allocated[slot]):
            n = int(rng.integers(1, int(paged.allocated[slot]) + 1))
            entries.append(paged.share_blocks(slot, n))
        elif op == 3 and entries:
            paged.release_blocks(
                entries.pop(int(rng.integers(0, len(entries)))))
        elif op == 4 and entries and int(paged.allocated[slot]) == 0:
            paged.adopt_blocks(
                slot, entries[int(rng.integers(0, len(entries)))])
        elif op == 5 and int(paged.allocated[slot]):
            paged.cow_for_write(slot, [0])
        assert paged.live_blocks + paged.free_block_count == paged.n_blocks
    for s in range(3):
        paged.free_slot(s)
    while entries:
        paged.release_blocks(entries.pop())
    assert paged.free_block_count == paged.n_blocks
    assert paged.live_blocks == 0
